"""LLM-specific autoscaling (paper §3.2.4): HPA vs KPA vs APA.

Paper claims (vs native HPA): −11.5% latency, +11.4% token throughput,
−33% scaling oscillations.  The HPA baseline additionally suffers the
legacy custom-metrics propagation delay the AIBrix path removes (the
paper's sliding-window-in-autoscaler optimization); KPA/APA read
zero-delay sliding windows.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.autoscaler.policies import make_autoscaler
from repro.core.sim import ClusterConfig, ServingCluster, SimEngineConfig
from repro.core.sim.workloads import burst


def _oscillations(history) -> int:
    """Direction changes of the ACTUAL replica-count series (the
    pod-churn the paper's oscillation metric captures)."""
    actual = [a for _, a, _ in history]
    changes, last_dir = 0, 0
    for a, b in zip(actual, actual[1:]):
        d = (b > a) - (b < a)
        if d and last_dir and d != last_dir:
            changes += 1
        if d:
            last_dir = d
    return changes


def _multi_burst(duration: float, seed: int):
    """Three successive bursts — the oscillation-inducing load."""
    third = duration / 3
    out = []
    for i in range(3):
        w = burst(base_rps=2.0, burst_rps=26.0, duration_s=third,
                  burst_at=third * 0.25, burst_len=third * 0.5,
                  seed=seed + i)
        for tr in w:
            tr.arrival += i * third
            tr.request.arrival_time = tr.arrival
        out.extend(w)
    return out


def _run(name: str, quick: bool = False) -> dict:
    cfg = get_config("deepseek-coder-7b")
    delay = 30.0 if name == "hpa" else 0.0      # legacy metrics path
    kw = {}
    if name == "hpa":
        # down-stabilization tuned to the workload period (as in
        # production HPA configs for bursty services) — with the stale
        # metrics path this is what makes native HPA chase the load
        kw = dict(scale_down_stabilization_s=60.0)
    elif name == "apa":
        kw = dict(up_fluctuation=0.2, down_fluctuation=0.5)
    asc = make_autoscaler(name, metric="concurrency", target=8.0,
                          min_replicas=2, max_replicas=12, **kw)
    ccfg = ClusterConfig(
        routing_policy="least-request", device_type="a10", num_engines=2,
        engine=SimEngineConfig(device_type="a10", max_batch=16),
        autoscaler=asc, metric_delay_s=delay, autoscale_period_s=2.0)
    cluster = ServingCluster(cfg, ccfg)
    dur = 240.0 if quick else 540.0
    wl = _multi_burst(dur, seed=2)
    s = cluster.run(wl)
    s["oscillations"] = _oscillations(cluster.scale_history)
    s["peak_replicas"] = max((d for _, _, d in cluster.scale_history),
                             default=0)
    # token throughput measured over the offered-load window (reaction
    # speed shows up as work completed in-window, not after drain)
    window_end = wl[-1].arrival
    done_in_window = [r for r in cluster.all_requests
                      if 0 < r.finish_time <= window_end]
    s["tokens_in_window"] = sum(r.total_tokens for r in done_in_window)
    return s


def main(quick: bool = False) -> list:
    rows = []
    cols = ("latency_avg_s", "latency_p99_s", "tokens_in_window",
            "total_tput_tok_s", "oscillations", "peak_replicas",
            "preemptions")
    print("autoscaler," + ",".join(cols))
    for name in ("hpa", "kpa", "apa"):
        s = _run(name, quick)
        rows.append((name, s))
        print(name + "," + ",".join(f"{s.get(c, 0):.1f}" for c in cols))
    base = dict(rows[0][1])
    for name, s in rows[1:]:
        # pod-seconds proxy: peak_replicas x run (overprovisioning);
        # in our replication native HPA's stale-metric pathology shows
        # up as monotone overshoot-and-hold rather than flapping — see
        # EXPERIMENTS.md for the discussion vs the paper's -33% claim.
        print(f"derived,{name}_vs_hpa"
              f",latency_reduction_pct="
              f"{100*(1-s['latency_avg_s']/max(base['latency_avg_s'],1e-9)):.1f}"
              f",p99_latency_reduction_pct="
              f"{100*(1-s['latency_p99_s']/max(base['latency_p99_s'],1e-9)):.1f}"
              f",peak_replica_reduction_pct="
              f"{100*(1-s['peak_replicas']/max(base['peak_replicas'],1)):.1f}"
              f",oscillations={s['oscillations']}_vs_{base['oscillations']}")
    return rows


if __name__ == "__main__":
    main()
