"""Prefill/decode disaggregation over the distributed KV pool.

The paper names this as what the pool enables ("future prefill/decode
disaggregation remote pool", citing DistServe).  We implement it and
measure the DistServe claim structure: colocated engines interleave
prefill chunks with decode iterations, so long prefills stall decoding
(ITL tail); disaggregating prefill and decode pods — with KV handed
over through the AIBrix pool — smooths ITL at the cost of a KV
transfer on the handoff path.

Setup: 4x A10 total.  colocated = 4 mixed engines; disaggregated =
2 prefill + 2 decode engines, handoff via pool.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.kvcache.pool import DistributedKVPool
from repro.core.sim.events import EventLoop
from repro.core.sim.sim_engine import SimEngine, SimEngineConfig
from repro.core.sim.workloads import sharegpt_like, summarize


def _run(disagg: bool, quick: bool = False) -> dict:
    cfg = get_config("deepseek-coder-7b")
    loop = EventLoop()
    pool = DistributedKVPool(capacity_bytes=96 << 30, policy="s3fifo",
                             metadata_lag=0.002, clock=loop.clock)
    engines = []
    if disagg:
        # 1P:3D — the workload is decode-residency-bound (150-token
        # outputs over ~1.9k contexts), so disaggregation rebalances
        # exactly as DistServe prescribes (role counts set by load)
        roles = ["prefill", "decode", "decode", "decode"]
    else:
        roles = ["mixed"] * 4
    for i, role in enumerate(roles):
        sc = SimEngineConfig(device_type="a10", max_batch=24,
                             chunk_size=512, role=role)
        eng = SimEngine(cfg, loop, sc, kv_pool=pool,
                        engine_id=f"{role}-{i}", node=f"node-{i}")
        engines.append(eng)
    prefillers = [e for e in engines if e.sc.role in ("prefill", "mixed")]
    decoders = [e for e in engines if e.sc.role in ("decode", "mixed")]

    def handoff(req):
        tgt = min(decoders, key=lambda e: len(e.running) + len(e.waiting))
        tgt.submit(req)

    for e in engines:
        e.handoff = handoff

    # under-capacity regime (DistServe's comparison point): 2 prefill
    # engines sustain ~5k tok/s; offer ~4.3k so both modes keep up and
    # the metric is interference, not queueing
    n = 150 if quick else 400
    wl = sharegpt_like(rate_rps=2.4, duration_s=n / 2.4, seed=3,
                       mean_prompt=1800, mean_output=150)
    rr = 0
    for tr in wl:
        def dispatch(tr=tr):
            nonlocal rr
            tgt = min(prefillers,
                      key=lambda e: len(e.waiting) + (e.prefilling is not None))
            tgt.submit(tr.request)
        loop.schedule(tr.arrival, dispatch)
    end = wl[-1].arrival + 600.0
    loop.run(until=end,
             stop_when=lambda: loop.clock.now > wl[-1].arrival
             and not any(e.has_work for e in engines))
    return summarize([tr.request for tr in wl])


def main(quick: bool = False):
    cols = ("ttft_avg_ms", "ttft_p99_ms", "itl_avg_ms", "itl_p99_ms",
            "total_tput_tok_s", "finished")
    print("mode," + ",".join(cols))
    rows = []
    for name, disagg in (("colocated", False), ("pd-disaggregated", True)):
        s = _run(disagg, quick)
        rows.append((name, s))
        print(name + "," + ",".join(f"{s.get(c, 0):.1f}" for c in cols))
    co, pd = rows[0][1], rows[1][1]
    print(f"derived,itl_p99_reduction_pct="
          f"{100*(1-pd['itl_p99_ms']/max(co['itl_p99_ms'],1e-9)):.1f}"
          f",itl_avg_reduction_pct="
          f"{100*(1-pd['itl_avg_ms']/max(co['itl_avg_ms'],1e-9)):.1f}"
          f",ttft_delta_pct="
          f"{100*(pd['ttft_avg_ms']/max(co['ttft_avg_ms'],1e-9)-1):.1f}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced request count (CI smoke)")
    main(quick=ap.parse_args().quick)
