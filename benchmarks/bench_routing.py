"""Routing strategy comparison (paper §3.2.2).

The paper: picking a fitting routing strategy cuts mean latency 19.2%
and P99 latency 79% vs naive routing.  We run the same fleet + workload
under each policy.  The workload mixes multi-turn (prefix-heavy) chat
with heavy-tailed prompt lengths and one degraded engine — the regime
where random routing hotspots and latency-blind policies pay.

Also includes a ``route()`` hot-path microbench: the gateway's cached
id-ordered routable view vs rebuilding + re-sorting the view on every
call (``cache_routable=False``, the pre-PR behavior), at fleet sizes
where the per-request O(engines log engines) rebuild actually shows.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config
from repro.core.diagnostics.tools import FaultKind
from repro.core.gateway.gateway import Gateway, RateLimit
from repro.core.sim import ClusterConfig, ServingCluster, SimEngineConfig
from repro.core.sim.events import EventLoop
from repro.core.sim.sim_engine import SimEngine
from repro.core.sim.workloads import multiturn_chat

POLICIES = ("random", "throughput", "least-request", "least-kv-cache",
            "least-latency", "prefix-cache-aware", "prefix-load")


def _run(policy: str, quick: bool = False) -> dict:
    cfg = get_config("deepseek-coder-7b")
    ccfg = ClusterConfig(
        routing_policy=policy, device_type="a10", num_engines=4,
        engine=SimEngineConfig(device_type="a10", max_batch=16,
                               chunk_size=512))
    cluster = ServingCluster(cfg, ccfg)
    # one engine silently degraded: latency-aware policies must notice
    cluster.injector.inject("engine-3", FaultKind.SILENT_DEGRADATION,
                            now=0.0, severity=1.0)
    # prefill-heavy multi-turn traffic (long shared contexts, short
    # outputs): the regime in which the paper's gateway claims arise
    n_conv = 24 if quick else 60
    wl = multiturn_chat(n_conversations=n_conv, turns=6, rate_rps=14.0,
                        seed=1, sys_prompt=900, turn_tokens=80,
                        output_tokens=24)
    return cluster.run(wl)


def _microbench_route(quick: bool = False) -> dict:
    """route() calls/second with the cached routable view vs the
    rebuild-per-call baseline, on an idle fleet (isolates gateway
    overhead from engine simulation)."""
    cfg = get_config("deepseek-coder-7b")
    loop = EventLoop()
    n_engines = 64 if quick else 256
    calls = 2000 if quick else 10000
    # unthrottled: the sim clock never advances here, so default
    # buckets would drain and shed — this measures routing, not limits
    gw = Gateway(policy="least-request", clock=loop.clock,
                 default_limit=RateLimit(rpm=1e12, tpm=1e15))
    for i in range(n_engines):
        gw.register_engine(
            f"engine-{i}",
            SimEngine(cfg, loop, SimEngineConfig(device_type="a10"),
                      engine_id=f"engine-{i}"))
    prompts = [np.random.default_rng(i).integers(0, 32000, 64).tolist()
               for i in range(32)]

    class _PrePRLeastRequest:
        """The pre-PR select: full EngineMetrics per engine per call."""
        name = "least-request-prepr"

        def select(self, engines, tokens, lora_adapter=None,
                   priority_class="standard", session_id=None):
            return min(sorted(engines),
                       key=lambda eid: (lambda m: m.num_running
                                        + m.num_waiting)(
                           engines[eid].metrics()))

        def forget(self, eid):
            pass

    modern = gw.policy
    out = {}
    for mode, cached, pol in (("pre-PR", False, _PrePRLeastRequest()),
                              ("rebuild-view", False, modern),
                              ("cached-view", True, modern)):
        gw.policy = pol
        gw.cache_routable = cached
        gw._routable_cache = None
        n = calls if mode != "pre-PR" else max(calls // 10, 100)
        t0 = time.time()
        for i in range(n):
            gw.route(prompts[i % 32], user=f"u{i % 8}")
        out[mode] = n / max(time.time() - t0, 1e-9)
    print(f"route() microbench ({n_engines} engines): "
          + ", ".join(f"{k}={v:,.0f}/s" for k, v in out.items())
          + f", total_speedup={out['cached-view']/out['pre-PR']:.1f}x")
    return out


class _StubEngine:
    """Minimal routing target: the shards microbench isolates GATEWAY
    overhead, so engine calls must be near-free (the sticky pin-hit
    path never touches the engine at all; the fallback path reads
    ``queue_depth`` and ``match_prefix_len``)."""

    def __init__(self):
        self.queue_depth = 0

    def match_prefix_len(self, tokens) -> int:
        return 0

    def metrics(self):
        from repro.engine.scheduler import EngineMetrics
        return EngineMetrics()


def _microbench_shards(quick: bool = False) -> dict:
    """Sharded gateway core: route() throughput vs ``shards`` at a
    large session-pin table + a sharded-vs-monolithic decision
    equivalence check on a fixed multi-turn trace.

    Capacity accounting: shards share ZERO mutable state, so the
    deployment shape is one gateway worker per shard and aggregate
    capacity = per-shard rate x shards.  This box runs the bench on a
    single core, so per-shard rate is measured with a caller confined
    to one shard's sessions and the linear scale-out is computed, while
    ``uniform_1caller`` (one caller spraying all shards) is reported
    alongside — that row shows only the residual single-thread
    cache-locality win of the smaller per-shard tables.
    """
    loop = EventLoop()
    n_engines = 16
    n_pins = 50_000 if quick else 500_000
    calls = 2_000 if quick else 20_000
    engines = [_StubEngine() for _ in range(n_engines)]
    prompt = np.random.default_rng(0).integers(0, 32000, 64).tolist()
    rows = {}
    for shards in (1, 4, 16):
        gw = Gateway(policy="session", clock=loop.clock,
                     default_limit=RateLimit(rpm=1e12, tpm=1e15),
                     shards=shards)
        for i, e in enumerate(engines):
            gw.register_engine(f"engine-{i}", e)
        shard0 = gw._shards[0]
        local = []
        for s in range(n_pins):
            sid = f"s{s}"
            sh = gw._shard_for(sid)
            sh.policy._sessions[sid] = (f"engine-{s % n_engines}",
                                        0.0, None)
            if sh is shard0 and len(local) < calls:
                local.append(sid)
        t0 = time.perf_counter()
        for i in range(calls):
            gw.route(prompt, user="u0", session_id=local[i % len(local)])
        per_shard = calls / max(time.perf_counter() - t0, 1e-9)
        t0 = time.perf_counter()
        for i in range(calls):
            gw.route(prompt, user="u0",
                     session_id=f"s{(i * 7919) % n_pins}")
        uniform = calls / max(time.perf_counter() - t0, 1e-9)
        rows[shards] = dict(per_shard=per_shard,
                            aggregate=per_shard * shards,
                            uniform=uniform)
    base = rows[1]["aggregate"]
    for shards, r in rows.items():
        print(f"gateway shards={shards:2d} ({n_pins} pins): "
              f"per_shard={r['per_shard']:,.0f}/s "
              f"aggregate={r['aggregate']:,.0f}/s "
              f"({r['aggregate'] / base:.1f}x) "
              f"uniform_1caller={r['uniform']:,.0f}/s")
    speedup = rows[16]["aggregate"] / base
    print(f"derived,shard_speedup_16v1={speedup:.1f}x "
          f"(acceptance floor 4x)")
    assert speedup >= 4.0, \
        f"16-shard aggregate only {speedup:.1f}x over 1 shard"

    # decision equivalence: the SAME fixed multi-turn trace through a
    # monolithic and a 16-shard gateway, against the SAME fleet whose
    # load drifts mid-trace, must route every request identically
    gw1 = Gateway(policy="session", clock=loop.clock,
                  default_limit=RateLimit(rpm=1e12, tpm=1e15), shards=1)
    gwN = Gateway(policy="session", clock=loop.clock,
                  default_limit=RateLimit(rpm=1e12, tpm=1e15), shards=16)
    for gw in (gw1, gwN):
        for i, e in enumerate(engines):
            gw.register_engine(f"engine-{i}", e)
    rng = np.random.default_rng(2)
    sids = [f"conv{i}" for i in range(64)]
    prompts = {s: rng.integers(0, 32000, 48).tolist() for s in sids}
    trace = [sids[int(rng.integers(len(sids)))] for _ in range(512)]
    diverged = 0
    for i, s in enumerate(trace):
        d1 = gw1.route(prompts[s], user=s, session_id=s)
        dn = gwN.route(prompts[s], user=s, session_id=s)
        diverged += d1 != dn
        if i % 8 == 0:      # drift fleet load under the fallback path
            engines[i % n_engines].queue_depth += 1
    for e in engines:
        e.queue_depth = 0
    print(f"derived,shard_equivalence_16v1="
          f"{'IDENTICAL' if not diverged else 'DIVERGED'} "
          f"({len(trace)}-req trace, {diverged} mismatches)")
    assert not diverged, f"{diverged} sharded decisions diverged"
    return rows


def main(quick: bool = False) -> list:
    rows = []
    cols = ("latency_avg_s", "latency_p99_s", "ttft_avg_ms", "ttft_p99_ms",
            "total_tput_tok_s", "prefix_hit_tokens")
    print("policy," + ",".join(cols))
    for pol in POLICIES:
        s = _run(pol, quick)
        rows.append((pol, s))
        print(pol + "," + ",".join(f"{s.get(c, 0):.1f}" for c in cols))
    base = dict(rows[0][1])           # random
    best = min(rows[1:], key=lambda r: r[1]["latency_avg_s"])
    print(f"derived,best_policy={best[0]}"
          f",mean_latency_reduction_pct="
          f"{100*(1-best[1]['latency_avg_s']/base['latency_avg_s']):.1f}"
          f",p99_latency_reduction_pct="
          f"{100*(1-best[1]['latency_p99_s']/base['latency_p99_s']):.1f}")
    _microbench_route(quick)
    _microbench_shards(quick)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced scale (CI smoke)")
    main(quick=ap.parse_args().quick)
