"""Routing strategy comparison (paper §3.2.2).

The paper: picking a fitting routing strategy cuts mean latency 19.2%
and P99 latency 79% vs naive routing.  We run the same fleet + workload
under each policy.  The workload mixes multi-turn (prefix-heavy) chat
with heavy-tailed prompt lengths and one degraded engine — the regime
where random routing hotspots and latency-blind policies pay.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.diagnostics.tools import FaultKind
from repro.core.sim import ClusterConfig, ServingCluster, SimEngineConfig
from repro.core.sim.workloads import multiturn_chat

POLICIES = ("random", "throughput", "least-request", "least-kv-cache",
            "least-latency", "prefix-cache-aware", "prefix-load")


def _run(policy: str, quick: bool = False) -> dict:
    cfg = get_config("deepseek-coder-7b")
    ccfg = ClusterConfig(
        routing_policy=policy, device_type="a10", num_engines=4,
        engine=SimEngineConfig(device_type="a10", max_batch=16,
                               chunk_size=512))
    cluster = ServingCluster(cfg, ccfg)
    # one engine silently degraded: latency-aware policies must notice
    cluster.injector.inject("engine-3", FaultKind.SILENT_DEGRADATION,
                            now=0.0, severity=1.0)
    # prefill-heavy multi-turn traffic (long shared contexts, short
    # outputs): the regime in which the paper's gateway claims arise
    n_conv = 24 if quick else 60
    wl = multiturn_chat(n_conversations=n_conv, turns=6, rate_rps=14.0,
                        seed=1, sys_prompt=900, turn_tokens=80,
                        output_tokens=24)
    return cluster.run(wl)


def main(quick: bool = False) -> list:
    rows = []
    cols = ("latency_avg_s", "latency_p99_s", "ttft_avg_ms", "ttft_p99_ms",
            "total_tput_tok_s", "prefix_hit_tokens")
    print("policy," + ",".join(cols))
    for pol in POLICIES:
        s = _run(pol, quick)
        rows.append((pol, s))
        print(pol + "," + ",".join(f"{s.get(c, 0):.1f}" for c in cols))
    base = dict(rows[0][1])           # random
    best = min(rows[1:], key=lambda r: r[1]["latency_avg_s"])
    print(f"derived,best_policy={best[0]}"
          f",mean_latency_reduction_pct="
          f"{100*(1-best[1]['latency_avg_s']/base['latency_avg_s']):.1f}"
          f",p99_latency_reduction_pct="
          f"{100*(1-best[1]['latency_p99_s']/base['latency_p99_s']):.1f}")
    return rows


if __name__ == "__main__":
    main()
