"""SLO-aware scheduling vs FIFO on the mixed-arrival sim scenario.

The tentpole claim (ISSUE 3, paper §"SLO-driven GPU optimizer"):
deadline-aware admission (strict priority rank, earliest-TTFT-slack
within a class) plus bounded priority preemption lets an engine hold
interactive TTFT while batch work rides in the same decode batch.
Under FIFO a short interactive prompt queues behind multi-second batch
prefills and decode residency; under SLO scheduling it jumps the
admission queue, so interactive P99 TTFT drops sharply at the same
total token throughput (the work is merely reordered, not shed —
preemption is rate-limited so little decode progress is discarded).

One SimEngine driving the SAME shared Scheduler as the real JAX engine
(the scheduling decisions measured here are the production code's),
identical ``slo_mixed`` workload for both modes.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.sim.events import EventLoop
from repro.core.sim.sim_engine import SimEngine, SimEngineConfig
from repro.core.sim.workloads import slo_mixed, summarize


def _run(slo: bool, quick: bool = False) -> dict:
    cfg = get_config("deepseek-coder-7b")
    loop = EventLoop()
    sc = SimEngineConfig(device_type="a10", max_batch=8, chunk_size=512,
                         slo_aware=slo, slo_preempt_cooldown_s=5.0)
    eng = SimEngine(cfg, loop, sc, engine_id="eng-0")
    # ~85% utilization: queueing is transient (total throughput equals
    # offered load in both modes, so the comparison isolates TTFT),
    # but a 1.8k-token batch prefill ahead of an interactive arrival
    # still costs FIFO seconds of queue time
    wl = slo_mixed(rate_rps=0.8, duration_s=(120.0 if quick else 300.0),
                   seed=11)
    for tr in wl:
        loop.schedule(tr.arrival, lambda tr=tr: eng.submit(tr.request))
    loop.run(until=wl[-1].arrival + 3600.0,
             stop_when=lambda: loop.clock.now > wl[-1].arrival
             and not eng.has_work)
    reqs = [tr.request for tr in wl]
    out = {"all": summarize(reqs)}
    for cls in ("interactive", "batch"):
        out[cls] = summarize([r for r in reqs
                              if r.priority_class == cls])
    out["engine"] = eng.metrics()
    return out


def main(quick: bool = False):
    cols = ("ttft_avg_ms", "ttft_p99_ms", "itl_p99_ms", "finished")
    print("mode,class," + ",".join(cols) + ",total_tput_tok_s")
    rows = []
    for name, slo in (("fifo", False), ("slo", True)):
        s = _run(slo, quick)
        rows.append((name, s))
        for cls in ("interactive", "batch"):
            print(f"{name},{cls},"
                  + ",".join(f"{s[cls].get(c, 0):.1f}" for c in cols)
                  + f",{s['all']['total_tput_tok_s']:.1f}")
        m = s["engine"]
        att = {c: f"{a:.2f}" for c, a, _i, _n in m.slo_by_class}
        print(f"{name},attainment,ttft_by_class={att},"
              f"preemptions={m.preemptions}")
    fifo, slo_r = rows[0][1], rows[1][1]
    imp = 100 * (1 - slo_r["interactive"]["ttft_p99_ms"]
                 / max(fifo["interactive"]["ttft_p99_ms"], 1e-9))
    tput = (slo_r["all"]["total_tput_tok_s"]
            / max(fifo["all"]["total_tput_tok_s"], 1e-9))
    print(f"derived,interactive_ttft_p99_improvement_pct={imp:.1f}"
          f",interactive_ttft_avg_reduction_pct="
          f"{100*(1-slo_r['interactive']['ttft_avg_ms']/max(fifo['interactive']['ttft_avg_ms'],1e-9)):.1f}"
          f",tput_ratio={tput:.3f}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced duration (CI smoke)")
    main(quick=ap.parse_args().quick)
