"""Chaos harness: SLO attainment + recompute waste under injected failures.

Two scenarios on the full ``ServingCluster`` stack (gateway -> SimEngine
fleet -> distributed KV pool, with the telemetry scrape -> DiagnosticMonitor
-> remediation loop armed):

1. ``crash``  — an engine dies mid-decode (DEVICE_LOST).  Four runs:

   * ``baseline``  no failure injected (the attainment ceiling);
   * ``ckpt``      KV-backed recovery: the recovery log checkpoints
     generated pages into the distributed pool, so harvested requests
     resume from the last checkpointed page on a survivor;
   * ``drop``      recovery without the log (``ckpt_interval_tokens=0``):
     harvested requests recompute from token 0 — the pool still covers
     their prompt prefix, but every generated token is re-decoded;
   * ``off``       ``crash_recovery=False``: requests aboard the dead
     engine are simply lost (the pre-chaos behavior).

   Metrics: interactive TTFT-SLO attainment (unfinished = miss),
   p50 end-to-end latency of the requests that were aboard at crash
   time (the "resumed" set), and wasted recompute tokens.

2. ``storm`` — all four chaos kinds in one schedule (crash, straggler,
   KV-pool partition, gateway restart) with hedging enabled: exercises
   detection -> quarantine/readmit, pool retry/backoff + recompute
   fallback, deferred dispatch across the gateway restart.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.sim.chaos import ChaosSchedule
from repro.core.sim.cluster_sim import ClusterConfig, ServingCluster
from repro.core.sim.sim_engine import SimEngineConfig
from repro.core.sim.workloads import slo_mixed
from repro.engine.scheduler import DEFAULT_SLO_CLASSES

ARCH = "deepseek-coder-7b"


def _p50(vals):
    return float(np.percentile(np.asarray(vals), 50)) if vals else 0.0


def _attainment(reqs, cls: str) -> float:
    """TTFT-SLO attainment for one class; unfinished requests count as
    misses (a lost request is the worst possible SLO outcome, not a
    sample to silently drop)."""
    sel = [r for r in reqs if r.priority_class == cls]
    if not sel:
        return 1.0
    tgt = DEFAULT_SLO_CLASSES[cls].ttft_s
    ok = sum(1 for r in sel if r.finish_time > 0 and r.ttft <= tgt)
    return ok / len(sel)


# ------------------------------------------------------------ scenario 1
def _run_crash(mode: str, quick: bool) -> dict:
    """One recovery-ablation mode, pooled over three workload seeds.

    The scenario is a 3-engine fleet at moderate load (so the two
    survivors have headroom to absorb the dead engine's work — at
    saturation, shedding the crashed requests is trivially the best
    attainment policy and the ablation measures nothing) with chat-like
    interactive turns long enough that some are mid-decode when the
    engine dies: those are exactly the requests the recovery log saves
    and the ``off`` ablation loses.  Per-seed crash cohorts are small
    (a crash catches whatever happens to be aboard), so attainment and
    resumed-latency stats are pooled across seeds rather than read off
    a single run.
    """
    cfg = get_config(ARCH)
    # fixed 45s window and 3 seeds even under --quick: the ablation
    # needs enough crashed-and-resumed requests for the stats to
    # separate the modes (each run is ~2s wall-clock, so CI cost is
    # negligible)
    del quick
    dur = 45.0
    ok = tot = n_crashed = wasted = ckpt_pages = 0
    finished = n_requests = 0
    resumed: list = []
    tgt = DEFAULT_SLO_CLASSES["interactive"].ttft_s
    for seed in (0, 1, 2):
        wl = slo_mixed(rate_rps=2.0, duration_s=dur, seed=seed,
                       interactive_frac=0.6, interactive_output=96.0)
        ecfg = SimEngineConfig(
            device_type="a10", max_batch=8, chunk_size=512,
            mixed_batching=True, slo_aware=True,
            ckpt_interval_tokens=(64 if mode == "ckpt" else 0))
        chaos = (None if mode == "baseline"
                 else ChaosSchedule.engine_crash(at=dur * 0.4))
        ccfg = ClusterConfig(num_engines=3, engine=ecfg, use_kv_pool=True,
                             chaos=chaos, crash_recovery=(mode != "off"))
        c = ServingCluster(cfg, ccfg)
        s = c.run(wl, drain_s=300.0)
        reqs = [tr.request for tr in wl]
        crashed = set(c.crashed_requests)
        resumed += [r.total_latency for r in reqs
                    if r.request_id in crashed and r.finish_time > 0]
        sel = [r for r in reqs if r.priority_class == "interactive"]
        ok += sum(1 for r in sel if r.finish_time > 0 and r.ttft <= tgt)
        tot += len(sel)
        n_crashed += len(crashed)
        wasted += s["wasted_tokens"]
        ckpt_pages += s["ckpt_pages"]
        finished += s["finished"]
        n_requests += len(reqs)
    return dict(mode=mode,
                interactive_att=(ok / tot if tot else 1.0),
                resumed_p50_s=_p50(resumed),
                n_crashed=n_crashed, n_resumed=len(resumed),
                finished=finished, n_requests=n_requests,
                wasted_tokens=wasted,
                ckpt_pages=ckpt_pages)


# ------------------------------------------------------------ scenario 2
def _run_storm(quick: bool) -> dict:
    cfg = get_config(ARCH)
    dur = 25.0 if quick else 60.0
    wl = slo_mixed(rate_rps=4.0, duration_s=dur, seed=9)
    ecfg = SimEngineConfig(device_type="a10", max_batch=8, chunk_size=512,
                           mixed_batching=True, slo_aware=True,
                           ckpt_interval_tokens=64)
    chaos = (ChaosSchedule.engine_crash(at=dur * 0.2)
             + ChaosSchedule.straggler(at=dur * 0.4, duration=dur * 0.3,
                                       severity=0.9)
             + ChaosSchedule.kv_partition(at=dur * 0.5, duration=dur * 0.2)
             + ChaosSchedule.gateway_restart(at=dur * 0.8, duration=2.0))
    ccfg = ClusterConfig(num_engines=4, engine=ecfg, use_kv_pool=True,
                         chaos=chaos, hedge_ratio=0.5)
    c = ServingCluster(cfg, ccfg)
    s = c.run(wl, drain_s=300.0)
    reqs = [tr.request for tr in wl]
    return dict(mode="storm",
                interactive_att=_attainment(reqs, "interactive"),
                finished=s["finished"], n_requests=len(reqs),
                crash_recovered=s["crash_recovered"],
                quarantines=s["quarantines"], readmits=s["readmits"],
                hedged=s["hedged"], gw_restarts=s["gw_restarts"],
                gw_deferred=s["gw_deferred"],
                pool_fetch_failures=s["pool_fetch_failures"],
                pool_publish_failures=s["pool_publish_failures"],
                kv_fetch_failures=s["kv_fetch_failures"],
                wasted_tokens=s["wasted_tokens"])


def _print(title: str, rows: list) -> None:
    keys = [k for k in rows[0] if k != "mode"]
    print(f"{title}: mode," + ",".join(keys))
    for r in rows:
        print("  " + r["mode"] + "," + ",".join(
            f"{r[k]:.3f}" if isinstance(r[k], float) else str(r[k])
            for k in keys))


def main(quick: bool = False):
    out = {}
    rows = [_run_crash(m, quick)
            for m in ("baseline", "ckpt", "drop", "off")]
    _print("engine crash mid-decode (recovery ablation)", rows)
    base, ckpt, drop, off = rows
    # attainment degradation vs the no-failure ceiling: KV-backed
    # recovery must lose measurably less than recovery-off
    deg_ckpt = base["interactive_att"] - ckpt["interactive_att"]
    deg_off = base["interactive_att"] - off["interactive_att"]
    print(f"  derived,resumed_p50_reduction_vs_drop_pct="
          f"{100*(1-ckpt['resumed_p50_s']/max(drop['resumed_p50_s'],1e-9)):.1f}"
          f",attainment_degradation_ckpt={deg_ckpt:.3f}"
          f",attainment_degradation_off={deg_off:.3f}"
          f",lost_requests_off={off['n_requests']-off['finished']}")
    out["crash"] = rows

    rows = [_run_storm(quick)]
    _print("chaos storm (crash+straggler+partition+gw restart)", rows)
    out["storm"] = rows
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced durations (CI smoke)")
    main(quick=ap.parse_args().quick)
