"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle, us/call.

On this CPU container the Pallas kernels execute in interpret mode, so
absolute numbers are NOT TPU times — the benchmark validates shape
scaling and records the oracle-relative cost of the kernel path.  On a
real TPU the same harness times the compiled kernels.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels import ref as kref


def _time(fn, *args, reps=3):
    """(us_per_call, warm_result) — warms up (compiles) exactly once and
    hands the warm result back so callers can diff kernel vs oracle
    without re-executing either path."""
    warm = fn(*args)
    if isinstance(warm, tuple):
        warm[0].block_until_ready()
    else:
        jax.block_until_ready(warm)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6, warm


def main(quick: bool = False):
    rng = np.random.default_rng(0)
    print("name,us_per_call,derived")
    rows = []
    cases = [(4, 8, 4, 64, 16, 8)] if quick else \
        [(4, 8, 4, 64, 16, 8), (8, 16, 8, 128, 16, 16)]
    for (b, h, hkv, d, page, nb) in cases:
        p = b * nb + 1
        q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(p, page, hkv, d)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(p, page, hkv, d)), jnp.float32)
        bt = jnp.asarray(rng.integers(0, p, (b, nb)), jnp.int32)
        ln = jnp.full((b,), nb * page, jnp.int32)
        t_k, out_k = _time(lambda: ops.paged_attention(q, kp, vp, bt, ln))
        t_r, out_r = _time(lambda: ops.paged_attention(q, kp, vp, bt, ln,
                                                       impl="ref"))
        err = float(jnp.max(jnp.abs(out_k - out_r)))
        name = f"paged_attn_b{b}h{h}d{d}"
        rows.append((name, t_k))
        print(f"{name},{t_k:.0f},ref_us={t_r:.0f};max_err={err:.1e}")
    for (b, s, h, hkv, d) in ([(2, 256, 8, 4, 64)] if quick else
                              [(2, 256, 8, 4, 64), (1, 1024, 8, 2, 128)]):
        q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        ln = jnp.full((b,), s, jnp.int32)
        t_k, out_k = _time(lambda: ops.flash_attention(q, k, v, ln))
        t_r, out_r = _time(lambda: ops.flash_attention(q, k, v, ln,
                                                       impl="ref"))
        err = float(jnp.max(jnp.abs(out_k - out_r)))
        name = f"flash_prefill_b{b}s{s}h{h}"
        rows.append((name, t_k))
        print(f"{name},{t_k:.0f},ref_us={t_r:.0f};max_err={err:.1e}")
    # chunked prefill straight over the paged pool: the fused engine's
    # prefill hot path (kernels/paged_prefill.py) vs the dense
    # gather-the-block-table oracle it replaced
    for (b, ctx, s, h, hkv, d, page) in (
            [(1, 0, 256, 8, 2, 64, 16)] if quick else
            [(1, 0, 1024, 8, 2, 128, 64), (1, 512, 512, 8, 2, 128, 64)]):
        nb = (ctx + s) // page
        p = nb + 2
        q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(p, page, hkv, d)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(p, page, hkv, d)), jnp.float32)
        bt = jnp.asarray(rng.permutation(p)[:b * nb].reshape(b, nb),
                         jnp.int32)
        cx = jnp.full((b,), ctx, jnp.int32)
        cl = jnp.full((b,), s, jnp.int32)
        t_k, out_k = _time(lambda: ops.paged_prefill(q, kp, vp, bt, cx, cl))
        t_r, out_r = _time(lambda: ops.paged_prefill(q, kp, vp, bt, cx, cl,
                                                     impl="ref"))
        err = float(jnp.max(jnp.abs(out_k - out_r)))
        name = f"paged_prefill_b{b}ctx{ctx}s{s}h{h}d{d}"
        rows.append((name, t_k))
        print(f"{name},{t_k:.0f},ref_us={t_r:.0f};speedup={t_r/t_k:.2f}x"
              f";max_err={err:.1e}")
    # engine step-input assembly: the pre-refactor engine re-allocated
    # ~6 numpy host arrays per step() before uploading; the ModelRunner
    # preallocates them once and re-fills the used slice.  This times
    # exactly that host-side prep (fill/alloc + device upload).
    b, nb, kk, s = (4, 8, 2, 16) if quick else (8, 32, 2, 64)
    num_pages = 512
    shapes = [(b,), (b,), (b, nb), (b,), (b,), (kk, s), (kk,), (kk,),
              (kk, nb)]
    dtypes = [np.int32, np.int32, np.int32, bool, np.int32, np.int32,
              np.int32, np.int32, np.int32]
    fills = [0, 0, num_pages, False, 0, 0, 0, 0, num_pages]

    def fresh_inputs():
        return tuple(jnp.asarray(np.full(sh, f, dt))
                     for sh, dt, f in zip(shapes, dtypes, fills))

    bufs = [np.full(sh, f, dt)
            for sh, dt, f in zip(shapes, dtypes, fills)]

    def persistent_inputs():
        for a, f in zip(bufs, fills):
            a[...] = f
        return tuple(jnp.asarray(a) for a in bufs)

    t_f, _ = _time(fresh_inputs, reps=100)
    t_p, _ = _time(persistent_inputs, reps=100)
    rows.append(("step_inputs_persistent", t_p))
    print(f"step_inputs_fresh,{t_f:.1f},")
    print(f"step_inputs_persistent,{t_p:.1f},"
          f"speedup_vs_fresh={t_f/max(t_p,1e-9):.2f}x")
    # step-loop overlap: the sync engine loop round-trips every step's
    # sampled tokens through the host (readback -> bookkeeping ->
    # re-upload as next step's input); the async loop keeps the token
    # feedback ON DEVICE and resolves step N's readback only after
    # step N+1 is dispatched.  This isolates that loop structure with
    # a jitted stand-in pass.
    dim, iters = (128, 20) if quick else (256, 40)
    w = jnp.asarray(rng.normal(size=(dim, dim)) / np.sqrt(dim),
                    jnp.float32)

    @jax.jit
    def _pass(x):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    def loop_sync():
        buf = np.zeros((4, dim), np.float32)
        x = jnp.asarray(buf)
        for _ in range(iters):
            buf[...] = np.asarray(_pass(x))   # device -> host sync
            x = jnp.asarray(buf)              # host -> device
        return x

    def loop_overlap():
        x = jnp.asarray(np.zeros((4, dim), np.float32))
        prev = None
        for _ in range(iters):
            out = _pass(x)
            x = out                           # feedback stays on device
            if prev is not None:
                np.asarray(prev)              # resolve step N-1 late
            prev = out
        np.asarray(prev)
        return x

    t_s, _ = _time(loop_sync, reps=5)
    t_o, _ = _time(loop_overlap, reps=5)
    rows.append(("step_loop_overlap", t_o))
    print(f"step_loop_sync,{t_s:.0f},iters={iters}")
    print(f"step_loop_overlap,{t_o:.0f},"
          f"host_gap_reduction={t_s/max(t_o,1e-9):.2f}x")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI smoke)")
    main(quick=ap.parse_args().quick)
