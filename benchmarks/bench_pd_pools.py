"""Static P/D splits vs attainment-driven auto-rebalancing role pools.

The role-aware control plane claim (ISSUE 4): a phase-shifting
workload — a prefill-heavy half (high-rate long prompts, short
outputs) followed by a decode-heavy half (long generations over short
prompts) — mis-sizes EVERY static prefill:decode split for one of its
phases: too few prefill members and prompts queue past the interactive
TTFT target; too few decode members and handed-off requests block on
decode slots while over-packed batches breach the ITL target.  The
RolePoolManager's attainment-driven rebalancer (one inverted-metric
autoscaler per pool: fleet TTFT attainment sizes the prefill pool,
fleet ITL attainment the decode pool, waiting-queue location
disambiguating TTFT deficits) migrates members live instead — same
engine count, better interactive SLO attainment across the shift.

Setup: 4x A10 SimEngines over the distributed pool, identical
``phase_shift`` workload for every mode; static 3P1D / 2P2D / 1P3D
vs ``--roles auto`` (even start + rebalancer).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.gateway.gateway import RateLimit
from repro.core.orchestration.pools import RebalanceConfig
from repro.core.sim.cluster_sim import ClusterConfig, ServingCluster
from repro.core.sim.sim_engine import SimEngineConfig
from repro.core.sim.workloads import phase_shift
from repro.engine.scheduler import DEFAULT_SLO_CLASSES


def interactive_attainment(requests) -> dict:
    """Per-request interactive SLO attainment: TTFT within target, ITL
    as the per-request fraction of inter-token gaps within target, and
    ``slo`` = fraction of requests meeting TTFT with at least 90% of
    their gaps within ITL.  A request still unserved at the drain
    deadline counts as a full miss — a mode must not score better by
    starving its worst-served requests out of the denominator."""
    cls = DEFAULT_SLO_CLASSES["interactive"]
    mine = [r for r in requests if r.priority_class == "interactive"]
    if not mine:
        return dict(ttft=1.0, itl=1.0, slo=1.0, finished=0)
    ttft_ok, itl_frac, good = [], [], []
    finished = 0
    for r in mine:
        if r.finish_time <= 0:
            ttft_ok.append(False)
            itl_frac.append(0.0)
            good.append(False)
            continue
        finished += 1
        t_ok = r.ttft <= cls.ttft_s
        gaps = r.itl
        frac = (sum(g <= cls.itl_s for g in gaps) / len(gaps)
                if gaps else 1.0)
        ttft_ok.append(t_ok)
        itl_frac.append(frac)
        good.append(t_ok and frac >= 0.9)
    n = len(mine)
    return dict(ttft=sum(ttft_ok) / n, itl=sum(itl_frac) / n,
                slo=sum(good) / n, finished=finished)


def _run(roles: str, rebalance, quick: bool = False) -> dict:
    cfg = get_config("deepseek-coder-7b")
    dur = 240.0 if quick else 600.0
    ccfg = ClusterConfig(
        routing_policy="least-request", num_engines=4,
        engine=SimEngineConfig(device_type="a10", max_batch=32,
                               chunk_size=512, mixed_batching=True,
                               max_prefills=2),
        roles=roles, rebalance=rebalance, kv_pool_bw=100e9,
        # the experiment measures pool sizing, not admission control
        rate_limit=RateLimit(rpm=1e8, tpm=1e12))
    cluster = ServingCluster(cfg, ccfg)
    wl = phase_shift(duration_s=dur, seed=5)
    s = cluster.run(wl, drain_s=300.0)
    reqs = [tr.request for tr in wl]
    half = dur / 2
    pa = interactive_attainment(
        [tr.request for tr in wl if tr.arrival < half])
    pb = interactive_attainment(
        [tr.request for tr in wl if tr.arrival >= half])
    att = interactive_attainment(reqs)
    # the headline: mean of per-phase attainment — robustness across
    # the regime shift, not swamped by the higher-rate phase's count
    att["slo_balanced"] = (pa["slo"] + pb["slo"]) / 2
    att["slo_prefill_phase"] = pa["slo"]
    att["slo_decode_phase"] = pb["slo"]
    att["total_tput_tok_s"] = s.get("total_tput_tok_s", 0.0)
    att["migrations"] = s.get("migrations", 0)
    att["pool_counts"] = s.get("pool_counts", {})
    att["submitted"] = len(wl)
    return att


def main(quick: bool = False):
    reb = RebalanceConfig(period_s=5.0, cooldown_s=60.0, warmup_s=30.0,
                          signal_class="interactive")
    modes = [("static-3P1D", "3P1D", None), ("static-2P2D", "2P2D", None),
             ("static-1P3D", "1P3D", None), ("auto", "auto", reb)]
    cols = ("slo_balanced", "slo_prefill_phase", "slo_decode_phase",
            "ttft", "itl", "total_tput_tok_s", "finished", "migrations")
    print("mode," + ",".join(cols) + ",final_pools")
    rows = []
    for name, roles, rb in modes:
        r = _run(roles, rb, quick)
        rows.append((name, r))
        print(name + "," + ",".join(
            f"{r[c]:.3f}" if isinstance(r[c], float) else str(r[c])
            for c in cols) + f",{r['pool_counts']}")
    auto = rows[-1][1]
    best_static = max(rows[:-1], key=lambda x: x[1]["slo_balanced"])
    imp = 100 * (auto["slo_balanced"]
                 / max(best_static[1]["slo_balanced"], 1e-9) - 1)
    print(f"derived,auto_vs_best_static({best_static[0]}),"
          f"slo_attainment_improvement_pct={imp:.1f}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced duration (CI smoke)")
    main(quick=ap.parse_args().quick)
