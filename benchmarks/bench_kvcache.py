"""Table 1 reproduction: distributed KV cache vs vLLM configurations.

Paper setup: Bird-SQL (Text2SQL) on 4x NVIDIA A10, deepseek-coder-7b.
Six rows: {default, chunked-prefill, prefix-caching} x {engine-only,
+ AIBrix distributed KV cache}.  The paper's headline: pool + prefix
caching beats engine prefix caching alone by ~50% peak throughput,
~-60/-70% avg/P99 TTFT, ~-30/-70% avg/P99 ITL.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.sim import ClusterConfig, ServingCluster, SimEngineConfig
from repro.core.sim.workloads import birdsql_like


def _run(prefix: bool, chunked: bool, pool: bool, *,
         n_requests: int = 500, rate: float = 30.0, seed: int = 0) -> dict:
    cfg = get_config("deepseek-coder-7b")
    ecfg = SimEngineConfig(device_type="a10", page_size=64, max_batch=24,
                           chunk_size=512, prefix_caching=prefix,
                           chunked_prefill=chunked)
    ccfg = ClusterConfig(routing_policy="least-request", device_type="a10",
                         num_engines=4, engine=ecfg, use_kv_pool=pool,
                         kv_pool_gb=64.0, kv_pool_policy="s3fifo")
    cluster = ServingCluster(cfg, ccfg)
    wl = birdsql_like(n_requests, rate_rps=rate, seed=seed)
    return cluster.run(wl)


ROWS = [
    ("vllm-default", dict(prefix=False, chunked=False, pool=False)),
    ("aibrix-kvpool+default", dict(prefix=False, chunked=False, pool=True)),
    ("vllm-chunked-prefill", dict(prefix=False, chunked=True, pool=False)),
    ("aibrix-kvpool+chunked", dict(prefix=False, chunked=True, pool=True)),
    ("vllm-prefix-caching", dict(prefix=True, chunked=True, pool=False)),
    ("aibrix-kvpool+prefix", dict(prefix=True, chunked=True, pool=True)),
]

COLS = ("prompt_tokens", "decode_tokens", "total_tput_tok_s",
        "decode_tput_tok_s", "ttft_avg_ms", "ttft_p99_ms", "itl_avg_ms",
        "itl_p99_ms", "completion_time_s")


def run(quick: bool = False) -> list:
    n = 150 if quick else 500
    out = []
    for name, kw in ROWS:
        s = _run(n_requests=n, **kw)
        out.append((name, {c: s.get(c, 0) for c in COLS},
                    s.get("remote_hit_tokens", 0)))
    return out


def main(quick: bool = False) -> list:
    rows = run(quick)
    hdr = ["method"] + list(COLS)
    print(",".join(hdr))
    for name, cols, _ in rows:
        print(name + "," + ",".join(f"{cols[c]:.1f}" for c in COLS))
    # derived: improvements of pool+prefix over engine prefix caching
    base = dict(rows[4][1])
    best = dict(rows[5][1])
    derived = {
        "throughput_gain_pct":
            100 * (best["total_tput_tok_s"] / max(base["total_tput_tok_s"],
                                                  1e-9) - 1),
        "ttft_avg_reduction_pct":
            100 * (1 - best["ttft_avg_ms"] / max(base["ttft_avg_ms"], 1e-9)),
        "ttft_p99_reduction_pct":
            100 * (1 - best["ttft_p99_ms"] / max(base["ttft_p99_ms"], 1e-9)),
        "itl_avg_reduction_pct":
            100 * (1 - best["itl_avg_ms"] / max(base["itl_avg_ms"], 1e-9)),
        "completion_reduction_pct":
            100 * (1 - best["completion_time_s"]
                   / max(base["completion_time_s"], 1e-9)),
    }
    print("derived," + ",".join(f"{k}={v:.1f}" for k, v in derived.items()))
    return rows


if __name__ == "__main__":
    main()
