"""Benchmark driver: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]``

Order mirrors the paper: Table 1 (distributed KV cache), routing
(§3.2.2), autoscaling (§3.2.4), heterogeneous serving (§3.2.7/Fig 7-8),
cold start (§3.2.3), LoRA density (§3.2.1), kernel microbench, and the
roofline table from the dry-run artifacts.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_autoscaling, bench_chaos, bench_coldstart,
                        bench_hetero, bench_kernels, bench_kv_tiers,
                        bench_kvcache, bench_lora, bench_pd_disagg,
                        bench_pd_pools, bench_routing, bench_sessions,
                        bench_slo, bench_speculative, roofline)
from repro.core.gateway.gateway import Gateway
from repro.core.sim.events import EventLoop
from repro.engine.runner import ModelRunner
from repro.engine.scheduler import Scheduler

SUITES = [
    ("table1_distributed_kvcache", bench_kvcache.main),
    ("routing_strategies", bench_routing.main),
    ("llm_autoscaling", bench_autoscaling.main),
    ("heterogeneous_slo_serving", bench_hetero.main),
    ("coldstart_streaming_loader", bench_coldstart.main),
    ("high_density_lora", bench_lora.main),
    ("pd_disaggregation_via_pool", bench_pd_disagg.main),
    ("pd_role_pools_rebalancing", bench_pd_pools.main),
    ("kv_tiers_swap_and_streaming", bench_kv_tiers.main),
    ("million_session_serving", bench_sessions.main),
    ("slo_aware_scheduling", bench_slo.main),
    ("chaos_and_crash_recovery", bench_chaos.main),
    ("pallas_kernels", bench_kernels.main),
    ("speculative_decoding", bench_speculative.main),
    ("roofline_from_dryrun", lambda quick=False: roofline.main("", quick)),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI mode)")
    ap.add_argument("--only", default="",
                    help="substring filter on suite name")
    args = ap.parse_args()
    failures = []
    for name, fn in SUITES:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} " + "=" * max(8, 60 - len(name)))
        t0 = time.time()
        shed0 = Gateway.total_shed
        ev0 = EventLoop.total_events
        wait0 = ModelRunner.total_device_wait_s
        lr0, lh0 = Gateway.total_lora_routed, Gateway.total_lora_hits
        lm0 = Scheduler.total_lora_miss
        try:
            fn(quick=args.quick)
            # loud load shedding: a suite whose gateway rate limiter
            # silently dropped requests must say so next to its results
            # (it served LESS than the offered load it reports against)
            shed = Gateway.total_shed - shed0
            note = f" [gateway shed {shed} request(s)!]" if shed else ""
            # host/device split: how long this suite's real engines sat
            # blocked on device readbacks (0 for sim-only suites)
            wait = ModelRunner.total_device_wait_s - wait0
            if wait > 0:
                note += f" [device wait {wait:.1f}s]"
            # multi-LoRA accounting: affinity hit rate of this suite's
            # LoRA-tagged routes + scheduler-level adapter misses (a
            # request that reached an engine without its adapter)
            lr = Gateway.total_lora_routed - lr0
            lm = Scheduler.total_lora_miss - lm0
            if lr > 0:
                lh = Gateway.total_lora_hits - lh0
                note += f" [lora affinity {lh}/{lr}, miss {lm}]"
            # event-core throughput: fired sim events per wall-second
            # of the whole suite (0 events for real-engine-only suites)
            ev = EventLoop.total_events - ev0
            wall = max(time.time() - t0, 1e-9)
            if ev > 0:
                note += f" [{ev} sim events, {ev / wall:,.0f}/wall-s]"
            print(f"----- {name} done in {wall:.1f}s{note}")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"\nFAILED suites: {failures}")
        sys.exit(1)
    print("\nall benchmark suites completed")


if __name__ == "__main__":
    main()
