"""§Roofline table builder: reads the dry-run JSON records and prints
the per-(arch x shape x mesh) three-term roofline with dominant
bottleneck, MODEL_FLOPS ratio, and HBM fit — EXPERIMENTS.md §Roofline
is generated from this output.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DEFAULT_DIRS = ("benchmarks/results/dryrun_optimized",
                "benchmarks/results/dryrun")


def load_records(result_dir: str = "") -> List[Dict]:
    dirs = [result_dir] if result_dir else [d for d in DEFAULT_DIRS
                                            if os.path.isdir(d)]
    recs = []
    for d in dirs[:1]:
        for f in sorted(glob.glob(os.path.join(d, "*.json"))):
            if f.endswith("_absorb.json"):
                continue          # A/B variant artifact, not a baseline row
            with open(f) as fh:
                recs.append(json.load(fh))
    return recs


def main(result_dir: str = "", quick: bool = False):
    recs = load_records(result_dir)
    if not recs:
        print("no dry-run records found — run "
              "`python -m repro.launch.dryrun` first")
        return []
    print("case,status,chips,GB_per_dev,fits_16G,compute_s,memory_s,"
          "collective_s,dominant,useful_flops_ratio,coll_MB_per_dev")
    rows = []
    for r in sorted(recs, key=lambda x: x["case"]):
        if r["status"] == "skipped":
            print(f"{r['case']},skipped,,,,,,,,,")
            continue
        if r["status"] == "error":
            print(f"{r['case']},ERROR,,,,,,,,,")
            continue
        t = r["roofline"]
        m = r["memory"]
        coll = r["collectives_per_device_bytes"].get("total", 0)
        rows.append(r)
        print(f"{r['case']},ok,{r['chips']}"
              f",{m['per_device_bytes']/1e9:.2f}"
              f",{int(m['fits_16g_hbm'])}"
              f",{t['compute_s']:.4f},{t['memory_s']:.4f}"
              f",{t['collective_s']:.4f},{t['dominant']}"
              f",{t['useful_flops_ratio']:.3f},{coll/1e6:.1f}")
    # summary: dominant-term census + worst fits
    census: Dict[str, int] = {}
    for r in rows:
        census[r["roofline"]["dominant"]] = \
            census.get(r["roofline"]["dominant"], 0) + 1
    n_fit = sum(int(r["memory"]["fits_16g_hbm"]) for r in rows)
    print(f"derived,dominant_census={census}"
          f",fits_hbm={n_fit}/{len(rows)}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "")
