"""Cold start / GPU streaming loader (paper §3.2.3).

Model-load wall time across artifact tiers, streaming vs sequential
loader, and the end-to-end effect on autoscaler actuation (pod-ready
latency) through the ColdStartManager.
"""
from __future__ import annotations

from repro.core.runtime.sidecar import (ColdStartManager, ModelArtifact,
                                        TIER_BW, load_time_s)

SIZES = {"7b-bf16": 14e9, "70b-bf16": 140e9}


def main(quick: bool = False):
    print("artifact,tier,sequential_s,streaming_s,speedup")
    rows = []
    for name, size in SIZES.items():
        for tier in ("remote", "local", "dram"):
            seq = load_time_s(size, tier, streaming=False)
            stream = load_time_s(size, tier, streaming=True)
            rows.append((name, tier, seq, stream))
            print(f"{name},{tier},{seq:.1f},{stream:.1f}"
                  f",{seq/stream:.2f}x")
    # cold-start-aware scheduling: best node beats the naive one
    mgr = ColdStartManager(streaming_loader=True)
    mgr.register_artifact(ModelArtifact(
        "m7b", 14e9, tier_by_node={"node-0": "dram", "node-1": "local"}))
    best = mgr.best_node("m7b", ["node-0", "node-1", "node-2"])
    t_best = mgr.cold_start_s("m7b", best)
    t_worst = mgr.cold_start_s("m7b", "node-2")
    print(f"derived,best_node={best},pod_ready_best_s={t_best:.1f}"
          f",pod_ready_remote_s={t_worst:.1f}"
          f",placement_speedup={t_worst/t_best:.2f}x")
    return rows


if __name__ == "__main__":
    main()
