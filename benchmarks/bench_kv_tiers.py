"""Tiered KV cache: swap-based preemption + streaming compressed handoff.

Three scenarios on the discrete-event simulator (the SAME unified
Scheduler the real JAX engine runs — see tests/test_kv_tiers.py for the
real-engine byte-identity pins):

1. ``preempt``   — SLO-preemption-heavy overload on one engine.  With a
   host-DRAM tier, ``Scheduler.preempt`` swaps the victim's pages out
   and resume continues decoding from where it stopped; without one it
   drops everything and recomputes from token 0.  Metric: p50 latency
   of the requests that actually got preempted (the "resumed" set).
2. ``multiturn`` — multi-turn chat on a device-KV-starved engine.  The
   allocator's eviction cascade parks victims in the host tier, so the
   next turn's prefix walk hits host DRAM instead of recomputing.
3. ``handoff``   — 1P+1D disaggregation.  Pool-handoff transfers move
   as page-group chunks: only the head group gates the tail recompute,
   later groups stream against the decode engine's compute; the int8
   wire format additionally halves the bytes.  Compared at EQUAL
   fabric bandwidth: eager whole-payload vs chunked vs chunked+int8.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.kvcache.pool import DistributedKVPool
from repro.core.sim.events import EventLoop
from repro.core.sim.sim_engine import SimEngine, SimEngineConfig
from repro.core.sim.workloads import (multiturn_chat, sharegpt_like,
                                      slo_mixed, summarize)

ARCH = "deepseek-coder-7b"


def _drain(loop, wl, engines):
    loop.run(until=wl[-1].arrival + 600.0,
             stop_when=lambda: loop.clock.now > wl[-1].arrival
             and not any(e.has_work for e in engines))


def _p50(vals):
    return float(np.percentile(np.asarray(vals), 50)) if vals else 0.0


# ------------------------------------------------------------ scenario 1
def _run_preempt(host_gb: float, quick: bool) -> dict:
    cfg = get_config(ARCH)
    loop = EventLoop()
    sc = SimEngineConfig(device_type="a10", max_batch=16, chunk_size=512,
                         mixed_batching=True, slo_aware=True,
                         slo_preempt_cooldown_s=0.25,
                         host_cache_gb=host_gb)
    eng = SimEngine(cfg, loop, sc)
    wl = slo_mixed(rate_rps=6.0, duration_s=25.0 if quick else 60.0,
                   seed=5, interactive_frac=0.6)
    for tr in wl:
        loop.schedule(tr.arrival, lambda tr=tr: eng.submit(tr.request))
    _drain(loop, wl, [eng])
    reqs = [tr.request for tr in wl]
    resumed = [r.total_latency for r in reqs
               if r.preempt_count > 0 and r.finish_time > 0]
    s = summarize(reqs)
    m = eng.metrics()
    return dict(mode="swap" if host_gb else "recompute",
                resumed_p50_s=_p50(resumed), n_resumed=len(resumed),
                preemptions=m.preemptions, swap_in=m.swap_in,
                tput=s["total_tput_tok_s"], finished=s["finished"])


# ------------------------------------------------------------ scenario 2
def _run_multiturn(host_gb: float, quick: bool) -> dict:
    cfg = get_config(ARCH)
    loop = EventLoop()
    sc = SimEngineConfig(device_type="a10", max_batch=8, chunk_size=512,
                         mixed_batching=True, num_pages=96,
                         host_cache_gb=host_gb)
    eng = SimEngine(cfg, loop, sc)
    wl = multiturn_chat(n_conversations=8 if quick else 12,
                        turns=4 if quick else 5, rate_rps=2.0, seed=11,
                        sys_prompt=600, turn_tokens=100,
                        output_tokens=80)
    for tr in wl:
        loop.schedule(tr.arrival, lambda tr=tr: eng.submit(tr.request))
    _drain(loop, wl, [eng])
    s = summarize([tr.request for tr in wl])
    m = eng.metrics()
    return dict(mode="host-tier" if host_gb else "device-only",
                ttft_avg_ms=s["ttft_avg_ms"], tput=s["total_tput_tok_s"],
                host_hit_tokens=m.host_hit_tokens,
                prefix_hit_tokens=m.prefix_hit_tokens,
                offloaded_mib=m.kv_bytes_offloaded >> 20,
                finished=s["finished"])


# ------------------------------------------------------------ scenario 3
def _run_handoff(chunk_pages: int, wire: str, quick: bool) -> dict:
    cfg = get_config(ARCH)
    loop = EventLoop()
    pool = DistributedKVPool(capacity_bytes=96 << 30,
                             metadata_lag=0.002, clock=loop.clock,
                             network_bw=6.25e9)      # 50 Gb/s fabric
    kw = dict(device_type="a10", max_batch=24, chunk_size=512,
              mixed_batching=True, handoff_chunk_pages=chunk_pages,
              wire_dtype=wire)
    pre = SimEngine(cfg, loop, SimEngineConfig(role="prefill", **kw),
                    kv_pool=pool, engine_id="p0", node="node-0")
    dec = SimEngine(cfg, loop, SimEngineConfig(role="decode", **kw),
                    kv_pool=pool, engine_id="d0", node="node-1")
    pre.handoff = dec.submit
    wl = sharegpt_like(rate_rps=0.7, duration_s=60.0 if quick else 150.0,
                       seed=7, mean_prompt=2400, mean_output=160)
    for tr in wl:
        loop.schedule(tr.arrival, lambda tr=tr: pre.submit(tr.request))
    _drain(loop, wl, [pre, dec])
    s = summarize([tr.request for tr in wl])
    mode = "eager" if chunk_pages == 0 else f"chunked({chunk_pages})"
    return dict(mode=f"{mode}/{wire}", ttft_avg_ms=s["ttft_avg_ms"],
                ttft_p99_ms=s["ttft_p99_ms"], itl_p99_ms=s["itl_p99_ms"],
                fetched_mib=dec.metrics().kv_bytes_fetched >> 20,
                finished=s["finished"])


def _print(title: str, rows: list) -> None:
    keys = [k for k in rows[0] if k != "mode"]
    print(f"{title}: mode," + ",".join(keys))
    for r in rows:
        print("  " + r["mode"] + "," + ",".join(
            f"{r[k]:.1f}" if isinstance(r[k], float) else str(r[k])
            for k in keys))


def main(quick: bool = False):
    out = {}
    rows = [_run_preempt(0.0, quick), _run_preempt(4.0, quick)]
    _print("preempt-heavy (slo_mixed overload)", rows)
    rec, swp = rows
    print(f"  derived,resumed_p50_reduction_pct="
          f"{100*(1-swp['resumed_p50_s']/max(rec['resumed_p50_s'],1e-9)):.1f}")
    out["preempt"] = rows

    rows = [_run_multiturn(0.0, quick), _run_multiturn(4.0, quick)]
    _print("multi-turn reuse (device KV starved)", rows)
    dev, host = rows
    print(f"  derived,ttft_reduction_pct="
          f"{100*(1-host['ttft_avg_ms']/max(dev['ttft_avg_ms'],1e-9)):.1f}")
    out["multiturn"] = rows

    rows = [_run_handoff(0, "fp16", quick), _run_handoff(4, "fp16", quick),
            _run_handoff(4, "int8", quick)]
    _print("P/D handoff (equal fabric bw)", rows)
    eager, chunked, c8 = rows
    print(f"  derived,chunked_ttft_reduction_pct="
          f"{100*(1-chunked['ttft_avg_ms']/max(eager['ttft_avg_ms'],1e-9)):.1f}"
          f",int8_ttft_reduction_pct="
          f"{100*(1-c8['ttft_avg_ms']/max(eager['ttft_avg_ms'],1e-9)):.1f}")
    out["handoff"] = rows
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced durations (CI smoke)")
    main(quick=ap.parse_args().quick)
