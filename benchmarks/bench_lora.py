"""High-density LoRA management (paper §3.2.1, Figure 2).

Long-tail adapter fleet: N adapters with zipf demand.  Compare
(a) dedicated-pod-per-adapter (the rigid baseline the paper calls out),
(b) AIBrix high-density placement (many adapters per pod, replicas by
heat) — pods needed, cost, and LoRA-affinity routing hit rate.
"""
from __future__ import annotations

import numpy as np

from repro.core.lora.manager import AdapterSpec, LoRAController
from repro.core.optimizer.profiles import DEVICES


def main(quick: bool = False):
    n_adapters = 12 if quick else 32
    pods = 4 if quick else 8
    slots_per_pod = 8
    rng = np.random.default_rng(0)
    heat = 1.0 / (np.arange(n_adapters) + 1.0)       # zipf demand
    heat = heat / heat.sum() * 20.0                  # total 20 rps

    ctrl = LoRAController(min_replicas=1, max_replicas=3)
    ctrl.register(AdapterSpec("base-sft", "llama-7b", rank=16))
    for i in range(n_adapters):
        ctrl.register(AdapterSpec(
            f"adapter-{i}", "llama-7b", rank=8,
            parent="base-sft" if i % 4 == 0 else None,
            requests_per_s=float(heat[i])))
    for p in range(pods):
        ctrl.add_pod(f"pod-{p}", capacity=slots_per_pod)
    actions = ctrl.sync({})
    plan = {p: sorted(s.loaded) for p, s in ctrl.pods.items()}

    placed = sum(len(v) for v in plan.values())
    covered = len({a for v in plan.values() for a in v})
    # dedicated baseline: one pod per adapter (+1 for base)
    dedicated_pods = n_adapters + 1
    density_pods = pods
    cost = DEVICES["a10"].cost_per_hour
    print("scheme,pods,adapters_covered,cost_per_hour")
    print(f"dedicated-pod-per-adapter,{dedicated_pods},{n_adapters + 1}"
          f",{dedicated_pods*cost:.2f}")
    print(f"aibrix-high-density,{density_pods},{covered}"
          f",{density_pods*cost:.2f}")
    hot_replicas = len(ctrl.endpoints("adapter-0"))
    cold_replicas = len(ctrl.endpoints(f"adapter-{n_adapters-1}"))
    print(f"derived,cost_reduction_pct="
          f"{100*(1-density_pods/dedicated_pods):.1f}"
          f",hot_adapter_replicas={hot_replicas}"
          f",cold_adapter_replicas={cold_replicas}"
          f",loads={ctrl.stats['loads']}")
    assert covered == n_adapters + 1, "density placement must cover all"
    return plan


if __name__ == "__main__":
    main()
