"""High-density LoRA management + serving (paper §3.2.1, Figure 2).

Three sections:

1. **Planner** — long-tail adapter fleet: N adapters with zipf demand.
   Compare (a) dedicated-pod-per-adapter (the rigid baseline the paper
   calls out) vs (b) AIBrix high-density placement (many adapters per
   pod, replicas by heat) — pods needed, cost, coverage.
2. **End-to-end serving** — the same zipf trace driven through the full
   ``ServingCluster`` stack (gateway -> LoRA-aware routing -> adapter
   tiering on the engines, demand-driven replanning) under
   ``lora-affinity`` vs an adapter-blind baseline at EQUAL engine
   count: affinity hit rate, cold-load stalls, $/attained-SLO.
3. **Real engine** — a small real-JAX fleet behind the same gateway +
   controller: affinity hit rate and cold loads on actual devices.
"""
from __future__ import annotations

import numpy as np

from repro.core.lora.manager import AdapterSpec, LoRAController
from repro.core.optimizer.profiles import DEVICES

SLO_TTFT_S = 0.5          # attained = TTFT within this bound


# ------------------------------------------------------------ 1. planner
def planner_section(quick: bool = False):
    n_adapters = 12 if quick else 32
    pods = 4 if quick else 8
    slots_per_pod = 8
    rng = np.random.default_rng(0)
    heat = 1.0 / (np.arange(n_adapters) + 1.0)       # zipf demand
    heat = heat / heat.sum() * 20.0                  # total 20 rps

    ctrl = LoRAController(min_replicas=1, max_replicas=3)
    ctrl.register(AdapterSpec("base-sft", "llama-7b", rank=16))
    for i in range(n_adapters):
        ctrl.register(AdapterSpec(
            f"adapter-{i}", "llama-7b", rank=8,
            parent="base-sft" if i % 4 == 0 else None,
            requests_per_s=float(heat[i])))
    for p in range(pods):
        ctrl.add_pod(f"pod-{p}", capacity=slots_per_pod)
    actions = ctrl.sync({})
    plan = {p: sorted(s.loaded) for p, s in ctrl.pods.items()}

    placed = sum(len(v) for v in plan.values())
    covered = len({a for v in plan.values() for a in v})
    # dedicated baseline: one pod per adapter (+1 for base)
    dedicated_pods = n_adapters + 1
    density_pods = pods
    cost = DEVICES["a10"].cost_per_hour
    print("scheme,pods,adapters_covered,cost_per_hour")
    print(f"dedicated-pod-per-adapter,{dedicated_pods},{n_adapters + 1}"
          f",{dedicated_pods*cost:.2f}")
    print(f"aibrix-high-density,{density_pods},{covered}"
          f",{density_pods*cost:.2f}")
    hot_replicas = len(ctrl.endpoints("adapter-0"))
    cold_replicas = len(ctrl.endpoints(f"adapter-{n_adapters-1}"))
    print(f"derived,cost_reduction_pct="
          f"{100*(1-density_pods/dedicated_pods):.1f}"
          f",hot_adapter_replicas={hot_replicas}"
          f",cold_adapter_replicas={cold_replicas}"
          f",loads={ctrl.stats['loads']}")
    assert covered == n_adapters + 1, "density placement must cover all"
    return plan


# ------------------------------------------------- 2. end-to-end serving
def _run_serving(policy: str, n_adapters: int, engines: int,
                 rate_rps: float, duration_s: float,
                 max_adapters: int = 9, seed: int = 1) -> dict:
    from repro.configs import get_config
    from repro.core.gateway.gateway import RateLimit
    from repro.core.sim import (ClusterConfig, ServingCluster,
                                SimEngineConfig)
    from repro.core.sim.workloads import lora_zipf

    cfg = get_config("deepseek-coder-7b")
    # fresh workload per run: the sim mutates Request state in place
    wl = lora_zipf(n_adapters=n_adapters, rate_rps=rate_rps,
                   duration_s=duration_s, seed=seed)
    ccfg = ClusterConfig(
        routing_policy=policy, device_type="a10", num_engines=engines,
        lora_adapters=n_adapters,
        rate_limit=RateLimit(rpm=10**9, tpm=10**12),
        engine=SimEngineConfig(device_type="a10", max_batch=16,
                               chunk_size=512,
                               max_adapters=max_adapters))
    s = ServingCluster(cfg, ccfg).run(wl)
    done = [tr.request for tr in wl if tr.request.finish_time > 0]
    attained = sum(1 for r in done if r.ttft <= SLO_TTFT_S)
    span_h = s["completion_time_s"] / 3600.0
    dollars = engines * DEVICES["a10"].cost_per_hour * span_h
    s["slo_attained"] = attained
    s["cost_per_1k_slo"] = 1000.0 * dollars / max(attained, 1)
    return s


def serving_section(quick: bool = False):
    n_adapters = 120 if quick else 1000
    engines = 4 if quick else 8
    rate = 12.0 if quick else 40.0
    duration = 30.0 if quick else 60.0
    cols = ("lora_affinity_hit_rate", "lora_cold_loads",
            "lora_cold_load_s", "lora_miss", "lora_shed",
            "ttft_avg_ms", "latency_avg_s", "slo_attained",
            "cost_per_1k_slo")
    print(f"\nserving: {n_adapters} adapters zipf, {engines} engines, "
          f"{rate:.0f} rps x {duration:.0f}s")
    print("policy," + ",".join(cols))
    rows = {}
    for policy in ("least-request", "lora-affinity"):
        s = _run_serving(policy, n_adapters, engines, rate, duration)
        rows[policy] = s
        print(policy + "," + ",".join(
            f"{s.get(c, 0):.3f}" if isinstance(s.get(c, 0), float)
            else str(s.get(c, 0)) for c in cols))
    aff, blind = rows["lora-affinity"], rows["least-request"]
    print(f"derived,affinity_hit_gain="
          f"{aff['lora_affinity_hit_rate'] - blind['lora_affinity_hit_rate']:.3f}"
          f",cold_load_reduction_pct="
          f"{100*(1 - aff['lora_cold_loads']/max(blind['lora_cold_loads'],1)):.1f}"
          f",cost_per_1k_slo_delta="
          f"{aff['cost_per_1k_slo'] - blind['cost_per_1k_slo']:+.4f}")
    assert aff["lora_affinity_hit_rate"] >= \
        blind["lora_affinity_hit_rate"], \
        "lora-affinity must beat adapter-blind routing on hit rate"
    assert aff["lora_cold_load_s"] <= blind["lora_cold_load_s"], \
        "lora-affinity must not stall more on cold loads"
    return rows


# ---------------------------------------------------- 3. real-JAX fleet
def real_engine_section(quick: bool = False):
    from repro.configs import get_reduced_config
    from repro.core.gateway.gateway import Gateway
    from repro.engine.engine import EngineConfig, InferenceEngine
    from repro.engine.request import Request, SamplingParams

    cfg = get_reduced_config("qwen3-0.6b")
    ecfg = EngineConfig(page_size=8, num_pages=64, max_batch=4,
                        max_pages_per_seq=16, chunk_size=16,
                        max_adapters=5)
    fleet = {f"engine-{i}": InferenceEngine(cfg, ecfg, seed=i)
             for i in range(2)}
    ctrl = LoRAController(min_replicas=1, max_replicas=2)
    n_adapters = 4 if quick else 6
    for i in range(n_adapters):
        ctrl.register(AdapterSpec(f"lora-{i}", cfg.name,
                                  requests_per_s=1.0 / (i + 1)))
    for eid in fleet:
        ctrl.add_pod(eid, capacity=ecfg.max_adapters - 1)
    gw = Gateway(policy="lora-affinity")
    for eid, eng in fleet.items():
        gw.register_engine(eid, eng)
    gw.attach_lora_controller(ctrl)
    ctrl.sync(fleet)

    rng = np.random.default_rng(0)
    heat = 1.0 / (np.arange(1, n_adapters + 1) ** 1.1)
    heat /= heat.sum()
    n_req = 8 if quick else 16
    reqs = []
    for _ in range(n_req):
        a = int(rng.choice(n_adapters, p=heat))
        r = Request(prompt_tokens=rng.integers(
                        0, cfg.vocab_size, 12).tolist(),
                    sampling=SamplingParams(max_new_tokens=4),
                    lora_adapter=f"lora-{a}")
        eid = gw.route(r.prompt_tokens, lora_adapter=r.lora_adapter)
        fleet[eid].submit(r)
        reqs.append(r)
    for eng in fleet.values():
        eng.run_until_idle()
    cold = sum(e.runner.adapter_loads for e in fleet.values())
    stall = sum(e.runner.adapter_load_s for e in fleet.values())
    finished = sum(1 for r in reqs if r.output_tokens)
    print(f"\nreal-jax,engines=2,adapters={n_adapters},requests={n_req}"
          f",finished={finished}"
          f",affinity_hit_rate={gw.stats.lora_affinity_hit_rate:.3f}"
          f",cold_loads={cold},cold_load_s={stall:.3f}")
    assert finished == n_req, "every routed request must finish"
    # the controller pre-placed the fleet, so routed requests land on a
    # resident pod far more often than the 1/2 an adapter-blind split
    # would give — and cold loads stay bounded by placement, not traffic
    assert gw.stats.lora_affinity_hit_rate >= 0.5
    assert cold <= n_adapters + ctrl.stats["loads"]
    return gw.stats


def main(quick: bool = False):
    plan = planner_section(quick)
    serving_section(quick)
    real_engine_section(quick)
    return plan


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
