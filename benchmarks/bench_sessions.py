"""Million-session serving: sticky sessions, SSD KV tier, event core.

Four scenarios on the cluster simulator, all driven by the lazy
``multi_round_qa`` trace (zipf-depth conversations, lognormal
think-times, growing shared prefixes):

1. ``scale``   — the headline: ≥100k concurrent sessions (~1M total in
   full mode) under ``routing_policy="session"`` with request
   retention off, reporting sessions/s, TTFT attainment and sim
   events/wall-second.  Memory stays flat: the trace is generated
   lazily and every finished Request streams into a StreamingSummary.
2. ``routing`` — session-sticky routing vs a prefix-affinity-blind
   baseline (least-request) on the same trace: stickiness converts
   each round's growing conversation prefix into cache hits.
3. ``ssd``     — host-DRAM-starved fleet with and without the SSD
   write-behind tier: idle-session prefixes survive host pressure on
   SSD instead of falling to recompute, so resumed turns keep their
   TTFT advantage.
4. ``event-core`` — same trace through the modern loop vs a faithful
   reconstruction of the pre-PR hot path (per-route re-sorted engine
   views, full EngineMetrics builds per engine per route, the
   unconditional scrape pump, retained requests, per-event full-fleet
   done() scans).  The headline is events/wall-second.
"""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.gateway.router import RoutingPolicy
from repro.core.sim import ClusterConfig, ServingCluster, SimEngineConfig
from repro.core.sim.workloads import multi_round_qa

ARCH = "deepseek-coder-7b"


class _PrePRPrefixLoad(RoutingPolicy):
    """The pre-PR prefix-load hot path, preserved verbatim for the
    event-core A/B: sort the fleet on every call and build the full
    EngineMetrics (windowed throughput, SLO stats) per engine."""
    name = "prefix-load-prepr"

    def __init__(self, load_weight: float = 0.02):
        self.load_weight = load_weight

    def select(self, engines, tokens, lora_adapter=None,
               priority_class="standard", session_id=None):
        n = max(len(tokens), 1)
        best, best_score = None, -1e18
        for eid in sorted(engines):
            e = engines[eid]
            m = e.metrics()
            cov = e.match_prefix_len(tokens) / n
            score = cov - self.load_weight * (m.num_running
                                              + m.num_waiting)
            if score > best_score:
                best, best_score = eid, score
        return best


def _legacyize(cluster: ServingCluster) -> None:
    """Reconstruct the pre-PR event loop on a live cluster."""
    cluster.gateway.cache_routable = False
    cluster.gateway.policy = _PrePRPrefixLoad()
    for e in cluster.engines.values():
        e.on_busy_changed = None          # done() falls to full scans
    cluster._busy_engines = 0
    cluster.loop.every(cluster.ccfg.scrape_period_s, cluster._scrape)


def _cluster(policy: str, engines: int, retain: bool = True,
             **ecfg_kw) -> ServingCluster:
    cfg = get_config(ARCH)
    ekw = dict(device_type="a10", max_batch=48, chunk_size=512,
               mixed_batching=True)
    ekw.update(ecfg_kw)
    ccfg = ClusterConfig(routing_policy=policy, num_engines=engines,
                         engine=SimEngineConfig(**ekw),
                         retain_requests=retain,
                         ttft_slo_s={"standard": 1.0})
    return ServingCluster(cfg, ccfg)


# ------------------------------------------------------------ scenario 1
def _run_scale(quick: bool) -> dict:
    # sized so the fleet runs near (not past) capacity: one a10 sim
    # engine sustains ~25 rps of this trace shape, and concurrency =
    # session rate x mean session lifetime (~0.8 think-gaps/session)
    n_sessions = 120_000 if quick else 1_000_000
    rate = 450.0 if quick else 1200.0
    cl = _cluster("session", engines=96 if quick else 256,
                  retain=False, host_cache_gb=2.0)
    tstats: dict = {}
    wl = multi_round_qa(n_sessions, rate, seed=3, rounds_max=4,
                        zipf_s=1.3, think_time_s=280.0 if quick
                        else 420.0, sys_prompt=24,
                        turn_tokens=12, output_tokens=4, stats=tstats)
    t0 = time.time()
    s = cl.run(wl, drain_s=120.0)
    wall = max(time.time() - t0, 1e-9)
    return dict(mode="quick" if quick else "full",
                sessions=n_sessions,
                peak_open_sessions=tstats.get("peak_open_sessions", 0),
                finished=s["finished"],
                sessions_per_s=n_sessions / s["completion_time_s"],
                ttft_avg_ms=s["ttft_avg_ms"],
                ttft_attainment=s.get("ttft_attainment", 0.0),
                session_hits=s["session_hits"],
                prefix_hit_tokens=s["prefix_hit_tokens"],
                sim_events=s["sim_events"],
                events_per_wall_s=s["sim_events"] / wall,
                wall_s=wall)


# ------------------------------------------------------------ scenario 2
def _run_routing(policy: str, quick: bool) -> dict:
    cl = _cluster(policy, engines=8, retain=False)
    wl = multi_round_qa(1200 if quick else 4000, 60.0, seed=7,
                        rounds_max=6, think_time_s=8.0, sys_prompt=256,
                        turn_tokens=64, output_tokens=16)
    s = cl.run(wl, drain_s=120.0)
    return dict(mode=policy, finished=s["finished"],
                ttft_avg_ms=s["ttft_avg_ms"],
                ttft_attainment=s.get("ttft_attainment", 0.0),
                prefix_hit_rate=s["prefix_hit_tokens"]
                / max(s["prompt_tokens"], 1),
                session_hits=s.get("session_hits", 0))


# ------------------------------------------------------------ scenario 3
def _run_ssd(ssd_gb: float, quick: bool) -> dict:
    # device KV pinned small + a host tier too small for the working
    # set: between rounds a session's pages cascade device -> host ->
    # SSD, and the next round's prefix walk either hits SSD or pays
    # full recompute
    cl = _cluster("session", engines=2, num_pages=128,
                  host_cache_gb=0.05, ssd_cache_gb=ssd_gb)
    wl = multi_round_qa(120 if quick else 300, 2.5, seed=11,
                        rounds_max=5, think_time_s=15.0,
                        sys_prompt=600, turn_tokens=100,
                        output_tokens=48)
    s = cl.run(wl, drain_s=240.0)
    return dict(mode=f"ssd={ssd_gb:g}GB" if ssd_gb else "no-ssd",
                finished=s["finished"],
                ttft_avg_ms=s["ttft_avg_ms"],
                ttft_p99_ms=s["ttft_p99_ms"],
                host_hit_tokens=s["host_hit_tokens"],
                ssd_hit_tokens=s["ssd_hit_tokens"],
                prefix_hit_tokens=s["prefix_hit_tokens"])


# ------------------------------------------------------------ scenario 4
def _run_loop(legacy: bool, quick: bool) -> dict:
    # pre-PR arm retains every Request (it had no streaming summary);
    # the modern arm streams finishes out
    cl = _cluster("prefix-load", engines=16, retain=legacy)
    if legacy:
        _legacyize(cl)
    wl = multi_round_qa(3000 if quick else 12000, 300.0, seed=3,
                        rounds_max=4, think_time_s=10.0, sys_prompt=32,
                        turn_tokens=16, output_tokens=4)
    t0 = time.time()
    s = cl.run(wl, drain_s=60.0)
    wall = max(time.time() - t0, 1e-9)
    return dict(mode="pre-PR-loop" if legacy else "event-core",
                finished=s["finished"], sim_events=s["sim_events"],
                wall_s=wall, events_per_wall_s=s["sim_events"] / wall)


def _print(title: str, rows: list) -> None:
    keys = [k for k in rows[0] if k != "mode"]
    print(f"{title}: mode," + ",".join(keys))
    for r in rows:
        print("  " + str(r["mode"]) + "," + ",".join(
            f"{r[k]:.1f}" if isinstance(r[k], float) else str(r[k])
            for k in keys))


def main(quick: bool = False):
    out = {}
    row = _run_scale(quick)
    _print("session scale (sticky routing, streaming summary)", [row])
    print(f"  derived,sessions_per_s={row['sessions_per_s']:.0f}"
          f",events_per_wall_s={row['events_per_wall_s']:.0f}")
    out["scale"] = [row]

    rows = [_run_routing("least-request", quick),
            _run_routing("session", quick)]
    _print("sticky vs prefix-blind routing", rows)
    blind, sticky = rows
    print(f"  derived,prefix_hit_rate_gain="
          f"{sticky['prefix_hit_rate'] - blind['prefix_hit_rate']:.3f}"
          f",ttft_reduction_pct="
          f"{100*(1-sticky['ttft_avg_ms']/max(blind['ttft_avg_ms'],1e-9)):.1f}")
    out["routing"] = rows

    rows = [_run_ssd(0.0, quick), _run_ssd(8.0, quick)]
    _print("SSD write-behind tier (host DRAM starved)", rows)
    off, on = rows
    print(f"  derived,ssd_hit_tokens={on['ssd_hit_tokens']}"
          f",resumed_ttft_reduction_pct="
          f"{100*(1-on['ttft_avg_ms']/max(off['ttft_avg_ms'],1e-9)):.1f}")
    out["ssd"] = rows

    rows = [_run_loop(True, quick), _run_loop(False, quick)]
    _print("event core (same trace)", rows)
    old, new = rows
    print(f"  derived,loop_speedup="
          f"{new['events_per_wall_s']/max(old['events_per_wall_s'],1e-9):.1f}x")
    out["loop"] = rows
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced scale (CI smoke; still >=100k sessions)")
    main(quick=ap.parse_args().quick)
