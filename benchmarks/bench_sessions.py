"""Million-session serving: sticky sessions, SSD KV tier, event core.

Six scenarios on the cluster simulator, all driven by the lazy
``multi_round_qa`` trace (zipf-depth conversations, lognormal
think-times, growing shared prefixes):

1. ``scale``   — the headline: ≥100k concurrent sessions (~1M total in
   full mode) under ``routing_policy="session"`` with request
   retention off, reporting sessions/s, TTFT attainment and sim
   events/wall-second.  Memory stays flat: the trace is generated
   lazily and every finished Request streams into a StreamingSummary.
2. ``routing`` — session-sticky routing vs a prefix-affinity-blind
   baseline (least-request) on the same trace: stickiness converts
   each round's growing conversation prefix into cache hits.
3. ``ssd``     — host-DRAM-starved fleet with and without the SSD
   write-behind tier: idle-session prefixes survive host pressure on
   SSD instead of falling to recompute, so resumed turns keep their
   TTFT advantage.
4. ``shared-ssd`` — per-engine SSD pools vs ONE host-shared
   content-addressed pool under a shared system prompt: the shared
   pool dedupes the fleet's common pages (one write instead of N) and
   serves them back to engines that never computed them
   (cross-engine SSD hits).
5. ``promotion`` — PR 9 SSD-on baseline vs predictive promotion: the
   session policy's think-time EWMA prefetches a returning session's
   SSD pages into host DRAM before the turn lands, taking the SSD
   read off the resumed turn's critical path.
6. ``event-core`` — same trace through the modern loop vs a faithful
   reconstruction of the pre-PR hot path (per-route re-sorted engine
   views, full EngineMetrics builds per engine per route, the
   unconditional scrape pump, retained requests, per-event full-fleet
   done() scans).  The headline is events/wall-second.
"""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.gateway.router import RoutingPolicy
from repro.core.sim import ClusterConfig, ServingCluster, SimEngineConfig
from repro.core.sim.workloads import multi_round_qa

ARCH = "deepseek-coder-7b"


class _PrePRPrefixLoad(RoutingPolicy):
    """The pre-PR prefix-load hot path, preserved verbatim for the
    event-core A/B: sort the fleet on every call and build the full
    EngineMetrics (windowed throughput, SLO stats) per engine."""
    name = "prefix-load-prepr"

    def __init__(self, load_weight: float = 0.02):
        self.load_weight = load_weight

    def select(self, engines, tokens, lora_adapter=None,
               priority_class="standard", session_id=None):
        n = max(len(tokens), 1)
        best, best_score = None, -1e18
        for eid in sorted(engines):
            e = engines[eid]
            m = e.metrics()
            cov = e.match_prefix_len(tokens) / n
            score = cov - self.load_weight * (m.num_running
                                              + m.num_waiting)
            if score > best_score:
                best, best_score = eid, score
        return best


def _legacyize(cluster: ServingCluster) -> None:
    """Reconstruct the pre-PR event loop on a live cluster."""
    cluster.gateway.cache_routable = False
    cluster.gateway.policy = _PrePRPrefixLoad()
    for e in cluster.engines.values():
        e.on_busy_changed = None          # done() falls to full scans
    cluster._busy_engines = 0
    cluster.loop.every(cluster.ccfg.scrape_period_s, cluster._scrape)


def _cluster(policy: str, engines: int, retain: bool = True,
             ccfg_kw: dict = None, **ecfg_kw) -> ServingCluster:
    cfg = get_config(ARCH)
    ekw = dict(device_type="a10", max_batch=48, chunk_size=512,
               mixed_batching=True)
    ekw.update(ecfg_kw)
    ccfg = ClusterConfig(routing_policy=policy, num_engines=engines,
                         engine=SimEngineConfig(**ekw),
                         retain_requests=retain,
                         ttft_slo_s={"standard": 1.0},
                         **(ccfg_kw or {}))
    return ServingCluster(cfg, ccfg)


# ------------------------------------------------------------ scenario 1
def _run_scale(quick: bool) -> dict:
    # sized so the fleet runs near (not past) capacity: one a10 sim
    # engine sustains ~25 rps of this trace shape, and concurrency =
    # session rate x mean session lifetime (~0.8 think-gaps/session)
    n_sessions = 120_000 if quick else 1_000_000
    rate = 450.0 if quick else 1200.0
    cl = _cluster("session", engines=96 if quick else 256,
                  retain=False, host_cache_gb=2.0)
    tstats: dict = {}
    wl = multi_round_qa(n_sessions, rate, seed=3, rounds_max=4,
                        zipf_s=1.3, think_time_s=280.0 if quick
                        else 420.0, sys_prompt=24,
                        turn_tokens=12, output_tokens=4, stats=tstats)
    t0 = time.time()
    s = cl.run(wl, drain_s=120.0)
    wall = max(time.time() - t0, 1e-9)
    return dict(mode="quick" if quick else "full",
                sessions=n_sessions,
                peak_open_sessions=tstats.get("peak_open_sessions", 0),
                finished=s["finished"],
                sessions_per_s=n_sessions / s["completion_time_s"],
                ttft_avg_ms=s["ttft_avg_ms"],
                ttft_attainment=s.get("ttft_attainment", 0.0),
                session_hits=s["session_hits"],
                prefix_hit_tokens=s["prefix_hit_tokens"],
                sim_events=s["sim_events"],
                events_per_wall_s=s["sim_events"] / wall,
                wall_s=wall)


# ------------------------------------------------------------ scenario 2
def _run_routing(policy: str, quick: bool) -> dict:
    cl = _cluster(policy, engines=8, retain=False)
    wl = multi_round_qa(1200 if quick else 4000, 60.0, seed=7,
                        rounds_max=6, think_time_s=8.0, sys_prompt=256,
                        turn_tokens=64, output_tokens=16)
    s = cl.run(wl, drain_s=120.0)
    return dict(mode=policy, finished=s["finished"],
                ttft_avg_ms=s["ttft_avg_ms"],
                ttft_attainment=s.get("ttft_attainment", 0.0),
                prefix_hit_rate=s["prefix_hit_tokens"]
                / max(s["prompt_tokens"], 1),
                session_hits=s.get("session_hits", 0))


# ------------------------------------------------------------ scenario 3
def _run_ssd(ssd_gb: float, quick: bool) -> dict:
    # device KV pinned small + a host tier too small for the working
    # set: between rounds a session's pages cascade device -> host ->
    # SSD, and the next round's prefix walk either hits SSD or pays
    # full recompute
    cl = _cluster("session", engines=2, num_pages=128,
                  host_cache_gb=0.05, ssd_cache_gb=ssd_gb)
    wl = multi_round_qa(120 if quick else 300, 2.5, seed=11,
                        rounds_max=5, think_time_s=15.0,
                        sys_prompt=600, turn_tokens=100,
                        output_tokens=48)
    s = cl.run(wl, drain_s=240.0)
    return dict(mode=f"ssd={ssd_gb:g}GB" if ssd_gb else "no-ssd",
                finished=s["finished"],
                ttft_avg_ms=s["ttft_avg_ms"],
                ttft_p99_ms=s["ttft_p99_ms"],
                host_hit_tokens=s["host_hit_tokens"],
                ssd_hit_tokens=s["ssd_hit_tokens"],
                prefix_hit_tokens=s["prefix_hit_tokens"])


# ------------------------------------------------------------ scenario 4
def _run_shared_ssd(shared: bool, quick: bool) -> dict:
    # every session opens with the SAME system prompt (shared_sys) and
    # routing is affinity-blind (least-request), so a resumed turn
    # regularly lands on an engine that never computed its prefix:
    # per-engine SSD pools miss (full recompute) and each engine writes
    # its own copy of the common pages, while the host-shared pool
    # serves them cross-engine and absorbs the duplicate writes
    cl = _cluster("least-request", engines=4, num_pages=128,
                  host_cache_gb=0.05, ssd_cache_gb=2.0,
                  ccfg_kw=dict(ssd_shared=shared, engines_per_host=4))
    wl = multi_round_qa(120 if quick else 300, 3.0, seed=13,
                        rounds_max=4, think_time_s=15.0,
                        sys_prompt=600, turn_tokens=100,
                        output_tokens=48, shared_sys=True)
    s = cl.run(wl, drain_s=240.0)
    return dict(mode="host-shared" if shared else "per-engine",
                finished=s["finished"],
                ttft_avg_ms=s["ttft_avg_ms"],
                ssd_hit_tokens=s["ssd_hit_tokens"],
                ssd_cross_hit_tokens=s.get("ssd_cross_hit_tokens", 0),
                ssd_puts=s.get("ssd_puts", 0),
                ssd_bytes_written=s.get("ssd_bytes_written", 0),
                ssd_dedup_puts=s.get("ssd_dedup_puts", 0),
                dedupe_ratio=s.get("ssd_dedupe_ratio", 0.0))


# ------------------------------------------------------------ scenario 5
def _run_promotion(lead_s: float, quick: bool) -> dict:
    # PR 9 SSD-on baseline (lead=0) vs predictive promotion: the
    # session policy's think-time EWMA fires a background prefetch
    # ``lead_s`` before the predicted turn, so the resumed prefix walk
    # hits host DRAM instead of paying the SSD read on the critical
    # path.  Agent-loop cadence (think_sigma=0.25): promotion targets
    # workloads whose turn arrivals are predictable; the host tier is
    # sized so a prefetched page survives the residual prediction
    # error, while idle sessions still spill to SSD between turns
    cl = _cluster("session", engines=2, num_pages=128,
                  host_cache_gb=8.0, ssd_cache_gb=16.0,
                  ccfg_kw=dict(promote_lead_s=lead_s,
                               promote_poll_period_s=0.5))
    wl = multi_round_qa(120 if quick else 300, 1.5, seed=11,
                        rounds_max=5, think_time_s=15.0,
                        sys_prompt=600, turn_tokens=100,
                        output_tokens=48, think_sigma=0.25)
    s = cl.run(wl, drain_s=240.0)
    return dict(mode=f"promote lead={lead_s:g}s" if lead_s
                else "ssd-on (PR9)",
                finished=s["finished"],
                ttft_avg_ms=s["ttft_avg_ms"],
                ttft_p99_ms=s["ttft_p99_ms"],
                host_hit_tokens=s["host_hit_tokens"],
                ssd_hit_tokens=s["ssd_hit_tokens"],
                promotions=s.get("promotions", 0),
                promote_hits=s.get("promote_hits", 0),
                promote_wasted=s.get("promote_wasted", 0))


# ------------------------------------------------------------ scenario 6
def _run_loop(legacy: bool, quick: bool) -> dict:
    # pre-PR arm retains every Request (it had no streaming summary);
    # the modern arm streams finishes out
    cl = _cluster("prefix-load", engines=16, retain=legacy)
    if legacy:
        _legacyize(cl)
    wl = multi_round_qa(3000 if quick else 12000, 300.0, seed=3,
                        rounds_max=4, think_time_s=10.0, sys_prompt=32,
                        turn_tokens=16, output_tokens=4)
    t0 = time.time()
    s = cl.run(wl, drain_s=60.0)
    wall = max(time.time() - t0, 1e-9)
    return dict(mode="pre-PR-loop" if legacy else "event-core",
                finished=s["finished"], sim_events=s["sim_events"],
                wall_s=wall, events_per_wall_s=s["sim_events"] / wall)


def _print(title: str, rows: list) -> None:
    keys = [k for k in rows[0] if k != "mode"]
    print(f"{title}: mode," + ",".join(keys))
    for r in rows:
        print("  " + str(r["mode"]) + "," + ",".join(
            f"{r[k]:.1f}" if isinstance(r[k], float) else str(r[k])
            for k in keys))


def main(quick: bool = False):
    out = {}
    row = _run_scale(quick)
    _print("session scale (sticky routing, streaming summary)", [row])
    print(f"  derived,sessions_per_s={row['sessions_per_s']:.0f}"
          f",events_per_wall_s={row['events_per_wall_s']:.0f}")
    out["scale"] = [row]

    rows = [_run_routing("least-request", quick),
            _run_routing("session", quick)]
    _print("sticky vs prefix-blind routing", rows)
    blind, sticky = rows
    print(f"  derived,prefix_hit_rate_gain="
          f"{sticky['prefix_hit_rate'] - blind['prefix_hit_rate']:.3f}"
          f",ttft_reduction_pct="
          f"{100*(1-sticky['ttft_avg_ms']/max(blind['ttft_avg_ms'],1e-9)):.1f}")
    out["routing"] = rows

    rows = [_run_ssd(0.0, quick), _run_ssd(8.0, quick)]
    _print("SSD write-behind tier (host DRAM starved)", rows)
    off, on = rows
    print(f"  derived,ssd_hit_tokens={on['ssd_hit_tokens']}"
          f",resumed_ttft_reduction_pct="
          f"{100*(1-on['ttft_avg_ms']/max(off['ttft_avg_ms'],1e-9)):.1f}")
    out["ssd"] = rows

    rows = [_run_shared_ssd(False, quick), _run_shared_ssd(True, quick)]
    _print("host-shared SSD pool (shared system prompt)", rows)
    per_eng, host = rows
    saved = per_eng["ssd_bytes_written"] - host["ssd_bytes_written"]
    print(f"  derived,cross_engine_ssd_hit_tokens="
          f"{host['ssd_cross_hit_tokens']}"
          f",dedupe_ratio={host['dedupe_ratio']:.2f}"
          f",ssd_write_bytes_saved_pct="
          f"{100 * saved / max(per_eng['ssd_bytes_written'], 1):.1f}")
    assert host["ssd_cross_hit_tokens"] > 0, \
        "host-shared pool produced no cross-engine SSD hits"
    assert host["ssd_bytes_written"] < per_eng["ssd_bytes_written"], \
        "host-shared pool did not reduce total SSD bytes written"
    out["shared-ssd"] = rows

    rows = [_run_promotion(0.0, quick), _run_promotion(4.0, quick)]
    _print("predictive KV promotion (think-time EWMA prefetch)", rows)
    base, promo = rows
    waste_frac = promo["promote_wasted"] / max(
        promo["promote_wasted"] + promo["promote_hits"], 1)
    print(f"  derived,promote_hits={promo['promote_hits']}"
          f",promote_waste_frac={waste_frac:.2f}"
          f",resumed_ttft_reduction_pct="
          f"{100*(1-promo['ttft_avg_ms']/max(base['ttft_avg_ms'],1e-9)):.1f}")
    assert promo["promote_hits"] > 0, "promotion never hit"
    # waste stays bounded: most of it is sessions that simply never
    # return (the predictor cannot know a conversation ended), so the
    # bar is "not everything is wasted", not "no waste"
    assert waste_frac < 0.9, \
        f"promotion waste fraction {waste_frac:.2f} unbounded"
    assert promo["ttft_avg_ms"] < base["ttft_avg_ms"], \
        "promotion did not cut resumed-turn TTFT"
    out["promotion"] = rows

    rows = [_run_loop(True, quick), _run_loop(False, quick)]
    _print("event core (same trace)", rows)
    old, new = rows
    print(f"  derived,loop_speedup="
          f"{new['events_per_wall_s']/max(old['events_per_wall_s'],1e-9):.1f}x")
    out["loop"] = rows
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced scale (CI smoke; still >=100k sessions)")
    ap.add_argument("--only", choices=["scale"], default=None,
                    help="run a single scenario (nightly guard lane)")
    ap.add_argument("--max-wall-s", type=float, default=0.0,
                    help="fail if the scale scenario exceeds this "
                         "wall-clock budget")
    ap.add_argument("--min-events-per-wall-s", type=float, default=0.0,
                    help="fail if the scale scenario's event-core "
                         "throughput regresses below this floor")
    args = ap.parse_args()
    if args.only == "scale":
        row = _run_scale(args.quick)
        _print("session scale (sticky routing, streaming summary)",
               [row])
        print(f"  derived,sessions_per_s={row['sessions_per_s']:.0f}"
              f",events_per_wall_s={row['events_per_wall_s']:.0f}"
              f",wall_s={row['wall_s']:.0f}")
        if args.max_wall_s and row["wall_s"] > args.max_wall_s:
            raise SystemExit(
                f"scale scenario took {row['wall_s']:.0f}s "
                f"(budget {args.max_wall_s:.0f}s)")
        if (args.min_events_per_wall_s
                and row["events_per_wall_s"]
                < args.min_events_per_wall_s):
            raise SystemExit(
                f"event core at {row['events_per_wall_s']:.0f} "
                f"events/wall-s (regression floor "
                f"{args.min_events_per_wall_s:.0f})")
    else:
        main(quick=args.quick)
