"""SLO-driven heterogeneous serving (paper §3.2.7, Figures 7-8).

(a) Fig 7 reproduction: cost-per-request by device x workload bucket —
    small requests favor A10, large favor L20.
(b) Fig 8 / experiment: ShareGPT + Text2SQL mixed demand; ILP-optimized
    heterogeneous allocation vs homogeneous L20: paper reports ~10% cost
    reduction at <= +20% latency within SLO.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.optimizer import (DEVICES, GPUOptimizer, LoadMonitor,
                                  ProfileTable, WorkloadBucket,
                                  homogeneous_cost)
from repro.core.optimizer.gpu_optimizer import DemandBucket
from repro.core.optimizer.profiles import PerfModel


def fig7_cost_matrix():
    cfg = get_config("deepseek-coder-7b")
    table = ProfileTable(cfg, slo_ttft_s=5.0, slo_itl_s=0.25)
    buckets = [WorkloadBucket(150, 50), WorkloadBucket(600, 100),
               WorkloadBucket(2000, 300), WorkloadBucket(6000, 400)]
    print("bucket(in,out)," + ",".join(d for d in ("a10", "l20", "v100")))
    rows = []
    for b in buckets:
        costs = {d: table.cost_per_request(d, b) * 1e6
                 for d in ("a10", "l20", "v100")}
        rows.append((b.key, costs))
        print(f"({b.in_len};{b.out_len})," +
              ",".join(f"{costs[d]:.2f}" for d in ("a10", "l20", "v100")))
    small_pref = min(rows[0][1], key=rows[0][1].get)
    large_pref = min(rows[2][1], key=rows[2][1].get)
    print(f"derived,small_bucket_prefers={small_pref}"
          f",large_bucket_prefers={large_pref}")
    return rows


def slo_allocation(quick: bool = False):
    cfg = get_config("deepseek-coder-7b")
    table = ProfileTable(cfg, slo_ttft_s=5.0, slo_itl_s=0.25)
    # ShareGPT-like (small) + Text2SQL-like (large prompt) mixed demand
    demand = [
        DemandBucket(WorkloadBucket(150, 60), 14.0),    # chat small
        DemandBucket(WorkloadBucket(450, 150), 6.0),    # chat medium
        DemandBucket(WorkloadBucket(1800, 40), 4.0),    # text2sql
        DemandBucket(WorkloadBucket(4000, 80), 1.0),    # long analysis
    ]
    opt = GPUOptimizer(table, ("a10", "l20", "v100"),
                       availability={"v100": 4})
    alloc = opt.optimize(demand)
    n_l20, cost_l20 = homogeneous_cost(table, demand, "l20")
    n_a10, cost_a10 = homogeneous_cost(table, demand, "a10")
    # latency proxy under each allocation: weighted request time at the
    # batch level each device uses for the bucket
    def latency(dev_mix):
        tot_rps = sum(d.rps for d in demand)
        t = 0.0
        for d in demand:
            if isinstance(dev_mix, str):
                dev = dev_mix
            else:
                cands = [g for (bk, g), v in alloc.assignment.items()
                         if bk == d.bucket.key]
                dev = cands[0] if cands else "l20"
            pm = PerfModel(cfg, DEVICES[dev])
            t += d.rps / tot_rps * pm.request_time(d.bucket, batch=8)
        return t

    lat_het = latency(alloc.assignment)
    lat_hom = latency("l20")
    print("allocation,counts,cost_per_hour,latency_proxy_s")
    print(f"heterogeneous,{alloc.counts},{alloc.cost_per_hour:.2f}"
          f",{lat_het:.2f}")
    print(f"homogeneous-l20,{{'l20': {n_l20}}},{cost_l20:.2f},{lat_hom:.2f}")
    print(f"homogeneous-a10,{{'a10': {n_a10}}},{cost_a10:.2f},-")
    saving = 100 * (1 - alloc.cost_per_hour / cost_l20)
    lat_delta = 100 * (lat_het / lat_hom - 1)
    print(f"derived,cost_reduction_vs_l20_pct={saving:.1f}"
          f",latency_delta_pct={lat_delta:.1f}")
    return alloc, (cost_l20, cost_a10)


def main(quick: bool = False):
    rows = fig7_cost_matrix()
    alloc = slo_allocation(quick)
    return rows, alloc


if __name__ == "__main__":
    main()
