"""Speculative n-gram decoding + the async overlapped engine loop.

Real JAX engine (CPU/interpret in this container), two workloads:

1. ``repetitive``  — prompts built from a repeated token motif, the
   case prompt-lookup drafting is designed for (summarization, code
   edits, quoting chat).  Target: >= 1.5x decode tokens/s over the
   non-speculative engine with BYTE-IDENTICAL greedy outputs.
2. ``adversarial`` — uniform-random prompts where the trailing n-gram
   almost never recurs, so drafting can only lose.  The adaptive
   acceptance-EWMA backoff (full -> 1 -> 0 drafts + periodic probe)
   must bound the regression to <= 5%.

A third section times the async overlapped loop (dispatch step N+1's
host scheduling + input prep while step N runs on device) on the
repetitive workload, reporting wall time and the engine's measured
device-wait / host-overhead split, again pinned byte-identical.

Speedups here are REAL measured wall-clock on the tiny reduced model;
absolute tokens/s are not TPU numbers, but the spec-on/spec-off ratio
exercises exactly the production step pipeline (fused verification
pass, budget-last drafting, EWMA backoff).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.configs import get_reduced_config
from repro.engine.engine import EngineConfig, InferenceEngine
from repro.engine.request import Request, SamplingParams

ARCH = "qwen3-0.6b"
MOTIF = [11, 23, 5, 17]


def _workload(kind: str, n: int, prompt_len: int,
              vocab: int, seed: int = 0) -> List[List[int]]:
    rng = np.random.default_rng(seed)
    prompts = []
    for i in range(n):
        if kind == "repetitive":
            # a per-request motif repeated to prompt_len: the trailing
            # n-gram always has an earlier occurrence to continue
            motif = [int(t) for t in rng.integers(0, vocab, 4)]
            reps = -(-prompt_len // len(motif))
            prompts.append((motif * reps)[:prompt_len])
        else:
            prompts.append([int(t) for t in
                            rng.integers(0, vocab, prompt_len)])
    return prompts


@dataclass
class RunResult:
    wall_s: float
    tokens: int
    outs: Dict[str, List[int]]
    acceptance: float
    drafted: int
    device_wait_s: float
    host_overhead_frac: float

    @property
    def tok_per_s(self) -> float:
        return self.tokens / max(self.wall_s, 1e-9)


def _run(cfg, prompts: List[List[int]], max_new: int,
         **ekw) -> RunResult:
    ecfg = EngineConfig(num_pages=256, max_batch=4, max_pages_per_seq=16,
                        chunk_size=32, **ekw)
    # warmup pass compiles every jitted shape this config will touch
    # (module-level jit caches carry over to the timed engine)
    warm = InferenceEngine(cfg, ecfg, seed=0)
    warm.submit(Request(request_id="w", prompt_tokens=list(prompts[0]),
                        sampling=SamplingParams(max_new_tokens=8)))
    warm.run_until_idle()
    eng = InferenceEngine(cfg, ecfg, seed=0)
    for i, p in enumerate(prompts):
        eng.submit(Request(
            request_id=f"r{i}", prompt_tokens=list(p),
            sampling=SamplingParams(max_new_tokens=max_new, seed=i)))
    t0 = time.perf_counter()
    eng.run_until_idle()
    wall = time.perf_counter() - t0
    m = eng.metrics()
    outs = {r.request_id: list(r.output_tokens) for r in eng.finished}
    return RunResult(wall, sum(len(o) for o in outs.values()), outs,
                     m.spec_acceptance, m.spec_drafted_tokens,
                     m.device_wait_s, m.host_overhead_frac)


def main(quick: bool = False):
    cfg = get_reduced_config(ARCH)
    n, max_new, plen = (4, 24, 16) if quick else (8, 48, 24)
    spec = 4
    print("workload,mode,tok_per_s,speedup,acceptance,identical")

    rep = _workload("repetitive", n, plen, cfg.vocab_size, seed=1)
    base = _run(cfg, rep, max_new)
    spec_on = _run(cfg, rep, max_new, spec_tokens=spec)
    ident = spec_on.outs == base.outs
    sp = spec_on.tok_per_s / max(base.tok_per_s, 1e-9)
    print(f"repetitive,spec_off,{base.tok_per_s:.1f},1.00,,")
    print(f"repetitive,spec_on,{spec_on.tok_per_s:.1f},{sp:.2f}x,"
          f"{spec_on.acceptance:.2f},{ident}")

    adv = _workload("adversarial", n, plen, cfg.vocab_size, seed=2)
    abase = _run(cfg, adv, max_new)
    aspec = _run(cfg, adv, max_new, spec_tokens=spec)
    aident = aspec.outs == abase.outs
    asp = aspec.tok_per_s / max(abase.tok_per_s, 1e-9)
    print(f"adversarial,spec_off,{abase.tok_per_s:.1f},1.00,,")
    print(f"adversarial,spec_on,{aspec.tok_per_s:.1f},{asp:.2f}x,"
          f"{aspec.acceptance:.2f},{aident}")

    # async overlapped loop: same repetitive workload, sync vs async
    asy = _run(cfg, rep, max_new, async_loop=True)
    print("\nloop,wall_s,tok_per_s,device_wait_s,host_frac,identical")
    print(f"sync,{base.wall_s:.2f},{base.tok_per_s:.1f},"
          f"{base.device_wait_s:.2f},{base.host_overhead_frac:.2f},")
    print(f"async,{asy.wall_s:.2f},{asy.tok_per_s:.1f},"
          f"{asy.device_wait_s:.2f},{asy.host_overhead_frac:.2f},"
          f"{asy.outs == base.outs}")

    ok_speed = sp >= 1.5
    ok_adv = asp >= 0.95
    print(f"\nspeculative speedup {sp:.2f}x "
          f"(target >=1.5x: {'OK' if ok_speed else 'MISS'}), "
          f"adversarial {asp:.2f}x "
          f"(floor >=0.95x: {'OK' if ok_adv else 'MISS'}), "
          f"greedy byte-identity: {ident and aident}")
    return [("spec_repetitive_speedup", sp),
            ("spec_adversarial_ratio", asp),
            ("spec_acceptance", spec_on.acceptance)]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI smoke)")
    main(quick=ap.parse_args().quick)
