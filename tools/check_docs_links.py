"""Docs link checker (CI docs step + tests/test_docs.py).

Fails when a markdown file contains a relative link whose target does
not exist on disk.  External links (http/https/mailto) and pure
in-page anchors are skipped — this is a repo-integrity check, not a
web crawler.

Usage: ``python tools/check_docs_links.py README.md docs/*.md``
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) and [text](target#anchor); skips images' alt text
# distinction (same syntax) and reference-style links (unused here)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")
EXTERNAL = re.compile(r"^(?:[a-z][a-z0-9+.-]*:)//|^mailto:")


def broken_links(md_path: Path) -> list:
    """(source, target) pairs whose relative target doesn't exist."""
    bad = []
    for m in LINK_RE.finditer(md_path.read_text()):
        target = m.group(1)
        if EXTERNAL.match(target):
            continue
        resolved = (md_path.parent / target).resolve()
        if not resolved.exists():
            bad.append((str(md_path), target))
    return bad


def main(paths) -> int:
    files = [Path(p) for p in paths]
    missing = [p for p in files if not p.exists()]
    if missing:
        print(f"docs check: missing file(s): {[str(p) for p in missing]}")
        return 1
    bad = [b for p in files for b in broken_links(p)]
    for src, target in bad:
        print(f"docs check: broken link in {src}: ({target})")
    if bad:
        return 1
    print(f"docs check: {len(files)} file(s), all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["README.md"]))
