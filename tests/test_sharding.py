"""Logical-axis sharding rules: divisibility fallback, axis-reuse
guards, FSDP toggling, and the long-context rule variant."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import sharding
from repro.models.sharding import (DEFAULT_RULES, LONG_CONTEXT_RULES,
                                   ShardingCtx)


class FakeMesh:
    """Duck-typed mesh: only .shape is consulted by ShardingCtx."""
    def __init__(self, shape):
        self.shape = shape


def _ctx(shape=None, rules=DEFAULT_RULES, **kw):
    return ShardingCtx(FakeMesh(shape or {"data": 16, "model": 16}),
                       rules, **kw)


def test_batch_shards_over_data():
    ctx = _ctx()
    assert ctx.spec_for((256, 4096), ("batch", "seq")) == P("data", "model")


def test_multipod_batch_uses_pod_and_data():
    ctx = _ctx({"pod": 2, "data": 16, "model": 16})
    spec = ctx.spec_for((256, 4096), ("batch", None))
    assert spec == P(("pod", "data"))


def test_divisibility_fallback_replicates():
    ctx = _ctx()
    # kv_heads=2 not divisible by model=16 -> cache_seq picks up model;
    # batch=8 not divisible by data=16 -> replicated batch
    spec = ctx.spec_for((8, 1024, 2, 64),
                        ("batch", "cache_seq", "kv_heads", None))
    assert tuple(spec) == (None, "model")
    # kv_heads=32 divisible -> kv_heads wins (higher priority than seq)
    spec2 = ctx.spec_for((32, 1024, 32, 64),
                         ("batch", "cache_seq", "kv_heads", None))
    assert spec2[0] == "data" and spec2[2] == "model"
    assert spec2[1] is None


def test_no_mesh_axis_used_twice():
    ctx = _ctx()
    # heads and mlp both want model: only one gets it
    spec = ctx.spec_for((64, 4096), ("heads", "mlp"))
    got = [s for s in spec if s is not None]
    assert got.count("model") <= 1


def test_fsdp_toggle():
    on = _ctx()
    off = _ctx(fsdp=False)
    axes = ("embed", "mlp")
    assert on.spec_for((4096, 11008), axes) == P("data", "model")
    s_off = off.spec_for((4096, 11008), axes)
    assert s_off == P(None, "model") or s_off == P("model")


def test_long_context_rules_shard_cache_seq_wide():
    ctx = ShardingCtx(FakeMesh({"pod": 2, "data": 16, "model": 16}),
                      LONG_CONTEXT_RULES)
    spec = ctx.spec_for((1, 524288, 8, 64),
                        ("batch", "cache_seq", "kv_heads", None))
    assert spec[1] == ("pod", "data")


def test_constrain_noop_without_context():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert sharding.constrain(x, ("batch", None)) is x


def test_data_shards_property():
    assert _ctx().data_shards == 16
    assert ShardingCtx(FakeMesh({"pod": 2, "data": 16, "model": 16}),
                       DEFAULT_RULES).data_shards == 32
    assert ShardingCtx(FakeMesh({}), DEFAULT_RULES).data_shards == 1
