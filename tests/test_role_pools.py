"""Role-aware control plane: dynamic P/D pools with live migration.

Covers the RolePoolManager drain/flip protocol on the simulator, the
attainment-driven rebalance loop converging in BOTH directions on a
phase-shifting scenario, per-pool autoscaler independence, the GPU
optimizer's split_roles planner, and — on the REAL JAX data plane —
that a mid-stream P->D role change yields byte-identical output to a
static topology (the PR-2 1P+1D smoke, extended with live migration).
"""
import time

import numpy as np
import pytest

from repro.configs import get_config, get_reduced_config
from repro.core.gateway.gateway import RateLimit
from repro.core.kvcache.pool import DistributedKVPool
from repro.core.optimizer.gpu_optimizer import DemandBucket, split_roles
from repro.core.optimizer.profiles import ProfileTable, WorkloadBucket
from repro.core.orchestration.pools import (AttainmentRebalancer,
                                            RebalanceConfig,
                                            RolePoolManager,
                                            parse_role_spec)
from repro.core.sim.cluster_sim import ClusterConfig, ServingCluster
from repro.core.sim.events import EventLoop
from repro.core.sim.sim_engine import SimEngine, SimEngineConfig
from repro.core.sim.workloads import phase_shift
from repro.engine import (EngineConfig, InferenceEngine, Request,
                          RequestState, SamplingParams)

ENGINE_KW = dict(page_size=8, num_pages=64, max_batch=4,
                 max_pages_per_seq=16, chunk_size=16)


# ---------------------------------------------------------------- parsing
def test_parse_role_spec():
    assert parse_role_spec("mixed", 3) == ["mixed"] * 3
    assert parse_role_spec("2P2D", 0) == ["prefill"] * 2 + ["decode"] * 2
    assert parse_role_spec("1p3d", 0) == ["prefill"] + ["decode"] * 3
    with pytest.raises(ValueError):
        parse_role_spec("0P2D", 0)
    with pytest.raises(ValueError):
        parse_role_spec("auto", 4)      # callers resolve 'auto' first


# ---------------------------------------------------------------- planner
def test_split_roles_directionality():
    """Prefill-heavy demand proposes more P, decode-heavy more D, and
    a fixed total is respected with at least one engine per role."""
    table = ProfileTable(get_config("deepseek-coder-7b"))
    heavy_p = split_roles(
        table, [DemandBucket(WorkloadBucket(1600, 24), 2.0)], "a10",
        total_engines=4, slo_ttft_s=0.5, slo_itl_s=0.05)
    heavy_d = split_roles(
        table, [DemandBucket(WorkloadBucket(96, 280), 2.0)], "a10",
        total_engines=4, slo_ttft_s=0.5, slo_itl_s=0.05)
    assert heavy_p.n_prefill + heavy_p.n_decode == 4
    assert heavy_d.n_prefill + heavy_d.n_decode == 4
    assert heavy_p.n_prefill > heavy_d.n_prefill
    assert heavy_p.n_prefill >= 1 and heavy_p.n_decode >= 1
    assert heavy_d.spec == f"{heavy_d.n_prefill}P{heavy_d.n_decode}D"
    # unconstrained sizing reflects the load directly
    free = split_roles(table,
                       [DemandBucket(WorkloadBucket(1600, 24), 8.0)],
                       "a10", slo_itl_s=0.05)
    assert free.n_prefill >= free.prefill_load


# ------------------------------------------------------- manager mechanics
def _sim_group(roles, loop=None, **eng_kw):
    cfg = get_config("deepseek-coder-7b")
    loop = loop or EventLoop()
    pool = DistributedKVPool(capacity_bytes=32 << 30, metadata_lag=0.002,
                             network_bw=100e9, clock=loop.clock)
    mgr = RolePoolManager(clock=loop.clock)
    kw = dict(device_type="a10", max_batch=16, chunk_size=512)
    kw.update(eng_kw)
    for i, role in enumerate(roles):
        sc = SimEngineConfig(role=role, **kw)
        eng = SimEngine(cfg, loop, sc, kv_pool=pool,
                        engine_id=f"engine-{i}", node=f"node-{i}")
        mgr.add_engine(f"engine-{i}", eng, role)
    return mgr, loop


def _sim_req(rng, plen=600, out=8):
    return Request(prompt_tokens=rng.integers(0, 32000, plen).tolist(),
                   sampling=SamplingParams(max_new_tokens=out))


def test_manager_migration_drains_and_flips():
    """P->D migration: the draining member admits nothing new, its
    queued work is re-delivered to the other prefill member, in-flight
    prefills finish through the pool handoff, and the role flips only
    once drained."""
    mgr, loop = _sim_group(["prefill", "prefill", "decode"])
    loop.every(0.25, lambda: mgr.poll(loop.clock.now))
    rng = np.random.default_rng(0)
    reqs = [_sim_req(rng) for _ in range(10)]
    for r in reqs:
        mgr.submit(r)
    loop.run(until=0.5, stop_when=lambda: loop.clock.now >= 0.4)
    victim = mgr.engines["engine-0"]
    mig = mgr.request_migration("prefill", "decode", loop.clock.now,
                                engine_id="engine-0")
    assert mig is not None and not mig.done
    assert mgr.role_of("engine-0") == "draining"
    assert victim.sched.draining
    # drained waiting queue went back to the control plane
    assert not victim.sched.waiting
    loop.run(until=1e6, stop_when=lambda: (
        not any(e.has_work for e in mgr.engines.values())
        and not mgr.draining))
    assert mig.done
    assert mgr.role_of("engine-0") == "decode"
    assert victim.sched.scfg.role == "decode"
    assert not victim.sched.draining
    assert mgr.counts()["prefill"] == 1 and mgr.counts()["decode"] == 2
    assert all(r.state == RequestState.FINISHED for r in reqs)
    # the flipped member now takes handoffs like any decoder
    assert "engine-0" in mgr.decoders()


def test_manager_refuses_draining_last_member():
    """Never drain the last frontend or the last decoder."""
    mgr, loop = _sim_group(["prefill", "decode"])
    assert mgr.request_migration("prefill", "decode", 0.0) is None
    assert mgr.request_migration("decode", "prefill", 0.0) is None
    assert not mgr.draining


# ------------------------------------------------------- rebalance loop
def test_rebalance_converges_both_directions():
    """Attainment-driven rebalancing on the phase-shifting cluster
    scenario: the prefill-heavy phase pulls a decode member into the
    prefill pool (D->P), the decode-heavy phase pushes prefill members
    out (P->D), and the auto run finishes everything it was offered."""
    cfg = get_config("deepseek-coder-7b")
    ccfg = ClusterConfig(
        routing_policy="least-request", num_engines=4,
        engine=SimEngineConfig(device_type="a10", max_batch=32,
                               chunk_size=512, mixed_batching=True,
                               max_prefills=2),
        roles="auto",
        rebalance=RebalanceConfig(period_s=5.0, cooldown_s=60.0,
                                  warmup_s=30.0),
        kv_pool_bw=100e9, rate_limit=RateLimit(rpm=1e8, tpm=1e12))
    cluster = ServingCluster(cfg, ccfg)
    wl = phase_shift(duration_s=200.0, seed=5)
    s = cluster.run(wl, drain_s=300.0)
    dirs = {(m.src, m.dst) for m in cluster.pool_mgr.migrations}
    assert ("decode", "prefill") in dirs     # prefill-heavy phase
    assert ("prefill", "decode") in dirs     # decode-heavy phase
    assert s["migrations"] >= 2
    assert s["finished"] == len(wl)
    # every migration completed a full drain before flipping
    assert all(m.done for m in cluster.pool_mgr.migrations)


def test_per_pool_autoscaler_decisions_independent():
    """One autoscaler instance per pool: the prefill scaler reacts only
    to TTFT attainment, the decode scaler only to ITL attainment."""
    rb = AttainmentRebalancer(RebalanceConfig())
    for t in range(0, 30):
        rb.store.record(float(t), "pool_ttft_attainment", 0.5)  # bad
        rb.store.record(float(t), "pool_itl_attainment", 1.0)   # perfect

    class _FakeMgr:
        pools = {"prefill": {"p0": None, "p1": None},
                 "decode": {"d0": None, "d1": None}, "mixed": {}}

    want = rb.desired(30.0, _FakeMgr())
    assert want["prefill"] > 2          # TTFT misses -> grow P pool
    assert want["decode"] <= 2          # perfect ITL -> no D growth
    # flipped signals -> flipped decisions, same instances
    for t in range(30, 120):
        rb.store.record(float(t), "pool_ttft_attainment", 1.0)
        rb.store.record(float(t), "pool_itl_attainment", 0.5)
    want = rb.desired(120.0, _FakeMgr())
    assert want["decode"] > 2
    assert want["prefill"] <= 2


# ------------------------------------------------------- sim mixed batching
def test_sim_engine_mixed_batching_completes():
    """SimEngine with mixed_batching=True runs the fused-step pricing
    path (decode rows + prefill chunks in one priced pass) and drains a
    workload with correct finish accounting."""
    cfg = get_config("deepseek-coder-7b")
    loop = EventLoop()
    eng = SimEngine(cfg, loop,
                    SimEngineConfig(device_type="a10", max_batch=8,
                                    chunk_size=256, mixed_batching=True,
                                    max_prefills=2))
    assert eng.sched.scfg.mixed_batching
    rng = np.random.default_rng(1)
    reqs = [_sim_req(rng, plen=500 + 50 * i, out=12) for i in range(6)]
    for i, r in enumerate(reqs):
        loop.schedule(0.05 * i, lambda r=r: eng.submit(r))
    loop.run(until=1e6, stop_when=lambda: not eng.has_work)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert all(len(r.output_tokens) == 12 for r in reqs)
    m = eng.metrics()
    assert m.finished_requests == 6


# ------------------------------------------------------- real-JAX migration
def test_real_engine_migration_byte_identical():
    """Extends the PR-2 1P+1D smoke with LIVE migration: a 2P+1D real
    JAX group serves a request whose prefill is mid-stream when its
    engine is told to become a decoder — the in-flight prefill finishes
    and hands off through the pool, the engine flips, and a follow-up
    request decodes on the migrated member.  All outputs byte-identical
    to a colocated engine with the same parameters."""
    cfg = get_reduced_config("qwen3-0.6b")
    t0 = time.monotonic()
    clock = lambda: time.monotonic() - t0    # noqa: E731
    pool = DistributedKVPool(capacity_bytes=1 << 30, metadata_lag=0.0,
                             clock=clock)
    mgr = RolePoolManager(clock=clock)
    engines = {}
    for eid, role in (("p0", "prefill"), ("p1", "prefill"),
                      ("d0", "decode")):
        engines[eid] = InferenceEngine(
            cfg, EngineConfig(role=role, **ENGINE_KW), clock=clock,
            kv_pool_client=pool, engine_id=eid, seed=0)
        mgr.add_engine(eid, engines[eid], role)
    rng = np.random.default_rng(34)
    prompt_a = rng.integers(0, cfg.vocab_size, 40).tolist()
    prompt_b = rng.integers(0, cfg.vocab_size, 24).tolist()
    req_a = Request(prompt_tokens=list(prompt_a),
                    sampling=SamplingParams(max_new_tokens=6))
    engines["p0"].submit(req_a)
    engines["p0"].step()                     # mid-prefill (40 > chunk 16)
    assert engines["p0"].prefills
    mig = mgr.request_migration("prefill", "decode", clock(),
                                engine_id="p0")
    assert mig is not None
    # new work routes around the draining member
    assert list(mgr.frontends()) == ["p1"]
    req_b = Request(prompt_tokens=list(prompt_b),
                    sampling=SamplingParams(max_new_tokens=6))
    mgr.submit(req_b)
    for _ in range(300):
        busy = False
        for eng in engines.values():
            if eng.has_work:
                eng.step()
                busy = True
        mgr.poll(clock())
        if not busy and not mgr.draining:
            break
    assert mig.done
    assert engines["p0"].sched.scfg.role == "decode"
    assert mgr.counts()["prefill"] == 1 and mgr.counts()["decode"] == 2
    assert req_a.state == RequestState.FINISHED
    assert req_b.state == RequestState.FINISHED
    # req_a's prefill finished on the DRAINING p0 and was handed off
    assert req_a not in engines["p0"].finished
    # byte-identical to a colocated engine with the same params
    for prompt, req in ((prompt_a, req_a), (prompt_b, req_b)):
        ref_eng = InferenceEngine(cfg, EngineConfig(**ENGINE_KW), seed=0)
        ref = Request(prompt_tokens=list(prompt),
                      sampling=SamplingParams(max_new_tokens=6))
        ref_eng.submit(ref)
        ref_eng.run_until_idle()
        assert req.output_tokens == ref.output_tokens
