"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED
same-family variant (2 layers, d_model<=512, <=4 experts), run one
forward/train step and one prefill+decode step on CPU, and assert
output shapes + finiteness.  The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_reduced_config
from repro.models import model as M
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import init_state, make_train_step


def _tokens(cfg, key, b, s):
    shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks else (b, s)
    return jax.random.randint(key, shape, 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_config_bounds(arch):
    cfg = get_reduced_config(arch)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    # family preserved
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(0)
    b, s = 2, 32
    toks = _tokens(cfg, key, b, s)
    batch = {"tokens": toks, "labels": toks,
             "weights": jnp.ones((b, s), jnp.float32)}
    state = init_state(cfg, key)
    step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10),
                           remat=False)
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), metrics
    assert float(metrics["grad_norm"]) > 0.0
    # params actually changed
    before = jax.tree.leaves(state.params)[0]
    after = jax.tree.leaves(new_state.params)[0]
    assert not jnp.allclose(before, after)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_shapes(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(1)
    b, s = 2, 16
    params = M.init(cfg, key)
    toks = _tokens(cfg, key, b, s)
    caches = M.init_cache(cfg, b, s + 8)
    logits, caches = M.prefill(params, cfg, toks, caches)
    want = ((b, cfg.num_codebooks, cfg.vocab_size) if cfg.num_codebooks
            else (b, cfg.vocab_size))
    assert logits.shape == want
    assert bool(jnp.all(jnp.isfinite(logits)))
    nxt = (jnp.zeros((b, cfg.num_codebooks), jnp.int32)
           if cfg.num_codebooks else jnp.zeros((b,), jnp.int32))
    lg, caches = M.decode_step(params, cfg, caches, nxt,
                               jnp.full((b,), s, jnp.int32))
    assert lg.shape == want
    assert bool(jnp.all(jnp.isfinite(lg)))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    """The KV/state cache must be exact: decoding token S after a
    prefill of S tokens reproduces the full-forward logits."""
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(2)
    b, s = 2, 24
    toks = _tokens(cfg, key, b, s + 1)
    params = M.init(cfg, key)
    x, _, _ = M.forward(params, cfg, toks, mode="full")
    ref = M.unembed(params, cfg, x[:, -1:])[:, 0]
    caches = M.init_cache(cfg, b, s + 8)
    _, caches = M.prefill(params, cfg, toks[:, :s], caches)
    got, _ = M.decode_step(params, cfg, caches, toks[:, s],
                           jnp.full((b,), s, jnp.int32))
    assert float(jnp.max(jnp.abs(ref - got))) < 5e-4


def test_param_counts_sane():
    # full configs should be in the ballpark of their names
    expect = {"qwen3-0.6b": (0.4e9, 1.2e9),
              "qwen2-1.5b": (1.2e9, 2.2e9),
              "glm4-9b": (7e9, 11e9),
              "deepseek-v2-236b": (180e9, 280e9),
              "chameleon-34b": (28e9, 40e9),
              "xlstm-1.3b": (0.9e9, 2.2e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("deepseek-v2-236b")
    total, active = cfg.param_count(), cfg.active_param_count()
    assert active < total * 0.25        # 236B total, ~21B active
    assert active > total * 0.02
