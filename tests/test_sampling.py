"""Sampler properties: greedy determinism, top-k/top-p support bounds."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.engine.sampling import sample


def test_greedy_is_argmax():
    logits = jnp.asarray([[0.1, 3.0, -1.0], [5.0, 0.0, 4.9]])
    out = sample(logits, jax.random.PRNGKey(0),
                 jnp.zeros(2))                  # temperature 0 => greedy
    assert out.tolist() == [1, 0]


def test_top_k_restricts_support():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 50)), jnp.float32)
    top2 = jnp.argsort(logits, axis=-1)[:, -2:]
    for seed in range(10):
        out = sample(logits, jax.random.PRNGKey(seed),
                     jnp.ones(4) * 1.5, top_k=2)
        for b in range(4):
            assert int(out[b]) in top2[b].tolist()


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 1000), st.floats(0.05, 0.95))
def test_top_p_never_picks_tail(seed, p):
    """With one dominant logit carrying > p of the mass, top-p must
    always return it."""
    logits = jnp.asarray([[10.0] + [0.0] * 20])
    out = sample(logits, jax.random.PRNGKey(seed), jnp.ones(1),
                 top_p=jnp.asarray([p]))
    assert int(out[0]) == 0


def test_mixed_batch_greedy_and_sampled():
    logits = jnp.asarray([[0.0, 9.0], [9.0, 0.0]])
    out = sample(logits, jax.random.PRNGKey(1),
                 jnp.asarray([0.0, 1.0]))       # row0 greedy, row1 temp 1
    assert int(out[0]) == 1
    assert int(out[1]) in (0, 1)
