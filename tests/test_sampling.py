"""Sampler properties: greedy determinism, top-k/top-p support bounds,
per-(seed, position) key derivation invariances."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.engine.sampling import row_keys, sample


def test_greedy_is_argmax():
    logits = jnp.asarray([[0.1, 3.0, -1.0], [5.0, 0.0, 4.9]])
    out = sample(logits, jax.random.PRNGKey(0),
                 jnp.zeros(2))                  # temperature 0 => greedy
    assert out.tolist() == [1, 0]


def test_top_k_restricts_support():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 50)), jnp.float32)
    top2 = jnp.argsort(logits, axis=-1)[:, -2:]
    for seed in range(10):
        out = sample(logits, jax.random.PRNGKey(seed),
                     jnp.ones(4) * 1.5, top_k=2)
        for b in range(4):
            assert int(out[b]) in top2[b].tolist()


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 1000), st.floats(0.05, 0.95))
def test_top_p_never_picks_tail(seed, p):
    """With one dominant logit carrying > p of the mass, top-p must
    always return it."""
    logits = jnp.asarray([[10.0] + [0.0] * 20])
    out = sample(logits, jax.random.PRNGKey(seed), jnp.ones(1),
                 top_p=jnp.asarray([p]))
    assert int(out[0]) == 0


def test_mixed_batch_greedy_and_sampled():
    logits = jnp.asarray([[0.0, 9.0], [9.0, 0.0]])
    out = sample(logits, jax.random.PRNGKey(1),
                 jnp.asarray([0.0, 1.0]))       # row0 greedy, row1 temp 1
    assert int(out[0]) == 1
    assert int(out[1]) in (0, 1)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 4096), st.integers(2, 6))
def test_row_keys_batch_permutation_invariant(seed, pos, b):
    """A row's sample depends only on its (sampling seed, absolute
    position) — not on where it sits in the batch or who shares the
    step with it.  This is what makes speculative verification and the
    async loop bit-exact with the plain loop at temperature > 0."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(b, 32)), jnp.float32)
    seeds = jnp.asarray(rng.integers(0, 1 << 20, b), jnp.uint32)
    positions = jnp.asarray([pos + i for i in range(b)], jnp.uint32)
    temps = jnp.ones(b) * 0.9
    out = sample(logits, jax.random.PRNGKey(0), temps,
                 keys=row_keys(seeds, positions))
    perm = rng.permutation(b)
    out_p = sample(logits[perm], jax.random.PRNGKey(7), temps,
                   keys=row_keys(seeds[perm], positions[perm]))
    assert out[perm].tolist() == out_p.tolist()


def test_row_keys_verification_width_invariant():
    """Sampling position p alone gives the same token as sampling it as
    one lane of a wider flattened verification batch (same seed, same
    logits row) — acceptance therefore reproduces the sequential
    samples exactly."""
    rng = np.random.default_rng(5)
    logits = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    seeds = jnp.asarray([42] * 4, jnp.uint32)
    positions = jnp.asarray([10, 11, 12, 13], jnp.uint32)
    temps = jnp.ones(4)
    wide = sample(logits, jax.random.PRNGKey(0), temps,
                  keys=row_keys(seeds, positions))
    for i in range(4):
        solo = sample(logits[i:i + 1], jax.random.PRNGKey(i), temps[:1],
                      keys=row_keys(seeds[i:i + 1], positions[i:i + 1]))
        assert int(solo[0]) == int(wide[i])
