"""Property tests for LoRAController placement/sync invariants.

Runs under real hypothesis when installed; the container falls back to
the seeded-random subset in ``_hypothesis_fallback``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    import hypothesis.strategies as st
except ImportError:                                  # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.core.lora.manager import AdapterSpec, LoRAController


def _build(n_pods, capacity, n_adapters, min_replicas=1, max_replicas=4,
           heat_exp=1.1):
    ctrl = LoRAController(min_replicas=min_replicas,
                          max_replicas=max_replicas)
    for i in range(n_adapters):
        ctrl.register(AdapterSpec(f"a-{i}", "base",
                                  requests_per_s=1.0 / (i + 1) ** heat_exp))
    for p in range(n_pods):
        ctrl.add_pod(f"pod-{p}", capacity=capacity)
    return ctrl


@settings(max_examples=40)
@given(st.integers(1, 6), st.integers(1, 8), st.integers(0, 24))
def test_plan_never_exceeds_pod_capacity(n_pods, capacity, n_adapters):
    ctrl = _build(n_pods, capacity, n_adapters)
    plan = ctrl.plan_placement()
    assert set(plan) == set(ctrl.pods)
    for pod_id, names in plan.items():
        assert len(names) <= ctrl.pods[pod_id].capacity


@settings(max_examples=40)
@given(st.integers(1, 6), st.integers(1, 8), st.integers(0, 24))
def test_every_adapter_covered_when_capacity_suffices(
        n_pods, capacity, n_adapters):
    """Coverage-first: whenever total slots >= adapter count, NO
    adapter is left unservable — hot replication only spends leftovers."""
    ctrl = _build(n_pods, capacity, n_adapters)
    plan = ctrl.plan_placement()
    covered = {a for names in plan.values() for a in names}
    if n_pods * capacity >= n_adapters:
        assert covered == set(ctrl.adapters)
    else:       # under-capacity: every slot is still spent
        assert sum(len(v) for v in plan.values()) == n_pods * capacity


@settings(max_examples=40)
@given(st.integers(2, 6), st.integers(2, 4), st.integers(1, 8))
def test_hot_adapter_gets_min_replicas_under_generous_capacity(
        n_pods, min_replicas, n_adapters):
    """With slack capacity the hottest adapter replicates to at least
    min(min_replicas, n_pods) pods."""
    want = min(min_replicas, n_pods)
    ctrl = _build(n_pods, capacity=n_adapters * min_replicas,
                  n_adapters=n_adapters, min_replicas=want)
    plan = ctrl.plan_placement()
    placed = sum(1 for names in plan.values() if "a-0" in names)
    assert placed >= want


@settings(max_examples=25)
@given(st.integers(1, 6), st.integers(1, 8), st.integers(0, 24))
def test_sync_is_churn_free_under_unchanged_heat(
        n_pods, capacity, n_adapters):
    """Placement is sticky: a second sync with identical demand issues
    zero load/unload actions."""
    ctrl = _build(n_pods, capacity, n_adapters)
    first = ctrl.sync({})
    assert any(first.values()) == bool(n_adapters)
    second = ctrl.sync({})
    assert all(acts == [] for acts in second.values())
    assert ctrl.stats["unloads"] == 0


@settings(max_examples=25)
@given(st.integers(2, 6), st.integers(2, 8), st.integers(1, 24))
def test_sync_reconciles_engine_drift(n_pods, capacity, n_adapters):
    """A pod whose engine view drifted (LRU eviction / autoload past
    the plan) is driven back to the planned state by the next sync."""
    class FakeEngine:
        def __init__(self):
            self.adapters = []
            self.calls = []

        def register_adapter(self, name):
            self.adapters.append(name)
            self.calls.append(f"load:{name}")

        def unregister_adapter(self, name):
            self.adapters.remove(name)
            self.calls.append(f"unload:{name}")

    ctrl = _build(n_pods, capacity, n_adapters)
    engines = {f"pod-{p}": FakeEngine() for p in range(n_pods)}
    ctrl.sync(engines)
    plan = {p: set(ctrl.pods[p].loaded) for p in ctrl.pods}
    # drift: pod-0's engine dropped everything, pod-1 gained a stray
    engines["pod-0"].adapters = []
    engines["pod-1"].adapters = list(ctrl.pods["pod-1"].loaded) + ["stray"]
    ctrl.register(AdapterSpec("stray", "base", requests_per_s=0.0))
    ctrl.sync(engines)
    for p, eng in engines.items():
        assert set(eng.adapters) == set(ctrl.pods[p].loaded)
        assert len(eng.adapters) <= capacity
    restored = {a for e in engines.values() for a in e.adapters}
    assert set(plan["pod-0"]) <= restored
