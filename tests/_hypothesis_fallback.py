"""Minimal stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite property-tests several control-plane components with
hypothesis.  The container image does not ship it, so this module
implements the tiny subset the tests use — ``given``/``settings``/
``HealthCheck`` and the ``integers``/``floats``/``lists``/
``sampled_from``/``composite`` strategies — as plain seeded random
sampling (no shrinking, fixed example counts).  Test modules import it
via::

    try:
        import hypothesis.strategies as st
        from hypothesis import given, settings
    except ImportError:
        from _hypothesis_fallback import given, settings, st

so installing the real hypothesis transparently upgrades the suite.
"""
from __future__ import annotations

import functools
import random

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    """A strategy is just a callable drawing one example from an RNG."""

    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


def tuples(*elements: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.example(rng)
                                       for s in elements))


def composite(fn):
    """``@st.composite`` — fn(draw, *args) becomes a strategy factory."""
    @functools.wraps(fn)
    def factory(*args, **kwargs):
        def draw_example(rng):
            def draw(strategy):
                return strategy.example(rng)
            return fn(draw, *args, **kwargs)
        return _Strategy(draw_example)
    return factory


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             suppress_health_check=(), **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        def wrapper(*args, **kwargs):
            # settings() is applied OUTSIDE given() and stamps the count
            # on this wrapper — read it at call time, not decoration time.
            max_examples = getattr(wrapper, "_fallback_max_examples",
                                   DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(max_examples):
                drawn = tuple(s.example(rng) for s in strategies)
                fn(*args, *drawn, **kwargs)
        # NOT functools.wraps: copying __wrapped__ would make pytest
        # resolve the property arguments as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


class _StrategiesModule:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)
    tuples = staticmethod(tuples)
    composite = staticmethod(composite)


st = _StrategiesModule()
