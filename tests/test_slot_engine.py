"""SlotEngine: real-JAX serving for the non-pageable families
(SSM / hybrid / sliding-window / codebook archs)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.engine.request import Request, RequestState, SamplingParams
from repro.engine.slot_engine import SlotEngine, SlotEngineConfig
from repro.models import model as M

NON_PAGEABLE = ("xlstm-1.3b", "hymba-1.5b", "gemma3-4b", "musicgen-large")


@pytest.mark.parametrize("arch", NON_PAGEABLE)
def test_slot_engine_serves_arch(arch):
    cfg = get_reduced_config(arch)
    eng = SlotEngine(cfg, SlotEngineConfig(max_slots=2, max_len=64),
                     seed=0)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(3):
        n = 10 + i
        if cfg.num_codebooks:
            prompt = rng.integers(0, cfg.vocab_size,
                                  (n, cfg.num_codebooks)).tolist()
        else:
            prompt = rng.integers(0, cfg.vocab_size, n).tolist()
        r = Request(prompt_tokens=prompt,
                    sampling=SamplingParams(max_new_tokens=5))
        reqs.append(r)
        eng.submit(r)
    eng.run_until_idle()
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert all(len(r.output_tokens) == 5 for r in reqs)


def test_slot_engine_greedy_matches_model_reference():
    cfg = get_reduced_config("xlstm-1.3b")
    eng = SlotEngine(cfg, SlotEngineConfig(max_slots=2, max_len=64),
                     seed=0)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 14).tolist()
    req = Request(prompt_tokens=prompt,
                  sampling=SamplingParams(max_new_tokens=5))
    eng.submit(req)
    eng.run_until_idle()
    caches = M.init_cache(cfg, 1, 64)
    logits, caches = M.prefill(eng.params, cfg,
                               jnp.asarray([prompt], jnp.int32), caches)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(4):
        lg, caches = M.decode_step(eng.params, cfg, caches,
                                   jnp.asarray([out[-1]], jnp.int32),
                                   jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert req.output_tokens == out


def test_slot_engine_rejects_oversized():
    cfg = get_reduced_config("hymba-1.5b")
    eng = SlotEngine(cfg, SlotEngineConfig(max_slots=1, max_len=32))
    r = Request(prompt_tokens=list(range(40)),
                sampling=SamplingParams(max_new_tokens=8))
    eng.submit(r)
    eng.step()
    assert r.state == RequestState.FAILED
