"""High-density LoRA serving pins on the REAL JAX data plane.

Byte-identity is the core contract: adapter weights are a pure
function of (engine seed, adapter NAME) — never of the HBM slot they
happen to occupy — so any tier movement (unregister/re-register, LRU
eviction through the host tier, slot reuse by another adapter) must
reproduce the exact same tokens.  The loud-miss tests pin the PR-8
behavior change: a request whose adapter is not resident queues (or is
shed after the timeout) and counts a ``lora_miss`` — it is NEVER
silently served by the base model.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_reduced_config
from repro.core.lora.manager import AdapterSpec, LoRAController
from repro.engine import (EngineConfig, InferenceEngine, Request,
                          SamplingParams)
from repro.engine.request import RequestState


def _engine(seed=0, **kw):
    cfg = get_reduced_config("qwen3-0.6b")
    defaults = dict(page_size=8, num_pages=64, max_batch=4,
                    max_pages_per_seq=16, chunk_size=16)
    defaults.update(kw)
    return cfg, InferenceEngine(cfg, EngineConfig(**defaults), seed=seed)


def _gen(eng, prompt, adapter=None, n=4):
    r = Request(prompt_tokens=list(prompt),
                sampling=SamplingParams(max_new_tokens=n),
                lora_adapter=adapter)
    eng.submit(r)
    eng.run_until_idle()
    assert r.state == RequestState.FINISHED
    return r.output_tokens


def test_reregister_is_byte_identical():
    """register -> generate -> unregister -> re-register reproduces the
    exact tokens; the round trip through the host tier is a hit."""
    cfg, eng = _engine()
    rng = np.random.default_rng(10)
    prompt = rng.integers(0, cfg.vocab_size, 12).tolist()
    eng.register_adapter("sql")
    first = _gen(eng, prompt, adapter="sql")
    eng.unregister_adapter("sql")
    assert "sql" not in eng.adapters
    eng.register_adapter("sql")
    assert eng.runner.adapter_host_hits == 1   # offloaded copy reused
    assert _gen(eng, prompt, adapter="sql") == first


def test_slot_reuse_never_leaks_old_weights():
    """Adapter 'b' loaded into a slot previously owned by 'a' must
    produce the same tokens as 'b' on a fresh engine."""
    cfg, eng_a = _engine(seed=0)
    _, eng_b = _engine(seed=0)
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 12).tolist()
    eng_a.register_adapter("a")
    _gen(eng_a, prompt, adapter="a")
    eng_a.unregister_adapter("a")
    eng_a.register_adapter("b")            # reuses a's slot
    eng_b.register_adapter("b")            # fresh slot, fresh engine
    assert _gen(eng_a, prompt, adapter="b") == \
        _gen(eng_b, prompt, adapter="b")


def test_mixed_batch_rows_match_single_adapter_runs():
    """base + two adapters batched together decode the same tokens as
    each run alone on a fresh engine with the same seed."""
    cfg, eng = _engine()
    eng.register_adapter("sql")
    eng.register_adapter("chat")
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab_size, 12).tolist()
               for _ in range(3)]
    reqs = [Request(prompt_tokens=prompts[0],
                    sampling=SamplingParams(max_new_tokens=4)),
            Request(prompt_tokens=prompts[1], lora_adapter="sql",
                    sampling=SamplingParams(max_new_tokens=4)),
            Request(prompt_tokens=prompts[2], lora_adapter="chat",
                    sampling=SamplingParams(max_new_tokens=4))]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    for r, adapter in zip(reqs, (None, "sql", "chat")):
        _, solo = _engine()
        if adapter:
            solo.register_adapter(adapter)
        assert _gen(solo, r.prompt_tokens, adapter=adapter) == \
            r.output_tokens, f"row {adapter or 'base'} diverged"


def test_lora_miss_is_loud_and_queues():
    """No silent base-model fallback: a request for a non-resident
    adapter waits (counting ONE lora_miss), then runs once the control
    plane registers the adapter."""
    cfg, eng = _engine(lora_autoload=False)
    rng = np.random.default_rng(13)
    r = Request(prompt_tokens=rng.integers(0, cfg.vocab_size, 8).tolist(),
                sampling=SamplingParams(max_new_tokens=3),
                lora_adapter="ghost")
    eng.submit(r)
    for _ in range(3):
        eng.step()
    assert r.state == RequestState.QUEUED
    assert not r.output_tokens
    m = eng.metrics()
    assert m.lora_miss == 1                # counted once, not per step
    assert m.lora_shed == 0
    eng.register_adapter("ghost")
    eng.run_until_idle()
    assert r.state == RequestState.FINISHED
    assert _gen(eng, r.prompt_tokens, adapter="ghost", n=3) == \
        r.output_tokens


def test_lora_miss_sheds_after_timeout():
    cfg, eng = _engine(lora_autoload=False, lora_queue_timeout_s=1e-9)
    rng = np.random.default_rng(14)
    r = Request(prompt_tokens=rng.integers(0, cfg.vocab_size, 8).tolist(),
                sampling=SamplingParams(max_new_tokens=3),
                lora_adapter="ghost")
    eng.submit(r)
    eng.step()
    assert r.state == RequestState.FAILED
    m = eng.metrics()
    assert m.lora_miss == 1
    assert m.lora_shed == 1


def test_lru_eviction_cascades_to_host_tier():
    """A full HBM bank evicts the LRU adapter into the host tier;
    re-loading it is a host hit and stays byte-identical."""
    cfg, eng = _engine(max_adapters=3)      # slot 0 = base, 2 usable
    rng = np.random.default_rng(15)
    prompt = rng.integers(0, cfg.vocab_size, 12).tolist()
    eng.register_adapter("a")
    baseline = _gen(eng, prompt, adapter="a")
    eng.register_adapter("b")
    eng.register_adapter("c")               # bank full: evicts LRU 'a'
    assert eng.runner.adapter_evictions == 1
    assert "a" not in eng.adapters
    assert sorted(eng.adapters) == ["b", "c"]
    eng.register_adapter("a")               # back through the host tier
    assert eng.runner.adapter_host_hits >= 1
    assert _gen(eng, prompt, adapter="a") == baseline


def test_unregister_defers_while_adapter_in_flight():
    cfg, eng = _engine()
    eng.register_adapter("sql")
    rng = np.random.default_rng(16)
    r = Request(prompt_tokens=rng.integers(0, cfg.vocab_size, 8).tolist(),
                sampling=SamplingParams(max_new_tokens=6),
                lora_adapter="sql")
    eng.submit(r)
    eng.step()                              # prefill admits the request
    eng.unregister_adapter("sql")
    assert "sql" in eng.adapters            # deferred, not yanked
    eng.run_until_idle()
    assert r.state == RequestState.FINISHED
    eng.step()                              # idle step flushes deferrals
    assert "sql" not in eng.adapters


def test_controller_sim_real_parity():
    """The shared LoRAController drives identical load/unload action
    sequences — and identical cold-load counts — whether the pods are
    real JAX engines or SimEngines."""
    from repro.core.sim.events import EventLoop
    from repro.core.sim.sim_engine import SimEngine, SimEngineConfig

    cfg = get_reduced_config("qwen3-0.6b")
    real = {f"engine-{i}": _engine(seed=i)[1] for i in range(2)}
    loop = EventLoop()
    sim = {f"engine-{i}": SimEngine(
               cfg, loop, SimEngineConfig(max_adapters=8),
               engine_id=f"engine-{i}") for i in range(2)}

    def drive(fleet):
        ctrl = LoRAController(min_replicas=1, max_replicas=2)
        for i in range(5):
            ctrl.register(AdapterSpec(f"lora-{i}", cfg.name,
                                      requests_per_s=1.0 / (i + 1)))
        for eid in fleet:
            ctrl.add_pod(eid, capacity=3)
        acts = [ctrl.sync(fleet)]
        # identical demand shift on both planes: the tail goes hot
        for t, name in enumerate(["lora-4"] * 6 + ["lora-0"]):
            ctrl.note_request(name, float(t))
        acts.append(ctrl.replan(fleet, now=7.0))
        return acts

    acts_real = drive(real)
    acts_sim = drive(sim)
    assert acts_real == acts_sim
    cold_real = sum(e.runner.adapter_loads for e in real.values())
    cold_sim = sum(e.metrics().lora_cold_loads for e in sim.values())
    assert cold_real == cold_sim > 0
