"""Tiered KV cache: host-DRAM tier, swap-based preemption, and the
compressed streaming pool handoff.

The byte-identity pins are the load-bearing ones: a swap-out/swap-in
cycle on the REAL JAX engine must resume decoding mid-sequence with
exactly the tokens the never-preempted run produces (the already-
generated prefix must survive the swap untouched), and the host-tier
cascade must serve a re-offered prefix byte-identically to a cold
recompute.  The int8 wire format is parity-pinned within
``INT8_WIRE_MAX_REL_ERR`` of the per-layer max-abs value."""
import logging
import time

import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.gateway.gateway import Gateway, RateLimit
from repro.core.kvcache.pool import DistributedKVPool
from repro.core.kvcache.tiers import (INT8_WIRE_MAX_REL_ERR, HostPagePool,
                                      compress_page, decompress_page,
                                      payload_nbytes)
from repro.core.sim.events import EventLoop
from repro.core.sim.sim_engine import SimEngine, SimEngineConfig
from repro.engine import (EngineConfig, InferenceEngine, Request,
                          RequestState, SamplingParams)
from repro.engine.page_table import PageAllocator

ENGINE_KW = dict(page_size=8, num_pages=64, max_batch=4,
                 max_pages_per_seq=16, chunk_size=16)


def _engine(seed=0, **kw):
    cfg = get_reduced_config("qwen3-0.6b")
    defaults = dict(ENGINE_KW)
    defaults.update(kw)
    return cfg, InferenceEngine(cfg, EngineConfig(**defaults), seed=seed)


def _greedy_reference(cfg, prompt, max_new, seed=0, **kw):
    _, ref_eng = _engine(seed=seed, **kw)
    ref = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=max_new))
    ref_eng.submit(ref)
    ref_eng.run_until_idle()
    return ref.output_tokens


# ------------------------------------------------------- swap preemption
def test_swap_preemption_byte_identical_resume():
    """Preempt a decoding request mid-stream on the real JAX engine
    with a host tier attached: its pages swap out, resume swaps them
    back in and CONTINUES from where it stopped — the already-generated
    tokens survive and the final output is byte-identical to the
    never-preempted run."""
    cfg, eng = _engine(host_cache_gb=0.25)
    rng = np.random.default_rng(41)
    prompt = rng.integers(0, cfg.vocab_size, 20).tolist()
    req = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=8))
    eng.submit(req)
    for _ in range(200):
        if len(req.output_tokens) >= 3:
            break
        eng.step()
    assert len(req.output_tokens) >= 3
    generated = list(req.output_tokens)
    eng.sched.preempt(req, eng.clock())
    assert req.state is RequestState.SWAPPED
    assert req.page_ids == []
    assert req.output_tokens == generated       # NOT reset
    assert len(eng.host_pool) > 0                # pages parked in DRAM
    eng.run_until_idle()
    assert req.state is RequestState.FINISHED
    # the pre-preemption prefix survived the swap: continued, not rerun
    assert req.output_tokens[:len(generated)] == generated
    assert req.output_tokens == _greedy_reference(cfg, prompt, 8)
    m = eng.metrics()
    assert m.swap_out == 1 and m.swap_in == 1 and m.preemptions == 1
    assert m.kv_bytes_offloaded > 0 and m.kv_bytes_fetched > 0
    assert req.preempt_count == 1


def test_swap_falls_back_to_recompute_when_tier_cannot_hold():
    """A host tier too small for the victim's pages falls back to the
    legacy drop-and-recompute path — still byte-identical under greedy
    decoding, just slower."""
    cfg, eng = _engine(host_cache_gb=1e-6)      # ~1 KiB: can_hold fails
    rng = np.random.default_rng(42)
    prompt = rng.integers(0, cfg.vocab_size, 20).tolist()
    req = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=6))
    eng.submit(req)
    for _ in range(200):
        if len(req.output_tokens) >= 2:
            break
        eng.step()
    eng.sched.preempt(req, eng.clock())
    assert req.state is RequestState.QUEUED     # legacy path
    assert req.output_tokens == []              # recompute from token 0
    eng.run_until_idle()
    assert req.output_tokens == _greedy_reference(cfg, prompt, 6)
    m = eng.metrics()
    assert m.preemptions == 1 and m.swap_out == 0 and m.swap_in == 0


def test_sim_swap_preemption_shares_scheduler_path():
    """The SAME Scheduler swap path runs under the simulator: an SLO
    preemption with a host tier attached swaps instead of resetting,
    and the victim finishes with its full output."""
    cfg = get_reduced_config("qwen3-0.6b")
    loop = EventLoop()
    sc = SimEngineConfig(device_type="a10", max_batch=2, chunk_size=64,
                         mixed_batching=True, slo_aware=True,
                         slo_preempt_cooldown_s=0.0, num_pages=128,
                         page_size=8, host_cache_gb=1.0)
    eng = SimEngine(cfg, loop, sc)
    rng = np.random.default_rng(43)
    batch = [Request(prompt_tokens=rng.integers(0, 100, 16).tolist(),
                     sampling=SamplingParams(max_new_tokens=400),
                     priority_class="batch", arrival_time=0.0)
             for _ in range(2)]
    for r in batch:
        eng.submit(r)
    urgent = Request(prompt_tokens=rng.integers(0, 100, 16).tolist(),
                     sampling=SamplingParams(max_new_tokens=4),
                     priority_class="interactive", arrival_time=0.0)
    loop.after(0.1, lambda: eng.submit(urgent))
    loop.run(until=1e6, stop_when=lambda: not eng.has_work)
    m = eng.metrics()
    assert m.preemptions >= 1 and m.swap_out >= 1
    assert m.swap_in == m.swap_out
    assert all(r.state is RequestState.FINISHED for r in batch + [urgent])
    assert all(len(r.output_tokens) == r.sampling.max_new_tokens
               for r in batch)


# ------------------------------------------------------ eviction cascade
def test_host_tier_cascade_eviction_order():
    """Device-cache victims cascade into the host tier in eviction
    (LRU-release) order, content-addressed by the same block hash."""
    host = HostPagePool(capacity_bytes=1 << 20)
    alloc = PageAllocator(4, page_size=4)
    alloc.on_evict = lambda pid, h, now: host.put(h, ("pl", pid), 64, now)
    pages = alloc.allocate(4, 1.0)
    for i, pid in enumerate(pages):
        alloc.register_hash(pid, f"h{i}")
    for t, idx in zip((2.0, 3.0, 4.0, 5.0), (2, 0, 3, 1)):
        alloc.release([pages[idx]], t)
    assert len(host) == 0                       # nothing evicted yet
    fresh = alloc.allocate(4, 6.0)              # forces 4 cascades
    assert fresh is not None
    assert host.keys() == ["h2", "h0", "h3", "h1"]   # LRU-release order
    assert host.get("h0") == ("pl", pages[0])


def test_host_tier_is_bounded_lru():
    host = HostPagePool(capacity_bytes=256)
    for i in range(6):
        assert host.put(f"k{i}", i, 64, now=float(i))
    assert len(host) == 4                       # 256 / 64
    assert host.keys() == ["k2", "k3", "k4", "k5"]
    assert host.stats.evictions == 2
    host.get("k2")                              # refresh
    host.put("k6", 6, 64)
    assert "k2" in host.keys() and "k3" not in host.keys()
    assert not host.put("huge", 0, 512)         # can never fit
    assert host.can_hold(256) and not host.can_hold(257)


def test_host_tier_serves_evicted_prefix_real_engine():
    """End-to-end cascade on the real JAX engine: a finished prompt's
    pages get evicted from the device cache under pressure, fall into
    the host tier, and a later request re-offering the prefix is served
    from host DRAM (host_hit_tokens) byte-identically to a cold run."""
    cfg, eng = _engine(host_cache_gb=0.25, num_pages=24)
    rng = np.random.default_rng(44)
    shared = rng.integers(0, cfg.vocab_size, 24).tolist()
    first = Request(prompt_tokens=list(shared),
                    sampling=SamplingParams(max_new_tokens=4))
    eng.submit(first)
    eng.run_until_idle()
    # pressure: distinct long prompts evict the shared prefix's pages
    for i in range(3):
        filler = Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, 120).tolist(),
            sampling=SamplingParams(max_new_tokens=2))
        eng.submit(filler)
        eng.run_until_idle()
    assert eng.sched.alloc.stats["evictions"] > 0
    assert eng.host_pool.stats.puts > 0
    again = Request(prompt_tokens=list(shared),
                    sampling=SamplingParams(max_new_tokens=4))
    eng.submit(again)
    eng.run_until_idle()
    m = eng.metrics()
    assert m.host_hit_tokens >= eng.ecfg.page_size
    assert again.output_tokens == first.output_tokens
    assert again.output_tokens == _greedy_reference(
        cfg, shared, 4, num_pages=24)


# ------------------------------------------------------------ int8 wire
@pytest.mark.parametrize("shape", [(2, 8, 2, 16), (4, 16, 1, 8)])
@pytest.mark.parametrize("scale", [1e-3, 1.0, 300.0])
def test_int8_roundtrip_parity_sweep(shape, scale):
    """Pinned wire tolerance: |x - roundtrip(x)| <= INT8_WIRE_MAX_REL_ERR
    * per-layer max|x|, across payload shapes and magnitudes."""
    rng = np.random.default_rng(45)
    k = (rng.standard_normal(shape) * scale).astype(np.float32)
    v = (rng.standard_normal(shape) * scale).astype(np.float32)
    cp = compress_page(k, v)
    dk, dv = decompress_page(cp)
    for x, d in ((k, dk), (v, dv)):
        bound = (INT8_WIRE_MAX_REL_ERR
                 * np.max(np.abs(x), axis=(1, 2, 3), keepdims=True))
        assert np.all(np.abs(x - d) <= bound + 1e-9)
    # the wire really is smaller: int8 + scales vs 2 fp32 arrays
    assert cp.nbytes < (k.nbytes + v.nbytes) // 2
    assert payload_nbytes(cp) == cp.nbytes
    assert payload_nbytes((k, v)) == k.nbytes + v.nbytes
    assert payload_nbytes(True, default=7) == 7


def test_int8_wire_real_pd_handoff():
    """1P+1D real JAX engines with the int8 wire: the decode engine
    serves a request whose (dequantized) KV it never prefilled and the
    pool stores the compressed size."""
    cfg = get_reduced_config("qwen3-0.6b")
    t0 = time.monotonic()
    clock = lambda: time.monotonic() - t0    # noqa: E731
    pool = DistributedKVPool(capacity_bytes=1 << 30, metadata_lag=0.0,
                             clock=clock)
    kw = dict(ENGINE_KW, wire_dtype="int8")
    pre = InferenceEngine(cfg, EngineConfig(role="prefill", **kw),
                          clock=clock, kv_pool_client=pool,
                          engine_id="p0", seed=0)
    dec = InferenceEngine(cfg, EngineConfig(role="decode", **kw),
                          clock=clock, kv_pool_client=pool,
                          engine_id="d0", seed=0)
    pre.handoff = dec.submit
    rng = np.random.default_rng(46)
    prompt = rng.integers(0, cfg.vocab_size, 24).tolist()
    req = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=6))
    pre.submit(req)
    for _ in range(200):
        if not (pre.has_work or dec.has_work):
            break
        if pre.has_work:
            pre.step()
        if dec.has_work:
            dec.step()
    assert req.state is RequestState.FINISHED
    assert dec.metrics().remote_hit_tokens >= 16
    # pool accounted the COMPRESSED wire size, not the raw page
    assert 0 < pool.stats.bytes_stored < 2 * pre.runner.page_bytes
    # fetched-byte accounting follows the wire size too
    assert 0 < dec.metrics().kv_bytes_fetched < 2 * pre.runner.page_bytes


# ----------------------------------------------- chunked handoff parity
def test_chunked_handoff_sim_real_admission_parity():
    """The chunked streaming handoff makes the SAME admission decisions
    on the real JAX data plane and the simulator: same pool-walk
    coverage (remote_hit_tokens) at the same page/chunk geometry, and
    the real pair stays byte-identical to a colocated engine."""
    cfg = get_reduced_config("qwen3-0.6b")
    rng = np.random.default_rng(47)
    prompt = rng.integers(0, cfg.vocab_size, 24).tolist()

    t0 = time.monotonic()
    clock = lambda: time.monotonic() - t0    # noqa: E731
    pool = DistributedKVPool(capacity_bytes=1 << 30, metadata_lag=0.0,
                             clock=clock)
    kw = dict(ENGINE_KW, handoff_chunk_pages=1)
    pre = InferenceEngine(cfg, EngineConfig(role="prefill", **kw),
                          clock=clock, kv_pool_client=pool,
                          engine_id="p0", seed=0)
    dec = InferenceEngine(cfg, EngineConfig(role="decode", **kw),
                          clock=clock, kv_pool_client=pool,
                          engine_id="d0", seed=0)
    pre.handoff = dec.submit
    req = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=6))
    pre.submit(req)
    for _ in range(200):
        if not (pre.has_work or dec.has_work):
            break
        if pre.has_work:
            pre.step()
        if dec.has_work:
            dec.step()
    assert req.state is RequestState.FINISHED
    assert req.output_tokens == _greedy_reference(cfg, prompt, 6)

    loop = EventLoop()
    spool = DistributedKVPool(capacity_bytes=1 << 30, metadata_lag=0.002,
                              clock=loop.clock)
    skw = dict(device_type="a10", page_size=8, max_batch=4,
               chunk_size=16, mixed_batching=True, handoff_chunk_pages=1)
    spre = SimEngine(cfg, loop, SimEngineConfig(role="prefill", **skw),
                     kv_pool=spool, engine_id="p0", node="n0")
    sdec = SimEngine(cfg, loop, SimEngineConfig(role="decode", **skw),
                     kv_pool=spool, engine_id="d0", node="n1")
    spre.handoff = sdec.submit
    sreq = Request(prompt_tokens=list(prompt),
                   sampling=SamplingParams(max_new_tokens=6),
                   arrival_time=0.0)
    spre.submit(sreq)
    loop.run(until=1e6,
             stop_when=lambda: not (spre.has_work or sdec.has_work))
    assert sreq.state is RequestState.FINISHED
    # same page walk on both data planes: identical pool coverage
    assert (sdec.metrics().remote_hit_tokens
            == dec.metrics().remote_hit_tokens > 0)


# --------------------------------------------------- loud load shedding
def test_gateway_shed_counting_and_logging(caplog):
    """Rate-limit drops are counted (instance + process-wide) and
    logged at most once per window — no more silent request loss."""
    now = [0.0]
    gw = Gateway(policy="least-request",
                 default_limit=RateLimit(rpm=60.0, tpm=1e9),
                 clock=lambda: now[0])

    class _H:
        def metrics(self):
            from repro.engine.scheduler import EngineMetrics
            return EngineMetrics()

    gw.register_engine("e0", _H())
    before = Gateway.total_shed
    with caplog.at_level(logging.WARNING, logger="repro.gateway"):
        routed = sum(gw.route([1, 2, 3]) is not None for _ in range(15))
    assert routed == 10                 # burst bucket: rpm/6
    assert gw.stats.shed == 5
    assert gw.stats.rejected_rpm == 5
    assert Gateway.total_shed - before == 5
    shed_logs = [r for r in caplog.records if "shed" in r.message]
    assert len(shed_logs) == 1          # once per window, not per drop
    now[0] = 11.0
    with caplog.at_level(logging.WARNING, logger="repro.gateway"):
        list(gw.route([1]) for _ in range(30))
    assert any("shed" in r.message
               for r in caplog.records[len(shed_logs):])
