"""Sharded gateway core: shard-map determinism, lazy stats merge,
per-shard LRU bounds, retag pin purging across shards, and the
think-time promotion predictor.

The load-bearing property is OBSERVATION EQUIVALENCE: a 16-shard
gateway must route every request of any interleaving of a session's
turns to the same engine a monolithic gateway would pick — sharding is
a capacity/locality optimisation, never a behavior change.
"""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.core.gateway.gateway import Gateway, GatewayStats, RateLimit
from repro.core.gateway.router import SessionAffinityPolicy


class _FakeEngine:
    def __init__(self, depth=0, cov=0):
        self.queue_depth = depth
        self._cov = cov

    def match_prefix_len(self, tokens):
        return min(self._cov, len(tokens))


def _gateway(shards, policy="session", **kw):
    gw = Gateway(policy=policy, shards=shards,
                 default_limit=RateLimit(rpm=1e12, tpm=1e15), **kw)
    for i in range(4):
        gw.register_engine(f"e{i}", _FakeEngine())
    return gw


# ----------------------------------------------------------- shard map
def test_shard_map_deterministic_and_spread():
    gw_a, gw_b = _gateway(8), _gateway(8)
    hit = set()
    for i in range(200):
        sid = f"conv{i}"
        ia = gw_a._shards.index(gw_a._shard_for(sid))
        ib = gw_b._shards.index(gw_b._shard_for(sid))
        assert ia == ib                  # crc32: process-independent
        hit.add(ia)
    assert len(hit) == 8                 # no dead shards at 200 keys
    # single-shard fast path short-circuits the hash entirely
    gw1 = _gateway(1)
    assert gw1._shard_for("anything") is gw1._shards[0]


# ---------------------------------------------------------- stats merge
def test_gateway_stats_merge_unit():
    a = GatewayStats(routed=3, rejected_rpm=1, lora_routed=2,
                     lora_hits=1, per_engine={"e0": 2, "e1": 1},
                     engine_failures={"e0": {"crash": 1}})
    b = GatewayStats(routed=5, rejected_tpm=2, lora_routed=1,
                     lora_hits=1, per_engine={"e1": 4},
                     engine_failures={"e0": {"crash": 2,
                                             "quarantine": 1}})
    m = GatewayStats.merge([a, b])
    assert m.routed == 8
    assert m.shed == 3                   # rpm + tpm read off the sums
    assert m.per_engine == {"e0": 2, "e1": 5}
    assert m.engine_failures == {"e0": {"crash": 3, "quarantine": 1}}
    assert m.lora_affinity_hit_rate == pytest.approx(2 / 3)


def test_stats_property_merges_live_shards():
    gw = _gateway(8)
    sids = [f"conv{i}" for i in range(64)]
    for sid in sids:
        gw.route([1, 2, 3], user=sid, session_id=sid)
    # the merged snapshot equals the per-shard sums, and per-engine
    # counts re-unify engines routed from different shards
    assert gw.stats.routed == 64
    assert sum(sh.stats.routed for sh in gw._shards) == 64
    assert max(sh.stats.routed for sh in gw._shards) < 64  # really split
    assert sum(gw.stats.per_engine.values()) == 64
    # failure accounting lands on the engine's home shard, merges back
    gw.note_failure("e0", "crash")
    gw.note_failure("e0", "crash")
    gw.note_failure("e1", "hedged")
    assert gw.stats.engine_failures["e0"] == {"crash": 2}
    assert gw.stats.engine_failures["e1"] == {"hedged": 1}
    # session counters merge across every shard's policy
    ss = gw.session_stats()
    assert ss["session_misses"] == 64
    assert ss["session_pins"] == 64


def test_shed_accounting_merges_and_windows_per_shard():
    now = [0.0]
    gw = Gateway(policy="least-request", shards=4,
                 default_limit=RateLimit(rpm=60.0, tpm=1e15),
                 clock=lambda: now[0])
    gw.register_engine("e0", _FakeEngine())
    before = Gateway.total_shed
    # burst capacity is rpm/6 = 10: user u0's shard sheds the rest
    for _ in range(25):
        gw.route([1], user="u0")
    assert gw.stats.shed == 15
    assert Gateway.total_shed - before == 15
    sh = gw._shard_for("u0")
    assert sh.stats.rejected_rpm == 15   # all on the home shard
    assert sh._shed_log_at > float("-inf")   # windowed log armed


# ------------------------------------------------------ per-shard bounds
def test_per_shard_user_bucket_lru_bound():
    gw = _gateway(4, policy="least-request")
    gw.max_user_buckets = 32             # per-shard cap = 8
    for i in range(400):
        gw.route([1], user=f"u{i}")
    for sh in gw._shards:
        assert len(sh._rpm) <= 8
        assert len(sh._tpm) <= 8
        assert set(sh._rpm) == set(sh._tpm)   # paired eviction


def test_per_shard_session_pin_lru_bound():
    gw = _gateway(4, policy="session", max_sessions=8)
    for i in range(400):
        sid = f"conv{i}"
        gw.route([1], user=sid, session_id=sid)
    for sh in gw._shards:
        assert len(sh.policy._sessions) <= 8
    assert gw.session_stats()["session_pins"] <= 32


# -------------------------------------------------- retag pin purging
@pytest.mark.parametrize("path", ["set_engine_pool", "reregister"])
def test_retag_to_non_frontend_purges_pins_every_shard(path):
    """Satellite regression: an engine retagged into a non-frontend
    pool (decode/draining) must lose its session pins in EVERY shard —
    a surviving pin would route the session into a pool that no longer
    accepts new work until TTL expiry."""
    gw = _gateway(8)
    for eid in list(gw.engines):
        gw.engine_pool[eid] = "mixed"
    sids = [f"conv{i}" for i in range(64)]
    for sid in sids:
        gw.route([1, 2], user=sid, session_id=sid)
    victims = [sid for sid in sids
               if gw._shard_for(sid).policy._sessions[sid][0] == "e0"]
    assert victims                       # some sessions pinned to e0
    if path == "set_engine_pool":
        gw.set_engine_pool("e0", "decode")
    else:
        gw.register_engine("e0", gw.engines["e0"], pool="decode")
    for sh in gw._shards:
        assert not any(ent[0] == "e0"
                       for ent in sh.policy._sessions.values())
    # the re-homed turn routes through the fallback to a frontend
    # engine — never to the decode member
    for sid in victims:
        assert gw.route([1, 2], user=sid, session_id=sid) != "e0"
    assert gw.session_stats()["session_rehomed"] == 0  # purged, not stale


# ----------------------------------------------- promotion predictor
def test_think_ewma_tracks_turn_gaps():
    now = [0.0]
    pol = SessionAffinityPolicy()
    pol.attach_clock(lambda: now[0])
    engines = {"a": _FakeEngine()}
    pol.select(engines, [1], session_id="s")
    assert pol.think_ewma("s") is None   # one turn: no gap yet
    now[0] = 10.0
    pol.select(engines, [1], session_id="s")
    assert pol.think_ewma("s") == pytest.approx(10.0)
    now[0] = 30.0                        # gap 20: ewma moves 0.4 toward
    pol.select(engines, [1], session_id="s")
    assert pol.think_ewma("s") == pytest.approx(
        0.6 * 10.0 + 0.4 * 20.0)


def test_due_promotions_fire_lead_early_and_invalidate_on_touch():
    now = [0.0]
    pol = SessionAffinityPolicy(promote_lead_s=4.0)
    pol.attach_clock(lambda: now[0])
    engines = {"a": _FakeEngine()}
    pol.select(engines, [1], session_id="s")
    now[0] = 10.0
    pol.select(engines, [1], session_id="s")   # ewma=10 -> fire at 16
    assert pol.due_promotions(15.9) == []
    assert pol.due_promotions(16.1) == [("s", "a")]
    assert pol.due_promotions(16.1) == []      # popped, not repeated
    # a touch between schedule and fire invalidates the stale entry
    now[0] = 20.0
    pol.select(engines, [1], session_id="s")   # re-arms with new stamp
    now[0] = 21.0
    pol.select(engines, [1], session_id="s")   # touch again: old stale
    fired = pol.due_promotions(1e9)
    assert ("s", "a") in fired and len(fired) == 1


def test_promote_heap_bounded_skips_not_grows():
    now = [0.0]
    pol = SessionAffinityPolicy(promote_lead_s=1.0)
    pol.MAX_PROMOTE_HEAP = 4             # instance override for test
    pol.attach_clock(lambda: now[0])
    engines = {"a": _FakeEngine()}
    for i in range(8):
        sid = f"s{i}"
        pol.select(engines, [1], session_id=sid)
        now[0] += 1.0
        pol.select(engines, [1], session_id=sid)
        now[0] += 1.0
    assert len(pol._promote_heap) <= 4
    assert pol.promote_skipped == 4


def test_gateway_due_promotions_merges_shards():
    now = [0.0]
    gw = Gateway(policy="session", shards=8, promote_lead_s=100.0,
                 default_limit=RateLimit(rpm=1e12, tpm=1e15),
                 clock=lambda: now[0])
    for i in range(4):
        gw.register_engine(f"e{i}", _FakeEngine())
    sids = [f"conv{i}" for i in range(32)]
    for sid in sids:
        gw.route([1], user=sid, session_id=sid)
    now[0] = 5.0
    for sid in sids:
        gw.route([1], user=sid, session_id=sid)
    due = gw.due_promotions(now[0])      # lead 100 >> ewma: all due
    assert sorted(sid for sid, _ in due) == sorted(sids)
    assert all(eid in gw.engines for _, eid in due)


# ------------------------------------------- observation equivalence
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 15),     # which session
                          st.integers(0, 3),      # engine whose load drifts
                          st.integers(0, 5)),     # drift amount
                min_size=1, max_size=120))
def test_sharded_routing_observation_equivalent_to_monolithic(ops):
    """Any interleaving of 16 sessions' turns, with fleet load drifting
    between requests, routes IDENTICALLY through 1 shard and 16 shards:
    pins are per-session (never split across shards) and the fallback
    reads only global fleet state plus the session's own prefix
    affinity entry.  (Sessions carry their own prompts, as real
    conversations do — the fallback's epsilon tie-break for a prefix
    SHARED by sessions on different shards is shard-local state and is
    the one deliberate non-equivalence, worth <1e-6 of score.)"""
    engines = [_FakeEngine() for _ in range(4)]
    gw1 = Gateway(policy="session", shards=1,
                  default_limit=RateLimit(rpm=1e12, tpm=1e15))
    gwN = Gateway(policy="session", shards=16,
                  default_limit=RateLimit(rpm=1e12, tpm=1e15))
    for gw in (gw1, gwN):
        for i, e in enumerate(engines):
            gw.register_engine(f"e{i}", e)
    for s_idx, drift_e, drift in ops:
        sid = f"conv{s_idx}"
        prompt = [1000 + s_idx] * 20
        d1 = gw1.route(prompt, user=sid, session_id=sid)
        dn = gwN.route(prompt, user=sid, session_id=sid)
        assert d1 == dn
        engines[drift_e].queue_depth += drift
    assert gw1.stats.routed == gwN.stats.routed == len(ops)
    s1, sN = gw1.session_stats(), gwN.session_stats()
    assert s1 == sN                      # hits/misses/pins all agree
