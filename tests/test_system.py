"""End-to-end behaviour tests for the AIBrix system (real JAX engine +
control plane, and the cluster simulator at scale)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced_config
from repro.core.gateway import Gateway
from repro.core.sim import (ClusterConfig, ServingCluster, SimEngineConfig)
from repro.core.sim.workloads import birdsql_like, multiturn_chat, summarize
from repro.engine import (EngineConfig, InferenceEngine, Request,
                          RequestState, SamplingParams)
from repro.models import model as M


def _engine(seed=0, **kw):
    cfg = get_reduced_config("qwen3-0.6b")
    defaults = dict(page_size=8, num_pages=64, max_batch=4,
                    max_pages_per_seq=16, chunk_size=16)
    defaults.update(kw)
    return cfg, InferenceEngine(cfg, EngineConfig(**defaults), seed=seed)


def test_engine_greedy_matches_model_reference():
    cfg, eng = _engine()
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 20).tolist()
    req = Request(prompt_tokens=prompt,
                  sampling=SamplingParams(max_new_tokens=6))
    eng.submit(req)
    eng.run_until_idle()
    caches = M.init_cache(cfg, 1, 64)
    logits, caches = M.prefill(params=eng.params, cfg=cfg,
                               tokens=jnp.asarray([prompt], jnp.int32),
                               caches=caches)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(5):
        lg, caches = M.decode_step(eng.params, cfg, caches,
                                   jnp.asarray([out[-1]], jnp.int32),
                                   jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert req.output_tokens == out


def test_engine_prefix_cache_reuse_and_release():
    cfg, eng = _engine()
    rng = np.random.default_rng(1)
    shared = rng.integers(0, cfg.vocab_size, 16).tolist()
    for i in range(3):
        eng.submit(Request(prompt_tokens=shared + [100 + i, 7, 9],
                           sampling=SamplingParams(max_new_tokens=3)))
    eng.run_until_idle()
    m = eng.metrics()
    assert m.finished_requests == 3
    assert m.prefix_hit_tokens >= 16 * 2      # 2nd + 3rd reuse the prefix
    # after drain, no pages leak (cached pages are evictable, not leaked)
    assert eng.alloc.num_free == eng.alloc.num_pages


def test_engine_multi_lora_batches():
    cfg, eng = _engine()
    eng.register_adapter("sql")
    eng.register_adapter("chat")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, 12).tolist()
               for _ in range(3)]
    reqs = [Request(prompt_tokens=prompts[0],
                    sampling=SamplingParams(max_new_tokens=4)),
            Request(prompt_tokens=prompts[1], lora_adapter="sql",
                    sampling=SamplingParams(max_new_tokens=4)),
            Request(prompt_tokens=prompts[2], lora_adapter="chat",
                    sampling=SamplingParams(max_new_tokens=4))]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    assert all(r.state == RequestState.FINISHED for r in reqs)
    # adapter must change the output vs base model for the same prompt
    r_base = Request(prompt_tokens=prompts[1],
                     sampling=SamplingParams(max_new_tokens=4))
    eng.submit(r_base)
    eng.run_until_idle()
    assert r_base.output_tokens != reqs[1].output_tokens


def test_engine_preemption_recovers():
    cfg, eng = _engine(num_pages=12, max_pages_per_seq=8, max_batch=3)
    rng = np.random.default_rng(3)
    for i in range(3):
        eng.submit(Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, 24).tolist(),
            sampling=SamplingParams(max_new_tokens=16)))
    eng.run_until_idle()
    m = eng.metrics()
    assert m.finished_requests == 3           # all complete despite pressure


def test_gateway_to_engine_roundtrip():
    cfg, e0 = _engine(seed=0)
    _, e1 = _engine(seed=1)
    gw = Gateway(policy="least-request")
    gw.register_engine("e0", e0)
    gw.register_engine("e1", e1)
    rng = np.random.default_rng(4)
    engines = {"e0": e0, "e1": e1}
    reqs = []
    for i in range(6):
        p = rng.integers(0, cfg.vocab_size, 10 + i).tolist()
        r = Request(prompt_tokens=p,
                    sampling=SamplingParams(max_new_tokens=3))
        eid = gw.route(p, est_output_tokens=3)
        engines[eid].submit(r)
        reqs.append(r)
    for eng in engines.values():
        eng.run_until_idle()
    assert all(len(r.output_tokens) == 3 for r in reqs)
    assert len(gw.stats.per_engine) == 2      # both engines used


# ----------------------------------------------------------- simulator
def test_cluster_sim_conserves_requests():
    cfg = get_config("deepseek-coder-7b")
    ccfg = ClusterConfig(num_engines=3,
                         engine=SimEngineConfig(device_type="a10"))
    cluster = ServingCluster(cfg, ccfg)
    wl = birdsql_like(120, rate_rps=6.0, seed=0)
    s = cluster.run(wl)
    assert s["finished"] + s["rejected"] == 120
    assert s["ttft_avg_ms"] > 0 and s["itl_avg_ms"] > 0


def test_distributed_pool_improves_ttft_on_shared_prefixes():
    cfg = get_config("deepseek-coder-7b")

    def run(pool):
        ccfg = ClusterConfig(
            num_engines=4, use_kv_pool=pool,
            engine=SimEngineConfig(device_type="a10",
                                   prefix_caching=False))
        cluster = ServingCluster(cfg, ccfg)
        return cluster.run(birdsql_like(200, rate_rps=12.0, seed=1))

    without = run(False)
    with_pool = run(True)
    assert with_pool["ttft_avg_ms"] < without["ttft_avg_ms"] * 0.8
    assert with_pool["remote_hit_tokens"] > 0


def test_prefix_routing_beats_random_on_multiturn():
    cfg = get_config("deepseek-coder-7b")

    def run(policy):
        ccfg = ClusterConfig(routing_policy=policy, num_engines=4,
                             engine=SimEngineConfig(device_type="a10"))
        cluster = ServingCluster(cfg, ccfg)
        wl = multiturn_chat(24, turns=5, rate_rps=8.0, seed=2)
        return cluster.run(wl)

    rnd = run("random")
    aff = run("prefix-load")
    assert aff["prefix_hit_tokens"] > rnd["prefix_hit_tokens"]
    assert aff["ttft_avg_ms"] <= rnd["ttft_avg_ms"] * 1.05
