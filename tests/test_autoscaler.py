"""Autoscaler policies + sliding-window metrics (paper §3.2.4)."""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.core.autoscaler import (APA, HPA, KPA, MetricStore,
                                   SlidingWindow, make_autoscaler)


def test_sliding_window_mean_and_trim():
    w = SlidingWindow(window_s=10.0, granularity_s=1.0)
    for t in range(20):
        w.record(float(t), float(t))
    # at t=19 the window covers [9, 19]
    assert 13.0 <= w.mean(19.0) <= 15.0
    assert w.mean(100.0) is None          # fully trimmed


def test_metric_store_propagation_delay():
    s = MetricStore(propagation_delay_s=15.0)
    s.record(0.0, "concurrency", 10.0)
    assert s.stable(5.0, "concurrency") is None       # still in flight
    assert s.stable(16.0, "concurrency") == 10.0      # delivered


def _store_with_load(values):
    s = MetricStore()
    for t, v in values:
        s.record(t, "m", v)
    return s


def test_kpa_panic_reacts_to_burst():
    s = _store_with_load([(float(t), 2.0 if t < 60 else 40.0)
                          for t in range(70)])
    kpa = make_autoscaler("kpa", metric="m", target=4.0)
    d = kpa.desired(69.5, s, current=1)
    assert d.desired >= 8
    assert d.panic


def test_apa_tolerance_band_no_flapping():
    # load right at capacity: APA must hold steady
    s = _store_with_load([(float(t), 8.05) for t in range(60)])
    apa = make_autoscaler("apa", metric="m", target=4.0,
                          up_fluctuation=0.1, down_fluctuation=0.2)
    assert apa.desired(59.5, s, current=2).desired == 2


def test_hpa_scale_down_stabilization():
    hpa = HPA(metric="m", target=4.0, sync_period_s=1.0,
              scale_down_stabilization_s=100.0)
    s = MetricStore(stable_window_s=5.0)
    for t in range(30):
        s.record(float(t), "m", 40.0)     # high load
    d_hi = hpa.desired(30.0, s, current=2)
    assert d_hi.desired >= 8
    for t in range(31, 60):
        s.record(float(t), "m", 0.5)      # load vanishes
    d_lo = hpa.desired(59.0, s, current=d_hi.desired)
    # stabilization window keeps the old (high) desired for a while
    assert d_lo.desired >= d_hi.desired


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(["hpa", "kpa", "apa"]),
       st.lists(st.floats(0.0, 100.0), min_size=5, max_size=50),
       st.integers(1, 16))
def test_desired_always_within_bounds(name, loads, current):
    """Property: any metric stream yields min<=desired<=max."""
    asc = make_autoscaler(name, metric="m", target=4.0,
                          min_replicas=1, max_replicas=20)
    s = MetricStore()
    for i, v in enumerate(loads):
        s.record(float(i), "m", v)
    d = asc.desired(float(len(loads)), s, current)
    assert 1 <= d.desired <= 20
