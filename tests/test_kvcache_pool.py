"""Distributed KV cache pool + eviction policies: unit and property
tests (hypothesis) for the paper's §3.2.5 mechanisms."""
try:
    import hypothesis.strategies as st
    from hypothesis import HealthCheck, given, settings
except ImportError:                       # pragma: no cover
    from _hypothesis_fallback import HealthCheck, given, settings, st

from repro.core.kvcache.eviction import LRU, LRUK, S3FIFO
from repro.core.kvcache.pool import DistributedKVPool
from repro.engine.page_table import PageAllocator, chunk_hashes


# ------------------------------------------------------------------ pool
def _pool(cap_blocks=8, policy="s3fifo", lag=0.0):
    return DistributedKVPool(capacity_bytes=cap_blocks * 1024,
                             block_bytes=1024, policy=policy,
                             metadata_lag=lag)


def test_publish_fetch_roundtrip():
    p = _pool()
    p.attach_engine("e0", "node-0")
    assert p.publish("h1", ("k", "v"), "e0", now=0.0)
    p.tick(1.0)
    assert p.fetch("h1", "e0", now=1.0) == ("k", "v")
    assert p.stats.hits_local == 1


def test_async_metadata_visibility_lag():
    p = _pool(lag=0.5)
    p.publish("h1", "x", "e0", now=0.0)
    assert p.fetch("h1", "e0", now=0.1) is None      # not yet visible
    assert p.fetch("h1", "e0", now=0.6) == "x"       # after the lag


def test_duplicate_publish_dropped_before_transfer():
    p = _pool()
    assert p.publish("h1", "x", "e0", now=0.0)
    p.tick(0.1)
    assert not p.publish("h1", "x2", "e1", now=0.2)
    assert p.stats.dup_puts_dropped == 1


def test_contains_includes_pending_metadata():
    """contains() answers 'does the pool know this hash' — including
    blocks still in the async metadata queue, so engines skip
    materializing payloads for blocks published moments ago."""
    p = _pool(lag=0.5)
    p.publish("h1", "x", "e0", now=0.0)
    assert p.contains("h1")                          # pending counts
    assert p.fetch("h1", "e0", now=0.1) is None      # but not fetchable
    p.tick(1.0)
    assert p.contains("h1") and p.fetch("h1", "e0", now=1.1) == "x"
    assert not p.contains("nope")


def test_colocated_vs_remote_hit_accounting():
    p = _pool()
    p.attach_engine("e0", "node-0")
    p.attach_engine("e1", "node-1")
    p.publish("h1", "x", "e0", now=0.0)
    p.tick(0.1)
    p.fetch("h1", "e0", now=0.2)
    p.fetch("h1", "e1", now=0.3)
    assert p.stats.hits_local == 1 and p.stats.hits_remote == 1
    assert p.stats.bytes_transferred == 1024          # remote only
    assert p.fetch_cost_s("h1", "e0") < p.fetch_cost_s("h1", "e1")


def test_capacity_eviction():
    p = _pool(cap_blocks=4)
    for i in range(8):
        p.publish(f"h{i}", i, "e0", now=float(i))
        p.tick(float(i) + 0.1)
    assert p.stats.bytes_stored <= p.capacity_bytes
    assert p.stats.evictions >= 4


def test_scan_resistance_s3fifo_beats_lru():
    """A one-shot scan must not flush the hot working set: the paper's
    scan-resistant eviction claim, demonstrated policy-vs-policy."""
    def run(policy):
        p = _pool(cap_blocks=16, policy=policy)
        hot = [f"hot{i}" for i in range(8)]
        for h in hot:
            p.publish(h, h, "e0")
            p.tick(1.0)
        hits = 0
        t = 2.0
        for round_ in range(30):
            for h in hot:                       # hot set re-referenced
                if p.fetch(h, "e0", now=t):
                    hits += 1
                else:
                    p.publish(h, h, "e0", now=t)
                t += 0.01
            # one-shot scan LARGER than capacity (the flush case)
            for j in range(16):
                p.publish(f"scan{round_}-{j}", j, "e0", now=t)
                t += 0.01
            p.tick(t)
        return hits

    assert run("s3fifo") > run("lru") * 3


# ------------------------------------------------------------------ eviction property tests
@st.composite
def ops_seq(draw):
    n = draw(st.integers(2, 60))
    ops = []
    for _ in range(n):
        kind = draw(st.sampled_from(["insert", "access", "evict"]))
        key = draw(st.integers(0, 12))
        ops.append((kind, key))
    return ops


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops_seq(), st.sampled_from(["lru", "s3fifo", "lru2"]))
def test_eviction_policy_invariants(ops, policy_name):
    policy = {"lru": LRU, "s3fifo": lambda: S3FIFO(16),
              "lru2": LRUK}[policy_name]()
    live = set()
    for kind, key in ops:
        if kind == "insert":
            policy.on_insert(key)
            live.add(key)
        elif kind == "access":
            policy.on_access(key)
        else:
            victim = policy.evict()
            if victim is not None:
                # victims must be live, and never resurrected silently
                assert victim in live
                live.discard(victim)
            else:
                assert not live or policy_name == "s3fifo"
    # draining evicts every remaining key exactly once
    drained = set()
    for _ in range(len(live) + 5):
        v = policy.evict()
        if v is None:
            break
        assert v in live and v not in drained
        drained.add(v)
    assert drained == live


# ------------------------------------------------------------------ allocator
@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 6), min_size=1, max_size=30),
       st.integers(8, 32))
def test_page_allocator_never_leaks(sizes, num_pages):
    """Property: allocate/release cycles conserve pages; refcounts never
    go negative; utilization stays within [0, 1]."""
    alloc = PageAllocator(num_pages, page_size=4)
    held = []
    t = 0.0
    for n in sizes:
        t += 1.0
        pages = alloc.allocate(n, t)
        if pages is not None:
            held.append(pages)
        assert 0.0 <= alloc.utilization <= 1.0
        if len(held) > 3:
            alloc.release(held.pop(0), t)
    for pages in held:
        alloc.release(pages, t)
    assert alloc.num_free == num_pages


def test_prefix_match_shares_pages():
    alloc = PageAllocator(64, page_size=4)
    tokens = list(range(20))
    pages = alloc.allocate(5, 0.0)
    for i, h in enumerate(chunk_hashes(tokens, 4)):
        alloc.register_hash(pages[i], h)
    matched, covered = alloc.match_prefix(tokens + [99, 98], 1.0)
    assert covered == 20
    assert matched == pages
    # matching must never cover the whole prompt exactly
    m2, c2 = alloc.match_prefix(tokens, 1.0)
    assert c2 < len(tokens)
    alloc.release(matched, 2.0)
    alloc.release(m2, 2.0)


def test_chunk_hashes_prefix_property():
    a = chunk_hashes(list(range(32)), 8)
    b = chunk_hashes(list(range(32)) + [7, 7, 7], 8)
    assert b[:len(a)] == a                       # prefix-stable
    c = chunk_hashes([1] + list(range(31)), 8)
    assert c[0] != a[0]                          # content-addressed
