"""Docs integrity: README/ARCHITECTURE exist and every relative link
in the markdown docs resolves (the CI docs-check step runs the same
checker standalone)."""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from check_docs_links import broken_links  # noqa: E402


def _doc_files():
    return [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]


def test_required_docs_exist():
    assert (REPO / "README.md").exists()
    assert (REPO / "docs" / "ARCHITECTURE.md").exists()


def test_no_broken_relative_links():
    bad = [b for p in _doc_files() for b in broken_links(p)]
    assert bad == [], f"broken relative links: {bad}"


def test_roadmap_references_architecture_doc():
    """ROADMAP must not reference the never-written DESIGN.md."""
    text = (REPO / "ROADMAP.md").read_text()
    assert "DESIGN.md" not in text
    assert "ARCHITECTURE.md" in text
