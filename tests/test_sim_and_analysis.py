"""Simulator internals, workload generators, analytic roofline model,
and the HLO collective parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.configs import INPUT_SHAPES, get_config
from repro.core.sim.events import EventLoop
from repro.core.sim.sim_engine import SimEngine, SimEngineConfig
from repro.core.sim.workloads import (birdsql_like, burst, multiturn_chat,
                                      sharegpt_like, summarize)
from repro.engine.request import Request, SamplingParams
from repro.launch import analytic, hlo_analysis
from repro.launch.mesh import make_debug_mesh


# ---------------------------------------------------------------- events
def test_event_loop_ordering_and_every():
    loop = EventLoop()
    seen = []
    loop.schedule(2.0, lambda: seen.append("b"))
    loop.schedule(1.0, lambda: seen.append("a"))
    loop.every(1.0, lambda: seen.append("t"), until=3.5)
    loop.run(until=10.0)
    assert seen[0] == "t" or seen[1] in ("a", "t")
    assert seen.count("t") == 3
    assert loop.clock.now <= 10.0


# ------------------------------------------------------------- workloads
def test_workload_generators_shapes():
    w1 = sharegpt_like(5.0, 10.0, seed=0)
    assert all(tr.request.prompt_len >= 8 for tr in w1)
    w2 = birdsql_like(50, 5.0, seed=0)
    # schema sharing: many requests share their first 1600 tokens
    first = [tuple(tr.request.prompt_tokens[:16]) for tr in w2]
    assert len(set(first)) <= 12
    w3 = multiturn_chat(4, 3, 5.0, seed=0)
    assert len(w3) == 12
    # turn k+1 of a conversation extends turn k's prompt
    conv0 = [tr.request for tr in w3 if tr.request.user == "conv-0"]
    for a, b in zip(conv0, conv0[1:]):
        assert b.prompt_tokens[:a.prompt_len] == a.prompt_tokens
    w4 = burst(1.0, 10.0, 30.0, 10.0, 10.0, seed=0)
    in_burst = sum(1 for tr in w4 if 10 <= tr.arrival < 20)
    out_burst = sum(1 for tr in w4 if tr.arrival < 10)
    assert in_burst > out_burst * 3


def test_summarize_percentiles():
    reqs = []
    for i in range(10):
        r = Request(prompt_tokens=[0] * 10, arrival_time=float(i))
        r.first_token_time = i + 0.1 * (i + 1)
        r.token_times = [r.first_token_time + 0.05]
        r.output_tokens = [1, 2]
        r.finish_time = r.token_times[-1]
        reqs.append(r)
    s = summarize(reqs)
    assert s["finished"] == 10
    assert s["ttft_p99_ms"] >= s["ttft_avg_ms"]


# ------------------------------------------------------------ sim engine
def test_sim_engine_progress_and_metrics():
    loop = EventLoop()
    cfg = get_config("deepseek-coder-7b")
    eng = SimEngine(cfg, loop, SimEngineConfig(device_type="a10"))
    for i in range(5):
        eng.submit(Request(prompt_tokens=list(range(500)),
                           sampling=SamplingParams(max_new_tokens=20),
                           arrival_time=0.0))
    loop.run(until=1e6, stop_when=lambda: not eng.has_work)
    m = eng.metrics()
    assert m.finished_requests == 5
    assert all(r.ttft > 0 and r.total_latency >= r.ttft
               for r in eng.finished)
    # physics sanity: prefill of 500 tokens on an a10 takes ~0.1s
    assert 0.01 < eng.finished[0].ttft < 5.0


def test_sim_engine_dead_device_stops():
    loop = EventLoop()
    cfg = get_config("deepseek-coder-7b")
    eng = SimEngine(cfg, loop, SimEngineConfig(device_type="a10"))
    eng.slowdown_fn = lambda: 0.0            # device lost
    eng.submit(Request(prompt_tokens=[1] * 100,
                       sampling=SamplingParams(max_new_tokens=5),
                       arrival_time=0.0))
    loop.run(until=100.0)
    assert eng.metrics().finished_requests == 0


def test_pd_disaggregation_handoff():
    from repro.core.kvcache.pool import DistributedKVPool
    loop = EventLoop()
    cfg = get_config("deepseek-coder-7b")
    pool = DistributedKVPool(capacity_bytes=8 << 30, metadata_lag=0.001,
                             clock=loop.clock)
    pre = SimEngine(cfg, loop, SimEngineConfig(role="prefill"),
                    kv_pool=pool, engine_id="p0", node="n0")
    dec = SimEngine(cfg, loop, SimEngineConfig(role="decode"),
                    kv_pool=pool, engine_id="d0", node="n1")
    pre.handoff = dec.submit
    req = Request(prompt_tokens=list(range(300)),
                  sampling=SamplingParams(max_new_tokens=10),
                  arrival_time=0.0)
    pre.submit(req)
    loop.run(until=1e5, stop_when=lambda: not (pre.has_work
                                               or dec.has_work))
    assert len(req.output_tokens) == 10
    assert req in dec.finished and req not in pre.finished
    assert dec.metrics().remote_hit_tokens > 0      # KV came via the pool


# ---------------------------------------------------------- analytic
@pytest.mark.parametrize("arch", ("qwen3-0.6b", "deepseek-v2-236b",
                                  "xlstm-1.3b"))
def test_analytic_estimates_positive_and_ordered(arch):
    cfg = get_config(arch)
    tr = analytic.estimate(cfg, "train", 256, 4096)
    pf = analytic.estimate(cfg, "prefill", 32, 32768)
    dc = analytic.estimate(cfg, "decode", 128, 32768)
    assert tr.flops > pf.flops > dc.flops > 0
    assert tr.model_flops <= tr.flops        # overhead ratio <= 1
    terms = analytic.roofline_terms(dc, 1e8, 256)
    assert terms["dominant"] in ("compute", "memory", "collective")
    assert 0 < terms["useful_flops_ratio"] <= 1.0


def test_moe_active_flops_below_dense_equivalent():
    ds = get_config("deepseek-v2-236b")
    est = analytic.estimate(ds, "decode", 128, 32768)
    dense_equiv = 2.0 * ds.param_count() * 128
    assert est.model_flops < dense_equiv * 0.25      # 21B active of 236B


# ---------------------------------------------------------- hlo parsing
def test_collective_report_counts_loop_trips():
    mesh = make_debug_mesh(1, 1)

    def f(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    # hand-check the parser on a synthetic HLO with a while loop
    hlo = """
ENTRY %main (a: f32[8]) -> f32[8] {
  %w = f32[8]{0} while(%a), condition=%cond, body=%body
}
%cond (s: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(12)
  %lt = pred[] compare(%i, %c)
}
%body (s: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ag = f32[64]{0} all-gather(%x), replica_groups={}
}
"""
    rep = hlo_analysis.collective_report(hlo)
    assert rep["all-gather"] == 12 * 64 * 4          # trip-count scaled
    assert rep["total"] == rep["all-gather"]


def test_op_histogram_smoke():
    hist = hlo_analysis.op_histogram(
        "%a = f32[2]{0} add(%x, %y)\n%b = f32[2]{0} multiply(%a, %a)")
    assert hist.get("add") == 1 and hist.get("multiply") == 1


def test_request_migration_via_pool():
    """Paper §3.1: the pool supports live request migration — generated
    KV moves with the request; only the block tail is recomputed."""
    from repro.core.kvcache.pool import DistributedKVPool
    loop = EventLoop()
    cfg = get_config("deepseek-coder-7b")
    pool = DistributedKVPool(capacity_bytes=8 << 30, metadata_lag=0.001,
                             clock=loop.clock)
    src = SimEngine(cfg, loop, SimEngineConfig(), kv_pool=pool,
                    engine_id="src", node="n0")
    dst = SimEngine(cfg, loop, SimEngineConfig(), kv_pool=pool,
                    engine_id="dst", node="n1")
    req = Request(prompt_tokens=list(range(256)),
                  sampling=SamplingParams(max_new_tokens=40),
                  arrival_time=0.0)
    src.submit(req)
    # let it prefill and decode ~10 tokens, then migrate
    loop.run(until=1e5,
             stop_when=lambda: len(req.output_tokens) >= 10)
    assert req in src.running
    assert src.migrate_out(req, dst)
    loop.run(until=1e6, stop_when=lambda: not (src.has_work
                                               or dst.has_work))
    assert len(req.output_tokens) == 40          # finished on dst
    assert req in dst.finished
    assert dst.metrics().remote_hit_tokens > 0   # KV moved via the pool
    assert src._m.get("migrations") == 1
