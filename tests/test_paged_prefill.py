"""Paged flash-prefill kernel parity + fused mixed-batch engine tests.

Kernel: interpret-mode Pallas vs the pure-jnp oracle across ragged
ctx/chunk lengths, GQA group ratios and page sizes.  Engine: the fused
token-budget scheduler must reproduce the legacy two-phase scheduler's
greedy outputs exactly, hold multiple requests in PREFILLING while
decoding, and respect the per-step token budget.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.engine import (EngineConfig, InferenceEngine, Request,
                          RequestState, SamplingParams)
from repro.kernels import ops
from repro.kernels import ref as kref

RNG = np.random.default_rng(7)


def _rand(shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


# ------------------------------------------------------------------ kernel
PAGED_PREFILL_CASES = [
    # (B, H, Hkv, D, page) — GQA ratios 1/2/4 x page sizes 8/16/64
    (2, 4, 4, 32, 8),       # MHA, small pages
    (1, 8, 4, 64, 16),      # GQA 2
    (2, 8, 2, 32, 16),      # GQA 4
    (1, 4, 4, 64, 64),      # MHA, big pages
    (2, 8, 2, 64, 64),      # GQA 4, big pages
    (1, 8, 4, 32, 8),       # GQA 2, small pages
]


@pytest.mark.parametrize("b,h,hkv,d,page", PAGED_PREFILL_CASES)
def test_paged_prefill_matches_ref(b, h, hkv, d, page):
    s = 24                              # ragged: not a block_q multiple
    cap = 192                           # tokens of paged capacity per seq
    nb = cap // page
    p = b * nb + 3
    kp = _rand((p, page, hkv, d))
    vp = _rand((p, page, hkv, d))
    bt = jnp.asarray(RNG.permutation(p)[:b * nb].reshape(b, nb), jnp.int32)
    ctx = jnp.asarray(RNG.integers(0, cap - s + 1, b), jnp.int32)
    chunk = jnp.asarray(RNG.integers(1, s + 1, b), jnp.int32)
    q = _rand((b, s, h, d))
    out = ops.paged_prefill(q, kp, vp, bt, ctx, chunk)
    refv = kref.paged_prefill_ref(q, kp, vp, bt, ctx, chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refv),
                               atol=3e-5, rtol=3e-5)


def test_paged_prefill_zero_ctx_equals_flash():
    """A first chunk (ctx=0) must agree with contiguous flash prefill."""
    b, s, h, hkv, d, page = 1, 32, 4, 2, 32, 8
    nb = s // page
    kp = _rand((nb + 1, page, hkv, d))
    vp = _rand((nb + 1, page, hkv, d))
    bt = jnp.arange(nb, dtype=jnp.int32)[None]
    q = _rand((b, s, h, d))
    ctx = jnp.zeros(b, jnp.int32)
    chunk = jnp.full(b, s, jnp.int32)
    out = ops.paged_prefill(q, kp, vp, bt, ctx, chunk)
    k = kp[:nb].reshape(1, s, hkv, d)
    v = vp[:nb].reshape(1, s, hkv, d)
    refv = kref.flash_prefill_ref(q, k, v, jnp.full(b, s, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(refv),
                               atol=3e-5, rtol=3e-5)


def test_paged_prefill_padding_rows_are_zero():
    b, s, h, hkv, d, page = 2, 16, 4, 2, 32, 8
    nb = 4
    kp = _rand((b * nb + 1, page, hkv, d))
    vp = _rand((b * nb + 1, page, hkv, d))
    bt = jnp.asarray(np.arange(b * nb).reshape(b, nb), jnp.int32)
    chunk = jnp.asarray([5, 12], jnp.int32)
    ctx = jnp.asarray([8, 0], jnp.int32)
    out = np.asarray(ops.paged_prefill(_rand((b, s, h, d)), kp, vp, bt,
                                       ctx, chunk))
    for i, c in enumerate([5, 12]):
        assert np.all(out[i, c:] == 0.0)
        assert np.any(out[i, :c] != 0.0)


# ------------------------------------------------------------------ engine
def _engine(seed=0, **kw):
    cfg = get_reduced_config("qwen3-0.6b")
    defaults = dict(page_size=8, num_pages=64, max_batch=4,
                    max_pages_per_seq=16, chunk_size=16)
    defaults.update(kw)
    return cfg, InferenceEngine(cfg, EngineConfig(**defaults), seed=seed)


def test_mixed_batch_matches_two_phase_greedy():
    """The fused mixed-batch scheduler must emit exactly the tokens the
    legacy one-prefill-at-a-time scheduler emits under greedy sampling."""
    cfg, _ = _engine()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (22, 35, 9, 28)]

    def run(mixed: bool):
        _, eng = _engine(mixed_batching=mixed, max_prefills=2)
        reqs = [Request(prompt_tokens=list(p),
                        sampling=SamplingParams(max_new_tokens=5))
                for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run_until_idle()
        return [r.output_tokens for r in reqs]

    assert run(True) == run(False)


def test_concurrent_prefills_while_decoding():
    cfg, eng = _engine(max_prefills=2)
    rng = np.random.default_rng(12)
    warm = Request(prompt_tokens=rng.integers(0, cfg.vocab_size, 10).tolist(),
                   sampling=SamplingParams(max_new_tokens=30))
    eng.submit(warm)
    while warm.state != RequestState.RUNNING:
        eng.step()
    for _ in range(2):          # two long, distinct-prefix prompts
        eng.submit(Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, 40).tolist(),
            sampling=SamplingParams(max_new_tokens=3)))
    eng.step()
    assert len(eng.prefills) == 2
    assert all(r.state == RequestState.PREFILLING for r in eng.prefills)
    assert warm.state == RequestState.RUNNING
    decoded_before = len(warm.output_tokens)
    eng.step()                  # decode continues alongside both prefills
    assert len(warm.output_tokens) > decoded_before
    eng.run_until_idle()
    assert eng.metrics().finished_requests == 3


def test_token_budget_caps_prefill_progress():
    cfg, eng = _engine(max_prefills=2, max_batch=2, chunk_size=16,
                       token_budget=12)
    rng = np.random.default_rng(13)
    reqs = [Request(prompt_tokens=rng.integers(0, cfg.vocab_size,
                                               40).tolist(),
                    sampling=SamplingParams(max_new_tokens=2))
            for _ in range(2)]
    for r in reqs:
        eng.submit(r)
    for _ in range(30):
        before = [r.prefill_done_tokens for r in reqs]
        n_dec = len(eng.running[:eng.ecfg.max_batch])
        eng.step()
        progressed = sum(r.prefill_done_tokens - b
                         for r, b in zip(reqs, before))
        assert n_dec + progressed <= eng.ecfg.step_token_budget
        if not eng.has_work:
            break
    assert all(r.state == RequestState.FINISHED for r in reqs)


def test_single_prefill_config_reproduces_legacy():
    """max_prefills=1 + mixed batching off == the old engine behavior."""
    cfg, eng = _engine(mixed_batching=False, max_prefills=1)
    rng = np.random.default_rng(14)
    r1 = Request(prompt_tokens=rng.integers(0, cfg.vocab_size, 20).tolist(),
                 sampling=SamplingParams(max_new_tokens=4))
    r2 = Request(prompt_tokens=rng.integers(0, cfg.vocab_size, 20).tolist(),
                 sampling=SamplingParams(max_new_tokens=4))
    eng.submit(r1)
    eng.submit(r2)
    eng.step()
    # legacy scheduler: never more than ONE request in PREFILLING
    assert len(eng.prefills) <= 1
    eng.run_until_idle()
    assert r1.state == r2.state == RequestState.FINISHED


def test_prefix_sharing_deferred_until_pages_register():
    """Cache-aware admission: a request sharing its leading block with an
    in-flight prefill waits, then reuses the registered prefix pages."""
    cfg, eng = _engine(max_prefills=2)
    rng = np.random.default_rng(15)
    shared = rng.integers(0, cfg.vocab_size, 16).tolist()
    reqs = [Request(prompt_tokens=shared + [1000 + i],
                    sampling=SamplingParams(max_new_tokens=2))
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert len(eng.prefills) == 1       # second deferred, not co-admitted
    eng.run_until_idle()
    assert eng.metrics().prefix_hit_tokens >= 16


def test_deferred_head_does_not_block_distinct_prefix():
    """A deferred prefix-sharer must not head-of-line-block a waiter
    with a distinct prefix from taking the free prefill slot."""
    cfg, eng = _engine(max_prefills=2, chunk_size=8)
    rng = np.random.default_rng(16)
    shared = rng.integers(0, cfg.vocab_size, 16).tolist()
    first = Request(prompt_tokens=shared + [7],
                    sampling=SamplingParams(max_new_tokens=2))
    sharer = Request(prompt_tokens=shared + [8],
                     sampling=SamplingParams(max_new_tokens=2))
    distinct = Request(
        prompt_tokens=rng.integers(0, cfg.vocab_size, 20).tolist(),
        sampling=SamplingParams(max_new_tokens=2))
    for r in (first, sharer, distinct):
        eng.submit(r)
    eng.step()
    assert first.state == RequestState.PREFILLING
    assert sharer.state == RequestState.QUEUED      # deferred
    assert distinct.state == RequestState.PREFILLING  # skipped past sharer
    eng.run_until_idle()
    assert eng.metrics().finished_requests == 3
    assert eng.metrics().prefix_hit_tokens >= 16    # sharer reused prefix
