"""Unified scheduler core: the Scheduler/ModelRunner split must be
behavior-preserving (greedy outputs identical to the model reference in
both scheduling modes), the real engine and the simulator must share
ONE Scheduler implementation, and P/D disaggregation must work on the
real JAX data plane (a decode engine serves a request whose KV it never
prefilled, byte-identical to a colocated engine)."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.kvcache.pool import DistributedKVPool
from repro.core.sim.events import EventLoop
from repro.core.sim.sim_engine import SimEngine, SimEngineConfig
from repro.engine import (EngineConfig, InferenceEngine, Request,
                          RequestState, SamplingParams, Scheduler)
from repro.engine.page_table import PageAllocator
from repro.engine.slot_engine import SlotEngine, SlotEngineConfig
from repro.models import model as M

ENGINE_KW = dict(page_size=8, num_pages=64, max_batch=4,
                 max_pages_per_seq=16, chunk_size=16)


def _engine(seed=0, **kw):
    cfg = get_reduced_config("qwen3-0.6b")
    defaults = dict(ENGINE_KW)
    defaults.update(kw)
    return cfg, InferenceEngine(cfg, EngineConfig(**defaults), seed=seed)


# ------------------------------------------------- greedy equivalence
@pytest.mark.parametrize("mixed", [True, False],
                         ids=["mixed", "two-phase"])
def test_engine_greedy_matches_model_reference(mixed):
    """Post-refactor engine (Scheduler + ModelRunner) must emit exactly
    the reference model's greedy tokens in BOTH scheduling modes."""
    cfg, eng = _engine(mixed_batching=mixed)
    rng = np.random.default_rng(31)
    prompt = rng.integers(0, cfg.vocab_size, 20).tolist()
    req = Request(prompt_tokens=prompt,
                  sampling=SamplingParams(max_new_tokens=6))
    eng.submit(req)
    eng.run_until_idle()
    caches = M.init_cache(cfg, 1, 64)
    logits, caches = M.prefill(params=eng.params, cfg=cfg,
                               tokens=jnp.asarray([prompt], jnp.int32),
                               caches=caches)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(5):
        lg, caches = M.decode_step(eng.params, cfg, caches,
                                   jnp.asarray([out[-1]], jnp.int32),
                                   jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert req.output_tokens == out


def test_two_phase_step_returns_actual_tokens():
    """A prefill chunk that does not complete the prompt produces no
    token; summed step() returns must equal the tokens generated."""
    cfg, eng = _engine(mixed_batching=False, chunk_size=16)
    rng = np.random.default_rng(32)
    req = Request(prompt_tokens=rng.integers(0, cfg.vocab_size,
                                             40).tolist(),
                  sampling=SamplingParams(max_new_tokens=4))
    eng.submit(req)
    returns = []
    while eng.has_work:
        returns.append(eng.step())
    # 40-token prompt / 16-token chunks: two chunks produce nothing,
    # the third completes the prefill and samples the first token
    assert returns[0] == 0 and returns[1] == 0 and returns[2] == 1
    assert sum(returns) == len(req.output_tokens) == 4


# ------------------------------------------------- shared scheduler
def test_sim_and_real_share_scheduler_implementation():
    """One Scheduler class drives both data planes; SimEngine carries
    no admission/budget/role logic of its own anymore."""
    for dup in ("_try_admit", "_maybe_finish", "_preempt"):
        assert not hasattr(SimEngine, dup)
    cfg, eng = _engine()
    loop = EventLoop()
    sim = SimEngine(get_reduced_config("qwen3-0.6b"), loop,
                    SimEngineConfig(device_type="a10"))
    assert type(eng.sched) is Scheduler
    assert type(sim.sched) is Scheduler


def test_sim_real_admission_parity():
    """Identical workloads admit in the same (FIFO) order through the
    shared Scheduler on both the real engine and the simulator."""
    cfg = get_reduced_config("qwen3-0.6b")
    rng = np.random.default_rng(33)
    prompts = [rng.integers(0, cfg.vocab_size, 12 + 4 * i).tolist()
               for i in range(5)]

    _, eng = _engine(mixed_batching=False)
    real_reqs = [Request(prompt_tokens=list(p),
                         sampling=SamplingParams(max_new_tokens=2))
                 for p in prompts]
    for r in real_reqs:
        eng.submit(r)
    eng.run_until_idle()

    loop = EventLoop()
    sim = SimEngine(get_reduced_config("qwen3-0.6b"), loop,
                    SimEngineConfig(device_type="a10"))
    sim_reqs = [Request(prompt_tokens=list(p),
                        sampling=SamplingParams(max_new_tokens=2),
                        arrival_time=0.0)
                for p in prompts]
    for r in sim_reqs:
        sim.submit(r)
    loop.run(until=1e6, stop_when=lambda: not sim.has_work)

    def admit_order(reqs):
        order = sorted(range(len(reqs)),
                       key=lambda i: reqs[i].schedule_time)
        return order

    assert all(r.state == RequestState.FINISHED for r in real_reqs)
    assert all(r.state == RequestState.FINISHED for r in sim_reqs)
    assert admit_order(real_reqs) == admit_order(sim_reqs)


def test_sim_real_admission_parity_mixed_batching():
    """The fused mixed-batch mode now also runs under the SimEngine
    (roofline-priced `B + K*chunk` steps): identical workloads admit in
    the same order through the SAME shared Scheduler on the real engine
    and the simulator with mixed_batching=True on both."""
    cfg = get_reduced_config("qwen3-0.6b")
    rng = np.random.default_rng(37)
    prompts = [rng.integers(0, cfg.vocab_size, 12 + 4 * i).tolist()
               for i in range(5)]

    _, eng = _engine(mixed_batching=True, max_prefills=2)
    real_reqs = [Request(prompt_tokens=list(p),
                         sampling=SamplingParams(max_new_tokens=2))
                 for p in prompts]
    for r in real_reqs:
        eng.submit(r)
    eng.run_until_idle()

    loop = EventLoop()
    sim = SimEngine(get_reduced_config("qwen3-0.6b"), loop,
                    SimEngineConfig(device_type="a10",
                                    mixed_batching=True, max_prefills=2))
    assert sim.sched.scfg.mixed_batching
    sim_reqs = [Request(prompt_tokens=list(p),
                        sampling=SamplingParams(max_new_tokens=2),
                        arrival_time=0.0)
                for p in prompts]
    for r in sim_reqs:
        sim.submit(r)
    loop.run(until=1e6, stop_when=lambda: not sim.has_work)

    def admit_order(reqs):
        return sorted(range(len(reqs)),
                      key=lambda i: (reqs[i].schedule_time, i))

    assert all(r.state == RequestState.FINISHED for r in real_reqs)
    assert all(r.state == RequestState.FINISHED for r in sim_reqs)
    assert admit_order(real_reqs) == admit_order(sim_reqs)


# ------------------------------------------------- real P/D disaggregation
def test_real_engine_pd_disagg_smoke():
    """1 prefill + 1 decode REAL JAX engine around the distributed KV
    pool: the decode engine serves a request whose KV it never
    prefilled, byte-identical to a colocated engine's greedy output."""
    cfg = get_reduced_config("qwen3-0.6b")
    t0 = time.monotonic()
    clock = lambda: time.monotonic() - t0    # noqa: E731
    pool = DistributedKVPool(capacity_bytes=1 << 30, metadata_lag=0.0,
                             clock=clock)
    pre = InferenceEngine(cfg, EngineConfig(role="prefill", **ENGINE_KW),
                          clock=clock, kv_pool_client=pool,
                          engine_id="p0", seed=0)
    dec = InferenceEngine(cfg, EngineConfig(role="decode", **ENGINE_KW),
                          clock=clock, kv_pool_client=pool,
                          engine_id="d0", seed=0)
    pre.handoff = dec.submit
    rng = np.random.default_rng(34)
    prompt = rng.integers(0, cfg.vocab_size, 24).tolist()
    req = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=6))
    pre.submit(req)
    for _ in range(200):
        if not (pre.has_work or dec.has_work):
            break
        if pre.has_work:
            pre.step()
        if dec.has_work:
            dec.step()
    assert req.state == RequestState.FINISHED
    assert req in dec.finished and req not in pre.finished
    assert pre.metrics().finished_requests == 0
    # the KV for the first two blocks travelled through the pool
    assert dec.metrics().remote_hit_tokens >= 16
    # byte-identical to a colocated engine with the same params
    ref_eng = InferenceEngine(cfg, EngineConfig(**ENGINE_KW), seed=0)
    ref = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=6))
    ref_eng.submit(ref)
    ref_eng.run_until_idle()
    assert req.output_tokens == ref.output_tokens


# ------------------------------------------------- slot engine parity
def test_slot_engine_metrics_parity():
    """SlotEngine rides the shared SchedulerCore: admitted_requests and
    avg_queue_time are populated, so gateway least-latency routing can
    rank slot engines like any other engine."""
    cfg = get_reduced_config("xlstm-1.3b")
    eng = SlotEngine(cfg, SlotEngineConfig(max_slots=2, max_len=64),
                     seed=0)
    rng = np.random.default_rng(35)
    for i in range(3):
        eng.submit(Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, 10).tolist(),
            sampling=SamplingParams(max_new_tokens=4)))
    eng.run_until_idle()
    m = eng.metrics()
    assert m.finished_requests == 3
    assert m.admitted_requests == 3
    assert m.avg_queue_time > 0.0    # third request waited for a slot
    assert m.avg_latency > 0.0


# ------------------------------------------------- O(1) LRU eviction
def test_page_allocator_lru_eviction_order():
    """Insertion-ordered eviction must still be least-recently-released
    first (the O(1) replacement for the min()-scan)."""
    alloc = PageAllocator(4, page_size=4)
    pages = alloc.allocate(4, 1.0)
    for i, pid in enumerate(pages):
        alloc.register_hash(pid, f"h{i}")
    # release out of page-id order: LRU order is release order
    for t, idx in zip((2.0, 3.0, 4.0, 5.0), (2, 0, 3, 1)):
        alloc.release([pages[idx]], t)
    victims = [alloc._pop_free(6.0) for _ in range(4)]
    assert victims == [pages[2], pages[0], pages[3], pages[1]]
    assert alloc.stats["evictions"] == 4
