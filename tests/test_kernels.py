"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_attention import paged_attention

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


PAGED_CASES = [
    # (B, H, Hkv, D, page, NB, dtype)
    (4, 8, 4, 64, 16, 8, jnp.float32),
    (2, 8, 8, 128, 16, 4, jnp.float32),     # MHA
    (3, 16, 2, 64, 32, 4, jnp.float32),     # high group ratio
    (1, 4, 1, 256, 16, 8, jnp.float32),     # MQA, big head
    (4, 8, 4, 64, 16, 8, jnp.bfloat16),     # serving dtype
]


@pytest.mark.parametrize("b,h,hkv,d,page,nb,dtype", PAGED_CASES)
def test_paged_attention_matches_ref(b, h, hkv, d, page, nb, dtype):
    p = b * nb + 3
    q = _rand((b, h, d), dtype)
    kp = _rand((p, page, hkv, d), dtype)
    vp = _rand((p, page, hkv, d), dtype)
    bt = jnp.asarray(RNG.permutation(p)[:b * nb].reshape(b, nb), jnp.int32)
    lengths = jnp.asarray(RNG.integers(1, nb * page + 1, b), jnp.int32)
    out = paged_attention(q, kp, vp, bt, lengths)
    refv = kref.paged_attention_ref(q, kp, vp, bt, lengths)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(refv, np.float32),
                               atol=tol, rtol=tol)


def test_paged_attention_length_one():
    """Degenerate cache of a single token."""
    q = _rand((2, 4, 64), jnp.float32)
    kp = _rand((8, 16, 2, 64), jnp.float32)
    vp = _rand((8, 16, 2, 64), jnp.float32)
    bt = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    lengths = jnp.ones(2, jnp.int32)
    out = paged_attention(q, kp, vp, bt, lengths)
    refv = kref.paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(out, refv, atol=2e-5, rtol=2e-5)


FLASH_CASES = [
    # (B, Sq, Sk, H, Hkv, D, window, q_offset, dtype)
    (2, 128, 128, 8, 4, 64, 0, 0, jnp.float32),
    (2, 128, 256, 4, 2, 64, 0, 128, jnp.float32),   # chunked prefill
    (1, 256, 256, 4, 1, 128, 64, 0, jnp.float32),   # sliding window MQA
    (2, 64, 64, 4, 4, 32, 0, 0, jnp.float32),
    (1, 192, 320, 6, 3, 64, 100, 128, jnp.float32),  # window + offset
    (2, 128, 128, 8, 4, 64, 0, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("b,sq,sk,h,hkv,d,win,qoff,dtype", FLASH_CASES)
def test_flash_prefill_matches_ref(b, sq, sk, h, hkv, d, win, qoff, dtype):
    q = _rand((b, sq, h, d), dtype)
    k = _rand((b, sk, hkv, d), dtype)
    v = _rand((b, sk, hkv, d), dtype)
    lengths = jnp.asarray(RNG.integers(sk // 2, sk + 1, b), jnp.int32)
    out = flash_prefill(q, k, v, lengths, window=win, q_offset=qoff,
                        block_q=64, block_k=64)
    refv = kref.flash_prefill_ref(q, k, v, lengths, window=win,
                                  q_offset=qoff)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(refv, np.float32),
                               atol=tol, rtol=tol)


def test_ops_wrapper_pads_ragged_seqs():
    """ops.flash_attention must handle non-block-multiple lengths."""
    q = _rand((2, 100, 4, 64), jnp.float32)
    k = _rand((2, 173, 2, 64), jnp.float32)
    v = _rand((2, 173, 2, 64), jnp.float32)
    lengths = jnp.asarray([173, 90], jnp.int32)
    out = ops.flash_attention(q, k, v, lengths, block_q=64, block_k=64)
    refv = kref.flash_prefill_ref(q, k, v, lengths)
    np.testing.assert_allclose(out, refv, atol=3e-5, rtol=3e-5)


def test_blockwise_model_attention_matches_materialized():
    """The pure-JAX flash used by train/prefill (repro.models.layers)."""
    from repro.models import layers
    q = _rand((2, 200, 8, 64), jnp.float32)
    k = _rand((2, 200, 4, 64), jnp.float32)
    v = _rand((2, 200, 4, 64), jnp.float32)
    for win in (0, 64):
        small = layers.attn_causal(q, k, v, window=win)
        blocked = layers._blockwise(q, k, v, scale=None, q_offset=0,
                                    window=win, softcap=0.0,
                                    norm="softmax", block_q=64, block_k=64)
        np.testing.assert_allclose(small, blocked, atol=2e-5, rtol=2e-5)


def test_blockwise_gradients_finite():
    from repro.models import layers

    def loss(q, k, v):
        return layers._blockwise(q, k, v, scale=None, q_offset=0, window=0,
                                 softcap=0.0, norm="softmax",
                                 block_q=64, block_k=64).sum()

    q = _rand((1, 128, 4, 32), jnp.float32)
    k = _rand((1, 128, 2, 32), jnp.float32)
    v = _rand((1, 128, 2, 32), jnp.float32)
    grads = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        assert bool(jnp.all(jnp.isfinite(g)))
