"""Control-plane components: LoRA controller, GPU optimizer,
orchestration (incl. rolling upgrade), diagnostics, runtime sidecar."""
import pytest

from repro.configs import get_config
from repro.core.diagnostics.tools import (DiagnosticMonitor, FailureInjector,
                                          FaultKind, Telemetry)
from repro.core.lora.manager import AdapterSpec, LoRAController
from repro.core.optimizer import (GPUOptimizer, ProfileTable, WorkloadBucket,
                                  homogeneous_cost)
from repro.core.optimizer.gpu_optimizer import DemandBucket, LoadMonitor
from repro.core.orchestration.cluster import (ClusterManager, EngineGroup,
                                              GroupSpec, PodState)
from repro.core.runtime.sidecar import (ColdStartManager, ModelArtifact,
                                        load_time_s)
from repro.core.sim.events import EventLoop


# ----------------------------------------------------------------- LoRA
def test_lora_registry_lineage():
    c = LoRAController()
    c.register(AdapterSpec("base", "llama"))
    c.register(AdapterSpec("child", "llama", parent="base"))
    assert c.lineage("child") == ["child", "base"]
    with pytest.raises(ValueError):
        c.deregister("base")              # has dependents
    with pytest.raises(KeyError):
        c.register(AdapterSpec("orphan", "llama", parent="missing"))


def test_lora_density_placement_covers_all_and_replicates_hot():
    c = LoRAController(min_replicas=1, max_replicas=3)
    for i in range(10):
        c.register(AdapterSpec(f"a{i}", "m", requests_per_s=10.0 / (i + 1)))
    for p in range(4):
        c.add_pod(f"pod-{p}", capacity=6)
    c.sync({})
    covered = {a for pod in c.pods.values() for a in pod.loaded}
    assert covered == {f"a{i}" for i in range(10)}
    assert len(c.endpoints("a0")) >= len(c.endpoints("a9"))
    for pod in c.pods.values():
        assert len(pod.loaded) <= 6


# ------------------------------------------------------------ optimizer
def test_gpu_optimizer_beats_or_matches_homogeneous():
    cfg = get_config("deepseek-coder-7b")
    table = ProfileTable(cfg, slo_ttft_s=5.0, slo_itl_s=0.25)
    demand = [DemandBucket(WorkloadBucket(150, 50), 20.0),
              DemandBucket(WorkloadBucket(2000, 300), 3.0)]
    alloc = GPUOptimizer(table, ("a10", "l20", "v100")).optimize(demand)
    assert alloc.feasible and sum(alloc.counts.values()) > 0
    _, cost_hom = homogeneous_cost(table, demand, "l20")
    assert alloc.cost_per_hour <= cost_hom * 1.001


def test_gpu_optimizer_respects_availability():
    cfg = get_config("deepseek-coder-7b")
    table = ProfileTable(cfg)
    demand = [DemandBucket(WorkloadBucket(150, 50), 50.0)]
    alloc = GPUOptimizer(table, ("a10",),
                         availability={"a10": 2}).optimize(demand)
    assert alloc.counts["a10"] <= 2


def test_load_monitor_buckets_gateway_logs():
    logs = [(float(i), 100, 50, "u", "e") for i in range(10)] + \
           [(float(i), 3000, 200, "u", "e") for i in range(5)]
    demand = LoadMonitor().demand(logs, window_s=100.0, now=10.0)
    assert len(demand) == 2
    assert sum(d.rps for d in demand) == pytest.approx(15 / 100.0)


# -------------------------------------------------------- orchestration
def _cluster(loop):
    cold = ColdStartManager()
    cold.register_artifact(ModelArtifact(
        "m7b", 14e9, tier_by_node={"node-0": "dram"}))
    cm = ClusterManager(cold, clock=loop.clock)
    for i in range(6):
        cm.add_node(f"node-{i}", "a10", 8)
    return cm


def test_pod_lifecycle_and_cold_start_aware_placement():
    loop = EventLoop()
    cm = _cluster(loop)
    pod = cm.create_pod("m7b", "a10")
    assert pod.node == "node-0"           # dram-cached artifact node
    assert pod.state == PodState.PULLING
    loop.after(pod.ready_at + 1.0, lambda: None)
    loop.run()
    ready = cm.tick()
    assert [p.pod_id for p in ready] == [pod.pod_id]
    assert pod.state == PodState.READY
    cm.delete_pod(pod.pod_id)
    assert cm.nodes["node-0"].used_devices == 0


def test_reconcile_scales_up_and_down():
    loop = EventLoop()
    cm = _cluster(loop)
    cm.reconcile("m7b", "a10", desired=3)
    assert len(cm.pods) == 3
    cm.reconcile("m7b", "a10", desired=1)
    alive = [p for p in cm.pods.values()
             if p.state not in (PodState.TERMINATING,)]
    assert len(alive) == 1


def test_rolling_upgrade_keeps_availability():
    loop = EventLoop()
    cm = _cluster(loop)
    grp = EngineGroup(GroupSpec("ds", "m7b", "a10", group_size=2,
                                replicas=2), cm, max_unavailable=1)
    grp.scale_to(2)

    def tick_until(pred):
        for _ in range(500):
            if pred():
                return
            loop.clock.now += 5.0
            cm.tick()
        raise AssertionError("tick_until never satisfied")

    tick_until(lambda: len(grp.ready_replicas()) == 2)
    log = grp.rolling_upgrade("v2", tick_until)
    assert all("upgraded" in line for line in log)
    versions = {cm.pods[p].version for pods in grp.replica_pods.values()
                for p in pods}
    assert versions == {"v2"}


# ---------------------------------------------------------- diagnostics
def test_injector_and_monitor_detect_each_fault():
    """Hard faults act on one sample; soft faults must persist for
    confirm_n consecutive scrapes and then quarantine (hysteresis)."""
    inj = FailureInjector()
    mon = DiagnosticMonitor(confirm_n=3)
    cases = [
        (FaultKind.DEVICE_LOST, "restart", 1),    # hard: immediate
        (FaultKind.ECC_ERROR, "cordon", 1),       # hard: immediate
        (FaultKind.THERMAL_THROTTLE, "quarantine", 3),  # soft: confirmed
    ]
    for i, (kind, action, samples) in enumerate(cases):
        pid = f"p{i}"
        inj.active.clear()
        inj.inject(pid, kind, now=0.0, severity=1.0)
        diags = []
        for t in range(1, samples + 1):
            s = inj.perturb(Telemetry(pod_id=pid, t=float(t),
                                      tokens_per_sec=100.0))
            diags += mon.observe(s)
        assert any(d.fault == kind and d.action == action
                   for d in diags), (kind, diags)


def test_silent_degradation_needs_history():
    inj = FailureInjector()
    mon = DiagnosticMonitor()
    for t in range(10):                  # healthy baseline
        mon.observe(Telemetry("p1", float(t), tokens_per_sec=100.0))
    inj.inject("p1", FaultKind.SILENT_DEGRADATION, 10.0, severity=0.9)
    found = []
    for t in range(10, 25):
        s = inj.perturb(Telemetry("p1", float(t), tokens_per_sec=100.0))
        found += mon.observe(s)
    assert any(d.fault == FaultKind.SILENT_DEGRADATION for d in found)


# -------------------------------------------------------------- runtime
def test_streaming_loader_beats_sequential():
    for tier in ("remote", "local", "dram"):
        assert load_time_s(14e9, tier, True) < load_time_s(14e9, tier, False)


def test_cold_start_manager_prefers_fastest_tier():
    m = ColdStartManager()
    m.register_artifact(ModelArtifact(
        "x", 14e9, tier_by_node={"a": "local", "b": "dram"}))
    assert m.best_node("x", ["a", "b", "c"]) == "b"
    assert m.cold_start_s("x", "b") < m.cold_start_s("x", "c")
