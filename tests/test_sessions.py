"""Million-session serving: sticky session routing, the SSD KV
write-behind tier, and the streaming run plumbing.

The load-bearing pins are the real-JAX byte-identity ones: a swap
victim whose pages were pushed OUT of the host tier into the SSD tier
must resume decoding byte-identically to a never-preempted run, and a
prefix re-offered after cascading device -> host -> SSD must be served
from the SSD tier with the same outputs as a cold recompute."""
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.core.gateway.gateway import Gateway, RateLimit
from repro.core.gateway.router import SessionAffinityPolicy
from repro.core.kvcache.tiers import SSDPagePool, SharedSSDPool
from repro.core.sim import ClusterConfig, ServingCluster, SimEngineConfig
from repro.core.sim.workloads import (StreamingDist, StreamingSummary,
                                      multi_round_qa, percentile,
                                      summarize)
from repro.engine import (EngineConfig, InferenceEngine, Request,
                          RequestState, SamplingParams)

ENGINE_KW = dict(page_size=8, num_pages=64, max_batch=4,
                 max_pages_per_seq=16, chunk_size=16)


class _FakeEngine:
    def __init__(self, depth=0, cov=0):
        self.queue_depth = depth
        self._cov = cov

    def match_prefix_len(self, tokens):
        return min(self._cov, len(tokens))


# --------------------------------------------------------- policy unit
def test_session_policy_sticky_then_rehomes_on_retire():
    pol = SessionAffinityPolicy()
    engines = {"a": _FakeEngine(depth=5), "b": _FakeEngine(depth=0)}
    toks = list(range(32))
    first = pol.select(engines, toks, session_id="s1")
    assert first == "b"                      # fallback: emptier engine
    # sticky even when the pinned engine becomes the busier one
    engines["b"].queue_depth = 50
    for _ in range(5):
        assert pol.select(engines, toks, session_id="s1") == "b"
    assert pol.hits == 5 and pol.misses == 1
    # engine retires: the stale pin re-homes through the fallback
    del engines["b"]
    assert pol.select(engines, toks, session_id="s1") == "a"
    assert pol.rehomed == 1
    assert pol.select(engines, toks, session_id="s1") == "a"  # re-pinned
    # forget() purges every session pinned to a retired engine
    pol.select(engines, toks, session_id="s2")
    pol.forget("a")
    assert len(pol._sessions) == 0


def test_session_policy_ttl_and_lru_bounds():
    now = [0.0]
    pol = SessionAffinityPolicy(max_sessions=3, ttl_s=10.0)
    pol.attach_clock(lambda: now[0])
    engines = {"a": _FakeEngine(), "b": _FakeEngine()}
    pol.select(engines, [1], session_id="s1")
    now[0] = 11.0                            # past TTL: stale pin dies
    pol.select(engines, [1], session_id="s1")
    assert pol.rehomed == 1
    # LRU bound: the table never exceeds max_sessions
    for i in range(10):
        pol.select(engines, [1], session_id=f"t{i}")
    assert len(pol._sessions) == 3
    # requests without a session flow through untouched
    assert pol.select(engines, [1], session_id=None) in engines
    assert len(pol._sessions) == 3


def test_routable_view_cache_tracks_direct_cordon_clear():
    """The cached routable view must refresh on ``cordoned.clear()``
    (the gateway-restart path mutates the set directly)."""
    gw = Gateway(policy="least-request",
                 default_limit=RateLimit(rpm=1e9, tpm=1e12))
    gw.register_engine("e0", _FakeEngine())
    gw.register_engine("e1", _FakeEngine())
    assert set(gw.routable_engines()) == {"e0", "e1"}
    gw.cordon("e0")
    assert set(gw.routable_engines()) == {"e1"}
    gw.cordoned.clear()                      # direct mutation
    assert set(gw.routable_engines()) == {"e0", "e1"}
    # and the cached view is id-ordered for policy determinism
    assert list(gw.routable_engines()) == ["e0", "e1"]


# ------------------------------------------------------- cluster churn
def _session_cluster(**ccfg_kw):
    kw = dict(routing_policy="session", num_engines=4,
              engine=SimEngineConfig(device_type="a10", max_batch=16,
                                     chunk_size=512,
                                     mixed_batching=True),
              retain_requests=False, ttft_slo_s={"standard": 2.0})
    kw.update(ccfg_kw)
    return ServingCluster(get_config("deepseek-coder-7b"),
                          ClusterConfig(**kw))


def test_cluster_session_stickiness_survives_retire_and_restart():
    """Mid-trace an engine retires gracefully AND the gateway restarts
    (wiping the session table): every request still finishes (zero
    lost), stickiness resumes, and re-homed turns go through the
    prefix-affinity fallback instead of erroring."""
    cl = _session_cluster()
    wl = list(multi_round_qa(60, 8.0, seed=5, rounds_max=5,
                             think_time_s=3.0, sys_prompt=64,
                             turn_tokens=32, output_tokens=8))
    cl.loop.after(4.0, cl._retire_engine)
    cl.loop.after(8.0, lambda: cl._gateway_restart(0.5))
    s = cl.run(wl, drain_s=300.0)
    assert s["finished"] + s["rejected"] == len(wl)   # zero lost
    assert s["rejected"] == 0
    assert cl.gw_restarts == 1
    assert s["session_hits"] > 0
    assert cl.active_replicas == 3                    # retire stuck
    # the post-restart policy is a fresh session table, still routing
    assert cl.gateway.policy.name == "session"


def test_cluster_streaming_summary_and_busy_count_paths():
    """retain_requests=False: no Request accumulates anywhere, the
    summary comes from the StreamingSummary, and the busy-count done()
    predicate drains the run to the same finished count as the
    retained path."""
    wl = list(multi_round_qa(40, 10.0, seed=9, rounds_max=4,
                             think_time_s=2.0, sys_prompt=48,
                             turn_tokens=24, output_tokens=8))
    cl_ret = _session_cluster(retain_requests=True)
    s_ret = cl_ret.run(list(wl), drain_s=300.0)
    cl_str = _session_cluster()
    s_str = cl_str.run(list(wl), drain_s=300.0)
    assert s_str["finished"] == s_ret["finished"] == len(wl)
    assert cl_str.all_requests == []
    assert all(len(e.sched.finished) == 0
               for e in cl_str.engines.values())
    assert s_str["ttft_attainment"] > 0
    assert abs(s_str["ttft_avg_ms"] - s_ret["ttft_avg_ms"]) < 1e-6
    assert cl_str._busy_engines == 0                 # balanced edges


# ------------------------------------------------------- SSD pool unit
def test_ssd_pool_write_behind_and_bounds():
    pool = SSDPagePool(capacity_bytes=256, ssd_bw=64.0,
                       write_buffer_bytes=128)
    assert pool.put("k0", "p0", 64, now=0.0)         # ready at t=1.0
    assert pool.get("k0", now=0.5) == "p0"           # dirty-buffer hit
    assert pool.stats.hits == 1
    # dirty buffer full: further puts are DROPPED (it's a cache)
    assert pool.put("k1", "p1", 64, now=0.0)
    assert not pool.put("k2", "p2", 64, now=0.0)
    assert pool.stats.dropped_puts == 1
    # the modelled serial writer drains at ssd_bw: k0 at 1s, k1 at 2s
    assert pool.get("k0", now=1.5) == "p0"           # durable now
    assert pool.put("k2", "p2", 64, now=1.5)         # buffer freed
    assert pool.get("k9", now=2.0) is None
    assert pool.stats.misses == 1
    # LRU bound on the durable store
    for i in range(3, 9):
        assert pool.put(f"k{i}", f"p{i}", 64, now=10.0 + i)
    pool.drain()
    assert len(pool) == 4                            # 256 / 64
    assert pool.stats.evictions > 0
    assert not pool.put("huge", "x", 512, now=50.0)  # can never fit
    pool.discard("k8")
    assert pool.get("k8", now=60.0) is None


def test_ssd_pool_file_backed_roundtrip(tmp_path):
    """File-backed mode: payloads pickle to disk via the write-behind
    thread and un-pickle byte-identically (numpy KV tuples)."""
    pool = SSDPagePool(capacity_bytes=1 << 20,
                       directory=str(tmp_path))
    rng = np.random.default_rng(0)
    k = rng.standard_normal((2, 8, 2, 4)).astype(np.float32)
    v = rng.standard_normal((2, 8, 2, 4)).astype(np.float32)
    assert pool.put("page", (k, v), k.nbytes + v.nbytes, now=0.0)
    pool.drain()
    got_k, got_v = pool.get("page", now=1.0)
    np.testing.assert_array_equal(got_k, k)
    np.testing.assert_array_equal(got_v, v)
    assert pool.stats.bytes_written == k.nbytes + v.nbytes
    pool.discard("page")
    assert pool.get("page", now=2.0) is None


# --------------------------------------------------- streaming summary
def test_streaming_summary_matches_exact_within_tolerance():
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(4000):
        r = Request(request_id=f"r{i}",
                    prompt_tokens=[1] * int(rng.integers(8, 64)))
        r.arrival_time = float(rng.uniform(0, 50))
        r.first_token_time = r.arrival_time + float(
            rng.lognormal(-1.0, 1.0))
        r.token_times = [r.first_token_time + 0.05 * j
                         for j in range(6)]
        r.output_tokens = [2] * 6
        r.finish_time = r.token_times[-1]
        reqs.append(r)
    exact = summarize(reqs)
    ss = StreamingSummary(exact_max=50,          # force the histogram
                          ttft_slo_s={"standard": 0.5})
    for r in reqs:
        ss.observe(r)
    approx = ss.summary()
    tol = ss.ttft_ms.rel_tolerance * 2 + 0.01    # pinned bin error
    for key in exact:
        a, b = exact[key], approx[key]
        assert abs(a - b) <= 1e-9 + tol * abs(a), (key, a, b)
    # attainment matches a direct count
    want = sum(r.ttft <= 0.5 for r in reqs) / len(reqs)
    assert abs(approx["ttft_attainment"] - want) < 1e-9


def test_streaming_dist_histogram_percentiles_pinned():
    rng = np.random.default_rng(4)
    vals = rng.lognormal(1.0, 1.5, 20000).tolist()
    d = StreamingDist(exact_max=100)
    for v in vals:
        d.add(v)
    for p in (50, 90, 99):
        exact = percentile(vals, p)
        assert abs(d.percentile(p) - exact) <= 0.03 * exact


# ------------------------------------------------------------ workload
def test_multi_round_qa_trace_properties():
    stats = {}
    trs = list(multi_round_qa(50, 20.0, seed=2, rounds_max=5,
                              think_time_s=4.0, stats=stats))
    assert all(trs[i].arrival <= trs[i + 1].arrival
               for i in range(len(trs) - 1))
    # deterministic regeneration (no stored history)
    trs2 = list(multi_round_qa(50, 20.0, seed=2, rounds_max=5,
                               think_time_s=4.0))
    assert [t.request.prompt_tokens for t in trs] \
        == [t.request.prompt_tokens for t in trs2]
    by_sid = {}
    for t in trs:
        by_sid.setdefault(t.request.session_id, []).append(
            t.request.prompt_tokens)
    assert len(by_sid) == 50
    for rounds in by_sid.values():           # rounds share a growing prefix
        for a, b in zip(rounds, rounds[1:]):
            assert b[:len(a)] == a and len(b) > len(a)
    assert stats["peak_open_sessions"] > 0


# ------------------------------------------------ shared SSD pool unit
def test_shared_ssd_pool_dedup_origin_and_cross_hits():
    """Two engines over ONE host pool: the second engine's put of a
    page the first already wrote is absorbed (dedup), and its get of
    the first engine's page classifies as a cross-engine hit."""
    pool = SharedSSDPool(capacity_bytes=1024, ssd_bw=1e9,
                         write_buffer_bytes=1024)
    a, b = pool.view("engine-a"), pool.view("engine-b")
    assert pool.view("engine-a") is a            # views are cached
    assert a.put("page0", "p0", 64, now=0.0)
    assert pool._origin["page0"] == "engine-a"
    # duplicate write from the sibling: no second copy, counted dedup
    assert b.put("page0", "p0", 64, now=0.0)
    assert pool.stats.puts == 1
    assert pool.dedup_puts == 1 and pool.dedup_bytes == 64
    assert b.stats.dup_puts == 1
    assert pool.dedupe_ratio == 0.5
    # same-engine re-put is a plain dup, NOT cross-engine dedupe
    assert a.put("page0", "p0", 64, now=0.0)
    assert pool.dedup_puts == 1
    # cross classification: b reads a's page, a reads its own
    assert b.get("page0", now=0.1) == "p0"
    assert b.cross_hits == 1 and b.last_get_cross
    assert a.get("page0", now=0.1) == "p0"
    assert a.cross_hits == 0 and not a.last_get_cross
    # per-view traffic stats stay separate, pool-global bytes shared
    assert a.stats.hits == 1 and b.stats.hits == 1
    assert pool.stats.hits == 2
    # eviction cleans the origin map (no leak across a long run)
    pool.drain()
    for i in range(1, 20):
        a.put(f"fill{i}", f"f{i}", 64, now=1.0 + i)
    pool.drain()
    assert len(pool._origin) == len(pool)


# ----------------------------------------------- sim promotion smoke
def test_sim_cluster_predictive_promotion_hits():
    """Cluster-sim promotion path end to end: the session policy's
    EWMA schedules prefetches, the promoter poll drives them at
    modelled SSD cost, and resumed turns hit the promoted host pages."""
    cl = ServingCluster(
        get_config("deepseek-coder-7b"),
        ClusterConfig(routing_policy="session", num_engines=2,
                      engine=SimEngineConfig(device_type="a10",
                                             max_batch=48,
                                             chunk_size=512,
                                             mixed_batching=True,
                                             num_pages=128,
                                             host_cache_gb=1.0,
                                             ssd_cache_gb=16.0),
                      retain_requests=False,
                      promote_lead_s=4.0,
                      promote_poll_period_s=0.5))
    wl = multi_round_qa(40, 1.5, seed=11, rounds_max=5,
                        think_time_s=15.0, sys_prompt=600,
                        turn_tokens=100, output_tokens=48,
                        think_sigma=0.25)
    s = cl.run(wl, drain_s=240.0)
    assert s["promotions"] > 0
    assert s["promote_hits"] > 0
    # promoted pages count as HOST hits (that is the whole point)
    assert s["host_hit_tokens"] > 0


# --------------------------------------------- real-JAX SSD tier pins
def _ssd_engine(host_pages, **kw):
    cfg = get_reduced_config("qwen3-0.6b")
    probe = InferenceEngine(cfg, EngineConfig(**ENGINE_KW), seed=0)
    page_bytes = probe.runner.page_bytes
    defaults = dict(ENGINE_KW,
                    host_cache_gb=host_pages * page_bytes / (1 << 30),
                    ssd_cache_gb=0.1)
    defaults.update(kw)
    return cfg, InferenceEngine(cfg, EngineConfig(**defaults), seed=0), \
        page_bytes


def _greedy_reference(cfg, prompt, max_new, **kw):
    defaults = dict(ENGINE_KW)
    defaults.update(kw)
    eng = InferenceEngine(cfg, EngineConfig(**defaults), seed=0)
    ref = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=max_new))
    eng.submit(ref)
    eng.run_until_idle()
    return ref.output_tokens


def test_ssd_tier_swap_resume_byte_identical_real_engine():
    """A preempted request whose swap pages were pushed host -> SSD
    resumes from the SSD tier and finishes byte-identically to the
    never-preempted run."""
    cfg, eng, page_bytes = _ssd_engine(host_pages=6)
    rng = np.random.default_rng(51)
    prompt = rng.integers(0, cfg.vocab_size, 20).tolist()
    req = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=8))
    eng.submit(req)
    for _ in range(200):
        if len(req.output_tokens) >= 3:
            break
        eng.step()
    generated = list(req.output_tokens)
    eng.sched.preempt(req, eng.clock())
    assert req.state is RequestState.SWAPPED
    # pressure the host tier until the victim's swap pages cascade
    # into the SSD write-behind pool
    swap_keys = [k for k in eng.host_pool.keys()
                 if str(k).startswith("swap/")]
    assert swap_keys
    for i in range(12):
        eng.host_pool.put(f"fill{i}", ("fill", i), page_bytes,
                          eng.clock())
    assert all(k not in eng.host_pool.keys() for k in swap_keys)
    eng.ssd_pool.drain()
    assert any(eng.ssd_pool.contains(k) for k in swap_keys)
    eng.run_until_idle()
    assert req.state is RequestState.FINISHED
    assert req.output_tokens[:len(generated)] == generated
    assert req.output_tokens == _greedy_reference(cfg, prompt, 8)
    m = eng.metrics()
    assert m.ssd_hit_tokens > 0
    assert m.swap_in == 1


def test_ssd_tier_serves_evicted_prefix_real_engine():
    """Device -> host -> SSD cascade: a prefix evicted through BOTH
    upper tiers is served from SSD on re-offer, byte-identically to a
    cold recompute."""
    cfg, eng, page_bytes = _ssd_engine(host_pages=2, num_pages=24)
    rng = np.random.default_rng(52)
    shared = rng.integers(0, cfg.vocab_size, 24).tolist()
    first = Request(prompt_tokens=list(shared),
                    sampling=SamplingParams(max_new_tokens=4))
    eng.submit(first)
    eng.run_until_idle()
    # pressure: long distinct prompts evict the shared pages from the
    # device cache into the 2-page host tier, which cascades to SSD
    for i in range(4):
        filler = Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, 120).tolist(),
            sampling=SamplingParams(max_new_tokens=2))
        eng.submit(filler)
        eng.run_until_idle()
    eng.ssd_pool.drain()
    assert eng.ssd_pool.stats.puts > 0
    again = Request(prompt_tokens=list(shared),
                    sampling=SamplingParams(max_new_tokens=4))
    eng.submit(again)
    eng.run_until_idle()
    m = eng.metrics()
    assert m.ssd_hit_tokens >= eng.ecfg.page_size
    assert again.output_tokens == first.output_tokens
    assert again.output_tokens == _greedy_reference(cfg, shared, 4,
                                                    num_pages=24)


# ------------------------------------- real-JAX host-shared SSD pool
def test_shared_ssd_pool_cross_engine_prefix_real_engine(tmp_path):
    """Two real engines attached to ONE host-level SSD pool: a prefix
    engine A computed and cascade-evicted is served to engine B — which
    never saw it — from the shared pool, byte-identically to A's run.
    Page keys are content-addressed (engine-independent), so the only
    new trust boundary is the pool itself."""
    cfg = get_reduced_config("qwen3-0.6b")
    probe = InferenceEngine(cfg, EngineConfig(**ENGINE_KW), seed=0)
    page_bytes = probe.runner.page_bytes
    pool = SharedSSDPool(capacity_bytes=1 << 27,
                         directory=str(tmp_path))
    ekw = dict(ENGINE_KW, num_pages=24,
               host_cache_gb=2 * page_bytes / (1 << 30),
               ssd_cache_gb=0.1)
    eng_a = InferenceEngine(cfg, EngineConfig(**ekw), seed=0,
                            engine_id="engine-a", ssd_pool=pool)
    eng_b = InferenceEngine(cfg, EngineConfig(**ekw), seed=0,
                            engine_id="engine-b", ssd_pool=pool)
    assert eng_a.ssd_pool.pool is eng_b.ssd_pool.pool
    rng = np.random.default_rng(53)
    shared = rng.integers(0, cfg.vocab_size, 24).tolist()
    first = Request(prompt_tokens=list(shared),
                    sampling=SamplingParams(max_new_tokens=4))
    eng_a.submit(first)
    eng_a.run_until_idle()
    # cascade A's copy of the prefix out of device + host into the pool
    for i in range(4):
        filler = Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, 120).tolist(),
            sampling=SamplingParams(max_new_tokens=2))
        eng_a.submit(filler)
        eng_a.run_until_idle()
    pool.drain()
    assert pool.stats.puts > 0
    # engine B re-offers the prefix COLD: its only source is the pool
    again = Request(prompt_tokens=list(shared),
                    sampling=SamplingParams(max_new_tokens=4))
    eng_b.submit(again)
    eng_b.run_until_idle()
    m = eng_b.metrics()
    assert m.ssd_hit_tokens >= eng_b.ecfg.page_size
    assert m.ssd_cross_hit_tokens >= eng_b.ecfg.page_size
    assert eng_b.ssd_pool.cross_hits > 0
    assert again.output_tokens == first.output_tokens
    assert again.output_tokens == _greedy_reference(cfg, shared, 4,
                                                    num_pages=24)


def test_swap_resume_through_shared_pool_byte_identical(tmp_path):
    """Swap-resume with the host-level SHARED pool as the third tier:
    a preempted request whose swap pages cascaded into the shared pool
    resumes byte-identically — swap keys are engine-private
    (``swap/<rid>/<i>``), so sharing the pool must not change the
    path's outputs."""
    cfg = get_reduced_config("qwen3-0.6b")
    probe = InferenceEngine(cfg, EngineConfig(**ENGINE_KW), seed=0)
    page_bytes = probe.runner.page_bytes
    pool = SharedSSDPool(capacity_bytes=1 << 27,
                         directory=str(tmp_path))
    ekw = dict(ENGINE_KW, host_cache_gb=6 * page_bytes / (1 << 30),
               ssd_cache_gb=0.1)
    eng = InferenceEngine(cfg, EngineConfig(**ekw), seed=0,
                          engine_id="engine-a", ssd_pool=pool)
    rng = np.random.default_rng(55)
    prompt = rng.integers(0, cfg.vocab_size, 20).tolist()
    req = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=8))
    eng.submit(req)
    for _ in range(200):
        if len(req.output_tokens) >= 3:
            break
        eng.step()
    generated = list(req.output_tokens)
    eng.sched.preempt(req, eng.clock())
    assert req.state is RequestState.SWAPPED
    swap_keys = [k for k in eng.host_pool.keys()
                 if str(k).startswith("swap/")]
    assert swap_keys
    for i in range(12):
        eng.host_pool.put(f"fill{i}", ("fill", i), page_bytes,
                          eng.clock())
    assert all(k not in eng.host_pool.keys() for k in swap_keys)
    pool.drain()
    assert any(pool.contains(k) for k in swap_keys)
    eng.run_until_idle()
    assert req.state is RequestState.FINISHED
    assert req.output_tokens[:len(generated)] == generated
    assert req.output_tokens == _greedy_reference(cfg, prompt, 8)
    m = eng.metrics()
    assert m.ssd_hit_tokens > 0
    assert m.ssd_cross_hit_tokens == 0   # own swap pages: never cross


# --------------------------------------- real-JAX promoted-page resume
def test_promoted_page_resume_byte_identical_real_engine():
    """Predictive promotion on the real engine: a finished session's
    pages cascade to SSD; ``promote_session`` prefetches them back into
    host DRAM on the background promoter thread; the session's next
    turn hits HOST (counted ``promote_hits``) and decodes
    byte-identically to a cold recompute."""
    cfg, eng, page_bytes = _ssd_engine(host_pages=8, num_pages=24)
    rng = np.random.default_rng(54)
    shared = rng.integers(0, cfg.vocab_size, 24).tolist()
    first = Request(prompt_tokens=list(shared), session_id="conv0",
                    sampling=SamplingParams(max_new_tokens=4))
    eng.submit(first)
    eng.run_until_idle()
    # pressure both upper tiers until the session's pages are SSD-only
    for i in range(6):
        filler = Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, 120).tolist(),
            sampling=SamplingParams(max_new_tokens=2))
        eng.submit(filler)
        eng.run_until_idle()
    eng.ssd_pool.drain()
    promotable = eng.sched.session_promotable("conv0")
    assert len(promotable) == 3          # 24-token prompt = 3 full pages
    # background prefetch, landed at the next step boundary
    assert eng.promote_session("conv0") == 3
    eng.drain_promotions()
    assert all(eng.host_pool.contains(k) for k in promotable)
    again = Request(prompt_tokens=list(shared), session_id="conv0",
                    sampling=SamplingParams(max_new_tokens=4))
    eng.submit(again)
    eng.run_until_idle()
    m = eng.metrics()
    # the admission walk reuses at most len(prompt)-1 tokens (the last
    # position must be computed for logits), so 2 of the 3 promoted
    # pages hit; the third stays host-resident, NOT wasted
    assert m.promote_hits >= 2
    assert m.host_hit_tokens >= 2 * eng.ecfg.page_size
    assert m.ssd_hit_tokens == 0         # nothing read on-demand
    assert again.output_tokens == first.output_tokens
    assert again.output_tokens == _greedy_reference(cfg, shared, 4,
                                                    num_pages=24)
    # nothing promoted went unused on this path
    assert m.promote_wasted == 0
