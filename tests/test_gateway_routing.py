"""Gateway + routing policy tests (paper §3.2.2) over stub engines."""
from dataclasses import dataclass, field
from typing import List

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.core.gateway import Gateway, RateLimit
from repro.core.gateway.router import POLICIES, make_policy
from repro.engine.engine import EngineMetrics


@dataclass
class StubEngine:
    m: EngineMetrics = field(default_factory=EngineMetrics)
    prefix_tokens: int = 0

    def metrics(self):
        return self.m

    def match_prefix_len(self, tokens):
        return min(self.prefix_tokens, len(tokens))


def _engines(**per_engine):
    return {k: v for k, v in per_engine.items()}


def test_least_request_picks_emptiest():
    e = _engines(
        a=StubEngine(EngineMetrics(num_running=5, num_waiting=2)),
        b=StubEngine(EngineMetrics(num_running=1)),
        c=StubEngine(EngineMetrics(num_running=3)))
    assert make_policy("least-request").select(e, [1, 2, 3]) == "b"


def test_least_kv_cache():
    e = _engines(a=StubEngine(EngineMetrics(kv_utilization=0.9)),
                 b=StubEngine(EngineMetrics(kv_utilization=0.2)))
    assert make_policy("least-kv-cache").select(e, []) == "b"


def test_least_latency():
    e = _engines(
        a=StubEngine(EngineMetrics(avg_latency=1.0, avg_queue_time=0.1)),
        b=StubEngine(EngineMetrics(avg_latency=0.3, avg_queue_time=0.2)))
    assert make_policy("least-latency").select(e, []) == "b"


def test_throughput_picks_lowest_tps():
    e = _engines(a=StubEngine(EngineMetrics(tokens_per_sec=900.0)),
                 b=StubEngine(EngineMetrics(tokens_per_sec=100.0)))
    assert make_policy("throughput").select(e, []) == "b"


def test_prefix_cache_aware_threshold():
    tokens = list(range(100))
    e = _engines(
        a=StubEngine(EngineMetrics(num_running=0), prefix_tokens=80),
        b=StubEngine(EngineMetrics(num_running=9), prefix_tokens=0))
    pol = make_policy("prefix-cache-aware", threshold=0.5)
    assert pol.select(e, tokens) == "a"
    # below threshold -> falls back to least-request
    e["a"].prefix_tokens = 10
    e["a"].m = EngineMetrics(num_running=9)
    e["b"].m = EngineMetrics(num_running=0)
    assert pol.select(e, tokens) == "b"


def test_lora_affinity():
    e = _engines(
        a=StubEngine(EngineMetrics(num_running=5,
                                   loaded_adapters=("sql",))),
        b=StubEngine(EngineMetrics(num_running=0)))
    pol = make_policy("lora-affinity")
    assert pol.select(e, [], lora_adapter="sql") == "a"
    assert pol.select(e, [], lora_adapter=None) == "b"


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(sorted(POLICIES)),
       st.lists(st.integers(0, 50), min_size=1, max_size=8),
       st.lists(st.integers(0, 500), min_size=0, max_size=20))
def test_policy_always_returns_registered_engine(policy_name, loads, tokens):
    """Property: every policy returns a valid engine id for any metric
    state (no crashes, no phantom targets)."""
    engines = {f"e{i}": StubEngine(EngineMetrics(
        num_running=n, tokens_per_sec=float(n), kv_utilization=n / 51.0,
        avg_latency=float(n)))
        for i, n in enumerate(loads)}
    pol = make_policy(policy_name)
    assert pol.select(engines, tokens) in engines


# ------------------------------------------------------------------ gateway
def test_gateway_rpm_limit():
    t = [0.0]
    gw = Gateway(policy="random", clock=lambda: t[0],
                 default_limit=RateLimit(rpm=60, tpm=1e9))
    gw.register_engine("e0", StubEngine())
    # burst capacity rpm/6 = 10 requests
    granted = sum(gw.route([1]) is not None for _ in range(40))
    assert granted == 10
    assert gw.stats.rejected_rpm == 30
    t[0] = 60.0       # a minute later tokens refilled
    assert gw.route([1]) is not None


def test_gateway_tpm_limit_counts_tokens():
    t = [0.0]
    gw = Gateway(policy="random", clock=lambda: t[0],
                 default_limit=RateLimit(rpm=1e9, tpm=600))
    gw.register_engine("e0", StubEngine())
    assert gw.route([0] * 50, est_output_tokens=50) is not None
    assert gw.route([0] * 500, est_output_tokens=500) is None
    assert gw.stats.rejected_tpm == 1


def test_gateway_per_user_isolation():
    t = [0.0]
    gw = Gateway(policy="random", clock=lambda: t[0],
                 default_limit=RateLimit(rpm=60, tpm=1e9))
    gw.register_engine("e0", StubEngine())
    for _ in range(10):
        gw.route([1], user="greedy")
    assert gw.route([1], user="greedy") is None      # exhausted
    assert gw.route([1], user="other") is not None   # isolated


def test_deregister_purges_policy_state():
    """Scale-down correctness: deregistering an engine must purge it
    from per-policy routing state (attainment EWMAs, prefix-affinity
    maps) — a drained/migrated pod can never be routed to again."""
    gw = Gateway(policy="prefix-load")
    hot = StubEngine(EngineMetrics(num_running=0), prefix_tokens=50)
    cold = StubEngine(EngineMetrics(num_running=0))
    gw.register_engine("hot", hot)
    gw.register_engine("cold", cold)
    tokens = list(range(50))
    assert gw.route(tokens) == "hot"
    assert "hot" in gw.policy._affinity.values()   # affinity earned
    gw.deregister_engine("hot")
    assert "hot" not in gw.policy._affinity.values()
    for _ in range(5):
        assert gw.route(tokens) == "cold"

    gw = Gateway(policy="slo-aware")
    good = StubEngine(EngineMetrics(
        slo_by_class=(("interactive", 0.95, 0.9, 20),)))
    bad = StubEngine(EngineMetrics(
        slo_by_class=(("interactive", 0.2, 0.9, 20),)))
    gw.register_engine("good", good)
    gw.register_engine("bad", bad)
    assert gw.route([1], priority_class="interactive") == "good"
    assert any(k[0] == "good" for k in gw.policy._att_ewma)
    gw.deregister_engine("good")
    assert not any(k[0] == "good" for k in gw.policy._att_ewma)
    assert gw.route([1], priority_class="interactive") == "bad"


def test_route_skips_non_frontend_pools():
    """Pool-tagged engines: new requests only route to prefill/mixed
    members; a 'draining' retag makes a member unroutable at once."""
    gw = Gateway(policy="least-request")
    for eid, pool in (("p0", "prefill"), ("p1", "prefill"),
                      ("d0", "decode")):
        gw.register_engine(eid, StubEngine(), pool=pool)
    for _ in range(6):
        assert gw.route([1]) in ("p0", "p1")
    gw.set_engine_pool("p0", "draining")
    for _ in range(6):
        assert gw.route([1]) == "p1"
    gw.set_engine_pool("p0", "decode")       # migration completed
    assert sorted(gw.routable_engines()) == ["p1"]
    # untagged engines keep the legacy behavior (all routable)
    gw2 = Gateway(policy="least-request")
    gw2.register_engine("e0", StubEngine())
    assert gw2.route([1]) == "e0"


def test_workload_histogram_feeds_load_monitor():
    gw = Gateway(policy="random")
    gw.register_engine("e0", StubEngine())
    for n, out in ((50, 20), (150, 20), (3000, 200), (150, 30)):
        gw.route([0] * n, est_output_tokens=out)
    hist = gw.workload_histogram()
    assert sum(hist.values()) == 4
    assert hist[(0, 0)] == 3          # three small-ish requests
