"""Speculative n-gram decoding + async overlapped loop.

Unit layer: the prompt-lookup drafter, acceptance rule and adaptive
backoff.  System layer: the real JAX engine must be BYTE-IDENTICAL
under greedy sampling with speculation on/off and with the async loop
on/off, never leak pages for rejected draft KV, never starve prefill,
and keep sim/real spec accounting flowing through the same scheduler
hook.
"""
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.sim import SimEngineConfig
from repro.core.sim.sim_engine import SimEngine
from repro.engine import (EngineConfig, InferenceEngine, Request,
                          SamplingParams)
from repro.engine.speculative import (DraftController, accept_length,
                                      ngram_propose)

# ----------------------------------------------------------- unit layer


def test_ngram_propose_continues_recent_occurrence():
    # trailing [5, 6] occurred earlier, continuation is [7, 8, 5]
    hist = [5, 6, 7, 8, 5, 6]
    assert ngram_propose(hist, 3) == [7, 8, 5]
    # max_draft caps the proposal
    assert ngram_propose(hist, 1) == [7]


def test_ngram_propose_prefers_most_recent_match():
    # trailing [2]: matches at idx 1 (-> 9) and idx 3 (-> 4); the most
    # recent earlier occurrence wins
    hist = [1, 2, 9, 2, 4, 2]
    assert ngram_propose(hist, 1, ngram_max=1) == [4]


def test_ngram_propose_no_match_or_budget_is_empty():
    assert ngram_propose([1, 2, 3, 4], 3) == []          # no repeats
    assert ngram_propose([5, 6, 5, 6], 0) == []          # no budget
    assert ngram_propose([7], 3) == []                   # too short


def test_accept_length_rules():
    # sampled[j] is the model's token after drafts[:j]
    assert accept_length([1, 2, 3], [1, 2, 3, 9]) == 3   # all accepted
    assert accept_length([1, 2, 3], [1, 7, 0, 0]) == 1   # diverge at 1
    assert accept_length([4], [9, 9]) == 0               # instant miss
    assert accept_length([], [5]) == 0                   # plain decode


def test_draft_controller_backoff_and_probe():
    ctl = DraftController(max_draft=4, probe_interval=3)
    req = Request(prompt_tokens=[1, 2, 1, 2],
                  sampling=SamplingParams(max_new_tokens=64))
    assert ctl.allowed(req) == 4                # optimistic start
    for _ in range(8):                          # drafts keep missing
        ctl.observe(req, drafted=4, accepted=0)
    assert req._spec_ewma < ctl.min_threshold
    assert ctl.allowed(req) == 1                # first call arms a probe
    assert [ctl.allowed(req) for _ in range(3)] == [0, 0, 0]
    assert ctl.allowed(req) == 1                # probe fires again
    for _ in range(8):                          # output turned repetitive
        ctl.observe(req, drafted=1, accepted=1)
    assert ctl.allowed(req) == 4                # recovered to full drafts


def test_draft_controller_caps_by_budget_and_room():
    ctl = DraftController(max_draft=4)
    req = Request(prompt_tokens=[5, 6, 5, 6],
                  sampling=SamplingParams(max_new_tokens=3))
    req.output_tokens = [5]
    # room = 3 - 1 - 1 = 1: the draft may never write KV past the
    # pages max_new_tokens reserved at admission
    assert len(ctl.propose(req, budget=8)) <= 1
    assert ctl.propose(req, budget=0) == []


# --------------------------------------------------------- system layer

REP_PROMPT = [5, 6, 7, 8] * 6


def _engine(**kw):
    cfg = get_reduced_config("qwen3-0.6b")
    defaults = dict(num_pages=128, max_batch=4, max_pages_per_seq=16,
                    chunk_size=16)
    defaults.update(kw)
    return cfg, InferenceEngine(cfg, EngineConfig(**defaults), seed=0)


def _run(prompts, max_new=10, stop=None, **kw):
    cfg, eng = _engine(**kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(
            request_id=f"r{i}", prompt_tokens=list(p),
            sampling=SamplingParams(max_new_tokens=max_new,
                                    stop_token=stop)))
    eng.run_until_idle()
    outs = {r.request_id: list(r.output_tokens) for r in eng.finished}
    return eng, outs


@pytest.fixture(scope="module")
def greedy_baseline():
    rng = np.random.default_rng(3)
    prompts = [REP_PROMPT, rng.integers(0, 64, 20).tolist(),
               [9, 9, 3, 9, 9, 3, 9, 9]]
    _, outs = _run(prompts)
    return prompts, outs


def test_spec_greedy_byte_identical(greedy_baseline):
    prompts, base = greedy_baseline
    eng, outs = _run(prompts, spec_tokens=4)
    assert outs == base
    m = eng.metrics()
    assert m.spec_drafted_tokens > 0            # speculation actually ran
    assert 0 < m.spec_accepted_tokens <= m.spec_drafted_tokens
    # rejected draft KV needs no rollback and leaks nothing
    assert eng.alloc.num_free == eng.alloc.num_pages


def test_async_loop_greedy_byte_identical(greedy_baseline):
    prompts, base = greedy_baseline
    eng, outs = _run(prompts, async_loop=True)
    assert outs == base
    assert eng.alloc.num_free == eng.alloc.num_pages
    assert eng.metrics().device_wait_s >= 0.0


def test_spec_plus_async_byte_identical(greedy_baseline):
    prompts, base = greedy_baseline
    _, outs = _run(prompts, spec_tokens=4, async_loop=True)
    assert outs == base


def test_spec_stop_token_mid_draft(greedy_baseline):
    """A stop token emitted inside an accepted draft burst must truncate
    the output exactly where the sync engine stops."""
    prompts, _ = greedy_baseline
    _, base = _run(prompts, max_new=12, stop=6)
    for kw in (dict(spec_tokens=4), dict(async_loop=True),
               dict(spec_tokens=4, async_loop=True)):
        _, outs = _run(prompts, max_new=12, stop=6, **kw)
        assert outs == base, kw


def test_spec_prefill_not_starved():
    """Drafts spend step budget LAST: with a budget barely above the
    decode row count, prefill chunks still make progress and every
    request finishes."""
    long_prompt = ([3, 1, 4, 1, 5, 9, 2, 6] * 8)[:60]
    eng, outs = _run([REP_PROMPT, REP_PROMPT, long_prompt],
                     max_new=8, spec_tokens=4, chunk_size=8)
    assert len(outs) == 3 and all(len(o) == 8 for o in outs.values())
    assert eng.metrics().finished_requests == 3


def _sim(**kw):
    from repro.configs import get_config
    from repro.core.sim.events import EventLoop
    loop = EventLoop()
    cfg = get_config("deepseek-coder-7b")
    eng = SimEngine(cfg, loop, SimEngineConfig(device_type="a10",
                                               mixed_batching=True, **kw))
    return loop, eng


def test_sim_spec_accounting_parity():
    """The simulator prices spec steps via the roofline and pushes
    synthetic acceptance through the SAME ``on_spec_batch`` hook the
    real engine uses, so sidecar counters mean the same thing in both
    worlds."""
    rate = 0.75
    loop, eng = _sim(spec_tokens=4, spec_accept_rate=rate)
    for i in range(8):
        eng.submit(Request(request_id=f"s{i}",
                           prompt_tokens=[1, 2, 3, 4] * 16,
                           sampling=SamplingParams(max_new_tokens=48),
                           arrival_time=0.0))
    loop.run(until=1e6, stop_when=lambda: not eng.has_work)
    m = eng.metrics()
    assert m.finished_requests == 8
    assert m.spec_drafted_tokens > 0
    assert m.spec_steps > 0
    assert 0 < m.spec_accepted_tokens <= m.spec_drafted_tokens
    assert abs(m.spec_acceptance - rate) < 0.15


def test_sim_spec_off_unchanged():
    loop, eng = _sim()
    eng.submit(Request(request_id="s", prompt_tokens=[1] * 32,
                       sampling=SamplingParams(max_new_tokens=16),
                       arrival_time=0.0))
    loop.run(until=1e6, stop_when=lambda: not eng.has_work)
    m = eng.metrics()
    assert m.finished_requests == 1
    assert m.spec_drafted_tokens == 0 and m.spec_steps == 0
