"""SLO-aware scheduling (ISSUE 3 tentpole): deadline-aware admission
ordering, priority preemption, per-class TTFT/ITL attainment under the
sim clock, sim/real parity of the SLO admission order, greedy-output
equivalence with SLO mode on vs off, SLO routing and the autoscaler's
inverted slo_attainment metric."""
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.autoscaler import MetricStore, make_autoscaler
from repro.core.gateway.router import make_policy
from repro.core.sim.events import EventLoop
from repro.core.sim.sim_engine import SimEngine, SimEngineConfig
from repro.engine import (EngineConfig, InferenceEngine, Request,
                          RequestState, SamplingParams, Scheduler,
                          SchedulerConfig)
from repro.engine.engine import EngineMetrics
from repro.engine.page_table import PageAllocator
from repro.engine.scheduler import DEFAULT_SLO_CLASSES, ClassSLO

ENGINE_KW = dict(page_size=8, num_pages=64, max_batch=4,
                 max_pages_per_seq=16, chunk_size=16)


def _req(cls, prompt_len=8, max_new=4, arrival=0.0, seed=0):
    rng = np.random.default_rng(seed)
    return Request(prompt_tokens=rng.integers(0, 100, prompt_len).tolist(),
                   sampling=SamplingParams(max_new_tokens=max_new),
                   arrival_time=arrival, priority_class=cls)


# ---------------------------------------------------- admission order
def test_slo_admission_order_priority_then_slack():
    """slo_aware admission is strict-priority across classes and
    earliest-slack (FIFO) within a class, regardless of submit order."""
    scfg = SchedulerConfig(page_size=4, max_batch=4, chunk_size=64,
                           max_prefills=4, slo_aware=True,
                           honor_stop_token=False)
    sched = Scheduler(scfg, PageAllocator(256, 4))
    b = _req("batch", arrival=0.0, seed=1)
    s = _req("standard", arrival=0.0, seed=2)
    i = _req("interactive", arrival=0.0, seed=3)
    for r in (b, s, i):            # FIFO would admit b first
        sched.enqueue(r, 0.0)
    sched.schedule(0.1)
    assert sched.prefills == [i, s, b]


def test_fifo_admission_unchanged_when_slo_off():
    scfg = SchedulerConfig(page_size=4, max_batch=4, chunk_size=64,
                           max_prefills=4, slo_aware=False,
                           honor_stop_token=False)
    sched = Scheduler(scfg, PageAllocator(256, 4))
    b, i = _req("batch", seed=1), _req("interactive", seed=3)
    sched.enqueue(b, 0.0)
    sched.enqueue(i, 0.0)
    sched.schedule(0.1)
    assert sched.prefills == [b, i]


# ------------------------------------------------------- preemption
def _drive_to_running(sched, req, now):
    """Fake-runner bookkeeping: complete the prefill in one chunk."""
    out = sched.schedule(now)
    assert any(w.req is req for w in out.prefills)
    work = [w for w in out.prefills if w.req is req][0]
    assert sched.note_prefill_progress(req, work.chunk_len)
    sched.finish_prefill(req, 1, now)


def test_priority_preemption_ordering():
    """An interactive prefill past its slack headroom preempts the
    lowest-priority decode with the least generated work; higher-rank
    requests never preempt equals or betters."""
    scfg = SchedulerConfig(page_size=4, max_batch=2, chunk_size=16,
                           mixed_batching=False, slo_aware=True,
                           honor_stop_token=False,
                           slo_preempt_cooldown_s=0.0)
    sched = Scheduler(scfg, PageAllocator(256, 4))
    b1 = _req("batch", max_new=50, arrival=0.0, seed=1)
    b2 = _req("batch", max_new=50, arrival=0.0, seed=2)
    sched.enqueue(b1, 0.0)
    sched.enqueue(b2, 0.0)
    _drive_to_running(sched, b1, 0.01)
    _drive_to_running(sched, b2, 0.02)
    # b1 has MORE decode progress than b2
    sched.on_decode_batch([b1, b2], [5, 5], 0.1)
    sched.on_decode_batch([b1], [5], 0.2)
    assert len(b1.output_tokens) > len(b2.output_tokens)
    # interactive request whose TTFT deadline (0.5s) has passed by the
    # time the next iteration is scheduled
    urgent = _req("interactive", arrival=0.0, seed=3)
    sched.enqueue(urgent, 1.0)     # arrival stamped 1.0
    out = sched.schedule(2.0)      # slack = 0.5 - 1.0 < headroom
    # b2 (least work to discard) was evicted, b1 survives, urgent admitted
    assert b2.state == RequestState.QUEUED and b2 in sched.waiting
    assert b1 in sched.running
    assert sched.prefills == [urgent]
    assert sched.metrics(2.0).preemptions == 1
    assert out.prefills[0].req is urgent


def test_no_preemption_within_same_class():
    """A batch request can never preempt another batch decode."""
    scfg = SchedulerConfig(page_size=4, max_batch=1, chunk_size=16,
                           mixed_batching=False, slo_aware=True,
                           honor_stop_token=False,
                           slo_preempt_cooldown_s=0.0)
    sched = Scheduler(scfg, PageAllocator(256, 4))
    b1 = _req("batch", max_new=50, arrival=0.0, seed=1)
    sched.enqueue(b1, 0.0)
    _drive_to_running(sched, b1, 0.01)
    late = _req("batch", arrival=0.0, seed=2)
    sched.enqueue(late, 100.0)
    sched.schedule(200.0)          # far past even the batch deadline
    assert b1 in sched.running
    assert sched.metrics(200.0).preemptions == 0


# ------------------------------------------ per-class attainment (sim)
def test_per_class_ttft_attainment_under_sim_clock():
    """SchedulerCore's per-class attainment accounting must match the
    attainment recomputed from the raw per-request timestamps."""
    cfg = get_reduced_config("qwen3-0.6b")
    loop = EventLoop()
    sim = SimEngine(cfg, loop, SimEngineConfig(
        device_type="a10", max_batch=4, slo_aware=True))
    rng = np.random.default_rng(40)
    reqs = []
    for k in range(12):
        cls = "interactive" if k % 2 == 0 else "batch"
        r = Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, 600).tolist(),
            sampling=SamplingParams(max_new_tokens=16),
            arrival_time=0.0, priority_class=cls)
        reqs.append(r)
        sim.submit(r)
    loop.run(until=1e6, stop_when=lambda: not sim.has_work)
    m = sim.metrics()
    by_class = {c: (ta, ia, n) for c, ta, ia, n in m.slo_by_class}
    for cls in ("interactive", "batch"):
        sub = [r for r in reqs if r.priority_class == cls]
        tgt = DEFAULT_SLO_CLASSES[cls]
        expect_ttft = np.mean([r.ttft <= tgt.ttft_s for r in sub])
        ta, ia, n = by_class[cls]
        assert n == len(sub)
        assert ta == pytest.approx(expect_ttft)
        assert 0.0 <= ia <= 1.0
    assert 0.0 <= m.slo_attainment <= 1.0


# ------------------------------------------------- sim/real parity
def test_sim_real_slo_admission_parity():
    """The SLO admission order is produced by the one shared Scheduler:
    identical mixed-class workloads admit in the same order on the real
    JAX engine and the simulator — and that order is NOT FIFO."""
    cfg = get_reduced_config("qwen3-0.6b")
    rng = np.random.default_rng(41)
    classes = ["batch", "interactive", "standard",
               "batch", "interactive", "standard"]
    prompts = [rng.integers(0, cfg.vocab_size, 12 + 4 * i).tolist()
               for i in range(len(classes))]

    def mk():
        return [Request(prompt_tokens=list(p),
                        sampling=SamplingParams(max_new_tokens=2),
                        priority_class=c)
                for p, c in zip(prompts, classes)]

    eng = InferenceEngine(
        cfg, EngineConfig(mixed_batching=False, slo_aware=True,
                          max_batch=2, **{k: v for k, v in ENGINE_KW.items()
                                          if k != "max_batch"}), seed=0)
    real = mk()
    for r in real:
        eng.submit(r)
    eng.run_until_idle()

    loop = EventLoop()
    sim = SimEngine(cfg, loop, SimEngineConfig(
        device_type="a10", max_batch=2, slo_aware=True))
    simr = mk()
    for r in simr:
        r.arrival_time = 0.0
        sim.submit(r)
    loop.run(until=1e6, stop_when=lambda: not sim.has_work)

    def admit_order(reqs):
        return sorted(range(len(reqs)),
                      key=lambda i: reqs[i].schedule_time)

    assert all(r.state == RequestState.FINISHED for r in real + simr)
    assert admit_order(real) == admit_order(simr)
    # interactive (1, 4) first, then standard (2, 5), then batch (0, 3)
    assert admit_order(real) == [1, 4, 2, 5, 0, 3]


# ------------------------------------------------- greedy equivalence
def test_greedy_outputs_identical_slo_on_vs_off():
    """SLO mode reorders admission; it must not change the data plane:
    every request's greedy tokens are identical with SLO on and off."""
    cfg = get_reduced_config("qwen3-0.6b")
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab_size, 16 + 8 * i).tolist()
               for i in range(3)]
    classes = ["batch", "interactive", "standard"]
    outs = []
    for slo in (False, True):
        eng = InferenceEngine(cfg, EngineConfig(slo_aware=slo,
                                                **ENGINE_KW), seed=0)
        reqs = [Request(prompt_tokens=list(p),
                        sampling=SamplingParams(max_new_tokens=5),
                        priority_class=c)
                for p, c in zip(prompts, classes)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_idle()
        outs.append([r.output_tokens for r in reqs])
    assert outs[0] == outs[1]


# ------------------------------------------------------- gateway
class _Stub:
    def __init__(self, m):
        self.m = m

    def metrics(self):
        return self.m

    def match_prefix_len(self, tokens):
        return 0


def test_slo_aware_routing_by_class_attainment():
    """slo-aware routing prefers the engine holding THIS class's SLO,
    not the one with the best overall latency."""
    good = _Stub(EngineMetrics(
        avg_queue_time=0.05,
        slo_by_class=(("interactive", 0.95, 0.9, 20),)))
    bad = _Stub(EngineMetrics(
        avg_queue_time=0.05,
        slo_by_class=(("interactive", 0.20, 0.9, 20),)))
    pol = make_policy("slo-aware")
    engines = {"a": bad, "b": good}
    assert pol.select(engines, [1, 2], priority_class="interactive") == "b"
    # queue pressure is weighed against the class TTFT budget: a queue
    # that eats an interactive budget is fine for batch
    slow = _Stub(EngineMetrics(avg_queue_time=0.45, slo_attainment=1.0))
    empty = _Stub(EngineMetrics(avg_queue_time=0.0, slo_attainment=0.9))
    engines = {"slow": slow, "empty": empty}
    assert pol.select(engines, [1], priority_class="interactive") == "empty"
    assert pol.select(engines, [1], priority_class="batch") == "slow"


def test_all_policies_accept_priority_class():
    from repro.core.gateway.router import POLICIES
    engines = {"a": _Stub(EngineMetrics()), "b": _Stub(EngineMetrics())}
    for name in POLICIES:
        pol = make_policy(name)
        assert pol.select(engines, [1, 2, 3],
                          priority_class="interactive") in engines


# ------------------------------------------------------- autoscaler
def _attainment_store(value, n=70):
    s = MetricStore()
    for t in range(n):
        s.record(float(t), "slo_attainment", value)
    return s


@pytest.mark.parametrize("name", ["hpa", "kpa", "apa"])
def test_autoscalers_scale_up_on_slo_misses(name):
    """slo_attainment is inverted: a drop BELOW target adds replicas."""
    asc = make_autoscaler(name, metric="slo_attainment", target=0.95,
                          max_replicas=32)
    d = asc.desired(69.5, _attainment_store(0.4), current=2)
    assert d.desired > 2


def test_autoscaler_holds_when_slo_met():
    for name in ("hpa", "kpa", "apa"):
        asc = make_autoscaler(name, metric="slo_attainment", target=0.95)
        d = asc.desired(69.5, _attainment_store(0.99), current=4)
        assert d.desired <= 4


def test_autoscaler_scales_back_down_after_slo_recovery():
    """Perfect attainment must shed the replicas a miss burst added
    (miss-ratio pressure, not the ratcheting target/measured form)."""
    for name in ("kpa", "apa"):
        asc = make_autoscaler(name, metric="slo_attainment", target=0.95,
                              min_replicas=1, max_replicas=32)
        d = asc.desired(69.5, _attainment_store(1.0), current=16)
        assert d.desired < 16, name


def test_preemption_fires_when_page_starved():
    """Capacity-blocked includes page starvation with open slots: an
    urgent interactive prefill evicts a batch decode for its pages."""
    scfg = SchedulerConfig(page_size=4, max_batch=8, chunk_size=64,
                           mixed_batching=False, slo_aware=True,
                           honor_stop_token=False,
                           slo_preempt_cooldown_s=0.0)
    sched = Scheduler(scfg, PageAllocator(16, 4))    # 64 tokens of KV
    b1 = _req("batch", prompt_len=24, max_new=30, arrival=0.0, seed=1)
    sched.enqueue(b1, 0.0)
    _drive_to_running(sched, b1, 0.01)       # holds 14 of 16 pages
    urgent = _req("interactive", prompt_len=24, max_new=8, seed=3)
    sched.enqueue(urgent, 1.0)
    sched.schedule(2.0)     # slot free, pages not: must preempt b1
    assert b1.state == RequestState.QUEUED
    assert sched.prefills == [urgent]
    assert sched.metrics(2.0).preemptions == 1
