"""Chaos harness + KV-backed crash recovery.

The load-bearing pin is the real-JAX one: after a simulated engine
kill, a request resumed on a SURVIVOR from its recovery-log checkpoint
must finish byte-identical to the never-crashed greedy run, with the
checkpointed pages served from the distributed pool (not recomputed).
Everything else exercises the detection -> remediation chain: per-fault
monitor actions, quarantine hysteresis (no flapping), pool-partition
retry/backoff with recompute fallback, straggler hedging, and the
cluster-level crash-recovery loop.
"""
import time

import numpy as np
import pytest

from repro.configs import get_config, get_reduced_config
from repro.core.diagnostics.tools import (DiagnosticMonitor, FailureInjector,
                                          FaultKind, Telemetry)
from repro.core.kvcache.pool import DistributedKVPool, KVPoolError
from repro.core.sim.chaos import ChaosEvent, ChaosSchedule
from repro.core.sim.cluster_sim import ClusterConfig, ServingCluster
from repro.core.sim.events import EventLoop
from repro.core.sim.sim_engine import SimEngine, SimEngineConfig
from repro.core.sim.workloads import slo_mixed
from repro.engine import (EngineConfig, InferenceEngine, Request,
                          RequestState, SamplingParams)

ARCH = "deepseek-coder-7b"
ENGINE_KW = dict(page_size=8, num_pages=64, max_batch=4,
                 max_pages_per_seq=16, chunk_size=16)


# ------------------------------------------------------------- schedule
def test_chaos_schedule_validates_and_composes():
    with pytest.raises(ValueError):
        ChaosEvent(1.0, "meteor_strike")
    with pytest.raises(ValueError):
        ChaosEvent(-1.0, "engine_crash")
    sched = (ChaosSchedule.straggler(at=20.0, duration=5.0)
             + ChaosSchedule.engine_crash(at=10.0))
    assert len(sched) == 2
    assert [e.at for e in sched] == [10.0, 20.0]   # iteration is sorted


# ------------------------------------------------------------- injector
def test_injector_clear_leaves_no_empty_entries():
    inj = FailureInjector()
    inj.inject("p0", FaultKind.THERMAL_THROTTLE, now=0.0)
    inj.inject("p0", FaultKind.LINK_FLAP, now=0.0)
    inj.clear("p0", FaultKind.LINK_FLAP)
    assert [f.kind for f in inj.active["p0"]] == \
        [FaultKind.THERMAL_THROTTLE]
    inj.clear("p0", FaultKind.THERMAL_THROTTLE)
    # no empty-list tombstone left behind (unbounded growth in long runs)
    assert "p0" not in inj.active
    inj.clear("p0", FaultKind.DEVICE_LOST)      # clearing absent: no-op
    assert "p0" not in inj.active


# -------------------------------------------------- monitor: per fault
def _sample(pid, t, **kw):
    return Telemetry(pod_id=pid, t=float(t), tokens_per_sec=100.0, **kw)


def test_monitor_device_lost_restarts_immediately():
    mon = DiagnosticMonitor()
    diags = mon.observe(_sample("p0", 1.0, heartbeat_ok=False))
    assert [(d.fault, d.action) for d in diags] == \
        [(FaultKind.DEVICE_LOST, "restart")]


def test_monitor_fatal_ecc_cordons_immediately():
    mon = DiagnosticMonitor()
    diags = mon.observe(_sample("p0", 1.0, ecc_dbe=1))
    assert any(d.fault == FaultKind.ECC_ERROR and d.action == "cordon"
               for d in diags)


def test_monitor_thermal_quarantines_after_confirm():
    inj = FailureInjector()
    mon = DiagnosticMonitor(confirm_n=3)
    inj.inject("p0", FaultKind.THERMAL_THROTTLE, now=0.0, severity=1.0)
    diags = []
    for t in range(1, 4):
        diags += mon.observe(inj.perturb(_sample("p0", t)))
    assert sum(1 for d in diags if d.action == "quarantine") == 1
    assert any(d.fault == FaultKind.THERMAL_THROTTLE for d in diags)
    assert "p0" in mon.quarantined


def test_monitor_link_flap_quarantines_after_confirm():
    mon = DiagnosticMonitor(confirm_n=3)
    diags = []
    for t in range(1, 8):
        diags += mon.observe(_sample("p0", t, link_up=False))
    qs = [d for d in diags if d.action == "quarantine"]
    assert len(qs) == 1 and qs[0].fault == FaultKind.LINK_FLAP


def test_monitor_silent_degradation_quarantines_with_history():
    inj = FailureInjector()
    mon = DiagnosticMonitor(confirm_n=3)
    for t in range(10):                         # healthy baseline
        mon.observe(_sample("p1", t))
    inj.inject("p1", FaultKind.SILENT_DEGRADATION, 10.0, severity=0.9)
    diags = []
    for t in range(10, 25):
        diags += mon.observe(inj.perturb(_sample("p1", t)))
    assert any(d.fault == FaultKind.SILENT_DEGRADATION
               and d.action == "quarantine" for d in diags)


# ------------------------------------------------- monitor: hysteresis
def test_monitor_flapping_engine_does_not_oscillate():
    """An engine alternating anomalous/clean every scrape must neither
    quarantine (streak never reaches confirm_n) nor — once quarantined
    by a sustained anomaly — bounce between readmit and re-quarantine."""
    mon = DiagnosticMonitor(confirm_n=3, quarantine_s=5.0, readmit_n=5)
    diags = []
    t = 0.0
    for i in range(20):                          # flapping: 1 on, 1 off
        t += 1.0
        bad = (i % 2 == 0)
        diags += mon.observe(_sample("pf", t, ecc_sbe=60 if bad else 0))
    assert diags == []                           # hysteresis holds it

    # sustained anomaly -> one quarantine
    for _ in range(3):
        t += 1.0
        diags += mon.observe(_sample("pf", t, ecc_sbe=60))
    assert [d.action for d in diags] == ["quarantine"]

    # flapping DURING quarantine: clean streak keeps resetting, so the
    # pod is neither readmitted nor re-quarantined
    for i in range(20):
        t += 1.0
        bad = (i % 2 == 0)
        diags += mon.observe(_sample("pf", t, ecc_sbe=60 if bad else 0))
    assert [d.action for d in diags] == ["quarantine"]

    # genuinely clean -> exactly one readmit
    for _ in range(6):
        t += 1.0
        diags += mon.observe(_sample("pf", t))
    assert [d.action for d in diags] == ["quarantine", "readmit"]
    assert "pf" not in mon.quarantined


def test_monitor_escalates_stuck_quarantine_to_restart():
    mon = DiagnosticMonitor(confirm_n=2, escalate_s=10.0)
    diags = []
    for t in range(1, 14):
        diags += mon.observe(_sample("pe", t, ecc_sbe=60))
        if any(d.action == "restart" for d in diags):
            break
    assert [d.action for d in diags] == ["quarantine", "restart"]
    assert "pe" not in mon.quarantined           # state dropped on restart


# -------------------------------------------- pool partition + backoff
def test_kv_pool_partition_raises_counts_and_heals():
    t = [0.0]
    pool = DistributedKVPool(capacity_bytes=1 << 20, metadata_lag=0.0,
                             clock=lambda: t[0])
    pool.partition(now=0.0, duration=5.0)
    with pytest.raises(KVPoolError):
        pool.publish("h0", b"x", "e0", 0.0, size_bytes=8)
    with pytest.raises(KVPoolError):
        pool.fetch("h0", "e0", 1.0)
    assert pool.stats.publish_failures == 1
    assert pool.stats.fetch_failures == 1
    t[0] = 6.0                                   # window elapsed
    assert not pool.partitioned(6.0)
    pool.partition(now=6.0, duration=60.0)
    pool.heal()                                  # explicit heal wins
    pool.publish("h0", b"x", "e0", 6.0, size_bytes=8)
    assert pool.fetch("h0", "e1", 6.1) == b"x"


def test_scheduler_survives_partition_with_recompute_fallback():
    """Two sim engines sharing a pool: engine A publishes a prompt's
    pages; the pool partitions; engine B gets the same prompt and must
    fall back to recompute (bounded retries + breaker, no crash), then
    resume pool fetches after the partition heals + backoff expires."""
    cfg = get_config(ARCH)
    loop = EventLoop()
    pool = DistributedKVPool(capacity_bytes=4 << 30, metadata_lag=0.0,
                             clock=loop.clock)
    # engine-local prefix caching off: the healed-pool stage below must
    # go back to the POOL for its pages, not hit b's local cache
    kw = dict(device_type="a10", page_size=16, max_batch=4,
              chunk_size=512, prefix_caching=False)
    a = SimEngine(cfg, loop, SimEngineConfig(**kw), kv_pool=pool,
                  engine_id="a")
    b = SimEngine(cfg, loop, SimEngineConfig(**kw), kv_pool=pool,
                  engine_id="b")
    prompt = [7] * 256
    r0 = Request(prompt_tokens=list(prompt),
                 sampling=SamplingParams(max_new_tokens=4))
    loop.schedule(0.0, lambda: a.submit(r0))
    loop.run(until=20.0, stop_when=lambda: not a.has_work)
    assert pool.stats.puts > 0                   # prompt pages published
    pool.tick(loop.clock.now)                    # flush pending metadata
    assert pool.stats.bytes_stored > 0

    pool.partition(now=loop.clock.now, duration=30.0)
    r1 = Request(prompt_tokens=list(prompt),
                 sampling=SamplingParams(max_new_tokens=4))
    t1 = loop.clock.now
    loop.schedule(t1 + 0.1, lambda: b.submit(r1))
    loop.run(until=t1 + 30.0, stop_when=lambda: loop.clock.now > t1 + 0.1
             and not b.has_work)
    assert r1.state is RequestState.FINISHED     # recompute fallback
    mb = b.metrics()
    assert mb.remote_hit_tokens == 0
    assert mb.kv_fetch_failures > 0              # breaker counted it

    pool.heal()
    r2 = Request(prompt_tokens=list(prompt),
                 sampling=SamplingParams(max_new_tokens=4))
    t2 = loop.clock.now + 10.0                   # past the 8s max backoff
    loop.schedule(t2, lambda: b.submit(r2))
    loop.run(until=t2 + 30.0, stop_when=lambda: loop.clock.now > t2
             and not b.has_work)
    assert r2.state is RequestState.FINISHED
    assert b.metrics().remote_hit_tokens > 0     # pool fetches resumed


# ------------------------------------------------- gateway-level pieces
def test_gateway_cordon_and_straggler_detection():
    class FakeMetrics:
        def __init__(self, tps, waiting):
            self.tokens_per_sec = tps
            self.num_waiting = waiting
            self.num_running = 0
            self.num_active_tokens = 0
            self.kv_utilization = 0.0

    class FakeEngine:
        def __init__(self, tps, waiting=1):
            self._m = FakeMetrics(tps, waiting)

        def metrics(self):
            return self._m

    from repro.core.gateway.gateway import Gateway
    gw = Gateway(policy="least-request")
    gw.register_engine("fast0", FakeEngine(100.0))
    gw.register_engine("fast1", FakeEngine(100.0))
    gw.register_engine("slow", FakeEngine(10.0))
    assert gw.straggler_engines(ratio=0.5) == ["slow"]
    # an idle slow engine is not worth hedging
    gw.engines["slow"]._m.num_waiting = 0
    assert gw.straggler_engines(ratio=0.5) == []

    gw.cordon("fast1", reason="quarantine")
    assert "fast1" not in gw.routable_engines()
    assert "fast1" in gw.engines                 # still registered
    assert gw.stats.engine_failures["fast1"]["quarantine"] == 1
    gw.uncordon("fast1")
    assert "fast1" in gw.routable_engines()


# ------------------------------------------------- cluster-level chaos
def _cluster(chaos, n=3, ckpt=64, seed=3, rate=3.0, dur=15.0, mb=8,
             **ccfg_kw):
    cfg = get_config(ARCH)
    wl = slo_mixed(rate_rps=rate, duration_s=dur, seed=seed)
    ecfg = SimEngineConfig(device_type="a10", max_batch=mb, chunk_size=512,
                           mixed_batching=True,
                           ckpt_interval_tokens=ckpt)
    ccfg = ClusterConfig(num_engines=n, engine=ecfg, use_kv_pool=True,
                         chaos=chaos, **ccfg_kw)
    c = ServingCluster(cfg, ccfg)
    s = c.run(wl, drain_s=300.0)
    return c, s, [tr.request for tr in wl]


def test_cluster_engine_crash_recovers_all_requests():
    c, s, reqs = _cluster(ChaosSchedule.engine_crash(at=5.0))
    assert s["crashed_requests"] > 0
    assert s["crash_recovered"] == s["crashed_requests"]
    assert s["finished"] == len(reqs)            # nothing lost
    assert s["ckpt_pages"] > 0                   # recovery log was fed
    # the dead engine was replaced and removed from pool membership
    dead = [eid for eid, e in c.engines.items() if not e.alive]
    assert len(dead) == 1
    assert c.pool_mgr.role_of(dead[0]) is None
    assert dead[0] not in c.gateway.engines


def test_cluster_crash_without_recovery_loses_requests():
    _, s, reqs = _cluster(ChaosSchedule.engine_crash(at=5.0),
                          crash_recovery=False)
    assert s["crashed_requests"] > 0
    assert s["crash_recovered"] == 0
    assert s["finished"] < len(reqs)             # the pre-chaos behavior


def test_cluster_straggler_quarantine_and_hedging():
    # the straggler starts only after the monitor has a dozen clean
    # scrapes: silent-degradation detection compares against a baseline
    # median of the FIRST positive throughput samples, so a fault at
    # t=3s would pollute the baseline and never be diagnosed
    # max_batch=2 keeps queues non-empty under load: hedging only moves
    # NOT-yet-started requests, so the straggler must actually queue
    c, s, reqs = _cluster(
        ChaosSchedule.straggler(at=12.0, duration=25.0, severity=0.95,
                                fault=FaultKind.SILENT_DEGRADATION),
        n=4, rate=6.0, dur=30.0, mb=2, hedge_ratio=0.6)
    assert s["finished"] == len(reqs)
    # detection fired: the slow engine was cordoned out of routing
    assert s["quarantines"] >= 1
    # hedging pulled queued work off the straggler before/while the
    # monitor's confirm window elapsed
    assert s["hedged"] >= 1


def test_cluster_kv_partition_degrades_to_recompute():
    _, s, reqs = _cluster(ChaosSchedule.kv_partition(at=3.0, duration=8.0))
    assert s["finished"] == len(reqs)            # nobody crashed on it
    assert s["pool_publish_failures"] + s["pool_fetch_failures"] > 0
    assert s["kv_fetch_failures"] > 0            # engines hit the breaker


def test_cluster_gateway_restart_defers_then_delivers():
    c, s, reqs = _cluster(ChaosSchedule.gateway_restart(at=4.0,
                                                        duration=2.0))
    assert s["gw_restarts"] == 1
    assert s["gw_deferred"] > 0                  # dispatches were deferred
    assert s["finished"] == len(reqs)            # clients retried through
    assert c.gateway.cordoned == set()           # warm state wiped


def test_retire_engine_removes_pool_membership():
    cfg = get_config(ARCH)
    ccfg = ClusterConfig(num_engines=2,
                         engine=SimEngineConfig(device_type="a10"))
    c = ServingCluster(cfg, ccfg)
    eids = list(c.engines)
    assert all(c.pool_mgr.role_of(e) is not None for e in eids)
    c._retire_engine()
    gone = [e for e in eids if c.pool_mgr.role_of(e) is None]
    assert len(gone) == 1                        # satellite fix: no ghost
    assert len(c.gateway.engines) == 1


# ---------------------------------------------- real JAX engine: resume
def test_crash_recovery_real_engine_byte_identical():
    """Kill engine A mid-decode past a recovery-log checkpoint; the
    harvested request resumes on engine B from the checkpointed pages
    (pool-backed, not recomputed) and the final output is byte-identical
    to the never-crashed greedy run."""
    cfg = get_reduced_config("qwen3-0.6b")
    t0 = time.monotonic()
    clock = lambda: time.monotonic() - t0        # noqa: E731
    pool = DistributedKVPool(capacity_bytes=1 << 30, metadata_lag=0.0,
                             clock=clock)
    kw = dict(ENGINE_KW, ckpt_interval_tokens=8)   # every full page
    a = InferenceEngine(cfg, EngineConfig(**kw), clock=clock,
                        kv_pool_client=pool, engine_id="a", seed=0)
    b = InferenceEngine(cfg, EngineConfig(**kw), clock=clock,
                        kv_pool_client=pool, engine_id="b", seed=0)
    rng = np.random.default_rng(53)
    prompt = rng.integers(0, cfg.vocab_size, 20).tolist()
    max_new = 16

    # uncrashed greedy reference on a fresh engine
    ref_eng = InferenceEngine(cfg, EngineConfig(**ENGINE_KW), seed=0)
    ref = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=max_new))
    ref_eng.submit(ref)
    ref_eng.run_until_idle()
    assert len(ref.output_tokens) == max_new

    req = Request(prompt_tokens=list(prompt),
                  sampling=SamplingParams(max_new_tokens=max_new))
    a.submit(req)
    for _ in range(400):                         # decode past a page edge
        if len(req.output_tokens) >= 10:
            break
        a.step()
    assert len(req.output_tokens) >= 10
    generated = list(req.output_tokens)
    assert a.metrics().ckpt_pages >= 1
    # the recovery log covers at least one GENERATED page
    assert req.ckpt_tokens > len(prompt)

    lost = a.sched.crash_takeover(a.clock())     # engine A is dead now
    assert lost == [req]
    assert req.state is RequestState.QUEUED
    covered = req.ckpt_tokens - len(prompt)
    # rewind kept the checkpoint-covered generated prefix, dropped the
    # uncovered tail (it will be re-decoded on B)
    assert req.output_tokens == generated[:covered]
    assert req.prompt_tokens == prompt           # never folded
    resume_cov = req.ckpt_tokens                 # page-aligned coverage

    b.submit(req)
    b.run_until_idle()
    assert req.state is RequestState.FINISHED
    # byte-identical continuation from the checkpointed prefix
    assert req.output_tokens == ref.output_tokens
    # resumed from the pool: B fetched EVERY checkpointed page (prompt
    # + generated, including the decode-computed final page) instead
    # of recomputing any of them
    assert b.metrics().remote_hit_tokens == resume_cov
    assert b.sched._m["crash_resumes"] == 1
