"""GPU-optimizer walkthrough (paper §3.2.7): from live gateway logs to
an ILP allocation to autoscaler desired-replica feeds.

    PYTHONPATH=src python examples/hetero_optimizer.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.gateway import Gateway
from repro.core.optimizer import (GPUOptimizer, LoadMonitor, ProfileTable,
                                  homogeneous_cost)


class _NullEngine:
    def metrics(self):
        from repro.engine.engine import EngineMetrics
        return EngineMetrics()

    def match_prefix_len(self, tokens):
        return 0


def main():
    cfg = get_config("deepseek-coder-7b")
    gw = Gateway(policy="random")
    gw.register_engine("e0", _NullEngine())

    # simulate an hour of mixed traffic hitting the gateway
    rng = np.random.default_rng(0)
    t = 0.0
    for _ in range(3000):
        t += rng.exponential(1 / 25.0)
        if rng.random() < 0.7:      # chat
            ilen = int(np.clip(rng.lognormal(5.3, 0.8), 16, 4000))
            olen = int(np.clip(rng.lognormal(4.6, 0.7), 8, 800))
        else:                       # text2sql
            ilen = int(np.clip(rng.normal(1800, 300), 800, 6000))
            olen = int(np.clip(rng.normal(30, 10), 5, 90))
        gw.clock = lambda t=t: t
        gw.route([0] * ilen, est_output_tokens=olen)

    monitor = LoadMonitor()
    demand = monitor.demand(gw.request_log, window_s=t)
    print("demand buckets (in,out -> rps):")
    for d in demand:
        print(f"  {d.bucket.key}: {d.rps:.2f} rps")

    table = ProfileTable(cfg, slo_ttft_s=5.0, slo_itl_s=0.25)
    opt = GPUOptimizer(table, ("a10", "l20", "v100"),
                       availability={"v100": 2})
    alloc = opt.optimize(demand)
    print(f"\nILP allocation: {alloc.counts}  "
          f"${alloc.cost_per_hour:.2f}/h  {alloc.note or '(milp)'}")
    for (bucket, dev), rps in sorted(alloc.assignment.items()):
        print(f"  bucket {bucket} -> {dev}: {rps:.2f} rps")
    n, c = homogeneous_cost(table, demand, "l20")
    print(f"homogeneous l20 baseline: {n} pods  ${c:.2f}/h")
    print(f"cost reduction: {100*(1-alloc.cost_per_hour/c):.1f}%")
    print("\nautoscaler metric source:", opt.metric_source(demand))
    print("hetero_optimizer OK")


if __name__ == "__main__":
    main()
