"""Full AIBrix control-plane demo on the cluster simulator:

gateway routing + distributed KV cache pool + APA autoscaling + a
failure injection handled by the diagnostics -> orchestration loop,
over a Bird-SQL-like workload at production scale (simulated 4-40x A10
fleet serving deepseek-coder-7b).

    PYTHONPATH=src python examples/serve_cluster.py
"""
from repro.configs import get_config
from repro.core.autoscaler.policies import make_autoscaler
from repro.core.diagnostics.tools import FaultKind
from repro.core.sim import ClusterConfig, ServingCluster, SimEngineConfig
from repro.core.sim.workloads import birdsql_like


def main():
    cfg = get_config("deepseek-coder-7b")
    ccfg = ClusterConfig(
        routing_policy="prefix-load",
        device_type="a10",
        num_engines=4,
        engine=SimEngineConfig(device_type="a10", max_batch=24,
                               chunk_size=512),
        use_kv_pool=True, kv_pool_gb=64.0, kv_pool_policy="s3fifo",
        autoscaler=make_autoscaler("apa", metric="concurrency",
                                   target=12.0, min_replicas=2,
                                   max_replicas=10),
        telemetry=True)
    cluster = ServingCluster(cfg, ccfg)

    # inject a thermal throttle mid-run; the monitor should catch it
    cluster.loop.after(30.0, lambda: cluster.injector.inject(
        "engine-1", FaultKind.THERMAL_THROTTLE, 30.0, severity=0.8))

    wl = birdsql_like(800, rate_rps=18.0, seed=7)
    summary = cluster.run(wl)

    print("== cluster summary ==")
    for k in ("finished", "total_tput_tok_s", "ttft_avg_ms", "ttft_p99_ms",
              "itl_avg_ms", "latency_p99_s", "prefix_hit_tokens",
              "remote_hit_tokens", "pool_evictions", "rejected"):
        v = summary.get(k, 0)
        print(f"  {k:22s} {v:.1f}" if isinstance(v, float)
              else f"  {k:22s} {v}")
    print(f"  replicas over time: "
          f"{[d for _, _, d in cluster.scale_history[::20]]}")
    print(f"  diagnoses: "
          f"{[(d.pod_id, d.fault.value, d.action) for d in cluster.diagnoses[:4]]}")
    print(f"  pool stats: {cluster.kv_pool.stats}")
    assert summary["finished"] >= 780        # a few may be re-queued
    print("serve_cluster OK")


if __name__ == "__main__":
    main()
