"""End-to-end training driver: a ~100M-param qwen3-family model on the
synthetic Markov corpus for a few hundred steps (CPU, ~10-20 min full;
--steps 30 for a quick pass).  Shows a real decreasing loss curve,
checkpointing, and restore.

    PYTHONPATH=src python examples/train_dense_100m.py --steps 300
"""
import argparse

import jax

from repro.configs import get_config
from repro.data import synthetic_lm_batches
from repro.models.config import ModelConfig
from repro.training import checkpoint as ckpt
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train_loop


def model_100m() -> ModelConfig:
    # qwen3 family scaled to ~100M params (8 layers, d=512, vocab 16k)
    return get_config("qwen3-0.6b").replace(
        name="qwen3-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, d_ff=1536, vocab_size=16_384, head_dim=64,
        layer_pattern=("dense",) * 8, max_seq_len=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_100m")
    args = ap.parse_args()

    cfg = model_100m()
    from repro.models import model as M
    n = cfg.param_count()
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")
    data = synthetic_lm_batches(cfg.vocab_size, args.batch, args.seq,
                                corpus_tokens=400_000)
    opt = AdamWConfig(lr=6e-4, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps, weight_decay=0.05)
    state, history = train_loop(
        cfg, opt, data, args.steps, key=jax.random.PRNGKey(0),
        log_every=max(args.steps // 15, 1),
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=max(args.steps // 2, 10))
    for h in history:
        print(f"  step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"lr {h['lr']:.2e}  gnorm {h['grad_norm']:.2f}  "
              f"({h['elapsed_s']:.0f}s)")
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first - 0.3, "loss should drop on the Markov corpus"
    # checkpoint roundtrip
    step = ckpt.latest_step(args.ckpt_dir)
    restored = ckpt.restore(args.ckpt_dir, state, step)
    leaf0 = jax.tree.leaves(restored.params)[0]
    print(f"checkpoint restore OK (step {step}, leaf {leaf0.shape})")
    print("train_dense_100m OK")


if __name__ == "__main__":
    main()
