"""Long-context decoding with a constant-size state (the long_500k
story at CPU scale): an xLSTM-family model decodes with a context far
beyond what its (constant-size!) state stores explicitly, served
through the SlotEngine.  The full-size analogue — 524,288-token decode
sharded across pooled pod HBM — is exercised by
``python -m repro.launch.dryrun --arch xlstm-1.3b --shape long_500k``.

    PYTHONPATH=src python examples/long_context_ssm.py
"""
import numpy as np

from repro.configs import get_reduced_config
from repro.engine.request import Request, SamplingParams
from repro.engine.slot_engine import SlotEngine, SlotEngineConfig
from repro.models import model as M


def main():
    cfg = get_reduced_config("xlstm-1.3b")
    print(f"model: {cfg.name}  (mLSTM/sLSTM pattern "
          f"{cfg.layer_runs}, no KV cache)")

    # state size is CONSTANT in sequence length — measure it
    caches = M.init_cache(cfg, 1, 8)
    import jax
    state_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(caches))
    print(f"recurrent state: {state_bytes/1e3:.1f} KB per sequence, "
          f"independent of context length")

    eng = SlotEngine(cfg, SlotEngineConfig(max_slots=2, max_len=1024))
    rng = np.random.default_rng(0)
    # a 700-token context — far past anything storable at KV-cache cost
    long_prompt = rng.integers(0, cfg.vocab_size, 700).tolist()
    req = Request(prompt_tokens=long_prompt,
                  sampling=SamplingParams(max_new_tokens=24))
    eng.submit(req)
    eng.run_until_idle()
    print(f"context {len(long_prompt)} tokens -> generated "
          f"{len(req.output_tokens)} tokens: {req.output_tokens[:12]}...")
    assert len(req.output_tokens) == 24
    print("long_context_ssm OK")


if __name__ == "__main__":
    main()
