"""Quickstart: serve a model with batched requests through the real
JAX engine behind an AIBrix gateway — end to end on CPU in ~30s.

    PYTHONPATH=src python examples/quickstart.py

What happens:
  1. a reduced qwen3-family model is instantiated (random weights),
  2. two InferenceEngine pods register with the Gateway,
  3. a batch of requests (sharing a system-prompt prefix) is routed
     with the prefix-cache-aware policy and served with continuous
     batching + paged KV cache,
  4. per-request TTFT/ITL and the engines' prefix-hit stats print.
"""
import time

import numpy as np

from repro.configs import get_reduced_config
from repro.core.gateway import Gateway
from repro.core.sim.workloads import summarize
from repro.engine import (EngineConfig, InferenceEngine, Request,
                          SamplingParams)


def main():
    cfg = get_reduced_config("qwen3-0.6b")
    t0 = time.monotonic()
    clock = lambda: time.monotonic() - t0        # noqa: E731

    gateway = Gateway(policy="prefix-cache-aware", clock=clock)
    engines = {}
    for i in range(2):
        eng = InferenceEngine(
            cfg,
            EngineConfig(page_size=8, num_pages=256, max_batch=4,
                         max_pages_per_seq=32, chunk_size=32),
            clock=clock, engine_id=f"engine-{i}", seed=i)
        engines[f"engine-{i}"] = eng
        gateway.register_engine(f"engine-{i}", eng)

    rng = np.random.default_rng(0)
    system_prompt = rng.integers(0, cfg.vocab_size, 32).tolist()
    requests = []
    for i in range(10):
        prompt = system_prompt + rng.integers(
            0, cfg.vocab_size, 8 + (i % 5)).tolist()
        req = Request(prompt_tokens=prompt,
                      sampling=SamplingParams(max_new_tokens=12,
                                              temperature=0.0),
                      arrival_time=clock())
        target = gateway.route(prompt, est_output_tokens=12)
        engines[target].submit(req)
        requests.append((target, req))
        for eng in engines.values():             # interleave serving
            if eng.has_work:
                eng.step()
    while any(e.has_work for e in engines.values()):
        for eng in engines.values():
            if eng.has_work:
                eng.step()

    print("routing decisions:", dict(gateway.stats.per_engine))
    for eid, req in requests[:4]:
        print(f"  req {req.request_id} -> {eid}: "
              f"out={req.output_tokens}  ttft={req.ttft*1e3:.0f}ms")
    stats = summarize([r for _, r in requests])
    print("summary:", {k: round(v, 2) if isinstance(v, float) else v
                       for k, v in stats.items()})
    for eid, eng in engines.items():
        m = eng.metrics()
        print(f"  {eid}: finished={m.finished_requests} "
              f"prefix_hit_tokens={m.prefix_hit_tokens} "
              f"kv_util={m.kv_utilization:.2f}")
    assert stats["finished"] == len(requests)
    print("quickstart OK")


if __name__ == "__main__":
    main()
