"""AdamW + cosine LR schedule (pure JAX, pytree-generic, no optax)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac
                    + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params
                 ) -> tuple:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        update = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        # moments stored in their carried dtype (bf16 for 200B-scale —
        # halves optimizer-state HBM; math is always f32)
        return ((p.astype(jnp.float32) - lr * update).astype(p.dtype),
                mf.astype(m.dtype), vf.astype(v.dtype))

    flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics: Dict[str, jax.Array] = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_mu, new_nu), metrics
