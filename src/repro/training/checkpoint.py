"""Flat-namespace checkpointing: pytree -> one .npz per step + manifest.

No external deps (no orbax); arrays are saved by their tree path so a
checkpoint round-trips through any pytree with matching structure.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}"))
    elif hasattr(tree, "_fields"):            # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}/{k}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def save(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **{k: v for k, v in flat.items()})
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump({"latest_step": step, "latest": path}, f)
    return path


def latest_step(directory: str) -> int:
    try:
        with open(os.path.join(directory, "manifest.json")) as f:
            return json.load(f)["latest_step"]
    except FileNotFoundError:
        return 0


def restore(directory: str, like: Any, step: int = 0) -> Any:
    """Restore into the structure of ``like``."""
    step = step or latest_step(directory)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}/{k}") for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(*(rebuild(getattr(tree, k), f"{prefix}/{k}")
                                for k in tree._fields))
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}/{i}") for i, v in enumerate(tree)]
            return type(tree)(vals)
        arr = data[prefix]
        return jnp.asarray(arr, dtype=tree.dtype if hasattr(tree, "dtype")
                           else None)

    return rebuild(like)
