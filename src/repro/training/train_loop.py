"""Training step/loop used by the train_4k dry-run shape and the
end-to-end example driver (examples/train_dense_100m.py)."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                      adamw_update)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_state(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32
               ) -> TrainState:
    params = M.init(cfg, key, dtype)
    return TrainState(params, adamw_init(params))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    remat: bool = True, grad_accum: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_accum`` > 1 splits the global batch into microbatches and
    accumulates f32 gradients in a rematerialized scan — activation
    peak memory scales 1/grad_accum at unchanged math (the standard
    recipe that brings 200B-scale training into per-chip HBM).
    """

    def grads_of(params, batch):
        def loss_of(p):
            return M.loss_fn(p, cfg, batch, remat=remat)
        return jax.value_and_grad(loss_of, has_aux=True)(params)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if grad_accum <= 1:
            (loss, metrics), grads = grads_of(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                g_acc, loss_acc = acc
                (loss, _m), g = grads_of(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = {"nll": loss}
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params)
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return TrainState(new_params, new_opt), metrics

    return train_step


def train_loop(cfg: ModelConfig, opt_cfg: AdamWConfig, data_iter,
               num_steps: int, key: Optional[jax.Array] = None,
               log_every: int = 10, dtype=jnp.float32,
               checkpoint_dir: Optional[str] = None,
               checkpoint_every: int = 0):
    """Simple single-host loop; returns (state, history)."""
    from repro.training import checkpoint as ckpt
    key = key if key is not None else jax.random.PRNGKey(0)
    state = init_state(cfg, key, dtype)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    history = []
    t0 = time.time()
    for step in range(num_steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if step % log_every == 0 or step == num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["elapsed_s"] = time.time() - t0
            history.append(m)
        if checkpoint_dir and checkpoint_every and \
                (step + 1) % checkpoint_every == 0:
            ckpt.save(checkpoint_dir, step + 1, state)
    return state, history
