"""Serving launcher: one AIBrix pod group on this host.

``python -m repro.launch.serve --arch qwen3-0.6b --requests 16`` spins
up N real JAX engines behind the AIBrix gateway (routing policy
selectable), serves a synthetic batch of requests end-to-end, and prints
the per-request latency metrics the paper's evaluations report.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_reduced_config
from repro.core.gateway import Gateway
from repro.core.sim.workloads import summarize
from repro.engine import EngineConfig, InferenceEngine, Request, \
    SamplingParams


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--engines", type=int, default=2)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--policy", default="prefix-cache-aware")
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    t0 = time.monotonic()
    clock = lambda: time.monotonic() - t0      # noqa: E731
    gw = Gateway(policy=args.policy, clock=clock)
    engines = {}
    for i in range(args.engines):
        eng = InferenceEngine(
            cfg, EngineConfig(page_size=8, num_pages=256, max_batch=4,
                              max_pages_per_seq=32, chunk_size=32),
            clock=clock, engine_id=f"engine-{i}", seed=i)
        engines[f"engine-{i}"] = eng
        gw.register_engine(f"engine-{i}", eng)

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 24).tolist()
    reqs = []
    for i in range(args.requests):
        prompt = shared + rng.integers(
            0, cfg.vocab_size, max(args.prompt_len - 24, 4)).tolist()
        r = Request(prompt_tokens=prompt,
                    sampling=SamplingParams(max_new_tokens=args.max_new),
                    arrival_time=clock())
        eid = gw.route(prompt, est_output_tokens=args.max_new)
        engines[eid].submit(r)
        reqs.append((eid, r))
        # interleave a bit of serving with arrivals
        for eng in engines.values():
            if eng.has_work:
                eng.step()
    while any(e.has_work for e in engines.values()):
        for eng in engines.values():
            if eng.has_work:
                eng.step()

    print(f"\nrouting ({args.policy}):", dict(gw.stats.per_engine))
    s = summarize([r for _, r in reqs])
    for k, v in s.items():
        print(f"  {k:22s} {v:.2f}" if isinstance(v, float) else
              f"  {k:22s} {v}")
    for eid, eng in engines.items():
        m = eng.metrics()
        print(f"  {eid}: finished={m.finished_requests} "
              f"prefix_hit_tokens={m.prefix_hit_tokens} "
              f"kv_util={m.kv_utilization:.2f}")


if __name__ == "__main__":
    main()
