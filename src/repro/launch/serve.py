"""Serving launcher: one AIBrix pod group on this host.

``python -m repro.launch.serve --arch qwen3-0.6b --requests 16`` spins
up N real JAX engines behind the AIBrix gateway (routing policy
selectable), serves a synthetic batch of requests end-to-end, and prints
the per-request latency metrics the paper's evaluations report.

Prefill/decode disaggregation (paper §3.2.5) on the REAL data plane:
``--roles 2P2D`` builds 2 prefill + 2 decode JAX engines around a
shared :class:`DistributedKVPool`.  Prefill engines publish each
finished prompt's KV pages into the pool (content-addressed by block
hash) and hand the request to the least-loaded decode engine, which
pulls the pages at admission and only recomputes the tail block before
decoding — the DistServe-style handoff the cluster simulator's
``benchmarks/bench_pd_disagg.py`` measures at scale, here executed by
the actual jitted engines.

SLO-aware serving: ``--slo`` turns on deadline-aware scheduling in
every engine (priority classes with TTFT/ITL targets, earliest-slack
admission, bounded priority preemption); ``--interactive-frac`` sets
the interactive/batch request mix and ``--policy slo-aware`` routes by
per-class attainment instead of raw latency.  Per-class attainment is
printed per engine (``benchmarks/bench_slo.py`` measures the same
policy on the simulator).
"""
from __future__ import annotations

import argparse
import re
import time

import numpy as np

from repro.configs import get_reduced_config
from repro.core.gateway import Gateway
from repro.core.kvcache.pool import DistributedKVPool
from repro.core.sim.workloads import summarize
from repro.engine import EngineConfig, InferenceEngine, Request, \
    SamplingParams


def parse_roles(spec: str, default_engines: int):
    """'mixed' -> N mixed engines; '2P2D'/'1p3d' -> disaggregated."""
    if not spec or spec == "mixed":
        return ["mixed"] * default_engines
    m = re.fullmatch(r"(\d+)[pP](\d+)[dD]", spec)
    if m is None:
        raise ValueError(
            f"--roles {spec!r}: expected 'mixed' or '<n>P<m>D'")
    n_p, n_d = int(m.group(1)), int(m.group(2))
    if n_p == 0 or n_d == 0:
        raise ValueError(
            f"--roles {spec!r}: a disaggregated group needs at least "
            "one prefill AND one decode engine")
    return ["prefill"] * n_p + ["decode"] * n_d


def build_engines(cfg, roles, clock, ecfg_kw=None):
    """A pod group: engines (+ pool & handoff wiring when disaggregated).

    Returns (engines dict, frontends dict, pool).  ``frontends`` are the
    engines that accept NEW requests (prefill or mixed) — decode engines
    only receive handed-off work.
    """
    kw = dict(page_size=8, num_pages=256, max_batch=4,
              max_pages_per_seq=32, chunk_size=32)
    kw.update(ecfg_kw or {})
    disagg = any(r != "mixed" for r in roles)
    pool = None
    if disagg:
        pool = DistributedKVPool(capacity_bytes=1 << 30,
                                 metadata_lag=0.0, clock=clock)
    engines = {}
    for i, role in enumerate(roles):
        eid = f"{role}-{i}" if disagg else f"engine-{i}"
        engines[eid] = InferenceEngine(
            cfg, EngineConfig(role=role, **kw), clock=clock,
            kv_pool_client=pool, engine_id=eid, seed=0 if disagg else i)
    if disagg:
        decoders = [e for e in engines.values()
                    if e.ecfg.role in ("decode", "mixed")]

        def handoff(req):
            tgt = min(decoders, key=lambda e: len(e.running)
                      + len(e.waiting) + len(e.prefills))
            tgt.submit(req)

        for e in engines.values():
            if e.ecfg.role == "prefill":
                e.handoff = handoff
    frontends = {eid: e for eid, e in engines.items()
                 if e.ecfg.role in ("prefill", "mixed")}
    return engines, frontends, pool


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--engines", type=int, default=None,
                    help="pod count for --roles mixed (default 2)")
    ap.add_argument("--roles", default="mixed",
                    help="'mixed' (default, --engines colocated pods) or "
                         "'2P2D'-style prefill/decode disaggregation")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--policy", default="prefix-cache-aware")
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slo", action="store_true",
                    help="SLO-aware scheduling (priority classes, "
                         "earliest-slack admission, preemption); pair "
                         "with --policy slo-aware for SLO routing")
    ap.add_argument("--interactive-frac", type=float, default=0.5,
                    help="fraction of requests tagged priority class "
                         "'interactive' (the rest are 'batch')")
    args = ap.parse_args()

    if args.engines is not None and args.roles != "mixed":
        ap.error("--engines only applies to --roles mixed; a "
                 "'<n>P<m>D' spec fixes the pod count itself")
    cfg = get_reduced_config(args.arch)
    t0 = time.monotonic()
    clock = lambda: time.monotonic() - t0      # noqa: E731
    roles = parse_roles(args.roles, args.engines or 2)
    gw = Gateway(policy=args.policy, clock=clock)
    engines, frontends, pool = build_engines(
        cfg, roles, clock, ecfg_kw=dict(slo_aware=args.slo))
    for eid, eng in frontends.items():
        gw.register_engine(eid, eng)

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 24).tolist()
    reqs = []
    for i in range(args.requests):
        prompt = shared + rng.integers(
            0, cfg.vocab_size, max(args.prompt_len - 24, 4)).tolist()
        pclass = ("interactive" if rng.random() < args.interactive_frac
                  else "batch")
        r = Request(prompt_tokens=prompt,
                    sampling=SamplingParams(max_new_tokens=args.max_new),
                    arrival_time=clock(), priority_class=pclass)
        eid = gw.route(prompt, est_output_tokens=args.max_new,
                       priority_class=pclass)
        engines[eid].submit(r)
        reqs.append((eid, r))
        # interleave a bit of serving with arrivals
        for eng in engines.values():
            if eng.has_work:
                eng.step()
    while any(e.has_work for e in engines.values()):
        for eng in engines.values():
            if eng.has_work:
                eng.step()

    print(f"\nrouting ({args.policy}):", dict(gw.stats.per_engine))
    s = summarize([r for _, r in reqs])
    for k, v in s.items():
        print(f"  {k:22s} {v:.2f}" if isinstance(v, float) else
              f"  {k:22s} {v}")
    for eid, eng in engines.items():
        m = eng.metrics()
        print(f"  {eid}: finished={m.finished_requests} "
              f"prefix_hit_tokens={m.prefix_hit_tokens} "
              f"remote_hit_tokens={m.remote_hit_tokens} "
              f"kv_util={m.kv_utilization:.2f}")
        if m.slo_by_class:
            rows = " ".join(
                f"{c}: ttft={ta:.2f} itl={ia:.2f} n={n}"
                for c, ta, ia, n in m.slo_by_class)
            print(f"    slo_attainment={m.slo_attainment:.2f} [{rows}]")
    if pool is not None:
        st = pool.stats
        print(f"  pool: puts={st.puts} hits={st.hits_local + st.hits_remote}"
              f" dup_drops={st.dup_puts_dropped}"
              f" bytes_stored={st.bytes_stored}")


if __name__ == "__main__":
    main()
