"""Serving launcher: one AIBrix pod group on this host.

``python -m repro.launch.serve --arch qwen3-0.6b --requests 16`` spins
up N real JAX engines behind the AIBrix gateway (routing policy
selectable), serves a synthetic batch of requests end-to-end, and prints
the per-request latency metrics the paper's evaluations report.

Prefill/decode disaggregation (paper §3.2.5) on the REAL data plane:
``--roles 2P2D`` builds 2 prefill + 2 decode JAX engines around a
shared :class:`DistributedKVPool`.  Prefill engines publish each
finished prompt's KV pages into the pool (content-addressed by block
hash) and hand the request to the least-loaded decode engine, which
pulls the pages at admission and only recomputes the tail block before
decoding — the DistServe-style handoff the cluster simulator's
``benchmarks/bench_pd_disagg.py`` measures at scale, here executed by
the actual jitted engines.

Role pools: every engine group is owned by a
:class:`~repro.core.orchestration.pools.RolePoolManager` — the gateway
routes new requests to the prefill pool only and handoffs load-balance
over the decode pool.  ``--roles auto`` lets the control plane pick
the split: the GPU optimizer's ``split_roles`` planner proposes the
initial P:D ratio from the roofline profile and the request shape, and
an :class:`AttainmentRebalancer` adapts it live (attainment-driven
role migration — no restarts) while the group serves.

Tiered KV cache: ``--host-cache-gb`` gives every engine a host-DRAM
page tier below device HBM — device-cache evictions cascade into it
(content-addressed by the same block hashes) and preemption *swaps*
the victim's pages out instead of recomputing from token 0.
``--wire-dtype int8`` quantizes pool-handoff payloads with per-layer
scales so a P->D handoff moves ~4x fewer bytes; transfers stream in
page-group chunks either way (``EngineConfig.handoff_chunk_pages``).

SLO-aware serving: ``--slo`` turns on deadline-aware scheduling in
every engine (priority classes with TTFT/ITL targets, earliest-slack
admission, bounded priority preemption); ``--interactive-frac`` sets
the interactive/batch request mix and ``--policy slo-aware`` routes by
per-class attainment instead of raw latency.  Per-class attainment is
printed per engine (``benchmarks/bench_slo.py`` measures the same
policy on the simulator).

High-density multi-LoRA (paper §3.2.1): ``--adapters N`` registers N
LoRA adapters with a :class:`LoRAController` (zipf-shaped demand
prior), density-places them over the engines' HBM adapter banks, and
tags every request with a zipf-drawn adapter.  ``--lora-policy``
selects the gateway policy for the run (default ``lora-affinity`` —
requests route to pods where their adapter is already resident; the
controller's registry backs endpoint discovery).  Affinity hit rate,
cold loads, and scheduler-level adapter misses are printed at the end
(``benchmarks/bench_lora.py`` measures the same path at cluster scale).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_reduced_config
from repro.core.gateway import Gateway
from repro.core.kvcache.pool import DistributedKVPool
from repro.core.lora.manager import AdapterSpec, LoRAController
from repro.core.optimizer.gpu_optimizer import DemandBucket, split_roles
from repro.core.optimizer.profiles import ProfileTable, WorkloadBucket
from repro.core.orchestration.pools import (AttainmentRebalancer,
                                            RebalanceConfig,
                                            RolePoolManager,
                                            parse_role_spec)
from repro.core.sim.workloads import summarize
from repro.engine import EngineConfig, InferenceEngine, Request, \
    SamplingParams
from repro.engine.scheduler import DEFAULT_SLO_CLASSES


def parse_roles(spec: str, default_engines: int):
    """Back-compat alias for the shared role-spec parser."""
    return parse_role_spec(spec, default_engines)


def auto_roles(cfg, n_engines: int, prompt_len: int, max_new: int,
               rate_rps: float = 1.0, device: str = "a10"):
    """``--roles auto``: seed the P:D split from the GPU optimizer's
    roofline planner over the offered request shape (the live
    rebalancer adapts it from attainment once serving starts —
    ``device`` names the planner's roofline profile, which need not
    match the host exactly for the seed to be useful)."""
    interactive = DEFAULT_SLO_CLASSES["interactive"]
    rs = split_roles(ProfileTable(cfg),
                     [DemandBucket(WorkloadBucket(prompt_len, max_new),
                                   rate_rps)],
                     device=device, total_engines=n_engines,
                     slo_ttft_s=interactive.ttft_s,
                     slo_itl_s=interactive.itl_s)
    return ["prefill"] * rs.n_prefill + ["decode"] * rs.n_decode, rs


def build_engines(cfg, roles, clock, ecfg_kw=None, gateway=None,
                  force_pool=False, ssd_pool=None):
    """A pod group under a RolePoolManager.

    Returns ``(engines dict, manager, pool)``.  The manager owns the
    role pools, wires the prefill->decode handoff and (when a gateway
    is passed) registers each engine under its pool so routing only
    sees frontends.  Disaggregated groups get a DistributedKVPool;
    ``force_pool`` builds one for all-mixed groups too (the chaos
    drill's crash recovery and partition scenarios need it).
    ``ssd_pool`` is a host-level :class:`SharedSSDPool` every engine
    attaches to (per-engine accounting views) instead of creating a
    private SSD tier.
    """
    kw = dict(page_size=8, num_pages=256, max_batch=4,
              max_pages_per_seq=32, chunk_size=32)
    kw.update(ecfg_kw or {})
    disagg = any(r != "mixed" for r in roles)
    pool = None
    if disagg or force_pool:
        pool = DistributedKVPool(capacity_bytes=1 << 30,
                                 metadata_lag=0.0, clock=clock)
    manager = RolePoolManager(clock=clock, gateway=gateway)
    engines = {}
    for i, role in enumerate(roles):
        eid = f"engine-{i}"
        engines[eid] = InferenceEngine(
            cfg, EngineConfig(role=role, **kw), clock=clock,
            kv_pool_client=pool, engine_id=eid, seed=0 if disagg else i,
            ssd_pool=ssd_pool)
        manager.add_engine(eid, engines[eid], role)
    return engines, manager, pool


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--engines", type=int, default=None,
                    help="pod count for --roles mixed (default 2) or "
                         "--roles auto (default 4)")
    ap.add_argument("--roles", default="mixed",
                    help="'mixed' (default, --engines colocated pods), "
                         "'2P2D'-style static disaggregation, or "
                         "'auto' (optimizer-proposed split, adapted "
                         "live by the attainment rebalancer)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--policy", default="prefix-cache-aware")
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slo", action="store_true",
                    help="SLO-aware scheduling (priority classes, "
                         "earliest-slack admission, preemption); pair "
                         "with --policy slo-aware for SLO routing")
    ap.add_argument("--interactive-frac", type=float, default=0.5,
                    help="fraction of requests tagged priority class "
                         "'interactive' (the rest are 'batch')")
    ap.add_argument("--device", default="a10",
                    help="roofline profile the --roles auto planner "
                         "sizes the initial P:D split against")
    ap.add_argument("--host-cache-gb", type=float, default=0.5,
                    help="host-DRAM KV tier per engine (GB): device "
                         "evictions cascade into it and preemption "
                         "swaps instead of recomputing; 0 disables")
    ap.add_argument("--ssd-cache-gb", type=float, default=0.0,
                    help="file-backed SSD KV tier per engine (GB) below "
                         "the host tier: host evictions write behind to "
                         "SSD and prefix walks fall device -> host -> "
                         "SSD before recompute; 0 disables")
    ap.add_argument("--ssd-shared", action="store_true",
                    help="share ONE host-level SSD pool across all "
                         "engines (content-addressed dedupe, one "
                         "write-behind drain): a prefix evicted by "
                         "engine A is an SSD hit for engine B; total "
                         "capacity = --ssd-cache-gb x engines")
    ap.add_argument("--gateway-shards", type=int, default=1,
                    help="shard the gateway's hot mutable state "
                         "(session pins, rate buckets, failure "
                         "accounting) N ways so route() cost stays "
                         "flat as the pin table grows")
    ap.add_argument("--promote-lead-s", type=float, default=0.0,
                    help="predictive KV promotion: with --policy "
                         "session, prefetch a session's SSD pages back "
                         "into host DRAM this many seconds before its "
                         "think-time EWMA predicts the next turn "
                         "(0 disables)")
    ap.add_argument("--wire-dtype", default="int8",
                    choices=("fp", "int8"),
                    help="pool-handoff wire format: 'int8' quantizes "
                         "page payloads with per-layer scales (~4x "
                         "fewer handoff bytes), 'fp' is byte-exact")
    ap.add_argument("--chaos", default="none",
                    choices=("none", "engine_crash", "kv_partition"),
                    help="mid-run chaos drill on the REAL engines: "
                         "'engine_crash' kills the busiest engine after "
                         "half the requests (harvested work re-delivers "
                         "to survivors; pair with --ckpt-interval so "
                         "running decodes resume from the recovery log "
                         "instead of recomputing), 'kv_partition' "
                         "partitions the KV pool for 2s (engines "
                         "degrade to recompute behind the breaker)")
    ap.add_argument("--ckpt-interval", type=int, default=0,
                    help="recovery-log checkpoint interval in tokens "
                         "(0 disables): running decodes periodically "
                         "publish their KV pages so a crash rewinds to "
                         "the last checkpoint, not to token 0")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="speculative n-gram decoding: max prompt-"
                         "lookup draft tokens verified per decode row "
                         "in one fused pass (0 disables); outputs stay "
                         "byte-identical under greedy decoding")
    ap.add_argument("--adapters", type=int, default=0,
                    help="register N LoRA adapters (zipf demand prior) "
                         "with a LoRAController, density-place them "
                         "over the engines and tag every request with "
                         "a zipf-drawn adapter (0 disables)")
    ap.add_argument("--lora-policy", default="lora-affinity",
                    help="gateway routing policy when --adapters is "
                         "set (default lora-affinity: route to pods "
                         "where the adapter is already resident)")
    ap.add_argument("--async-loop", action="store_true",
                    help="overlap host scheduling/input prep for step "
                         "N+1 with step N's device compute (decode "
                         "steps dispatch before the previous readback)")
    args = ap.parse_args()

    if args.engines is not None and args.roles not in ("mixed", "auto"):
        ap.error("--engines only applies to --roles mixed/auto; a "
                 "'<n>P<m>D' spec fixes the pod count itself")
    if args.roles == "auto" and args.engines is not None \
            and args.engines < 2:
        ap.error("--roles auto needs --engines >= 2 (one prefill AND "
                 "one decode pod)")
    if args.adapters and args.roles != "mixed":
        ap.error("--adapters needs --roles mixed (the P->D handoff "
                 "path does not carry adapter state yet)")
    cfg = get_reduced_config(args.arch)
    t0 = time.monotonic()
    clock = lambda: time.monotonic() - t0      # noqa: E731
    rebalancer = None
    if args.roles == "auto":
        roles, rs = auto_roles(cfg, args.engines or 4,
                               args.prompt_len, args.max_new,
                               device=args.device)
        rebalancer = AttainmentRebalancer(
            RebalanceConfig(period_s=0.5, cooldown_s=5.0, warmup_s=2.0))
        print(f"auto roles: optimizer proposes {rs.spec} "
              f"(prefill_load={rs.prefill_load:.3f}, "
              f"decode_load={rs.decode_load:.3f})")
    else:
        roles = parse_role_spec(args.roles, args.engines or 2)
    disagg = any(r != "mixed" for r in roles)
    if disagg:
        # int8 is the launcher's default deployment posture — say so
        # loudly: the wire is lossy (parity within the pinned
        # tolerance), pass --wire-dtype fp for byte-exact handoffs
        print(f"kv tiers: host_cache={args.host_cache_gb}GB/engine, "
              f"ssd_cache={args.ssd_cache_gb}GB/engine, "
              f"pool wire={args.wire_dtype}"
              + (" (quantized; --wire-dtype fp for byte-exact)"
                 if args.wire_dtype == "int8" else ""))
    policy = args.lora_policy if args.adapters else args.policy
    policy_kw = {}
    if args.promote_lead_s > 0 and policy == "session":
        policy_kw["promote_lead_s"] = args.promote_lead_s
    gw = Gateway(policy=policy, clock=clock,
                 shards=args.gateway_shards, **policy_kw)
    shared_ssd = None
    if args.ssd_shared and args.ssd_cache_gb > 0 \
            and args.host_cache_gb > 0:
        from repro.core.kvcache.tiers import SharedSSDPool
        import tempfile
        shared_ssd = SharedSSDPool(
            capacity_bytes=int(args.ssd_cache_gb * (1 << 30)
                               * len(roles)),
            directory=tempfile.mkdtemp(prefix="kv-ssd-host-"))
        print(f"kv tiers: ONE host-shared SSD pool "
              f"({args.ssd_cache_gb * len(roles):.1f}GB) across "
              f"{len(roles)} engine(s)")
    engines, manager, pool = build_engines(
        cfg, roles, clock,
        ecfg_kw=dict(slo_aware=args.slo,
                     host_cache_gb=args.host_cache_gb,
                     ssd_cache_gb=args.ssd_cache_gb,
                     wire_dtype=args.wire_dtype,
                     ckpt_interval_tokens=args.ckpt_interval,
                     spec_tokens=args.spec_tokens,
                     async_loop=args.async_loop),
        gateway=gw, force_pool=args.chaos != "none",
        ssd_pool=shared_ssd)
    lora_ctrl = None
    lora_heat = None
    if args.adapters:
        lora_ctrl = LoRAController(min_replicas=1, max_replicas=2)
        for i in range(args.adapters):
            lora_ctrl.register(AdapterSpec(
                f"lora-{i}", cfg.name, requests_per_s=1.0 / (i + 1)))
        slots = max(EngineConfig().max_adapters - 1, 1)
        for eid in engines:
            lora_ctrl.add_pod(eid, capacity=slots)
        gw.attach_lora_controller(lora_ctrl)
        lora_ctrl.sync(engines)
        lora_heat = 1.0 / (np.arange(1, args.adapters + 1) ** 1.1)
        lora_heat /= lora_heat.sum()
        print(f"lora: {args.adapters} adapter(s) density-placed over "
              f"{len(engines)} engine(s) ({slots} slots each), "
              f"policy={policy}, controller loads="
              f"{lora_ctrl.stats['loads']}")
    if args.chaos == "engine_crash" and not args.ckpt_interval:
        print("chaos: --ckpt-interval 0 — crashed decodes recompute "
              "from token 0 (set e.g. --ckpt-interval 16 to resume "
              "from the recovery log)")

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 24).tolist()
    reqs = []

    def pump():
        for eng in list(engines.values()):
            if eng.has_work:
                eng.step()
        manager.poll(clock())
        if rebalancer is not None:
            rebalancer.step(clock(), manager)
        if args.promote_lead_s > 0:
            for sid, eid in gw.due_promotions(clock()):
                if eid in engines:
                    engines[eid].promote_session(sid)

    def chaos_drill():
        """Mid-run failure injection against the live engine group."""
        now = clock()
        if args.chaos == "kv_partition":
            pool.partition(now=now, duration=2.0)
            print(f"[chaos] t={now:.2f}s kv pool partitioned for 2.0s "
                  "(fetch/publish fail; the breaker degrades admission "
                  "to recompute until it heals)")
            return
        # let in-flight work decode past a checkpoint boundary first:
        # the drill demonstrates the resume path, and a kill during
        # prefill leaves the recovery log nothing to cover
        for _ in range(40):
            pump()
        now = clock()
        # crash the engine carrying the most work: harvest everything
        # it owns (running decodes rewind to their recovery-log
        # checkpoint when --ckpt-interval fed one) and re-deliver
        victim = max(engines, key=lambda e: len(engines[e].running)
                     + len(engines[e].prefills) + len(engines[e].waiting))
        eng = engines.pop(victim)
        lost = eng.sched.crash_takeover(now)
        manager.remove_engine(victim)
        gw.note_failure(victim, "crash")
        for r in lost:
            eid = gw.route(r.prompt_tokens,
                           est_output_tokens=args.max_new,
                           priority_class=r.priority_class)
            engines[eid].submit(r)
        resumed = sum(1 for r in lost
                      if getattr(r, "_resume_decode", False)
                      or r.output_tokens)
        print(f"[chaos] t={now:.2f}s engine {victim} crashed: "
              f"{len(lost)} request(s) harvested, {resumed} resuming "
              "from the recovery log, rest recompute")

    drill_after = args.requests // 2 if args.chaos != "none" else -1
    for i in range(args.requests):
        prompt = shared + rng.integers(
            0, cfg.vocab_size, max(args.prompt_len - 24, 4)).tolist()
        pclass = ("interactive" if rng.random() < args.interactive_frac
                  else "batch")
        adapter = None
        if args.adapters:
            adapter = f"lora-{int(rng.choice(args.adapters, p=lora_heat))}"
        r = Request(prompt_tokens=prompt,
                    sampling=SamplingParams(max_new_tokens=args.max_new),
                    arrival_time=clock(), priority_class=pclass,
                    lora_adapter=adapter)
        eid = gw.route(prompt, est_output_tokens=args.max_new,
                       lora_adapter=adapter, priority_class=pclass)
        engines[eid].submit(r)
        reqs.append((eid, r))
        # interleave a bit of serving with arrivals
        pump()
        if i + 1 == drill_after:
            chaos_drill()
    while any(e.has_work for e in engines.values()) or manager.draining:
        pump()
    for eng in engines.values():
        eng.drain_async()       # resolve any in-flight async dispatch

    print(f"\nrouting ({policy}):", dict(gw.stats.per_engine))
    s = summarize([r for _, r in reqs])
    for k, v in s.items():
        print(f"  {k:22s} {v:.2f}" if isinstance(v, float) else
              f"  {k:22s} {v}")
    for eid, eng in engines.items():
        m = eng.metrics()
        print(f"  {eid} [{manager.role_of(eid)}]: "
              f"finished={m.finished_requests} "
              f"prefix_hit_tokens={m.prefix_hit_tokens} "
              f"remote_hit_tokens={m.remote_hit_tokens} "
              f"host_hit_tokens={m.host_hit_tokens} "
              f"ssd_hit_tokens={m.ssd_hit_tokens} "
              f"kv_util={m.kv_utilization:.2f}")
        if m.swap_out or m.kv_bytes_offloaded:
            print(f"    tiers: swap_out={m.swap_out} swap_in={m.swap_in}"
                  f" offloaded={m.kv_bytes_offloaded >> 10}KiB"
                  f" fetched={m.kv_bytes_fetched >> 10}KiB")
        if m.spec_drafted_tokens:
            print(f"    spec: drafted={m.spec_drafted_tokens} "
                  f"accepted={m.spec_accepted_tokens} "
                  f"acceptance={m.spec_acceptance:.2f}")
        if args.async_loop or m.device_wait_s:
            print(f"    overlap: device_wait={m.device_wait_s:.2f}s "
                  f"host_overhead_frac={m.host_overhead_frac:.2f}")
        if m.slo_by_class:
            rows = " ".join(
                f"{c}: ttft={ta:.2f} itl={ia:.2f} n={n}"
                for c, ta, ia, n in m.slo_by_class)
            print(f"    slo_attainment={m.slo_attainment:.2f} [{rows}]")
    if any(r != "mixed" for r in roles):
        print(f"  pools: {manager.counts()} "
              f"migrations={len(manager.migrations)}")
    if pool is not None:
        st = pool.stats
        print(f"  pool: puts={st.puts} hits={st.hits_local + st.hits_remote}"
              f" dup_drops={st.dup_puts_dropped}"
              f" bytes_stored={st.bytes_stored}")
    if shared_ssd is not None:
        cross = sum(e.metrics().ssd_cross_hit_tokens
                    for e in engines.values())
        print(f"  ssd(shared): puts={shared_ssd.stats.puts} "
              f"dedup_puts={shared_ssd.dedup_puts} "
              f"dedupe_ratio={shared_ssd.dedupe_ratio:.2f} "
              f"bytes_written={shared_ssd.stats.bytes_written} "
              f"dropped_puts={shared_ssd.stats.dropped_puts} "
              f"cross_hit_tokens={cross}")
    if args.adapters:
        cold = sum(e.runner.adapter_loads for e in engines.values())
        stall = sum(e.runner.adapter_load_s for e in engines.values())
        miss = sum(e.metrics().lora_miss for e in engines.values())
        print(f"  lora: affinity_hits={gw.stats.lora_hits}"
              f"/{gw.stats.lora_routed} "
              f"(rate={gw.stats.lora_affinity_hit_rate:.2f}) "
              f"cold_loads={cold} cold_load_s={stall:.2f} miss={miss}")
    if args.chaos != "none":
        wasted = sum(e.metrics().wasted_tokens for e in engines.values())
        ckpt = sum(e.metrics().ckpt_pages for e in engines.values())
        fails = sum(e.metrics().kv_fetch_failures
                    for e in engines.values())
        unfinished = sum(1 for _, r in reqs
                         if len(r.output_tokens) < args.max_new)
        print(f"  chaos({args.chaos}): unfinished={unfinished} "
              f"wasted_tokens={wasted} ckpt_pages={ckpt} "
              f"kv_fetch_failures={fails}")


if __name__ == "__main__":
    main()
