"""Production mesh construction (TPU v5e; 256 chips/pod, 2 pods).

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — dryrun.py must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
