"""Analytic FLOPs / HBM-bytes model per (architecture x input shape).

XLA's cost_analysis counts while-loop bodies once (see hlo_analysis),
so scanned-layer models undercount by ~n_layers.  The roofline table
therefore uses this structural model for the compute and memory terms
(exact for the code we wrote — every matmul is enumerated below) and the
trip-count-corrected HLO parse for the collective term.  cost_analysis
is still recorded for cross-checking single-layer magnitudes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.models import config as C
from repro.models.config import ModelConfig


@dataclass
class WorkEstimate:
    flops: float               # total FLOPs for the step (fwd+bwd if train)
    hbm_bytes: float           # HBM traffic for the step
    model_flops: float         # 6·N·D (train) or 2·N·D (inference) headline
    note: str = ""


def _attn_flops(cfg: ModelConfig, ltype: str, b: int, s: int,
                ctx: float) -> float:
    """Forward attention-core FLOPs for one layer over the whole batch.
    ``ctx`` = average attended context per query token."""
    h, dh = cfg.n_heads, cfg.head_dim
    if ltype in (C.MLA_DENSE, C.MLA_MOE):
        m = cfg.mla
        dh_qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        return 2.0 * b * s * ctx * h * (dh_qk + m.v_head_dim)
    if ltype in (C.MLSTM,):
        xc = cfg.xlstm
        di = int(xc.mlstm_proj_factor * cfg.d_model)
        return 2.0 * b * s * ctx * di * 2
    if ltype in (C.SLSTM,):
        return 0.0
    return 2.0 * b * s * ctx * h * dh * 2        # QK^T + AV


def _layer_ctx(cfg: ModelConfig, ltype: str, s: int, kind: str,
               cache_len: int) -> float:
    """Average context per query for this layer type."""
    window = cfg.sliding_window
    if kind == "decode":
        full = float(cache_len)
        if ltype in (C.SWA, C.HYMBA) and window:
            return min(window, full)
        if ltype in (C.MLSTM, C.SLSTM):
            return 0.0
        return full
    # train/prefill: causal mean context = s/2, or window
    if ltype in (C.SWA, C.HYMBA) and window:
        return min(window, s / 2.0)
    if ltype == C.SLSTM:
        return 0.0
    if ltype == C.MLSTM:
        return s / 2.0
    return s / 2.0


def _kv_bytes_per_token(cfg: ModelConfig, ltype: str, dtype_bytes: int
                        ) -> float:
    if ltype in (C.MLA_DENSE, C.MLA_MOE):
        return (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * dtype_bytes
    if ltype in (C.MLSTM, C.SLSTM):
        return 0.0
    return 2.0 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes


def estimate(cfg: ModelConfig, kind: str, batch: int, seq: int,
             dtype_bytes: int = 2) -> WorkEstimate:
    """kind: train | prefill | decode.  decode: seq = cache length,
    1 new token per sequence."""
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    b = batch
    s = 1 if kind == "decode" else seq
    tokens = b * s

    # matmul FLOPs through parameters: 2·N_active per token forward
    fwd = 2.0 * n_active * tokens
    attn = 0.0
    state_flops = 0.0
    for ltype in cfg.layer_pattern:
        ctx = _layer_ctx(cfg, ltype, seq if kind != "decode" else seq, kind,
                         cache_len=seq)
        attn += _attn_flops(cfg, ltype, b, s, ctx)
        if ltype in (C.HYMBA, C.HYMBA_GLOBAL) and cfg.ssm:
            di = cfg.ssm.expand * cfg.d_model
            state_flops += 6.0 * tokens * di * cfg.ssm.state_size
        if ltype == C.MLSTM and kind == "decode":
            xc = cfg.xlstm
            di = int(xc.mlstm_proj_factor * cfg.d_model)
            dh = di // xc.num_heads
            state_flops += 4.0 * b * xc.num_heads * dh * dh
        if ltype == C.SLSTM:
            dh = cfg.d_model // cfg.xlstm.num_heads
            state_flops += 2.0 * tokens * 4 * cfg.d_model * dh

    fwd += attn + state_flops
    mult = 3.0 if kind == "train" else 1.0       # bwd ≈ 2x fwd
    flops = fwd * mult

    # ---- HBM bytes
    pbytes = n_params * dtype_bytes
    kv_tok = sum(_kv_bytes_per_token(cfg, lt, dtype_bytes)
                 for lt in cfg.layer_pattern)
    act = tokens * cfg.d_model * dtype_bytes     # one residual stream pass
    if kind == "train":
        # params: read fwd + read bwd + write; adam m,v: rw in f32;
        # activations: remat keeps ~2 passes per layer
        hbm = (pbytes * 3 + n_params * 4 * 4
               + act * cfg.n_layers * 4)
    elif kind == "prefill":
        hbm = pbytes + kv_tok * tokens + act * cfg.n_layers * 2
    else:                                        # decode
        read_ctx = 0.0
        for lt in cfg.layer_pattern:
            ctx = _layer_ctx(cfg, lt, seq, "decode", seq)
            read_ctx += _kv_bytes_per_token(cfg, lt, dtype_bytes) * ctx
        hbm = pbytes + b * read_ctx + b * kv_tok + act * cfg.n_layers * 2
    model_flops = (6.0 if kind == "train" else 2.0) * n_active * tokens
    return WorkEstimate(flops=flops, hbm_bytes=hbm, model_flops=model_flops)


# TPU v5e constants (per chip) — §Roofline hardware numbers
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link


def roofline_terms(est: WorkEstimate, collective_bytes_per_dev: float,
                   chips: int) -> Dict[str, float]:
    compute_s = est.flops / (chips * PEAK_FLOPS)
    memory_s = est.hbm_bytes / (chips * HBM_BW)
    collective_s = collective_bytes_per_dev / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "useful_flops_ratio": est.model_flops / max(est.flops, 1.0),
    }
