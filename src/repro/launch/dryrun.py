import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked) ---
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, InputShape,  # noqa: E402
                           get_config, shape_applicable)
from repro.launch import analytic, hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models import sharding  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.training.optimizer import AdamWConfig, AdamWState  # noqa: E402
from repro.training.train_loop import TrainState, make_train_step  # noqa: E402

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) combination this lowers
and compiles the real step function against ShapeDtypeStruct stand-ins
(zero device allocation), proving the distribution config is coherent:
shardings legal, collectives supported, memory within per-chip HBM.

Outputs one JSON record per case (memory analysis, cost analysis,
trip-count-corrected collective bytes, analytic roofline terms) into
``--out`` for EXPERIMENTS.md §Dry-run / §Roofline.
"""

DTYPE = jnp.bfloat16


def _scalar_axes():
    return ()


def _axes_like(tree, axes_leaf_tree):
    return axes_leaf_tree


def input_specs(cfg: ModelConfig, shape: InputShape, ctx: sharding.ShardingCtx
                ) -> Tuple[Dict, Dict]:
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
    allocation) for one case.  Returns (kwargs for .lower, axes info)."""
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks else (b, s)
    tok_axes = (("batch", "seq", None) if cfg.num_codebooks
                else ("batch", "seq"))

    def sds(shp, dt, axes):
        return jax.ShapeDtypeStruct(
            shp, dt, sharding=ctx.sharding_for(shp, axes))

    if shape.kind == "train":
        params_abs = M.abstract(cfg, DTYPE)
        paxes = M.param_axes(cfg)
        # sharding regime by model size:
        #   < 8B : ZeRO-1 — weights replicated across data (TP only),
        #          optimizer moments sharded data x model.  Kills the
        #          per-layer FSDP weight all-gathers that dominate the
        #          collective term for small models on 256 chips.
        #   >= 8B: full FSDP (weights + moments 2D-sharded).
        full_fsdp = cfg.param_count() >= 8e9
        pctx = ctx if full_fsdp else sharding.ShardingCtx(
            ctx.mesh, tuple(ctx.rules.items()), fsdp=False)
        params = sharding.with_shardings(pctx, params_abs, paxes)
        # bf16 Adam moments at 200B-scale (math stays f32 in the update)
        opt_dtype = jnp.bfloat16 if cfg.param_count() >= 5e10 \
            else jnp.float32
        opt_abs = M.abstract(cfg, opt_dtype)
        mu = sharding.with_shardings(ctx, opt_abs, paxes)
        nu = sharding.with_shardings(ctx, opt_abs, paxes)
        state = TrainState(params, AdamWState(
            jax.ShapeDtypeStruct((), jnp.int32,
                                 sharding=ctx.sharding_for((), ())),
            mu, nu))
        batch = {
            "tokens": sds(tok_shape, jnp.int32, tok_axes),
            "labels": sds(tok_shape, jnp.int32, tok_axes),
            "weights": sds((b, s), jnp.float32, ("batch", "seq")),
        }
        return {"state": state, "batch": batch}, {}

    params_abs = M.abstract(cfg, DTYPE)
    params = sharding.with_shardings(ctx, params_abs, M.param_axes(cfg))
    if shape.kind == "prefill":
        caches = sharding.with_shardings(
            ctx, M.abstract_cache(cfg, b, s, DTYPE), M.cache_axes(cfg, b, s))
        return {"params": params,
                "tokens": sds(tok_shape, jnp.int32, tok_axes),
                "caches": caches}, {}
    # decode: ONE new token against a seq_len cache
    caches = sharding.with_shardings(
        ctx, M.abstract_cache(cfg, b, s, DTYPE), M.cache_axes(cfg, b, s))
    dec_tok = ((b, cfg.num_codebooks) if cfg.num_codebooks else (b,))
    dec_axes = (("batch", None) if cfg.num_codebooks else ("batch",))
    return {"params": params, "caches": caches,
            "tokens": sds(dec_tok, jnp.int32, dec_axes),
            "positions": sds((b,), jnp.int32, ("batch",))}, {}


def step_fn(cfg: ModelConfig, shape: InputShape, donate: bool = True):
    """Returns (fn, donate_argnames).  Donation aliases the updated
    train state / KV caches onto their inputs — without it the compiled
    module holds input AND output copies of the biggest buffers
    (§Perf iteration 1: musicgen decode 27.4 -> see EXPERIMENTS.md)."""
    if shape.kind == "train":
        opt = AdamWConfig()
        # microbatch big models: activation peak ~ 1/grad_accum
        n = cfg.param_count()
        accum = 1 if n < 1.2e9 else (4 if n < 12e9 else
                                     (8 if n < 50e9 else 16))
        ts = make_train_step(cfg, opt, remat=True, grad_accum=accum)

        def train_step(state, batch):
            return ts(state, batch)
        return train_step, (("state",) if donate else ())
    if shape.kind == "prefill":
        def prefill_step(params, tokens, caches):
            return M.prefill(params, cfg, tokens, caches)
        return prefill_step, (("caches",) if donate else ())

    def serve_step(params, caches, tokens, positions):
        return M.decode_step(params, cfg, caches, tokens, positions)
    return serve_step, (("caches",) if donate else ())


def run_case(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, rules: Optional[tuple] = None,
             mla_absorb: Optional[bool] = None,
             save_hlo: Optional[str] = None) -> Dict:
    cfg = get_config(arch)
    if mla_absorb is not None and cfg.mla is not None:
        cfg = cfg.replace(mla_absorb=mla_absorb)
    shape = INPUT_SHAPES[shape_name]
    if shape.kind in ("prefill", "decode"):
        # inference weight layout: no per-step FSDP weight gathers
        cfg = cfg.replace(inference_weight_layout=True)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    case = f"{arch}|{shape_name}|{mesh_name}"
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"case": case, "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    if rules is None:
        rules = (sharding.LONG_CONTEXT_RULES if shape_name == "long_500k"
                 else sharding.DEFAULT_RULES)
    ctx = sharding.ShardingCtx(mesh, rules)
    t0 = time.time()
    try:
        with mesh, sharding.use_sharding(ctx):
            kwargs, _ = input_specs(cfg, shape, ctx)
            fn, donate_names = step_fn(cfg, shape)
            lowered = jax.jit(fn, donate_argnames=donate_names
                              ).lower(**kwargs)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = hlo_analysis.collective_report(hlo)
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
        est = analytic.estimate(cfg, shape.kind, shape.global_batch,
                                shape.seq_len)
        terms = analytic.roofline_terms(est, coll.get("total", 0), chips)
        per_dev_bytes = (mem.argument_size_in_bytes
                         + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes
                         - mem.alias_size_in_bytes)
        rec = {
            "case": case, "status": "ok",
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "chips": chips,
            "compile_s": round(time.time() - t0, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_bytes": per_dev_bytes,
                "fits_16g_hbm": bool(per_dev_bytes < 16e9),
            },
            "cost_analysis": {
                "flops_raw": cost.get("flops", 0.0),
                "bytes_raw": cost.get("bytes accessed", 0.0),
                "note": "XLA counts while bodies once; see analytic",
            },
            "collectives_per_device_bytes": coll,
            "analytic": {
                "flops": est.flops, "hbm_bytes": est.hbm_bytes,
                "model_flops": est.model_flops,
            },
            "roofline": terms,
        }
        if verbose:
            print(f"[OK] {case}: compile {rec['compile_s']}s, "
                  f"{per_dev_bytes/1e9:.2f} GB/dev, "
                  f"dominant={terms['dominant']}, "
                  f"coll={coll.get('total',0)/1e6:.1f} MB/dev")
            print("     memory_analysis:", mem)
            print("     cost_analysis: flops=%.3e bytes=%.3e" %
                  (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0)))
        return rec
    except Exception as e:
        if verbose:
            traceback.print_exc()
        return {"case": case, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "compile_s": round(time.time() - t0, 1)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (10 assigned)")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(INPUT_SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="pod",
                    choices=("pod", "multipod", "both"))
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--mla-absorb", action="store_true",
                    help="use the absorbed MLA decode path")
    args = ap.parse_args()
    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_case(arch, shape, mp,
                               mla_absorb=args.mla_absorb or None)
                tag = rec["case"].replace("|", "_")
                if args.mla_absorb:
                    tag += "_absorb"
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
                if rec["status"] == "skipped":
                    print(f"[SKIP] {rec['case']}: {rec['reason']}")
                elif rec["status"] == "error":
                    print(f"[ERR] {rec['case']}: {rec['error']}")
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped "
          f"(documented), {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
