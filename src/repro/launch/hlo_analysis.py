"""Compiled-HLO analysis for the roofline report.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so for
scanned-layer models both its FLOPs and its collective traffic
undercount by ~n_layers.  This module parses the optimized HLO text into
computations, attributes collective ops to their enclosing while bodies,
recovers trip counts from the loop conditions' compare-against-constant,
and reports trip-count-corrected collective bytes per primitive kind.

This is the "profile" of the §Perf loop: redundant all-gathers, layout
copies around collectives, and reshape/transpose chatter all show up in
the per-op table.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# header = "name (params...) -> result {"; params may nest parens
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    collective_bytes: Dict[str, int] = field(default_factory=dict)
    whiles: List[Tuple[str, str]] = field(default_factory=list)  # cond, body
    calls: List[str] = field(default_factory=list)


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        cur.lines.append(line)
    return comps


def _analyze_computation(comp: Computation) -> None:
    for line in comp.lines:
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        for kind in COLLECTIVES:
            # op name appears as `kind(` start of rhs expression
            if re.search(rf"\s{kind}(-start|-done)?\(", rhs) or \
                    rhs.lstrip().startswith(f"{kind}("):
                if f"{kind}-done(" in rhs:
                    continue        # avoid double count of async pairs
                comp.collective_bytes[kind] = (
                    comp.collective_bytes.get(kind, 0)
                    + _shape_bytes(lhs + rhs.split("(")[0]))
                break
        wm = _WHILE_RE.search(s)
        if wm:
            comp.whiles.append((wm.group(1), wm.group(2)))
        for cm in re.finditer(r"(?:call|fusion)\(.*?to_apply=%?([\w\.\-]+)",
                              s):
            comp.calls.append(cm.group(1))


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    consts = []
    for line in comp.lines:
        consts += [int(x) for x in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def collective_report(hlo_text: str) -> Dict[str, int]:
    """Trip-count-corrected collective bytes by kind + total."""
    comps = _split_computations(hlo_text)
    for c in comps.values():
        _analyze_computation(c)

    memo: Dict[str, Dict[str, int]] = {}

    def total_of(name: str, depth: int = 0) -> Dict[str, int]:
        if name in memo or depth > 12:
            return memo.get(name, {})
        comp = comps.get(name)
        if comp is None:
            return {}
        out = defaultdict(int)
        for k, v in comp.collective_bytes.items():
            out[k] += v
        for callee in comp.calls:
            for k, v in total_of(callee, depth + 1).items():
                out[k] += v
        for cond, body in comp.whiles:
            trips = _trip_count(comps, cond)
            for k, v in total_of(body, depth + 1).items():
                out[k] += v * trips
        memo[name] = dict(out)
        return memo[name]

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: sum everything once
        out = defaultdict(int)
        for c in comps.values():
            for k, v in c.collective_bytes.items():
                out[k] += v
        result = dict(out)
    else:
        result = total_of(entry)
    result["total"] = sum(v for k, v in result.items() if k != "total")
    return result


def op_histogram(hlo_text: str) -> Dict[str, int]:
    """Rough opcode histogram of the entry module (perf-loop smell test:
    count copies/transposes/reshapes near collectives)."""
    hist: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*[\w\[\],\{\}\s]*?\s([a-z][\w\-]*)\(", line)
        if m:
            hist[m.group(1)] += 1
    return dict(sorted(hist.items(), key=lambda kv: -kv[1]))
