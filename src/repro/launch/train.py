"""Training launcher: ``python -m repro.launch.train --arch qwen3-0.6b``.

On real hardware this runs the sharded train step on the production
mesh; on this CPU container use --debug for a reduced config on a 1x1
mesh (the full configs are exercised via dryrun.py).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced_config
from repro.data import synthetic_lm_batches
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import model as M
from repro.models import sharding
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_loop import TrainState, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--debug", action="store_true",
                    help="reduced config on a debug mesh (CPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--log-json", default="")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.debug \
        else get_config(args.arch)
    mesh = make_debug_mesh() if args.debug \
        else make_production_mesh(multi_pod=args.multi_pod)
    ctx = sharding.ShardingCtx(mesh, sharding.DEFAULT_RULES)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    data = synthetic_lm_batches(cfg.vocab_size, args.batch, args.seq,
                                num_codebooks=cfg.num_codebooks)
    with mesh, sharding.use_sharding(ctx):
        params = M.init(cfg, jax.random.PRNGKey(0))
        state = TrainState(params, adamw_init(params))
        step = jax.jit(make_train_step(cfg, opt_cfg, remat=not args.debug))
        history = []
        for i in range(args.steps):
            state, metrics = step(state, next(data))
            if i % 10 == 0 or i == args.steps - 1:
                row = {"step": i,
                       **{k: float(v) for k, v in metrics.items()}}
                history.append(row)
                print(row)
    if args.log_json:
        with open(args.log_json, "w") as f:
            json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()
