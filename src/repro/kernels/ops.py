"""Public jit'd wrappers for the Pallas kernels.

Handles shape padding (seq lens to block multiples, batch page tables),
platform dispatch (interpret=True off-TPU so CPU tests execute the real
kernel bodies), and an ``impl`` switch so every call site can be A/B'd
against the pure-jnp oracle (impl="ref").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_prefill as _flash
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.paged_prefill import paged_prefill as _paged_pre


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def paged_attention(q, k_pages, v_pages, block_tables, lengths,
                    *, impl: str = "pallas"):
    """Decode attention over paged KV.  See kernels/ref.py for shapes."""
    if impl == "ref":
        return ref.paged_attention_ref(q, k_pages, v_pages, block_tables,
                                       lengths)
    return _paged(q, k_pages, v_pages, block_tables, lengths,
                  interpret=not _on_tpu())


def paged_prefill(q, k_pages, v_pages, block_tables, ctx_lens, chunk_lens,
                  *, block_q: int = 128, impl: str = "pallas"):
    """Chunked-prefill attention directly over paged KV (chunk K/V must
    already be scattered into the pages).  Pads the chunk dim to a
    block_q multiple; see kernels/ref.py for shapes."""
    if impl == "ref":
        return ref.paged_prefill_ref(q, k_pages, v_pages, block_tables,
                                     ctx_lens, chunk_lens)
    b, s, h, d = q.shape
    bq = min(block_q, _round_up(s, 8))
    s_p = _round_up(s, bq)
    if s_p != s:
        q = jnp.pad(q, ((0, 0), (0, s_p - s), (0, 0), (0, 0)))
    out = _paged_pre(q, k_pages, v_pages, block_tables, ctx_lens,
                     chunk_lens, block_q=bq, interpret=not _on_tpu())
    return out[:, :s]


def paged_verify(q, k_pages, v_pages, block_tables, ctx_lens, draft_lens,
                 *, impl: str = "pallas"):
    """Speculative-verification attention: each decode row is a short
    multi-query chunk ``[last_token, draft_1..draft_d]`` at a dynamic
    context offset — exactly the paged-prefill shape, so the lanes ride
    :func:`paged_prefill` with ``block_q`` sized for the small draft
    window (one q-block instead of a 128-wide tile mostly full of
    padding).  ``draft_lens`` counts valid rows per lane (1 + accepted
    drafts to verify; 0 marks an idle decode slot)."""
    sd = q.shape[1]
    return paged_prefill(q, k_pages, v_pages, block_tables, ctx_lens,
                         draft_lens, block_q=min(_round_up(sd, 8), 32),
                         impl=impl)


def flash_attention(q, k, v, lengths, *, window: int = 0, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    impl: str = "pallas"):
    """Causal/windowed prefill attention with automatic seq padding."""
    if impl == "ref":
        return ref.flash_prefill_ref(q, k, v, lengths, window=window,
                                     q_offset=q_offset)
    b, sq, h, d = q.shape
    sk = k.shape[1]
    bq = min(block_q, _round_up(sq, 8))
    bk = min(block_k, _round_up(sk, 8))
    sq_p = _round_up(sq, bq)
    sk_p = _round_up(sk, bk)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    out = _flash(q, k, v, lengths, window=window, q_offset=q_offset,
                 block_q=bq, block_k=bk, interpret=not _on_tpu())
    return out[:, :sq]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
