"""Pallas TPU paged-attention decode kernel.

The serving hot-spot: one query token per sequence attends over a paged
KV cache addressed through per-sequence block tables (vLLM-style).  TPU
adaptation (vs. the CUDA original): block tables ride in as *scalar
prefetch* so each grid step's BlockSpec index_map can stage exactly one
KV page HBM->VMEM ahead of compute; the flash accumulator lives in VMEM
scratch and persists across the (sequential, innermost) page dimension
of the grid.  MXU alignment comes from the (G, page) x (page, D) matmul
shapes — head_dim is 64..256 and page_size defaults to 64.

Grid: (batch, kv_heads, num_blocks); one program handles the G = H/Hkv
query-head group for one page of one sequence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(
    # scalar prefetch
    block_tables_ref,   # (B, NB) int32
    lengths_ref,        # (B,) int32
    # inputs (blocked)
    q_ref,              # (1, 1, G, D)
    k_ref,              # (1, page, 1, D)
    v_ref,              # (1, page, 1, D)
    # output
    o_ref,              # (1, 1, G, D)
    # scratch
    acc_ref,            # (G, D) f32
    m_ref,              # (G, 1) f32
    l_ref,              # (G, 1) f32
    *, page_size: int,
):
    b = pl.program_id(0)
    i = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    page_start = i * page_size

    @pl.when(page_start < length)
    def _compute():
        g, d = q_ref.shape[2], q_ref.shape[3]
        q = q_ref[0, 0].astype(jnp.float32) * (d ** -0.5)      # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)                 # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)                 # (page, D)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                # (G, page)
        pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        logits = jnp.where(pos < length, logits, NEG_INF)
        # --- online softmax update
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(logits, axis=-1, keepdims=True)        # (G, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)                            # (G, page)
        l_ref[...] = l_prev * alpha + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(i == nb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("page_size", "interpret"))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array,
                    *, page_size: int = 0,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, D); k_pages/v_pages: (P, page, Hkv, D);
    block_tables: (B, NB) int32; lengths: (B,) int32 -> (B, H, D)."""
    b, h, d = q.shape
    _, page, hkv, _ = k_pages.shape
    if page_size == 0:
        page_size = page
    assert page == page_size
    nb = block_tables.shape[1]
    g = h // hkv
    q4 = q.reshape(b, hkv, g, d)

    def kv_map(b_, h_, i_, bt, ln):
        # pages past the sequence length are masked out of compute; clamp
        # their index to the last live page so the dead grid steps re-stage
        # an already-resident page instead of DMA'ing padding entries.
        last = jnp.maximum((ln[b_] + page_size - 1) // page_size - 1, 0)
        return bt[b_, jnp.minimum(i_, last)], 0, h_, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, i_, bt, ln:
                         (b_, h_, 0, 0)),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, i_, bt, ln:
                               (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, page_size=page_size),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, q4, k_pages, v_pages)
    return out.reshape(b, h, d)
