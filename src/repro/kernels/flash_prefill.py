"""Pallas TPU flash-attention prefill kernel (causal, sliding-window,
chunked-prefill aware).

The prefill hot-spot.  Tiled (q_block x kv_block) online-softmax flash
attention with GQA folded into the q-block rows (the G query heads of a
KV-head group share the staged K/V tile — one HBM->VMEM copy serves G
heads).  ``q_offset`` places the query chunk at absolute positions for
chunked prefill (queries [q_offset, q_offset+Sq) attend over K/V
[0, Sk)).  Off-diagonal tiles that the causal/window mask fully excludes
are skipped before any compute.

Grid: (batch, kv_heads, q_blocks, kv_blocks), kv innermost (sequential)
so the VMEM accumulator carries across KV tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref,
            *, block_q: int, block_k: int, window: int, q_offset: int):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)
    g, d = q_ref.shape[2], q_ref.shape[4]
    rows = g * block_q

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = qi * block_q + q_offset              # first absolute q position
    q_hi = q_lo + block_q - 1                   # last absolute q position
    k_lo = ki * block_k
    # causal: tile dead if all kpos > all qpos; window: dead if all kpos
    # <= all qpos - window.
    alive = (k_lo <= q_hi) & (lengths_ref[b] > k_lo)
    if window:
        alive &= (k_lo + block_k - 1) > (q_lo - window)

    @pl.when(alive)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32).reshape(rows, d) * (d ** -0.5)
        k = k_ref[0, :, 0].astype(jnp.float32)                 # (bk, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                # (rows, bk)
        rowid = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
        qpos = q_lo + rowid % block_q            # row = g*bq + j
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = (kpos <= qpos) & (kpos < lengths_ref[b])
        if window:
            mask &= kpos > (qpos - window)
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        # rows fully masked in this tile have m_new == NEG_INF; exp(0)=1
        # would pollute the accumulator — zero them via the mask.
        p = jnp.where(mask, jnp.exp(logits - m_new), 0.0)
        l_ref[...] = l_prev * alpha + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = ((acc_ref[...] / l)
                       .reshape(g, block_q, d).astype(o_ref.dtype))


@functools.partial(jax.jit, static_argnames=(
    "window", "q_offset", "block_q", "block_k", "interpret"))
def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                  lengths: jax.Array, *, window: int = 0, q_offset: int = 0,
                  block_q: int = 128, block_k: int = 128,
                  interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D); lengths: (B,) valid K
    tokens.  Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, \
        f"seq lens ({sq},{sk}) must tile by ({block_q},{block_k})"
    nq, nk = sq // block_q, sk // block_k
    # layout: (B, Hkv, G, Sq, D) so one block carries the whole head group
    q5 = q.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, block_q, d),
                         lambda b_, h_, qi, ki, ln: (b_, h_, 0, qi, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, qi, ki, ln: (b_, ki, h_, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, qi, ki, ln: (b_, ki, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, block_q, d),
                               lambda b_, h_, qi, ki, ln: (b_, h_, 0, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((g * block_q, d), jnp.float32),
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          window=window, q_offset=q_offset),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, sq, d), q.dtype),
        interpret=interpret,
    )(lengths, q5, k, v)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
