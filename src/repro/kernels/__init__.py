# Serving compute hot-spots (the role vLLM's CUDA paged-attention /
# flash kernels play in the paper's stack), adapted to TPU as Pallas
# kernels.  ops.py = jit'd wrappers; ref.py = pure-jnp oracles.
from repro.kernels.ops import flash_attention, paged_attention  # noqa: F401
