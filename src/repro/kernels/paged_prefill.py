"""Pallas TPU paged flash-prefill kernel (chunked prefill over paged KV).

The second serving hot-spot, closing the gap the decode kernel left
open: a prefill chunk whose K/V (and all earlier context) already live
in the global page pool attends *directly over the pages* — no
per-layer ``k_pages[block_table]`` materialization and no dense
(S, NB*page) score matrix.  Block tables ride in as scalar prefetch so
each grid step's BlockSpec index_map stages KV pages HBM->VMEM; the
chunk's dynamic context offset (``ctx_lens``) is a traced scalar, not a
static kernel param, so one compiled kernel serves every chunk position
of every request.

Each grid step stages a PAIR of pages (two scalar-prefetched K and V
BlockSpecs) so the MXU sees a (G*bq, 2*page) score tile per step — one
page per step would halve the tile and double the sequential grid
length.  Masking: query row j of request b sits at absolute position
``ctx_lens[b] + j`` and may see keys at positions <= that (causal over
the whole paged history, chunk included).  Rows past ``chunk_lens[b]``
are padding and fully masked (their output rows are zero).  Pages past
the live context clamp their index_map to the last live page so dead
grid steps re-stage an already-resident page instead of burning
HBM->VMEM bandwidth on padding block-table entries, and skip compute
via ``pl.when``.

Grid: (batch, kv_heads, q_blocks, page_pairs), page dim innermost
(sequential) so the VMEM flash accumulator carries across pages.  GQA
is folded into the q-block rows — the G query heads of a KV-head group
share each staged page pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

PAIR = 2                      # pages staged per sequential grid step


def _kernel(
    # scalar prefetch
    block_tables_ref,   # (B, NB) int32
    ctx_lens_ref,       # (B,) int32 — tokens already in pages before chunk
    chunk_lens_ref,     # (B,) int32 — valid tokens in the chunk
    # inputs (blocked)
    q_ref,              # (1, 1, G, bq, D)
    k0_ref, k1_ref,     # (1, page, 1, D) — the staged page pair
    v0_ref, v1_ref,     # (1, page, 1, D)
    # output
    o_ref,              # (1, 1, G, bq, D)
    # scratch
    acc_ref,            # (G*bq, D) f32
    m_ref,              # (G*bq, 1) f32
    l_ref,              # (G*bq, 1) f32
    *, block_q: int, page_size: int,
):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)
    g, d = q_ref.shape[2], q_ref.shape[4]
    rows = g * block_q
    span = PAIR * page_size

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ctx = ctx_lens_ref[b]
    total = ctx + chunk_lens_ref[b]
    q_lo = ctx + qi * block_q               # first absolute q position
    q_hi = q_lo + block_q - 1               # last absolute q position
    k_lo = ki * span
    # tile dead if: every key pos is beyond every causal q pos, the pair
    # is past the live context, or the whole q block is chunk padding.
    alive = (k_lo <= q_hi) & (k_lo < total) & \
        (qi * block_q < chunk_lens_ref[b])

    @pl.when(alive)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32).reshape(rows, d) * (d ** -0.5)
        k = jnp.concatenate([k0_ref[0, :, 0], k1_ref[0, :, 0]]).astype(
            jnp.float32)                                       # (span, D)
        v = jnp.concatenate([v0_ref[0, :, 0], v1_ref[0, :, 0]]).astype(
            jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                # (rows, span)
        rowid = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
        j = rowid % block_q                  # row = g*bq + j
        qpos = q_lo + j
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, span), 1)
        mask = (kpos <= qpos) & (kpos < total) & \
            (qi * block_q + j < chunk_lens_ref[b])
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        # rows fully masked in this tile have m_new == NEG_INF; exp(0)=1
        # would pollute the accumulator — zero them via the mask.
        p = jnp.where(mask, jnp.exp(logits - m_new), 0.0)
        l_ref[...] = l_prev * alpha + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = ((acc_ref[...] / l)
                       .reshape(g, block_q, d).astype(o_ref.dtype))


def _live_page(bt, ctx, chunk, b, i, nb, page_size):
    """Clamp page index ``i`` to the request's last live (or last real)
    page so masked-out grid steps never DMA padding block-table entries."""
    total = ctx[b] + chunk[b]
    last = jnp.maximum((total + page_size - 1) // page_size - 1, 0)
    return bt[b, jnp.minimum(jnp.minimum(i, last), nb - 1)]


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def paged_prefill(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                  block_tables: jax.Array, ctx_lens: jax.Array,
                  chunk_lens: jax.Array, *, block_q: int = 128,
                  interpret: bool = True) -> jax.Array:
    """q: (B, S, H, D) chunk queries; k_pages/v_pages: (P, page, Hkv, D);
    block_tables: (B, NB) int32; ctx_lens/chunk_lens: (B,) int32.
    Pages must already contain the chunk's own K/V.  Returns
    (B, S, H, D) with rows >= chunk_lens[b] zeroed."""
    b, s, h, d = q.shape
    _, page, hkv, _ = k_pages.shape
    nb = block_tables.shape[1]
    g = h // hkv
    block_q = min(block_q, s)
    assert s % block_q == 0, \
        f"chunk len {s} must tile by block_q {block_q}"
    nq = s // block_q
    npair = -(-nb // PAIR)
    # layout: (B, Hkv, G, S, D) so one block carries the whole head group
    q5 = q.reshape(b, s, hkv, g, d).transpose(0, 2, 3, 1, 4)

    def kv_map(which):
        def index_map(b_, h_, qi, ki, bt, cx, cl):
            return (_live_page(bt, cx, cl, b_, PAIR * ki + which, nb,
                               page), 0, h_, 0)
        return index_map

    kv_specs = [pl.BlockSpec((1, page, 1, d), kv_map(w))
                for w in range(PAIR)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, nq, npair),
        in_specs=[
            pl.BlockSpec((1, 1, g, block_q, d),
                         lambda b_, h_, qi, ki, bt, cx, cl:
                         (b_, h_, 0, qi, 0)),
            *kv_specs, *kv_specs,
        ],
        out_specs=pl.BlockSpec((1, 1, g, block_q, d),
                               lambda b_, h_, qi, ki, bt, cx, cl:
                               (b_, h_, 0, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((g * block_q, d), jnp.float32),
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, page_size=page),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, s, d), q.dtype),
        interpret=interpret,
    )(block_tables, ctx_lens, chunk_lens, q5, k_pages, k_pages,
      v_pages, v_pages)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)
