"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth for tests/test_kernels.py shape/dtype sweeps
(assert_allclose vs the interpret-mode kernels) and the reference path
the engine falls back to on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        block_tables: jax.Array, lengths: jax.Array
                        ) -> jax.Array:
    """Decode attention over a paged KV cache.

    q:            (B, H, D)         one query token per sequence
    k_pages:      (P, page, Hkv, D) global page pool
    v_pages:      (P, page, Hkv, D)
    block_tables: (B, NB) int32     page ids per sequence (padded arbitrary)
    lengths:      (B,) int32        tokens in cache (incl. current token)
    returns:      (B, H, D)
    """
    b, h, d = q.shape
    _, page, hkv, _ = k_pages.shape
    nb = block_tables.shape[1]
    g = h // hkv
    # gather pages -> contiguous (B, NB*page, Hkv, D)
    k = k_pages[block_tables].reshape(b, nb * page, hkv, d)
    v = v_pages[block_tables].reshape(b, nb * page, hkv, d)
    qf = (q.astype(jnp.float32) * d ** -0.5).reshape(b, hkv, g, d)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qf, k.astype(jnp.float32))
    valid = jnp.arange(nb * page)[None] < lengths[:, None]        # (B, K)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def paged_prefill_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      block_tables: jax.Array, ctx_lens: jax.Array,
                      chunk_lens: jax.Array) -> jax.Array:
    """Chunked-prefill attention over a paged KV cache (dense oracle).

    q:            (B, S, H, D)      one prefill chunk per request; row j
                                    sits at absolute pos ctx_lens[b] + j
    k_pages:      (P, page, Hkv, D) global page pool (chunk K/V already
    v_pages:      (P, page, Hkv, D)  scattered in)
    block_tables: (B, NB) int32     pages covering [0, ctx+chunk)
    ctx_lens:     (B,) int32        tokens in pages before the chunk
    chunk_lens:   (B,) int32        valid chunk tokens (rows beyond are
                                    padding; their output rows are 0)
    returns:      (B, S, H, D)
    """
    b, s, h, d = q.shape
    _, page, hkv, _ = k_pages.shape
    nb = block_tables.shape[1]
    g = h // hkv
    k = k_pages[block_tables].reshape(b, nb * page, hkv, d)
    v = v_pages[block_tables].reshape(b, nb * page, hkv, d)
    qpos = ctx_lens[:, None] + jnp.arange(s)[None]             # (B, S)
    kpos = jnp.arange(nb * page)
    total = (ctx_lens + chunk_lens)[:, None, None]
    mask = (kpos[None, None, :] <= qpos[:, :, None]) \
        & (kpos[None, None, :] < total) \
        & (jnp.arange(s)[None, :, None] < chunk_lens[:, None, None])
    qf = (q.astype(jnp.float32) * d ** -0.5).reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    any_valid = mask.any(-1)[:, None, None, :, None]
    probs = jnp.where(any_valid, probs, 0.0)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      lengths: jax.Array, *, window: int = 0,
                      q_offset: int = 0) -> jax.Array:
    """Causal (optionally sliding-window) prefill attention.

    q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D); lengths: (B,) valid k tokens.
    ``q_offset`` places the query chunk at absolute positions
    [q_offset, q_offset+Sq) — used by chunked prefill.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    mask = mask[None] & (kpos[None, None, :] < lengths[:, None, None])
    qf = (q.astype(jnp.float32) * d ** -0.5).reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    # rows with no valid key (qpos >= length under a window) define to 0
    any_valid = mask.any(-1)[:, None, None, :, None]
    probs = jnp.where(any_valid, probs, 0.0)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)
