from repro.engine.engine import (EngineConfig, EngineMetrics,  # noqa: F401
                                 InferenceEngine)
from repro.engine.request import Request, RequestState, SamplingParams  # noqa: F401
from repro.engine.runner import ModelRunner  # noqa: F401
from repro.engine.scheduler import (ScheduleOutput, Scheduler,  # noqa: F401
                                    SchedulerConfig, SchedulerCore)
