from repro.engine.engine import (EngineConfig, EngineMetrics,  # noqa: F401
                                 InferenceEngine)
from repro.engine.request import Request, RequestState, SamplingParams  # noqa: F401
