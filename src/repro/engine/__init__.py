from repro.engine.engine import (EngineConfig, EngineMetrics,  # noqa: F401
                                 InferenceEngine)
from repro.engine.request import Request, RequestState, SamplingParams  # noqa: F401
from repro.engine.runner import ModelRunner  # noqa: F401
from repro.engine.scheduler import (DEFAULT_SLO_CLASSES,  # noqa: F401
                                    ClassSLO, ScheduleOutput, Scheduler,
                                    SchedulerConfig, SchedulerCore)
