"""Slot-based engine for non-pageable architectures (SSM / hybrid /
codebook models).

The paged engine requires uniform full-attention layers; xLSTM, Hymba,
gemma3-style local:global patterns and MusicGen's codebook stream do
not fit page tables.  The SlotEngine serves *any* ModelConfig with the
substrate's contiguous per-slot caches (recurrent states double as the
"KV cache" for SSM layers — constant-size, so slots never grow).

Same handle contract as InferenceEngine (submit/step/metrics/
match_prefix_len), so the gateway and control plane treat both alike —
and since the scheduler-core refactor the queue/admission/finish
bookkeeping is the shared :class:`repro.engine.scheduler.SchedulerCore`
(the same stop predicate, queue-time and latency EWMAs, throughput
window and per-class SLO attainment accounting the paged engines use),
so ``admitted_requests``, ``avg_queue_time`` and ``slo_attainment``
feed gateway routing with the same semantics as every other engine.
Prefix caching is not available here: an SSM has no token-addressable
KV — the pool-equivalent is recurrent-state snapshotting at fixed
strides (see docs/ARCHITECTURE.md "SlotEngine note", partial support).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.request import Request, RequestState
from repro.engine.sampling import sample
from repro.engine.scheduler import EngineMetrics, SchedulerCore
from repro.models import model as M
from repro.models.config import ModelConfig


@dataclass
class SlotEngineConfig:
    max_slots: int = 4
    max_len: int = 256
    dtype: str = "float32"


class SlotEngine:
    def __init__(self, cfg: ModelConfig, ecfg: SlotEngineConfig = None,
                 params=None, clock: Callable[[], float] = time.monotonic,
                 engine_id: str = "slot-0", seed: int = 0):
        self.cfg = cfg
        self.ecfg = ecfg or SlotEngineConfig()
        self.clock = clock
        self.engine_id = engine_id
        dtype = jnp.dtype(self.ecfg.dtype)
        self.params = params if params is not None else M.init(
            cfg, jax.random.PRNGKey(seed), dtype)
        self.caches = M.init_cache(cfg, self.ecfg.max_slots,
                                   self.ecfg.max_len, dtype)
        self.slots: List[Optional[Request]] = [None] * self.ecfg.max_slots
        self.core = SchedulerCore()
        self._key = jax.random.PRNGKey(seed + 1)

    # ------------------------------------------------------------ contract
    def submit(self, req: Request) -> None:
        self.core.enqueue(req, self.clock())

    @property
    def waiting(self) -> List[Request]:
        return self.core.waiting

    @property
    def finished(self) -> List[Request]:
        return self.core.finished

    @property
    def has_work(self) -> bool:
        return bool(self.core.waiting or any(self.slots))

    def match_prefix_len(self, tokens) -> int:
        return 0                     # no token-addressable KV (SSM note)

    @property
    def queue_depth(self) -> int:
        """Cheap routing-load accessor (== metrics() num_running +
        num_waiting)."""
        return (sum(r is not None for r in self.slots)
                + len(self.core.waiting))

    def register_adapter(self, name, weights=None):   # parity no-op
        pass

    def unregister_adapter(self, name):
        pass

    # ------------------------------------------------------------ internals
    def _prefill_into_slot(self, req: Request, slot: int) -> None:
        toks = np.asarray([req.prompt_tokens], np.int32)
        one_cache = M.init_cache(self.cfg, 1, self.ecfg.max_len,
                                 jax.tree.leaves(self.caches)[0].dtype
                                 if jax.tree.leaves(self.caches) else
                                 jnp.float32)
        logits, one_cache = M.prefill(self.params, self.cfg,
                                      jnp.asarray(toks), one_cache)
        # write the single-row cache into this slot's row
        self.caches = jax.tree.map(
            lambda c, n: c.at[:, slot].set(n[:, 0]) if c.ndim >= 2 else c,
            self.caches, one_cache)
        tok = self._sample(logits.reshape(1, -1), [req])[0]
        now = self.clock()
        tok = tok.tolist() if self.cfg.num_codebooks else int(tok)
        self._push_token(req, tok, now, first=True)
        req.state = RequestState.RUNNING
        self.core.note_admitted(req, now)
        req.slot = slot
        self.slots[slot] = req

    def _push_token(self, req: Request, tok, now, first=False) -> None:
        if self.cfg.num_codebooks:
            req.output_tokens.append(tok)
        else:
            req.output_tokens.append(int(tok))
        if first:
            req.first_token_time = now
        else:
            req.token_times.append(now)
        self.core.note_tokens(now, 1)

    def _sample(self, logits, reqs) -> np.ndarray:
        if self.cfg.num_codebooks:
            # greedy per codebook
            lg = logits.reshape(len(reqs), self.cfg.num_codebooks, -1)
            return np.asarray(jnp.argmax(lg, -1), np.int32)
        b = logits.shape[0]
        temps = np.zeros(b, np.float32)
        for i, r in enumerate(reqs[:b]):
            temps[i] = r.sampling.temperature
        self._key, sub = jax.random.split(self._key)
        return np.asarray(sample(logits, sub, jnp.asarray(temps)))

    def step(self) -> int:
        # admit (shared admission scan: FIFO, failing oversized requests)
        while self.core.waiting and None in self.slots:
            req = self.core.waiting[0]
            total = req.prompt_len + req.sampling.max_new_tokens
            if total > self.ecfg.max_len:
                req.state = RequestState.FAILED
                self.core.waiting.pop(0)
                continue
            self.core.waiting.pop(0)
            self._prefill_into_slot(req, self.slots.index(None))
            self._maybe_finish(self.slots[req.slot])
            return 1
        # batched decode over active slots
        active = [r for r in self.slots if r is not None]
        if not active:
            return 0
        b = self.ecfg.max_slots
        if self.cfg.num_codebooks:
            toks = np.zeros((b, self.cfg.num_codebooks), np.int32)
        else:
            toks = np.zeros(b, np.int32)
        pos = np.zeros(b, np.int32)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            last = r.output_tokens[-1]
            toks[i] = last
            pos[i] = r.prompt_len + len(r.output_tokens) - 1
        logits, self.caches = M.decode_step(
            self.params, self.cfg, self.caches, jnp.asarray(toks),
            jnp.asarray(pos))
        new = self._sample(np.asarray(logits).reshape(b, -1)
                           if not self.cfg.num_codebooks else logits,
                           [r or Request(prompt_tokens=[0])
                            for r in self.slots])
        now = self.clock()
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            tok = new[i].tolist() if self.cfg.num_codebooks else new[i]
            self._push_token(r, tok, now)
            self._maybe_finish(r)
        return len(active)

    def _maybe_finish(self, req: Request) -> None:
        if req is None or not self.core.request_done(req):
            return
        if req.slot >= 0:
            self.slots[req.slot] = None
            req.slot = -1
        self.core.note_finished(req, self.clock())

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.has_work:
                return
            self.step()
        raise RuntimeError("slot engine did not drain")

    def metrics(self) -> EngineMetrics:
        now = self.clock()
        used = sum(r is not None for r in self.slots)
        return EngineMetrics(
            num_running=used, num_waiting=len(self.core.waiting),
            kv_utilization=used / max(self.ecfg.max_slots, 1),
            tokens_per_sec=self.core.throughput(now),
            avg_latency=self.core.avg_latency,
            avg_queue_time=self.core.avg_queue_time,
            admitted_requests=self.core.admitted_count,
            finished_requests=self.core.finished_count,
            slo_attainment=self.core.slo_attainment(now),
            slo_by_class=self.core.slo_class_stats(now),
            slo_itl_attainment=self.core.slo_itl_attainment(now))
