"""Token sampling: greedy / temperature / top-k / top-p, batched."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("top_k",))
def sample(logits: jax.Array, key: jax.Array, temperature: jax.Array,
           top_k: int = 0, top_p: jax.Array = None) -> jax.Array:
    """logits: (B, V); temperature: (B,). temperature<=0 -> greedy."""
    b, v = logits.shape
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1)
    t = jnp.maximum(temperature, 1e-4)[:, None]
    scaled = logits / t
    if top_k:
        kth = jnp.sort(scaled, axis=-1)[:, -top_k][:, None]
        scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
    if top_p is not None:
        sorted_ = jnp.sort(scaled, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(csum < top_p[:, None], axis=-1)
        cutoff = jnp.take_along_axis(sorted_, cutoff_idx[:, None], axis=-1)
        scaled = jnp.where(scaled >= cutoff, scaled, -jnp.inf)
    keys = jax.random.split(key, b)
    sampled = jax.vmap(lambda k, lg: jax.random.categorical(k, lg))(
        keys, scaled)
    return jnp.where(temperature <= 0.0, greedy, sampled).astype(jnp.int32)
