"""Token sampling: greedy / temperature / top-k / top-p, batched.

Two keying modes:

- legacy: one ``key`` split across the batch — fine for a fixed batch,
  but the per-row streams depend on batch *order*, so permuting the
  batch (or verifying several positions of one row in a single pass,
  as speculative decoding does) changes the samples.
- per-position (``keys``): one PRNG key per row derived from the
  request's sampling seed and the *absolute token position* via
  :func:`row_keys`.  Sampling then commutes with batch permutation and
  with how many positions a single pass verifies — the property that
  makes speculative verification byte-identical to step-by-step
  decoding even at temperature > 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@jax.jit
def row_keys(seeds: jax.Array, positions: jax.Array) -> jax.Array:
    """One PRNG key per row: fold the request's sampling seed, then the
    absolute token position, into a fixed root key.  Depends on nothing
    else — not batch order, not how many tokens a pass verifies."""
    root = jax.random.PRNGKey(0)

    def mk(s, p):
        return jax.random.fold_in(jax.random.fold_in(root, s), p)

    return jax.vmap(mk)(seeds.astype(jnp.uint32),
                        positions.astype(jnp.uint32))


@functools.partial(jax.jit, static_argnames=("top_k",))
def sample(logits: jax.Array, key: jax.Array, temperature: jax.Array,
           top_k: int = 0, top_p: jax.Array = None,
           keys: jax.Array = None) -> jax.Array:
    """logits: (B, V); temperature: (B,). temperature<=0 -> greedy.
    ``keys`` (B, key_size), e.g. from :func:`row_keys`, overrides the
    batch-order-dependent split of ``key``."""
    b, v = logits.shape
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1)
    t = jnp.maximum(temperature, 1e-4)[:, None]
    scaled = logits / t
    if top_k:
        kth = jnp.sort(scaled, axis=-1)[:, -top_k][:, None]
        scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
    if top_p is not None:
        sorted_ = jnp.sort(scaled, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(csum < top_p[:, None], axis=-1)
        cutoff = jnp.take_along_axis(sorted_, cutoff_idx[:, None], axis=-1)
        scaled = jnp.where(scaled >= cutoff, scaled, -jnp.inf)
    if keys is None:
        keys = jax.random.split(key, b)
    sampled = jax.vmap(lambda k, lg: jax.random.categorical(k, lg))(
        keys, scaled)
    return jnp.where(temperature <= 0.0, greedy, sampled).astype(jnp.int32)
