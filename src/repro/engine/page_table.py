"""Page allocator + hash-based prefix cache (vLLM-style) for one engine.

Pages are the unit of both memory management and *reuse*: a full page of
``page_size`` tokens is content-addressed by the rolling hash of every
token up to and including that page.  The AIBrix distributed KV pool
(repro.core.kvcache) speaks the same block-hash language, which is what
makes cross-engine reuse possible: an engine that misses locally can ask
the pool for the page payload by hash.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple


def chunk_hashes(tokens: Sequence[int], page_size: int,
                 salt: str = "") -> List[str]:
    """Rolling content hash per *full* page of the token prefix.

    ``salt`` namespaces the hash chain — LoRA requests pass their
    adapter name so KV computed under one adapter (the v-projection
    changes cached values) can never be prefix-matched, pool-shared,
    or swap-restored into a request running a different adapter.  Base
    requests use the empty salt, keeping their hashes stable."""
    out = []
    h = hashlib.sha256()
    if salt:
        h.update(bytes(salt, "utf-8"))
    n_full = len(tokens) // page_size
    for i in range(n_full):
        chunk = tokens[i * page_size:(i + 1) * page_size]
        h.update(bytes(str(list(chunk)), "utf-8"))
        out.append(h.hexdigest()[:24])
    return out


@dataclass
class PageInfo:
    page_id: int
    block_hash: Optional[str] = None
    ref_count: int = 0
    last_used: float = 0.0


class PageAllocator:
    """Fixed pool of physical pages with refcounted prefix caching."""

    def __init__(self, num_pages: int, page_size: int):
        self.num_pages = num_pages
        self.page_size = page_size
        self.free: List[int] = list(range(num_pages))
        self.pages: Dict[int, PageInfo] = {
            i: PageInfo(i) for i in range(num_pages)}
        # block hash -> page id, for pages whose contents are a full,
        # content-addressed token block (prefix-cache index)
        self.hash_index: Dict[str, int] = {}
        # evictable cached pages (ref_count == 0, hash set), maintained
        # in LRU order by insertion: release() re-inserts at the end
        # (move-to-end), so eviction pops the front in O(1) instead of
        # a min()-scan over timestamps
        self._cached_lru: Dict[int, float] = {}
        self.stats = {"allocated": 0, "cache_hits": 0, "cache_misses": 0,
                      "evictions": 0}
        # eviction cascade hook: called as (page_id, block_hash, now)
        # BEFORE a hash-indexed cached page is recycled, while its
        # contents are still intact — the tiered-KV engine offloads the
        # victim into the host-DRAM tier here instead of dropping it
        self.on_evict: Optional[Callable[[int, str, float], None]] = None

    # ---------------------------------------------------------------- util
    @property
    def num_free(self) -> int:
        return len(self.free) + len(self._cached_lru)

    @property
    def utilization(self) -> float:
        in_use = self.num_pages - len(self.free) - len(self._cached_lru)
        return in_use / max(self.num_pages, 1)

    # ---------------------------------------------------------------- alloc
    def _pop_free(self, now: float) -> Optional[int]:
        if self.free:
            return self.free.pop()
        if self._cached_lru:            # evict LRU cached page: O(1)
            pid = next(iter(self._cached_lru))
            del self._cached_lru[pid]
            info = self.pages[pid]
            if info.block_hash:
                if self.on_evict is not None:
                    self.on_evict(pid, info.block_hash, now)
                self.hash_index.pop(info.block_hash, None)
            info.block_hash = None
            self.stats["evictions"] += 1
            return pid
        return None

    def allocate(self, n: int, now: float = 0.0) -> Optional[List[int]]:
        """Allocate n fresh pages (or None if impossible)."""
        if self.num_free < n:
            return None
        out = []
        for _ in range(n):
            pid = self._pop_free(now)
            assert pid is not None
            info = self.pages[pid]
            info.ref_count = 1
            info.last_used = now
            out.append(pid)
        self.stats["allocated"] += n
        return out

    def retain(self, page_ids: Sequence[int]) -> None:
        for pid in page_ids:
            info = self.pages[pid]
            if info.ref_count == 0:
                self._cached_lru.pop(pid, None)
            info.ref_count += 1

    def release(self, page_ids: Sequence[int], now: float = 0.0) -> None:
        """Drop a reference; hash-indexed pages become evictable cache,
        anonymous pages return to the free list."""
        for pid in page_ids:
            info = self.pages[pid]
            info.ref_count -= 1
            assert info.ref_count >= 0, f"double free of page {pid}"
            if info.ref_count == 0:
                if info.block_hash:
                    info.last_used = now
                    # move-to-end keeps dict order == LRU order
                    self._cached_lru.pop(pid, None)
                    self._cached_lru[pid] = now
                else:
                    self.free.append(pid)

    # ---------------------------------------------------------------- prefix
    def register_hash(self, page_id: int, block_hash: str) -> None:
        info = self.pages[page_id]
        info.block_hash = block_hash
        self.hash_index[block_hash] = page_id

    def match_prefix(self, tokens: Sequence[int], now: float = 0.0,
                     salt: str = "") -> Tuple[List[int], int]:
        """Longest cached prefix -> (page_ids retained, tokens covered).

        Never matches the *entire* prompt (the last partial/full block is
        always recomputed so prefill produces at least one new token).
        """
        hashes = chunk_hashes(tokens, self.page_size, salt)
        matched: List[int] = []
        for i, h in enumerate(hashes):
            covered = (i + 1) * self.page_size
            if covered >= len(tokens):
                break
            pid = self.hash_index.get(h)
            if pid is None:
                break
            matched.append(pid)
        if matched:
            self.retain(matched)
            self.stats["cache_hits"] += len(matched)
        self.stats["cache_misses"] += max(
            len(hashes) - len(matched), 0)
        return matched, len(matched) * self.page_size

    def match_len(self, tokens: Sequence[int], salt: str = "") -> int:
        """Non-mutating variant for router scoring (no retain)."""
        hashes = chunk_hashes(tokens, self.page_size, salt)
        n = 0
        for i, h in enumerate(hashes):
            if (i + 1) * self.page_size >= len(tokens):
                break
            if h not in self.hash_index:
                break
            n += 1
        return n * self.page_size
