"""ModelRunner: executes a ``ScheduleOutput`` on the real JAX model.

The runner is the data plane the unified :class:`repro.engine.scheduler.
Scheduler` drives — it owns everything device-shaped: the model params,
the paged KV ``PagePool`` (donated through every jitted call, so the
pages are updated in place rather than copied), the high-density LoRA
bank, the sampling PRNG stream, and the *persistent preallocated host
input buffers* for step assembly.

The buffer point matters for step overhead: the pre-refactor engine
re-allocated ~6 numpy arrays (tokens / positions / block tables /
active mask / adapter ids, plus the prefill-chunk set) on every
``step()`` before uploading them.  The runner allocates them once at
construction and re-fills the used slice per step; ``benchmarks/
bench_kernels.py --quick`` ("step_inputs" rows) tracks the win.

The runner also owns the page *payload* side of the distributed KV
pool protocol: publishing freshly filled prompt pages (skipping the
device→host copy when the pool already holds the hash) and writing
fetched remote pages into local device pages.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import paged_model as PM
from repro.engine.request import Request
from repro.engine.sampling import sample
from repro.engine.scheduler import PrefillWork, ScheduleOutput
from repro.models import model as M
from repro.models.config import ModelConfig


class ModelRunner:
    """Turns declarative schedules into jitted forward passes."""

    def __init__(self, cfg: ModelConfig, ecfg, params=None, seed: int = 0):
        self.cfg, self.ecfg = cfg, ecfg
        dtype = jnp.dtype(ecfg.dtype)
        self.params = params if params is not None else M.init(
            cfg, jax.random.PRNGKey(seed), dtype)
        self.pool = PM.init_pool(cfg, ecfg.num_pages + 1, ecfg.page_size,
                                 dtype)  # +1: OOB scratch page for drops
        self.lora = PM.init_lora(cfg, ecfg.max_adapters, ecfg.lora_rank,
                                 dtype)
        self._adapter_ids: Dict[str, int] = {}
        self._free_adapter_slots = list(range(1, ecfg.max_adapters))
        self._key = jax.random.PRNGKey(seed + 1)
        # persistent host input buffers (allocated once, refilled per
        # step; block tables are sliced to the bucketed width in use)
        b, kk = ecfg.max_batch, ecfg.max_prefills
        nbmax = ecfg.max_pages_per_seq
        self._dec_toks = np.zeros(b, np.int32)
        self._dec_pos = np.zeros(b, np.int32)
        self._dec_bts = np.full((b, nbmax), ecfg.num_pages, np.int32)
        self._dec_active = np.zeros(b, bool)
        self._dec_aids = np.zeros(b, np.int32)
        # floor of one row: two-phase prefill writes row 0 even when
        # the mixed scheduler is configured with max_prefills=0
        kk1 = max(kk, 1)
        self._pre_toks = np.zeros((kk1, ecfg.chunk_size), np.int32)
        self._pre_ctx = np.zeros(kk1, np.int32)
        self._pre_chunk = np.zeros(kk1, np.int32)
        self._pre_aids = np.zeros(kk1, np.int32)
        self._pre_bts = np.full((kk1, nbmax), ecfg.num_pages, np.int32)
        # outputs of the most recent jitted call.  jnp.asarray may
        # zero-copy alias a host buffer on some backend/dtype combos
        # (CPU float32 does), so before REFILLING the persistent
        # buffers we block on the previous step's computation — it must
        # not be able to read next-step data through an alias.
        self._inflight = None

    def _sync_inflight(self) -> None:
        if self._inflight is not None:
            jax.block_until_ready(self._inflight)
            self._inflight = None

    # ------------------------------------------------------------- LoRA
    def register_adapter(self, name: str, weights: dict = None) -> int:
        """Dynamic high-density LoRA registration (paper §3.2.1)."""
        if name in self._adapter_ids:
            return self._adapter_ids[name]
        if not self._free_adapter_slots:
            raise RuntimeError("adapter slots exhausted")
        idx = self._free_adapter_slots.pop(0)
        if weights is None:
            weights = PM.make_adapter(self.cfg, self.ecfg.lora_rank,
                                      jax.random.fold_in(self._key, idx))
        self.lora = {k: self.lora[k].at[idx].set(weights[k])
                     for k in self.lora}
        self._adapter_ids[name] = idx
        return idx

    def unregister_adapter(self, name: str) -> None:
        idx = self._adapter_ids.pop(name, None)
        if idx is not None:
            self.lora = {k: self.lora[k].at[idx].set(0.0) for k in self.lora}
            self._free_adapter_slots.append(idx)

    @property
    def adapters(self) -> List[str]:
        return sorted(self._adapter_ids)

    @property
    def adapter_ids(self) -> Dict[str, int]:
        return self._adapter_ids

    def _aid(self, req: Request) -> int:
        return self._adapter_ids.get(req.lora_adapter or "", 0)

    # ---------------------------------------------------------- sampling
    def sample(self, logits, reqs) -> np.ndarray:
        b = logits.shape[0]
        temps = np.zeros(b, np.float32)
        tops = np.ones(b, np.float32)
        for i, r in enumerate(reqs[:b]):
            temps[i] = r.sampling.temperature
            tops[i] = r.sampling.top_p
        self._key, sub = jax.random.split(self._key)
        return np.asarray(sample(logits, sub, jnp.asarray(temps),
                                 top_k=0, top_p=jnp.asarray(tops)))

    # ------------------------------------------------------- input prep
    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.ecfg.page_size)

    def _bt_width(self, pages_needed: int) -> int:
        """Bucketed block-table width: bounds the decode kernel's page
        grid by what the batch actually uses (multiples of 4 to limit
        recompiles) instead of the full ``max_pages_per_seq``."""
        cap = -(-max(pages_needed, 1) // 4) * 4
        return min(cap, self.ecfg.max_pages_per_seq)

    def _decode_inputs(self, reqs):
        self._sync_inflight()
        ecfg = self.ecfg
        nb = self._bt_width(max((self._pages_for(
            r.prompt_len + len(r.output_tokens)) for r in reqs),
            default=1))
        toks, pos = self._dec_toks, self._dec_pos
        active, aids = self._dec_active, self._dec_aids
        bts = self._dec_bts[:, :nb]
        toks[:] = 0
        pos[:] = 0
        bts[:] = ecfg.num_pages             # OOB scratch page
        active[:] = False
        aids[:] = 0
        for i, r in enumerate(reqs):
            toks[i] = r.output_tokens[-1]
            pos[i] = r.prompt_len + len(r.output_tokens) - 1
            n = min(len(r.page_ids), nb)
            bts[i, :n] = r.page_ids[:n]
            active[i] = True
            aids[i] = self._aid(r)
        return toks, pos, bts, active, aids

    def _prefill_inputs(self, works: List[PrefillWork], s: int):
        self._sync_inflight()
        ecfg = self.ecfg
        kk = ecfg.max_prefills
        if s == ecfg.chunk_size:
            pre_toks = self._pre_toks
        else:                               # unchunked: dynamic width
            pre_toks = np.zeros((kk, s), np.int32)
        pre_ctx, pre_chunk = self._pre_ctx, self._pre_chunk
        pre_aids = self._pre_aids
        nb_pre = self._bt_width(max((self._pages_for(w.start + w.chunk_len)
                                     for w in works), default=1))
        pre_bts = self._pre_bts[:, :nb_pre]
        pre_toks[:] = 0
        pre_ctx[:] = 0
        pre_chunk[:] = 0
        pre_aids[:] = 0
        pre_bts[:] = ecfg.num_pages
        for i, w in enumerate(works):
            p, c = w.req, w.chunk_len
            pre_toks[i, :c] = p.prompt_tokens[w.start:w.start + c]
            pre_ctx[i] = w.start
            pre_chunk[i] = c
            n = min(len(p.page_ids), nb_pre)
            pre_bts[i, :n] = p.page_ids[:n]
            pre_aids[i] = self._aid(p)
        return pre_toks, pre_ctx, pre_chunk, pre_aids, pre_bts

    # ---------------------------------------------------------- execute
    def run_mixed(self, out: ScheduleOutput) -> Tuple[jax.Array, jax.Array]:
        """One fused decode+prefill pass; returns (dec_logits, pre_logits)."""
        ecfg = self.ecfg
        pre_toks, pre_ctx, pre_chunk, pre_aids, pre_bts = \
            self._prefill_inputs(out.prefills, out.pad_len)
        toks, pos, bts, active, aids = self._decode_inputs(out.decode)
        dec_logits, pre_logits, self.pool = PM.mixed_step(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(bts), jnp.asarray(active), jnp.asarray(pre_toks),
            jnp.asarray(pre_bts), jnp.asarray(pre_ctx),
            jnp.asarray(pre_chunk), self.lora, jnp.asarray(aids),
            jnp.asarray(pre_aids), cfg=self.cfg,
            page_size=ecfg.page_size, impl=ecfg.impl)
        self._inflight = (dec_logits, pre_logits)
        return dec_logits, pre_logits

    def run_decode(self, reqs: List[Request]) -> jax.Array:
        ecfg = self.ecfg
        toks, pos, bts, active, aids = self._decode_inputs(reqs)
        logits, self.pool = PM.decode_batch(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(bts), jnp.asarray(active), self.lora,
            jnp.asarray(aids), cfg=self.cfg, page_size=ecfg.page_size,
            impl=ecfg.impl)
        self._inflight = logits
        return logits

    def run_prefill(self, work: PrefillWork) -> jax.Array:
        """One (possibly chunked) prefill for ONE request (two-phase)."""
        self._sync_inflight()
        ecfg = self.ecfg
        req, s, c = work.req, work.pad_len, work.chunk_len
        if s == ecfg.chunk_size:
            toks = self._pre_toks[:1]
            toks[:] = 0
        else:
            toks = np.zeros((1, s), np.int32)
        toks[0, :c] = req.prompt_tokens[work.start:work.start + c]
        nb = self._bt_width(self._pages_for(work.start + c))
        bt = self._pre_bts[:1, :nb]
        bt[:] = ecfg.num_pages              # OOB scratch page
        n = min(len(req.page_ids), nb)
        bt[0, :n] = req.page_ids[:n]
        logits, self.pool = PM.prefill_step(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(bt),
            jnp.int32(work.start), jnp.int32(c),
            self.lora, jnp.asarray([self._aid(req)], jnp.int32),
            cfg=self.cfg, page_size=ecfg.page_size, impl=ecfg.impl)
        self._inflight = logits
        return logits

    # ------------------------------------------------------- pool payloads
    @property
    def page_bytes(self) -> int:
        """Raw (k + v) payload bytes of one page — what the host tier's
        capacity accounting and the transfer counters charge."""
        k = self.pool.k
        return int(2 * k[:, 0].size * k.dtype.itemsize)

    def page_payload(self, pid: int):
        """Materialize one page's (k, v) arrays for a pool publish or a
        host-tier offload — the device→host copy the Scheduler's
        contains() gate avoids for blocks the pool already knows.
        ``np.array`` forces a real copy: host-tier entries outlive this
        step, and on CPU backends a zero-copy view could alias a
        donated buffer the next jitted call overwrites in place."""
        return (np.array(self.pool.k[:, pid]),
                np.array(self.pool.v[:, pid]))

    def write_remote_page(self, pid: int, k_page, v_page) -> None:
        """Install a page payload fetched from the distributed pool."""
        self.pool = PM.PagePool(
            self.pool.k.at[:, pid].set(k_page),
            self.pool.v.at[:, pid].set(v_page))
