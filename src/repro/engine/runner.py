"""ModelRunner: executes a ``ScheduleOutput`` on the real JAX model.

The runner is the data plane the unified :class:`repro.engine.scheduler.
Scheduler` drives — it owns everything device-shaped: the model params,
the paged KV ``PagePool`` (donated through every jitted call, so the
pages are updated in place rather than copied), the high-density LoRA
bank, the sampling PRNG stream, and the *persistent preallocated host
input buffers* for step assembly.

The buffer point matters for step overhead: the pre-refactor engine
re-allocated ~6 numpy arrays (tokens / positions / block tables /
active mask / adapter ids, plus the prefill-chunk set) on every
``step()`` before uploading them.  The runner allocates them once at
construction and re-fills the used slice per step; ``benchmarks/
bench_kernels.py --quick`` ("step_inputs" rows) tracks the win.

The runner also owns the page *payload* side of the distributed KV
pool protocol: publishing freshly filled prompt pages (skipping the
device→host copy when the pool already holds the hash) and writing
fetched remote pages into local device pages.
"""
from __future__ import annotations

import time
import zlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import paged_model as PM
from repro.engine.request import Request
from repro.engine.sampling import row_keys, sample
from repro.engine.scheduler import PrefillWork, ScheduleOutput
from repro.engine.speculative import accept_length
from repro.models import model as M
from repro.models.config import ModelConfig


class ModelRunner:
    """Turns declarative schedules into jitted forward passes."""

    # process-wide device-wait accumulator: benchmarks/run.py prints the
    # per-suite delta (same pattern as Gateway.total_shed)
    total_device_wait_s = 0.0

    def __init__(self, cfg: ModelConfig, ecfg, params=None, seed: int = 0):
        self.cfg, self.ecfg = cfg, ecfg
        dtype = jnp.dtype(ecfg.dtype)
        self.params = params if params is not None else M.init(
            cfg, jax.random.PRNGKey(seed), dtype)
        self.pool = PM.init_pool(cfg, ecfg.num_pages + 1, ecfg.page_size,
                                 dtype)  # +1: OOB scratch page for drops
        self.lora = PM.init_lora(cfg, ecfg.max_adapters, ecfg.lora_rank,
                                 dtype)
        self._adapter_ids: Dict[str, int] = {}
        self._free_adapter_slots = list(range(1, ecfg.max_adapters))
        self._key = jax.random.PRNGKey(seed + 1)
        # adapter tiering (HBM bank <-> bounded host DRAM tier <->
        # artifact store): weights are a pure function of (engine seed,
        # adapter NAME) — never of the HBM slot — so eviction is always
        # safe (re-load is byte-identical) and slot reuse can't leak.
        # ``_adapter_base_key`` is separate from the sampling key
        # stream, which ``sample`` mutates.
        self._adapter_base_key = jax.random.PRNGKey(seed + 2)
        self._adapter_lru: Dict[str, int] = {}
        self._lru_tick = 0
        self._host_adapters: Dict[str, dict] = {}
        self._host_adapter_slots = int(
            getattr(ecfg, "host_adapter_slots", 32))
        self.adapter_loads = 0          # non-resident registers paid
        self.adapter_load_s = 0.0       # wall seconds stalled on them
        self.adapter_evictions = 0      # LRU HBM-bank evictions
        self.adapter_host_hits = 0      # loads served from the host tier
        # persistent host input buffers (allocated once, refilled per
        # step; block tables are sliced to the bucketed width in use)
        b, kk = ecfg.max_batch, ecfg.max_prefills
        nbmax = ecfg.max_pages_per_seq
        self._dec_toks = np.zeros(b, np.int32)
        self._dec_pos = np.zeros(b, np.int32)
        self._dec_bts = np.full((b, nbmax), ecfg.num_pages, np.int32)
        self._dec_active = np.zeros(b, bool)
        self._dec_aids = np.zeros(b, np.int32)
        # floor of one row: two-phase prefill writes row 0 even when
        # the mixed scheduler is configured with max_prefills=0
        kk1 = max(kk, 1)
        self._pre_toks = np.zeros((kk1, ecfg.chunk_size), np.int32)
        self._pre_ctx = np.zeros(kk1, np.int32)
        self._pre_chunk = np.zeros(kk1, np.int32)
        self._pre_aids = np.zeros(kk1, np.int32)
        self._pre_bts = np.full((kk1, nbmax), ecfg.num_pages, np.int32)
        # speculative verification buffers: every decode row becomes a
        # fixed-width chunk [last_token, draft_1..draft_d] (padding to
        # the full width keeps the jitted spec step at ONE shape)
        sd = 1 + max(getattr(ecfg, "spec_tokens", 0), 0)
        self._spec_toks = np.zeros((b, sd), np.int32)
        self._spec_ctx = np.zeros(b, np.int32)
        self._spec_len = np.zeros(b, np.int32)
        # seconds spent blocked on device readback (this runner / all
        # runners) — the host-overhead signal the async loop shrinks
        self.device_wait_s = 0.0
        # outputs of the most recent jitted call.  jnp.asarray may
        # zero-copy alias a host buffer on some backend/dtype combos
        # (CPU float32 does), so before REFILLING the persistent
        # buffers we block on the previous step's computation — it must
        # not be able to read next-step data through an alias.
        self._inflight = None

    def _sync_inflight(self) -> None:
        if self._inflight is not None:
            t0 = time.perf_counter()
            jax.block_until_ready(self._inflight)
            dt = time.perf_counter() - t0
            self.device_wait_s += dt
            ModelRunner.total_device_wait_s += dt
            self._inflight = None

    def readback(self, arr) -> np.ndarray:
        """Block on a device array and charge the wait to the
        device-wait counters — the async loop's one sync point."""
        t0 = time.perf_counter()
        jax.block_until_ready(arr)
        dt = time.perf_counter() - t0
        self.device_wait_s += dt
        ModelRunner.total_device_wait_s += dt
        return np.asarray(arr)

    # ------------------------------------------------------------- LoRA
    def _adapter_key(self, name: str):
        """The 'artifact store': adapter weights derive from the NAME,
        so any tier can drop them and re-materialize byte-identically."""
        return jax.random.fold_in(
            self._adapter_base_key, zlib.crc32(name.encode()) & 0x7FFFFFFF)

    def _touch_adapter(self, name: str) -> None:
        self._lru_tick += 1
        self._adapter_lru[name] = self._lru_tick

    def register_adapter(self, name: str, weights: dict = None,
                         pinned=()) -> int:
        """Dynamic high-density LoRA registration (paper §3.2.1).

        When the HBM bank is full, the least-recently-used resident
        adapter not in ``pinned`` (adapters of in-flight batches) is
        evicted into the bounded host tier.  Weights come from, in
        order: the caller, the host tier, the artifact store
        (:meth:`_adapter_key`).  The wall time of a non-resident load —
        the cold-load stall — accumulates in ``adapter_load_s``."""
        if name in self._adapter_ids:
            self._touch_adapter(name)
            return self._adapter_ids[name]
        t0 = time.perf_counter()
        if not self._free_adapter_slots:
            victim = next(
                (n for n in sorted(self._adapter_ids,
                                   key=lambda a: self._adapter_lru.get(a, 0))
                 if n not in pinned), None)
            if victim is None:
                raise RuntimeError(
                    "adapter slots exhausted and every resident adapter "
                    "is pinned by an in-flight batch")
            self.unregister_adapter(victim)
            self.adapter_evictions += 1
        idx = self._free_adapter_slots.pop(0)
        if weights is None:
            host = self._host_adapters.pop(name, None)
            if host is not None:
                weights = host
                self.adapter_host_hits += 1
            else:
                weights = PM.make_adapter(self.cfg, self.ecfg.lora_rank,
                                          self._adapter_key(name))
        self.lora = {k: self.lora[k].at[idx].set(weights[k])
                     for k in self.lora}
        jax.block_until_ready(self.lora)
        self._adapter_ids[name] = idx
        self._touch_adapter(name)
        self.adapter_loads += 1
        self.adapter_load_s += time.perf_counter() - t0
        return idx

    def unregister_adapter(self, name: str) -> None:
        idx = self._adapter_ids.pop(name, None)
        if idx is None:
            return
        if self._host_adapter_slots > 0:
            # LRU cascade: HBM victims fall into the bounded host tier;
            # host overflow drops to the artifact store (safe — weights
            # are name-keyed, so re-load is byte-identical)
            self._host_adapters[name] = {
                k: np.array(self.lora[k][idx]) for k in self.lora}
            while len(self._host_adapters) > self._host_adapter_slots:
                self._host_adapters.pop(next(iter(self._host_adapters)))
        self._adapter_lru.pop(name, None)
        self.lora = {k: self.lora[k].at[idx].set(0.0) for k in self.lora}
        self._free_adapter_slots.append(idx)

    @property
    def adapters(self) -> List[str]:
        return sorted(self._adapter_ids)

    @property
    def adapter_ids(self) -> Dict[str, int]:
        return self._adapter_ids

    def _aid(self, req: Request) -> int:
        if not req.lora_adapter:
            return 0
        idx = self._adapter_ids.get(req.lora_adapter)
        if idx is None:
            # loud: a non-resident adapter must queue at admission
            # (Scheduler.adapter_ready), never silently serve base
            raise RuntimeError(
                f"request {req.request_id} reached the data plane with "
                f"non-resident adapter {req.lora_adapter!r}")
        self._touch_adapter(req.lora_adapter)
        return idx

    # ---------------------------------------------------------- sampling
    def sample(self, logits, reqs, positions=None) -> np.ndarray:
        """``positions`` (absolute index of the token being produced,
        per row) switches to per-position keying (seed x position via
        :func:`row_keys`): the sample then doesn't depend on batch
        order or on how many positions one pass verifies — required
        for speculative verification to match step-by-step decoding."""
        b = logits.shape[0]
        temps = np.zeros(b, np.float32)
        tops = np.ones(b, np.float32)
        for i, r in enumerate(reqs[:b]):
            temps[i] = r.sampling.temperature
            tops[i] = r.sampling.top_p
        keys = None
        if positions is not None:
            seeds = np.zeros(b, np.int32)
            pos = np.zeros(b, np.int32)     # pad to the logits batch
            for i, r in enumerate(reqs[:b]):
                seeds[i] = r.sampling.seed
                pos[i] = positions[i]
            keys = row_keys(jnp.asarray(seeds), jnp.asarray(pos))
            sub = self._key     # unused by sample() when keys given
        else:
            self._key, sub = jax.random.split(self._key)
        return self.readback(sample(logits, sub, jnp.asarray(temps),
                                    top_k=0, top_p=jnp.asarray(tops),
                                    keys=keys))

    # ------------------------------------------------------- input prep
    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.ecfg.page_size)

    def _bt_width(self, pages_needed: int) -> int:
        """Bucketed block-table width: bounds the decode kernel's page
        grid by what the batch actually uses (multiples of 4 to limit
        recompiles) instead of the full ``max_pages_per_seq``."""
        cap = -(-max(pages_needed, 1) // 4) * 4
        return min(cap, self.ecfg.max_pages_per_seq)

    def _decode_inputs(self, reqs):
        self._sync_inflight()
        ecfg = self.ecfg
        nb = self._bt_width(max((self._pages_for(
            r.prompt_len + len(r.output_tokens)) for r in reqs),
            default=1))
        toks, pos = self._dec_toks, self._dec_pos
        active, aids = self._dec_active, self._dec_aids
        bts = self._dec_bts[:, :nb]
        toks[:] = 0
        pos[:] = 0
        bts[:] = ecfg.num_pages             # OOB scratch page
        active[:] = False
        aids[:] = 0
        for i, r in enumerate(reqs):
            toks[i] = r.output_tokens[-1]
            pos[i] = r.prompt_len + len(r.output_tokens) - 1
            n = min(len(r.page_ids), nb)
            bts[i, :n] = r.page_ids[:n]
            active[i] = True
            aids[i] = self._aid(r)
        return toks, pos, bts, active, aids

    def _prefill_inputs(self, works: List[PrefillWork], s: int):
        self._sync_inflight()
        ecfg = self.ecfg
        kk = ecfg.max_prefills
        if s == ecfg.chunk_size:
            pre_toks = self._pre_toks
        else:                               # unchunked: dynamic width
            pre_toks = np.zeros((kk, s), np.int32)
        pre_ctx, pre_chunk = self._pre_ctx, self._pre_chunk
        pre_aids = self._pre_aids
        nb_pre = self._bt_width(max((self._pages_for(w.start + w.chunk_len)
                                     for w in works), default=1))
        pre_bts = self._pre_bts[:, :nb_pre]
        pre_toks[:] = 0
        pre_ctx[:] = 0
        pre_chunk[:] = 0
        pre_aids[:] = 0
        pre_bts[:] = ecfg.num_pages
        for i, w in enumerate(works):
            p, c = w.req, w.chunk_len
            pre_toks[i, :c] = p.prompt_tokens[w.start:w.start + c]
            pre_ctx[i] = w.start
            pre_chunk[i] = c
            n = min(len(p.page_ids), nb_pre)
            pre_bts[i, :n] = p.page_ids[:n]
            pre_aids[i] = self._aid(p)
        return pre_toks, pre_ctx, pre_chunk, pre_aids, pre_bts

    # ---------------------------------------------------------- execute
    def run_mixed(self, out: ScheduleOutput) -> Tuple[jax.Array, jax.Array]:
        """One fused decode+prefill pass; returns (dec_logits, pre_logits)."""
        ecfg = self.ecfg
        pre_toks, pre_ctx, pre_chunk, pre_aids, pre_bts = \
            self._prefill_inputs(out.prefills, out.pad_len)
        toks, pos, bts, active, aids = self._decode_inputs(out.decode)
        dec_logits, pre_logits, self.pool = PM.mixed_step(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(bts), jnp.asarray(active), jnp.asarray(pre_toks),
            jnp.asarray(pre_bts), jnp.asarray(pre_ctx),
            jnp.asarray(pre_chunk), self.lora, jnp.asarray(aids),
            jnp.asarray(pre_aids), cfg=self.cfg,
            page_size=ecfg.page_size, impl=ecfg.impl)
        self._inflight = (dec_logits, pre_logits)
        return dec_logits, pre_logits

    def run_decode(self, reqs: List[Request]) -> jax.Array:
        ecfg = self.ecfg
        toks, pos, bts, active, aids = self._decode_inputs(reqs)
        logits, self.pool = PM.decode_batch(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(bts), jnp.asarray(active), self.lora,
            jnp.asarray(aids), cfg=self.cfg, page_size=ecfg.page_size,
            impl=ecfg.impl)
        self._inflight = logits
        return logits

    # -------------------------------------------------- speculative step
    def _spec_inputs(self, reqs: List[Request], spec: List[List[int]]):
        self._sync_inflight()
        ecfg = self.ecfg
        nb = self._bt_width(max((self._pages_for(
            r.prompt_len + len(r.output_tokens) + len(d))
            for r, d in zip(reqs, spec)), default=1))
        toks, ctx, slen = self._spec_toks, self._spec_ctx, self._spec_len
        aids = self._dec_aids
        bts = self._dec_bts[:, :nb]
        toks[:] = 0
        ctx[:] = 0
        slen[:] = 0                         # 0 marks an idle lane
        aids[:] = 0
        bts[:] = ecfg.num_pages             # OOB scratch page
        for i, (r, d) in enumerate(zip(reqs, spec)):
            toks[i, 0] = r.output_tokens[-1]
            toks[i, 1:1 + len(d)] = d
            ctx[i] = r.prompt_len + len(r.output_tokens) - 1
            slen[i] = 1 + len(d)
            n = min(len(r.page_ids), nb)
            bts[i, :n] = r.page_ids[:n]
            aids[i] = self._aid(r)
        return toks, ctx, slen, bts, aids

    def run_spec(self, out: ScheduleOutput
                 ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """One speculative verification pass.  Decode-only schedules
        take :func:`PM.spec_decode_step` (no idle prefill lanes — the
        fast path the 1.5x target depends on); schedules carrying live
        prefill chunks fuse them via :func:`PM.spec_mixed_step`.
        Returns (spec logits (B, SD, V), prefill logits (K, V) | None).
        """
        ecfg = self.ecfg
        live = [w for w in out.prefills if w.chunk_len > 0]
        if live:
            pre_toks, pre_ctx, pre_chunk, pre_aids, pre_bts = \
                self._prefill_inputs(out.prefills, out.pad_len)
            toks, ctx, slen, bts, aids = self._spec_inputs(
                out.decode, out.spec)
            spec_logits, pre_logits, self.pool = PM.spec_mixed_step(
                self.params, self.pool, jnp.asarray(toks),
                jnp.asarray(ctx), jnp.asarray(slen), jnp.asarray(bts),
                jnp.asarray(pre_toks), jnp.asarray(pre_bts),
                jnp.asarray(pre_ctx), jnp.asarray(pre_chunk), self.lora,
                jnp.asarray(aids), jnp.asarray(pre_aids), cfg=self.cfg,
                page_size=ecfg.page_size, impl=ecfg.impl)
            self._inflight = (spec_logits, pre_logits)
            return spec_logits, pre_logits
        toks, ctx, slen, bts, aids = self._spec_inputs(out.decode, out.spec)
        spec_logits, self.pool = PM.spec_decode_step(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(ctx),
            jnp.asarray(slen), jnp.asarray(bts), self.lora,
            jnp.asarray(aids), cfg=self.cfg, page_size=ecfg.page_size,
            impl=ecfg.impl)
        self._inflight = spec_logits
        return spec_logits, None

    def verify_drafts(self, spec_logits, reqs: List[Request],
                      spec: List[List[int]]) -> List[List[int]]:
        """Sample EVERY verification row with per-position keys and
        accept each row's longest draft prefix that matches the model's
        own samples.  Returns per-request emitted token lists (accepted
        prefix + the bonus/correction token) — byte-identical to what
        step-by-step decoding would have produced."""
        b, sd, v = spec_logits.shape
        temps = np.zeros(b * sd, np.float32)
        tops = np.ones(b * sd, np.float32)
        seeds = np.zeros(b * sd, np.int32)
        pos = np.zeros(b * sd, np.int32)
        for i, r in enumerate(reqs):
            temps[i * sd:(i + 1) * sd] = r.sampling.temperature
            tops[i * sd:(i + 1) * sd] = r.sampling.top_p
            seeds[i * sd:(i + 1) * sd] = r.sampling.seed
            base = r.prompt_len + len(r.output_tokens)
            pos[i * sd:(i + 1) * sd] = base + np.arange(sd)
        keys = row_keys(jnp.asarray(seeds), jnp.asarray(pos))
        sampled = self.readback(sample(
            spec_logits.reshape(b * sd, v), self._key,
            jnp.asarray(temps), top_k=0, top_p=jnp.asarray(tops),
            keys=keys)).reshape(b, sd)
        emitted = []
        for i, (r, d) in enumerate(zip(reqs, spec)):
            m = accept_length(d, sampled[i, :len(d) + 1])
            emitted.append([int(t) for t in sampled[i, :m + 1]])
        return emitted

    # ------------------------------------------------ async decode step
    def run_decode_async(self, reqs: List[Request],
                         prev: Optional[dict]) -> jax.Array:
        """Dispatch a decode step WITHOUT blocking on the previous one.

        Fresh input buffers (the persistent ones require a sync before
        refill), a device-side gather for any input token still in
        flight (``prev["tok_dev"]`` holds the previous async step's
        sampled tokens, not yet read back — the host only has PENDING
        placeholders for them), and on-device sampling with per-
        position keys so the step's output is itself a device array the
        NEXT step can consume without a sync.  Returns the sampled
        tokens (device)."""
        ecfg = self.ecfg
        b = ecfg.max_batch
        nb = self._bt_width(max((self._pages_for(
            r.prompt_len + len(r.output_tokens)) for r in reqs),
            default=1))
        toks = np.zeros(b, np.int32)
        pos = np.zeros(b, np.int32)
        bts = np.full((b, nb), ecfg.num_pages, np.int32)
        active = np.zeros(b, bool)
        aids = np.zeros(b, np.int32)
        temps = np.zeros(b, np.float32)
        tops = np.ones(b, np.float32)
        seeds = np.zeros(b, np.int32)
        rows: List[int] = []
        srcs: List[int] = []
        prev_rows = ({id(r): j for j, r in enumerate(prev["reqs"])}
                     if prev else {})
        for i, r in enumerate(reqs):
            if getattr(r, "_pending_toks", 0) and id(r) in prev_rows:
                rows.append(i)              # token still on device
                srcs.append(prev_rows[id(r)])
            else:
                toks[i] = r.output_tokens[-1]
            pos[i] = r.prompt_len + len(r.output_tokens) - 1
            n = min(len(r.page_ids), nb)
            bts[i, :n] = r.page_ids[:n]
            active[i] = True
            aids[i] = self._aid(r)
            temps[i] = r.sampling.temperature
            tops[i] = r.sampling.top_p
            seeds[i] = r.sampling.seed
        tok_in = jnp.asarray(toks)
        if rows:
            tok_in = tok_in.at[jnp.asarray(np.asarray(rows))].set(
                prev["tok_dev"][jnp.asarray(np.asarray(srcs))])
        logits, self.pool = PM.decode_batch(
            self.params, self.pool, tok_in, jnp.asarray(pos),
            jnp.asarray(bts), jnp.asarray(active), self.lora,
            jnp.asarray(aids), cfg=self.cfg, page_size=ecfg.page_size,
            impl=ecfg.impl)
        keys = row_keys(jnp.asarray(seeds), jnp.asarray(pos + 1))
        tok_dev = sample(logits, self._key, jnp.asarray(temps),
                         top_k=0, top_p=jnp.asarray(tops), keys=keys)
        self._inflight = tok_dev
        return tok_dev

    def run_prefill(self, work: PrefillWork) -> jax.Array:
        """One (possibly chunked) prefill for ONE request (two-phase)."""
        self._sync_inflight()
        ecfg = self.ecfg
        req, s, c = work.req, work.pad_len, work.chunk_len
        if s == ecfg.chunk_size:
            toks = self._pre_toks[:1]
            toks[:] = 0
        else:
            toks = np.zeros((1, s), np.int32)
        toks[0, :c] = req.prompt_tokens[work.start:work.start + c]
        nb = self._bt_width(self._pages_for(work.start + c))
        bt = self._pre_bts[:1, :nb]
        bt[:] = ecfg.num_pages              # OOB scratch page
        n = min(len(req.page_ids), nb)
        bt[0, :n] = req.page_ids[:n]
        logits, self.pool = PM.prefill_step(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(bt),
            jnp.int32(work.start), jnp.int32(c),
            self.lora, jnp.asarray([self._aid(req)], jnp.int32),
            cfg=self.cfg, page_size=ecfg.page_size, impl=ecfg.impl)
        self._inflight = logits
        return logits

    # ------------------------------------------------------- pool payloads
    @property
    def page_bytes(self) -> int:
        """Raw (k + v) payload bytes of one page — what the host tier's
        capacity accounting and the transfer counters charge."""
        k = self.pool.k
        return int(2 * k[:, 0].size * k.dtype.itemsize)

    def page_payload(self, pid: int):
        """Materialize one page's (k, v) arrays for a pool publish or a
        host-tier offload — the device→host copy the Scheduler's
        contains() gate avoids for blocks the pool already knows.
        ``np.array`` forces a real copy: host-tier entries outlive this
        step, and on CPU backends a zero-copy view could alias a
        donated buffer the next jitted call overwrites in place."""
        return (np.array(self.pool.k[:, pid]),
                np.array(self.pool.v[:, pid]))

    def write_remote_page(self, pid: int, k_page, v_page) -> None:
        """Install a page payload fetched from the distributed pool."""
        self.pool = PM.PagePool(
            self.pool.k.at[:, pid].set(k_page),
            self.pool.v.at[:, pid].set(v_page))
