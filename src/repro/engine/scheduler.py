"""Unified scheduler core shared by every engine in the system.

One scheduling semantics, written once: the real JAX ``InferenceEngine``
(repro.engine.engine), the analytic cluster-simulator ``SimEngine``
(repro.core.sim.sim_engine) and the ``SlotEngine`` all drive the classes
in this module instead of carrying their own drifting copies of
admission / budget / finish logic.

Layers
------
``SchedulerCore``
    The engine-shape-agnostic request bookkeeping: arrival queue,
    admission accounting (queue-time EWMA, admitted counter), the
    finish/stop predicate (max_new_tokens + stop_token), latency EWMA
    and the sliding token-throughput window.  The slot engine builds
    directly on this.

``Scheduler``
    The paged-KV scheduler: cache-aware admission (prefix match, pool
    fetch, deferral of a prompt whose leading block hash matches an
    in-flight prefill), per-step token budget with chunk trimming,
    preemption, decode bookkeeping, and P/D roles.  ``schedule(now)``
    is *declarative*: it returns a :class:`ScheduleOutput` naming the
    decode rows and budget-trimmed prefill chunks for this iteration
    and mutates nothing but admission state — the host's "runner"
    (jitted forward passes for the real engine, the roofline cost model
    for the simulator) executes it and reports back through the
    ``note_* / finish_* / on_decode_batch`` bookkeeping calls.

Roles (paper §3.2.5, DistServe-style P/D disaggregation)
--------------------------------------------------------
``role="mixed"`` is a normal colocated engine.  ``role="prefill"``
prefills, publishes KV through the distributed pool, then hands the
request off (``handoff_prefill`` releases the pages and re-queues the
request for the decode side; the host delivers it via its ``handoff``
callable — synchronously for real engines, after the pool's metadata
lag for the simulator).  ``role="decode"`` engines admit handed-off
requests whose KV they pull from the pool by block hash, so they only
recompute the tail block before decoding.

SLO-aware scheduling (paper §"SLO-driven GPU optimizer")
---------------------------------------------------------
Requests carry a ``priority_class`` (interactive | standard | batch);
:data:`DEFAULT_SLO_CLASSES` maps each class to TTFT/ITL targets and a
preemption rank.  With ``SchedulerConfig.slo_aware=True`` admission is
deadline-aware — strict priority rank across classes, earliest TTFT
slack first within a class — and an interactive prefill about to miss
its TTFT target (slack below ``slo_preempt_headroom`` of the target)
may preempt one strictly-lower-priority decode (rate-limited by
``slo_preempt_cooldown_s``).  Per-class TTFT/ITL attainment is
accounted in :class:`SchedulerCore` regardless of mode, so the gateway
(``slo-aware`` routing policy) and the autoscaler (``slo_attainment``
metric) can consume it even from FIFO engines.  Because all of this
lives in the one shared Scheduler, the same SLO policy drives the real
JAX engine, the simulator and the P/D role split with no duplication.

Tiered KV placement (paper §3.2.5 + "KV cache offloading" line of work)
-----------------------------------------------------------------------
With a :class:`~repro.core.kvcache.tiers.HostPagePool` attached, KV
pages have three homes checked in order by the admission page walk:
device HBM (``PageAllocator`` prefix cache), host DRAM (the bounded
tier this scheduler feeds via the allocator's eviction cascade and via
swap-based preemption), and the cluster ``DistributedKVPool``.
``preempt`` then *swaps* instead of discarding: the victim's pages —
prompt and generated — are offloaded under per-request swap keys, the
request parks in ``waiting`` as ``SWAPPED``, and ``_try_resume`` swaps
the pages back in to continue decoding from ``prefill_done_tokens``
(byte-identical to the never-preempted run) rather than re-prefilling
from token 0.  Pool handoff transfers are chunked into page groups
(``handoff_chunk_pages``): only the head group must land before the
tail recompute starts; later groups are marked ``stream=True`` for the
host to overlap (the simulator prices them against the step's compute,
the real engine installs them synchronously).

All bookkeeping methods take an explicit ``now`` so the same code runs
under wall clock (real engines) and forward-dated discrete-event time
(the simulator).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.kvcache.pool import KVPoolError
from repro.core.kvcache.tiers import payload_nbytes
from repro.engine.page_table import PageAllocator, chunk_hashes
from repro.engine.request import Request, RequestState
from repro.engine.speculative import DraftController

# sentinel: continuation admission found the checkpoint unrecoverable
# (distinct from None = out of memory, retry later)
_RECOMPUTE = object()

# async overlapped loop: placeholder appended for a dispatched-but-not-
# yet-read-back decode token (never a real token id; patched at readback)
PENDING_TOKEN = -1


def window_throughput(events, now: float, horizon: float = 10.0) -> float:
    """tokens/sec over the span actually observed within ``horizon``.

    ``events`` is a list of (timestamp, token_count).  A fixed-horizon
    divisor deflated early/low-traffic readings (skewing gateway routing
    and autoscaler signals); the 1 s floor keeps a single post-idle
    burst from reading as a huge rate spike when polled within the same
    instant.  Shared by InferenceEngine, SlotEngine and SimEngine so
    their tokens_per_sec semantics cannot drift apart.
    """
    window = [(t, c) for t, c in events if t >= now - horizon]
    if not window:
        return 0.0
    span = max(now - window[0][0], 1.0)
    return sum(c for _, c in window) / span


@dataclass(frozen=True)
class ClassSLO:
    """Per-priority-class service-level objective.

    ``ttft_s``/``itl_s`` are the attainment targets (a request attains
    its TTFT SLO when ``req.ttft <= ttft_s``; each inter-token gap is
    checked against ``itl_s``).  ``rank`` orders preemption: lower rank
    preempts strictly higher rank, never the reverse.
    """
    ttft_s: float
    itl_s: float
    rank: int


DEFAULT_SLO_CLASSES: Dict[str, ClassSLO] = {
    "interactive": ClassSLO(ttft_s=0.5, itl_s=0.05, rank=0),
    "standard": ClassSLO(ttft_s=2.0, itl_s=0.2, rank=1),
    "batch": ClassSLO(ttft_s=30.0, itl_s=1.0, rank=2),
}

# role taxonomy shared by the scheduler, the gateway's pool routing and
# the RolePoolManager: frontend roles admit NEW requests, decoder roles
# accept prefill handoffs ('mixed' does both)
FRONTEND_ROLES = ("prefill", "mixed")
DECODER_ROLES = ("decode", "mixed")


def default_slo_classes() -> Dict[str, ClassSLO]:
    return dict(DEFAULT_SLO_CLASSES)


@dataclass
class EngineMetrics:
    """Snapshot consumed by gateway routing + autoscaler."""
    num_running: int = 0
    num_waiting: int = 0
    kv_utilization: float = 0.0
    tokens_per_sec: float = 0.0
    avg_latency: float = 0.0        # EWMA of per-request total latency
    avg_queue_time: float = 0.0
    admitted_requests: int = 0
    finished_requests: int = 0
    preemptions: int = 0
    prefix_hit_tokens: int = 0
    remote_hit_tokens: int = 0
    loaded_adapters: tuple = ()
    # multi-LoRA serving: requests that hit the admission gate with a
    # non-resident adapter (lora_miss), requests shed after waiting out
    # ``lora_queue_timeout_s`` (lora_shed), and the adapter-tier churn
    # the engine paid — non-resident loads, seconds stalled on them,
    # HBM-bank evictions and host-tier hits (filled by the engine, like
    # device_wait_s below)
    lora_miss: int = 0
    lora_shed: int = 0
    lora_cold_loads: int = 0
    lora_cold_load_s: float = 0.0
    lora_evictions: int = 0
    lora_host_hits: int = 0
    # SLO attainment: recent-window TTFT attainment fraction (1.0 when
    # nothing finished yet) + cumulative per-class rows of
    # (class, ttft_attainment, itl_attainment, finished)
    slo_attainment: float = 1.0
    slo_by_class: tuple = ()
    # recent-window ITL attainment (mean per-request fraction of
    # inter-token gaps within the class target) — the decode-pool
    # sizing signal for the role-pool rebalancer
    slo_itl_attainment: float = 1.0
    # tiered-KV transfer accounting (host tier + pool wire): tier
    # pressure signals for the rebalancer and dashboards
    host_hit_tokens: int = 0        # admission tokens served from host tier
    ssd_hit_tokens: int = 0         # tokens served from the SSD tier
    # host-shared SSD pool: tokens served from SSD pages some OTHER
    # engine on the host wrote (the cross-engine dedupe payoff), and
    # write-behind puts dropped on a full dirty buffer (satellite:
    # silent drops must be first-class)
    ssd_cross_hit_tokens: int = 0
    ssd_dropped_puts: int = 0
    # predictive promotion: host hits on pages the promoter prefetched
    # from SSD ahead of the predicted turn, vs promoted pages evicted
    # unused (wasted prefetch bandwidth)
    promote_hits: int = 0
    promote_wasted: int = 0
    kv_bytes_offloaded: int = 0     # device -> host (cascade + swap-out)
    kv_bytes_fetched: int = 0       # host/pool -> device (walk + swap-in)
    swap_out: int = 0               # preemptions that swapped (not dropped)
    swap_in: int = 0                # swapped requests resumed in place
    # failure handling: pool fetch/publish attempts lost to a partition
    # (after retries), generated tokens discarded by drop-and-recompute
    # resets, and recovery-log pages published by the checkpoint policy
    kv_fetch_failures: int = 0
    wasted_tokens: int = 0
    ckpt_pages: int = 0
    # speculative decoding: drafted vs model-confirmed verify tokens,
    # steps that carried drafts, and the acceptance fraction — what the
    # sim's expected-speedup pricing and the adaptive backoff key on
    spec_drafted_tokens: int = 0
    spec_accepted_tokens: int = 0
    spec_steps: int = 0
    spec_acceptance: float = 0.0
    # host/device overlap observability (filled by the real engine):
    # seconds blocked on device readback, and the fraction of step wall
    # time spent on host-side work — the gap the async loop hides
    device_wait_s: float = 0.0
    host_overhead_frac: float = 0.0


@dataclass
class SchedulerConfig:
    page_size: int = 16
    max_batch: int = 8              # decode slots / admission capacity
    max_pages_per_seq: int = 0      # 0 => unlimited (the simulator)
    chunk_size: int = 64            # chunked-prefill chunk
    chunked_prefill: bool = True
    prefix_caching: bool = True
    # -- fused mixed-batch scheduler --
    mixed_batching: bool = True     # False => legacy two-phase scheduler
    max_prefills: int = 2           # concurrent PREFILLING requests
    token_budget: int = 0           # 0 => max_batch + max_prefills*chunk
    # False => finish on max_new_tokens only (the simulator's decode
    # tokens are synthetic zeros, which a real EOS id could match)
    honor_stop_token: bool = True
    # -- P/D disaggregation --
    role: str = "mixed"             # mixed | prefill | decode
    # -- multi-LoRA serving --
    # a request whose adapter is not resident (``adapter_ready`` hook)
    # queues until the control plane loads it; after this many seconds
    # in the queue it is shed (FAILED) instead of silently serving
    # base-model outputs.  0 => queue forever.
    lora_queue_timeout_s: float = 30.0
    # -- tiered KV cache / streaming handoff --
    # pool-handoff transfers are split into groups of this many pages;
    # only the head group blocks the tail recompute, later groups are
    # marked streamable for the host to overlap.  0 => eager whole-
    # payload transfer (the pre-tier behavior).
    handoff_chunk_pages: int = 4
    # preemption offloads the victim's pages to the host tier (when one
    # is attached) and resumes from where it stopped; False restores
    # drop-and-recompute preemption even with a host tier present
    swap_preemption: bool = True
    # -- crash-recovery checkpoint policy (the recovery log) --
    # every ``ckpt_interval_tokens`` new sequence tokens, a running
    # decode's full KV blocks are published to the distributed pool
    # under their content hashes, so ``crash_takeover`` can resume the
    # request on another engine from the last checkpointed page.
    # 0 disables checkpointing (crash recovery degrades to
    # drop-and-recompute).  ``ckpt_budget_bytes`` bounds the publish
    # bytes per scheduler pass (0 => unbounded).
    ckpt_interval_tokens: int = 0
    ckpt_budget_bytes: int = 0
    # -- SLO-aware scheduling --
    # False => FIFO admission (legacy).  True => deadline-aware
    # admission: strict priority rank across classes, earliest TTFT
    # slack first within a class, priority preemption of lower-rank
    # decodes when a higher-rank prefill is about to miss TTFT.
    slo_aware: bool = False
    slo_classes: Dict[str, ClassSLO] = field(
        default_factory=default_slo_classes)
    # preempt when remaining slack < headroom * ttft target (0 still
    # preempts once the deadline has actually passed)
    slo_preempt_headroom: float = 0.25
    # minimum spacing between preemptions: bounds the decode work a
    # burst of urgent prefills can throw away
    slo_preempt_cooldown_s: float = 1.0
    # -- speculative n-gram decoding (mixed_batching only) --
    # max draft tokens proposed per decode row (0 disables).  Drafts
    # spend step budget LAST — after decode tokens and prefill chunks —
    # so prefill pressure naturally shrinks/starves them, and the
    # per-request acceptance EWMA (DraftController) backs draft length
    # off to 1 then 0 on low-acceptance outputs, re-probing every
    # ``spec_probe_interval`` passes.
    spec_tokens: int = 0
    spec_ngram_max: int = 3
    spec_ngram_min: int = 1
    spec_probe_interval: int = 50

    @property
    def step_token_budget(self) -> int:
        """Per-step budget charged decode-first; it trims prefill chunks
        only — the decode batch itself is bounded by ``max_batch``, not
        the budget (a budget below ``max_batch`` + 1 cannot throttle
        decode, it just starves prefill down to its 1-token floor)."""
        return self.token_budget or (
            self.max_batch + self.max_prefills * self.chunk_size)


@dataclass
class PrefillWork:
    """One in-flight prefill's chunk for this step."""
    req: Request
    start: int          # prefill_done_tokens at schedule time
    chunk_len: int      # budget-trimmed valid tokens (0 = starved)
    pad_len: int        # padded chunk width the runner should build


@dataclass
class ScheduleOutput:
    """Declarative description of one scheduler iteration."""
    mode: str                                   # mixed|prefill|decode|idle
    decode: List[Request] = field(default_factory=list)
    prefills: List[PrefillWork] = field(default_factory=list)
    pad_len: int = 0                            # chunk width (mixed)
    # speculative drafts, parallel to ``decode`` (row i verifies
    # ``spec[i]`` draft tokens; [] = plain decode row).  Empty overall
    # when no row drafted — the runner then takes the non-spec path.
    spec: List[List[int]] = field(default_factory=list)


class SchedulerCore:
    """Request bookkeeping shared by every engine shape (paged or slot):
    arrival queue, admission/finish accounting, stop predicate, EWMAs
    and the token-throughput window."""

    SLO_WINDOW_S = 60.0      # recent-window for the scalar attainment

    def __init__(self, honor_stop_token: bool = True,
                 slo_classes: Optional[Dict[str, ClassSLO]] = None):
        self.honor_stop_token = honor_stop_token
        self.slo_classes = slo_classes or default_slo_classes()
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        # million-request runs: when set, finished requests stream into
        # this callable (e.g. a StreamingSummary observer) instead of
        # accumulating in ``finished`` — stats without holding every
        # Request object for the whole run
        self.finish_sink: Optional[Callable[[Request], None]] = None
        self._m = dict(admitted=0, finished=0, preemptions=0,
                       prefix_hit_tokens=0, remote_hit_tokens=0)
        self._lat_ewma = 0.0
        self._q_ewma = 0.0
        self._tok_events: List[tuple] = []
        # per-class cumulative SLO accounting + recent TTFT-attainment
        # events (for the autoscaler's windowed slo_attainment signal)
        self._slo_stats: Dict[str, dict] = {}
        self._slo_events: List[tuple] = []
        # recent per-request ITL-attainment fractions — TTFT misses
        # point at prefill capacity, ITL misses at decode capacity, so
        # the role-pool rebalancer needs both windowed separately
        self._itl_events: List[tuple] = []

    # ---------------------------------------------------------- queue
    def enqueue(self, req: Request, now: float) -> None:
        if req.arrival_time == 0.0:
            req.arrival_time = now
        self.waiting.append(req)

    def note_admitted(self, req: Request, now: float) -> None:
        req.schedule_time = now
        self._m["admitted"] += 1
        self._q_ewma = 0.9 * self._q_ewma + 0.1 * req.queue_time

    # ---------------------------------------------------------- finish
    def request_done(self, req: Request) -> bool:
        sp = req.sampling
        if len(req.output_tokens) >= sp.max_new_tokens:
            return True
        return (self.honor_stop_token and sp.stop_token is not None
                and bool(req.output_tokens)
                and req.output_tokens[-1] == sp.stop_token)

    def note_finished(self, req: Request, now: float) -> None:
        req.finish_time = now
        req.state = RequestState.FINISHED
        if self.finish_sink is not None:
            self.finish_sink(req)
        else:
            self.finished.append(req)
        self._m["finished"] += 1
        self._lat_ewma = (0.9 * self._lat_ewma + 0.1 * req.total_latency
                          if self._lat_ewma else req.total_latency)
        self._note_slo(req, now)

    # ---------------------------------------------------------- SLO
    def slo_class(self, req: Request) -> ClassSLO:
        """The request's SLO targets; unknown classes fall back to
        'standard' so a typo'd class cannot crash the scheduler."""
        cls = self.slo_classes.get(req.priority_class)
        if cls is None:
            cls = self.slo_classes.get("standard",
                                       DEFAULT_SLO_CLASSES["standard"])
        return cls

    def _note_slo(self, req: Request, now: float) -> None:
        cls = self.slo_class(req)
        rec = self._slo_stats.setdefault(
            req.priority_class,
            dict(finished=0, ttft_ok=0, itl_total=0, itl_ok=0))
        ttft_ok = req.ttft <= cls.ttft_s
        rec["finished"] += 1
        rec["ttft_ok"] += int(ttft_ok)
        gaps = req.itl
        rec["itl_total"] += len(gaps)
        itl_ok = sum(1 for g in gaps if g <= cls.itl_s)
        rec["itl_ok"] += itl_ok
        self._slo_events.append((now, req.priority_class,
                                 1.0 if ttft_ok else 0.0))
        if gaps:
            self._itl_events.append((now, req.priority_class,
                                     itl_ok / len(gaps)))
        cutoff = now - self.SLO_WINDOW_S
        while self._slo_events and self._slo_events[0][0] < cutoff:
            self._slo_events.pop(0)
        while self._itl_events and self._itl_events[0][0] < cutoff:
            self._itl_events.pop(0)

    def slo_attainment(self, now: float) -> float:
        """TTFT attainment over the recent window; falls back to the
        cumulative fraction after a drain, 1.0 before any finish."""
        window = [ok for t, _c, ok in self._slo_events
                  if t >= now - self.SLO_WINDOW_S]
        if window:
            return sum(window) / len(window)
        fin = sum(r["finished"] for r in self._slo_stats.values())
        if fin:
            return (sum(r["ttft_ok"] for r in self._slo_stats.values())
                    / fin)
        return 1.0

    def slo_itl_attainment(self, now: float) -> float:
        """ITL attainment over the recent window (mean per-request
        fraction of inter-token gaps within target); falls back to the
        cumulative fraction after a drain, 1.0 before any finish."""
        window = [ok for t, _c, ok in self._itl_events
                  if t >= now - self.SLO_WINDOW_S]
        if window:
            return sum(window) / len(window)
        tot = sum(r["itl_total"] for r in self._slo_stats.values())
        if tot:
            return (sum(r["itl_ok"] for r in self._slo_stats.values())
                    / tot)
        return 1.0

    def slo_class_stats(self, now: Optional[float] = None) -> tuple:
        """(class, ttft_attainment, itl_attainment, finished) rows.
        With ``now``, TTFT attainment is computed over the recent
        window (what the slo-aware router should react to — an engine
        must not be penalized forever for a warm-up burst of misses),
        falling back to cumulative once the window is empty; without
        ``now`` (and for ITL/finished) the figures are cumulative."""
        rows = []
        for name in sorted(self._slo_stats):
            r = self._slo_stats[name]
            ttft_att = r["ttft_ok"] / max(r["finished"], 1)
            if now is not None:
                window = [ok for t, c, ok in self._slo_events
                          if c == name and t >= now - self.SLO_WINDOW_S]
                if window:
                    ttft_att = sum(window) / len(window)
            rows.append((name, ttft_att,
                         (r["itl_ok"] / r["itl_total"]
                          if r["itl_total"] else 1.0),
                         r["finished"]))
        return tuple(rows)

    # ---------------------------------------------------------- accessors
    @property
    def avg_latency(self) -> float:
        return self._lat_ewma

    @property
    def avg_queue_time(self) -> float:
        return self._q_ewma

    @property
    def admitted_count(self) -> int:
        return self._m["admitted"]

    @property
    def finished_count(self) -> int:
        return self._m["finished"]

    # ---------------------------------------------------------- tokens
    def note_tokens(self, now: float, n: int) -> None:
        self._tok_events.append((now, n))
        cutoff = now - 10.0
        while self._tok_events and self._tok_events[0][0] < cutoff:
            self._tok_events.pop(0)

    def throughput(self, now: float) -> float:
        return window_throughput(self._tok_events, now)


class Scheduler(SchedulerCore):
    """The paged-KV scheduler: one admission/budget/role implementation
    for the real JAX engine AND the cluster simulator.

    The KV tiers are consulted by the scheduler itself
    (``host_pool``/``kv_pool``/``engine_id``): the page walk — which
    blocks to ask for, in which tier, where to stop, allocation and
    hash registration — lives here, once.  Only the payload handling
    differs per host, via ``install_page(page_id, payload, req, now, *,
    source="host"|"pool", stream=bool, nbytes=int)``: the real engine
    writes the fetched arrays into a device page (ignoring the cost
    hints), the simulator attributes a transfer-time cost from them.
    ``page_payload(page_id)`` is the reverse hook (offload/publish
    materialization).
    """

    ROLES = ("mixed", "prefill", "decode")
    # process-wide LoRA-miss counter across every Scheduler instance —
    # benchmarks/run.py prints the per-suite delta so a suite whose
    # requests queued (or shed) behind non-resident adapters says so
    # next to its results
    total_lora_miss: int = 0

    def __init__(self, scfg: SchedulerConfig, alloc: PageAllocator,
                 kv_pool=None, engine_id: str = "engine-0",
                 install_page: Optional[Callable] = None,
                 publish_page: Optional[Callable] = None,
                 host_pool=None, page_payload: Optional[Callable] = None,
                 page_bytes: int = 0,
                 adapter_ready: Optional[Callable[[str], bool]] = None,
                 ssd_pool=None):
        super().__init__(honor_stop_token=scfg.honor_stop_token,
                         slo_classes=scfg.slo_classes)
        if scfg.role not in self.ROLES:
            raise ValueError(f"unknown scheduler role {scfg.role!r}; "
                             f"expected one of {self.ROLES}")
        self.scfg = scfg
        self.alloc = alloc
        self.kv_pool = kv_pool
        self.engine_id = engine_id
        self.install_page = install_page
        self.publish_page = publish_page
        # tiered KV: optional host-DRAM page tier between the device
        # allocator and the distributed pool.  ``page_payload(pid)``
        # materializes a device page for offload (real engines copy
        # the arrays off-device, the simulator returns an opaque
        # record); ``page_bytes`` is the raw per-page payload size the
        # transfer counters and capacity checks use.
        self.host_pool = host_pool
        # SSD third tier below host DRAM: host-tier capacity evictions
        # cascade into it (write-behind), and the admission walk/swap
        # resume consult it after host, before the distributed pool
        self.ssd_pool = ssd_pool
        self.page_payload = page_payload
        self.page_bytes = int(page_bytes)
        self._m.update(host_hit_tokens=0, ssd_hit_tokens=0,
                       ssd_cross_hit_tokens=0,
                       promote_hits=0, promote_wasted=0,
                       kv_bytes_offloaded=0,
                       kv_bytes_fetched=0, swap_out=0, swap_in=0,
                       kv_fetch_failures=0, wasted_tokens=0, ckpt_pages=0,
                       crash_resumes=0, spec_drafted_tokens=0,
                       spec_accepted_tokens=0, spec_steps=0,
                       lora_miss=0, lora_shed=0)
        # predictive promotion state: the block hashes of each finished
        # session's full-page prefix (what the next turn's walk will
        # ask for), and the host-tier keys the promoter parked there
        # but no request has touched yet (key -> session_id)
        self._session_pages: "collections.OrderedDict[str, list]" = \
            collections.OrderedDict()
        self._promoted: Dict[str, str] = {}
        # multi-LoRA admission gate: ``adapter_ready(name) -> bool``
        # reports adapter residency on this engine's data plane.  When
        # set, a request naming a non-resident adapter queues (counted
        # as a lora_miss, once) until the control plane loads it —
        # never silently serving base-model outputs — and is shed after
        # ``scfg.lora_queue_timeout_s`` in the queue.
        self.adapter_ready = adapter_ready
        # speculative n-gram drafting: the controller owns the adaptive
        # per-request draft-length policy (acceptance EWMA + probe)
        self.drafter = DraftController(
            max_draft=scfg.spec_tokens,
            ngram_max=scfg.spec_ngram_max,
            ngram_min=scfg.spec_ngram_min,
            probe_interval=scfg.spec_probe_interval) \
            if scfg.spec_tokens > 0 else None
        # pool-failure circuit breaker: after a failed fetch/publish
        # burst the scheduler stops talking to the pool until the
        # backoff deadline (exponential, reset on the next success)
        self._pool_backoff_until = float("-inf")
        self._pool_backoff_s = 0.0
        if host_pool is not None and page_payload is not None:
            # eviction cascade: device-cache victims fall into the host
            # tier (same block hashes) instead of being dropped
            alloc.on_evict = self._cascade_evict
            if ssd_pool is not None:
                # ...and host-tier victims fall one more level, into
                # the SSD write-behind tier, instead of being dropped
                host_pool.on_evict = self._host_evict
        self.prefills: List[Request] = []      # concurrent PREFILLING
        self.running: List[Request] = []
        # P/D handoff: host-provided delivery callable (a decode engine's
        # submit, or a load-balancing shim over several)
        self.handoff: Optional[Callable[[Request], None]] = None
        self._pending_handoff = 0
        self._last_preempt = -1e18      # SLO preemption cooldown clock
        # live role migration: a draining engine admits nothing new and
        # finishes in-flight work so the control plane can flip its role
        self.draining = False

    # ---------------------------------------------------------- views
    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.prefills
                    or self._pending_handoff)

    @property
    def wants_handoff(self) -> bool:
        return self.scfg.role == "prefill" and self.handoff is not None

    @property
    def drained(self) -> bool:
        """True when nothing admitted remains: safe to flip roles."""
        return not (self.waiting or self.prefills or self.running
                    or self._pending_handoff)

    # ------------------------------------------------------- role migration
    def set_role(self, role: str) -> None:
        """Flip this engine's serving role (live P/D migration).  Only
        legal on a drained engine — admitted work holds pages and
        handoff obligations that belong to the old role, so the control
        plane drains first (``draining`` + ``takeover_waiting``)."""
        if role not in self.ROLES:
            raise ValueError(f"unknown scheduler role {role!r}; "
                             f"expected one of {self.ROLES}")
        if not self.drained:
            raise RuntimeError(
                f"set_role({role!r}): engine has queued or admitted "
                "work; drain first (takeover_waiting + finish in-"
                "flight)")
        self.scfg.role = role

    def takeover_waiting(self) -> List[Request]:
        """Drain support: hand the not-yet-admitted queue back to the
        control plane so it can re-route the requests to another pool
        member (in-flight prefills are NOT touched — they finish here
        and leave through the normal pool-handoff path).  SWAPPED
        requests are re-routable too, but their parked KV lives in
        THIS engine's host tier — drop it and reset them to recompute
        on whichever member picks them up."""
        reqs, self.waiting = list(self.waiting), []
        for r in reqs:
            if r.state is RequestState.SWAPPED:
                self._drop_swap(r)
                self._reset_recompute(r)
        return reqs

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.scfg.page_size)

    def _first_hash(self, req: Request) -> Optional[str]:
        hs = chunk_hashes(req.prompt_tokens[:self.scfg.page_size],
                          self.scfg.page_size,
                          req.lora_adapter or "")
        return hs[0] if hs else None

    # ------------------------------------------------------- SLO ordering
    def slack(self, req: Request, now: float) -> float:
        """Seconds of TTFT headroom left (negative = deadline missed)."""
        return self.slo_class(req).ttft_s - (now - req.arrival_time)

    def _admission_key(self, now: float):
        """Deadline-aware admission order: strict priority rank across
        classes (livelock-free — a preempted batch request can never
        leapfrog a waiting interactive one), earliest TTFT slack first
        within a class, then arrival order."""
        return lambda r: (self.slo_class(r).rank, self.slack(r, now),
                          r.arrival_time, r.request_id)

    # ------------------------------------------------------- admission
    def try_admit(self, now: float) -> Optional[Request]:
        scfg = self.scfg
        if self.draining:
            return None     # migrating out: nothing new is admitted
        if not self.waiting or (len(self.running) + len(self.prefills)
                                >= scfg.max_batch):
            return None
        inflight_hashes = set()
        if scfg.prefix_caching and self.prefills:
            inflight_hashes = {self._first_hash(p) for p in self.prefills}
            inflight_hashes.discard(None)
        candidates = list(self.waiting)
        if scfg.slo_aware:
            candidates.sort(key=self._admission_key(now))
        req = None
        for cand in candidates:
            if cand.state is RequestState.SWAPPED:
                continue    # resumes through _try_resume, not admission
            if (self.adapter_ready is not None and cand.lora_adapter
                    and not self.adapter_ready(cand.lora_adapter)):
                # loud LoRA miss: the adapter is not resident, so this
                # request must wait for the control plane to load it
                # (only this request — later waiters still get the
                # slot), or be shed once it has waited out the timeout
                if not getattr(cand, "_lora_missed", False):
                    cand._lora_missed = True
                    self._m["lora_miss"] += 1
                    Scheduler.total_lora_miss += 1
                if (scfg.lora_queue_timeout_s > 0
                        and now - cand.arrival_time
                        > scfg.lora_queue_timeout_s):
                    cand.state = RequestState.FAILED
                    self.waiting.remove(cand)
                    self._m["lora_shed"] += 1
                continue
            total = cand.prompt_len + cand.sampling.max_new_tokens
            if (scfg.max_pages_per_seq
                    and self.pages_for(total) > scfg.max_pages_per_seq):
                cand.state = RequestState.FAILED
                self.waiting.remove(cand)
                continue
            if (inflight_hashes
                    and cand.prompt_len > scfg.page_size
                    and self._first_hash(cand) in inflight_hashes
                    and self.alloc.match_len(
                        cand.prompt_tokens,
                        cand.lora_adapter or "") == 0):
                # cache-aware admission: a prompt sharing its leading
                # block with an in-flight prefill waits for those pages
                # to register so it can reuse them instead of
                # recomputing the prefix — but only THAT request waits
                # (later waiters with distinct prefixes still get the
                # slot), and only when the wait can pay off: not when a
                # registered prefix already matches, nor when the prompt
                # is too short for match_prefix to ever reuse the block.
                continue
            req = cand
            break
        if req is None:
            return None
        if getattr(req, "_resume_decode", False):
            # crash-rewound decode victim: resume from the recovery log
            if self.wants_handoff:
                # a prefill-role engine can't host the decode; degrade
                # to a plain prefill+handoff of the original prompt
                req._resume_decode = False
                self._reset_recompute(req)
            else:
                got = self._admit_continuation(req, now)
                if got is not _RECOMPUTE:
                    return got      # admitted, or out of memory (None)
                # the pool no longer covers the checkpoint (partition
                # or eviction): degrade to full recompute below
                req._resume_decode = False
                self._reset_recompute(req)
        # a handoff-bound prefill engine never decodes: reserving pages
        # for the decode tokens would only shrink its prefill capacity
        # (the decode side allocates them at re-admission)
        total = req.prompt_len + (
            0 if self.wants_handoff else req.sampling.max_new_tokens)
        matched_pages: List[int] = []
        matched_tokens = 0
        if scfg.prefix_caching:
            matched_pages, matched_tokens = self.alloc.match_prefix(
                req.prompt_tokens, now, req.lora_adapter or "")
        local_tokens = matched_tokens
        # the lower tiers work even when engine-local prefix caching is
        # off (the paper's "KV cache + Default" rows): cross-engine
        # reuse is the pool's, not the engine's, feature
        fetched: List[tuple] = []
        if self.kv_pool is not None or self.host_pool is not None:
            rp, rt, fetched = self._pool_walk(req, matched_tokens, now)
            matched_pages += rp
            matched_tokens += rt
        need = self.pages_for(total) - len(matched_pages)
        fresh = self.alloc.allocate(need, now)
        if fresh is None:
            if scfg.prefix_caching and fetched:
                # keep the paid-for transfers: install + register the
                # fetched pages, then release them into the evictable
                # cache so the retry hits them locally via match_prefix
                # instead of re-fetching from the pool every step
                self._apply_fetched(fetched, req, now)
            self.alloc.release(matched_pages, now)
            return None     # no memory — stay queued
        # admission succeeded: only now install the fetched payloads
        # and count the remote hits (a retry after memory pressure must
        # not double-count them)
        self._apply_fetched(fetched, req, now)
        self.waiting.remove(req)
        req.page_ids = matched_pages + fresh
        req.cached_prefix_tokens = matched_tokens
        req.prefill_done_tokens = matched_tokens
        req.state = RequestState.PREFILLING
        self.note_admitted(req, now)
        self._m["prefix_hit_tokens"] += local_tokens
        return req

    def _pool_walk(self, req: Request, have_tokens: int, now: float
                   ) -> Tuple[List[int], int, List[tuple]]:
        """Extend a local prefix hit with pages from the lower tiers:
        walk the prompt's block hashes past the locally covered prefix,
        checking host DRAM, then the SSD tier, then the distributed
        pool (device -> host -> SSD -> distributed is the admission
        order) and allocating a local page per hit.  The tail block is
        never fetched (prefill must produce at least one new token),
        and the walk stops at the first miss in EVERY tier.

        Payload installation and hash registration are DEFERRED — the
        (page, hash, payload, source) tuples are returned for the
        caller to apply only once admission succeeds.  (Hash
        registration with local prefix caching off would also let a
        re-fetch of the same hash clobber hash_index while the stale
        page's eviction later deletes the live entry, so it is
        additionally gated on ``prefix_caching``.)"""
        ps = self.scfg.page_size
        hashes = chunk_hashes(req.prompt_tokens, ps,
                              req.lora_adapter or "")
        pages, tokens, fetched = [], 0, []
        for i in range(have_tokens // ps, len(hashes)):
            if (i + 1) * ps >= req.prompt_len:
                break
            payload, source, nbytes = self._tier_fetch(hashes[i], now)
            if payload is None:
                break
            pids = self.alloc.allocate(1, now)
            if not pids:
                break
            nbytes = payload_nbytes(payload, nbytes)
            fetched.append((pids[0], hashes[i], payload, source, nbytes))
            pages.append(pids[0])
            tokens += ps
        return pages, tokens, fetched

    def _tier_fetch(self, block_hash: str, now: float) -> Tuple:
        """One block's tier walk below the device: host DRAM, then the
        SSD write-behind tier, then the distributed pool.  Returns
        ``(payload, source, nbytes)`` with ``payload=None`` on a miss
        in every tier."""
        payload, source, nbytes = None, "host", self.page_bytes
        if self.host_pool is not None:
            payload = self.host_pool.get(block_hash, now)
        if payload is None and self.ssd_pool is not None:
            payload = self.ssd_pool.get(block_hash, now)
            if payload is not None:
                # a SharedSSDView flags hits on pages another engine on
                # this host wrote; "ssd-cross" is normalized back to
                # "ssd" before it reaches install_page
                source = "ssd-cross" if getattr(
                    self.ssd_pool, "last_get_cross", False) else "ssd"
        if payload is None and self.kv_pool is not None:
            payload = self._pool_fetch(block_hash, now)
            # stored wire size, NOT the raw page: int8-compressed
            # payloads move (and are charged as) fewer bytes
            nbytes = (self.kv_pool.size_of(block_hash)
                      or self.page_bytes)
            source = "pool"
        return payload, source, nbytes

    def _admit_continuation(self, req: Request, now: float):
        """Admit a crash-rewound decode victim by restoring its
        checkpointed KV and rejoining the decode batch directly,
        swap-in style.  EVERY covered page is fetched — including the
        final one, whose KV was decode-computed on the dead engine:
        re-prefilling it would subtly change the numerics and break
        byte-identical greedy resume.  Returns the request on success,
        ``None`` when out of memory (stay queued and retry), or the
        ``_RECOMPUTE`` sentinel when the pool no longer covers the
        checkpoint (caller degrades to full recompute)."""
        ps = self.scfg.page_size
        seq = list(req.prompt_tokens) + [int(t) for t in
                                         req.output_tokens]
        npages = len(seq) // ps
        if npages == 0 or npages * ps != len(seq):
            return _RECOMPUTE       # rewind always leaves page-aligned
        hashes = chunk_hashes(seq, ps, req.lora_adapter or "")
        fetched: List[tuple] = []
        pages: List[int] = []
        missing = False
        for i in range(npages):
            payload, source, nbytes = self._tier_fetch(hashes[i], now)
            if payload is None:
                missing = True
                break
            pids = self.alloc.allocate(1, now)
            if not pids:
                break               # no memory — stay queued
            fetched.append((pids[0], hashes[i], payload, source,
                            payload_nbytes(payload, nbytes)))
            pages.append(pids[0])
        if len(pages) < npages:
            self.alloc.release(pages, now)
            return _RECOMPUTE if missing else None
        total = req.prompt_len + req.sampling.max_new_tokens
        fresh = self.alloc.allocate(
            max(self.pages_for(total) - npages, 0), now)
        if fresh is None:
            self.alloc.release(pages, now)
            return None             # no memory — stay queued
        self._apply_fetched(fetched, req, now)
        self.waiting.remove(req)
        req.page_ids = pages + fresh
        req.cached_prefix_tokens = len(seq)
        req.prefill_done_tokens = req.prompt_len
        req._resume_decode = False  # type: ignore[attr-defined]
        req.state = RequestState.RUNNING
        self.running.append(req)
        self.note_admitted(req, now)
        self._m["crash_resumes"] += 1
        # a victim rewound onto its very last token is already done
        self.maybe_finish(req, now)
        return req

    # ------------------------------------------------ pool fault isolation
    POOL_RETRIES = 2            # in-line attempts before giving up
    POOL_BACKOFF_S = 0.5        # first backoff window after a failure
    POOL_BACKOFF_MAX_S = 8.0

    def _pool_fetch(self, block_hash: str, now: float):
        """``kv_pool.fetch`` behind a bounded retry + circuit breaker.
        A partitioned pool raises :class:`KVPoolError`; the walk must
        degrade to recompute, never crash the scheduler.  Failures
        open an exponential backoff window during which the pool is
        not consulted at all (every admission would otherwise pay the
        retry cost while the partition lasts)."""
        if now < self._pool_backoff_until:
            return None
        for _ in range(self.POOL_RETRIES):
            try:
                payload = self.kv_pool.fetch(block_hash, self.engine_id,
                                             now)
                self._pool_backoff_s = 0.0
                return payload
            except KVPoolError:
                continue
        self._note_pool_failure(now)
        return None

    def _pool_publish(self, pid: int, block_hash: str, req: Request,
                      now: float) -> bool:
        """``publish_page`` behind the same circuit breaker (a publish
        into a partitioned pool raises too).  Returns False when the
        publish did not happen."""
        if now < self._pool_backoff_until:
            return False
        try:
            self.publish_page(pid, block_hash, req, now)
            self._pool_backoff_s = 0.0
            return True
        except KVPoolError:
            self._note_pool_failure(now)
            return False

    def _note_pool_failure(self, now: float) -> None:
        self._m["kv_fetch_failures"] += 1
        self._pool_backoff_s = min(
            max(self._pool_backoff_s * 2, self.POOL_BACKOFF_S),
            self.POOL_BACKOFF_MAX_S)
        self._pool_backoff_until = now + self._pool_backoff_s

    def _apply_fetched(self, fetched: List[tuple], req: Request,
                       now: float) -> None:
        """Install the walk's deferred payloads, register their hashes
        (when locally cacheable) and count the per-tier hits.  The
        transfer is chunked into ``handoff_chunk_pages`` page groups:
        pages past the head group are handed to ``install_page`` with
        ``stream=True`` — the host may overlap them with the tail
        recompute (the simulator prices exactly that overlap)."""
        cp = self.scfg.handoff_chunk_pages
        ps = self.scfg.page_size
        for n, (pid, h, payload, source, nbytes) in enumerate(fetched):
            cross = source == "ssd-cross"
            src = "ssd" if cross else source
            if self.install_page is not None:
                self.install_page(pid, payload, req, now, source=src,
                                  stream=bool(cp) and n >= cp,
                                  nbytes=nbytes)
            if self.scfg.prefix_caching:
                self.alloc.register_hash(pid, h)
            if src == "pool":
                self._m["remote_hit_tokens"] += ps
            elif src == "ssd":
                self._m["ssd_hit_tokens"] += ps
                if cross:
                    self._m["ssd_cross_hit_tokens"] += ps
            else:
                self._m["host_hit_tokens"] += ps
                if self._promoted.pop(h, None) is not None:
                    # the promoter's prefetch paid off: this page was
                    # already in host DRAM when the turn landed
                    self._m["promote_hits"] += 1
            self._m["kv_bytes_fetched"] += nbytes

    def _cascade_evict(self, pid: int, block_hash: str,
                       now: float) -> None:
        """PageAllocator eviction hook: offload the victim page into
        the host tier (content-addressed by the same block hash)
        instead of dropping it."""
        if self.host_pool.contains(block_hash):
            return
        if self.host_pool.put(block_hash, self.page_payload(pid),
                              self.page_bytes, now):
            self._m["kv_bytes_offloaded"] += self.page_bytes

    def _host_evict(self, key: str, payload, nbytes: int,
                    now: float) -> None:
        """HostPagePool eviction hook: a host-tier victim (cache page
        OR parked swap entry) falls into the SSD write-behind tier
        instead of dropping — idle-session prefixes survive host
        pressure and resume from SSD."""
        if self._promoted.pop(key, None) is not None:
            # a promoted page evicted before any request touched it:
            # the prefetch spent SSD+DRAM bandwidth for nothing
            self._m["promote_wasted"] += 1
        # put unconditionally: a resident key is a cheap dup (refreshes
        # LRU, no rewrite) and on a host-shared pool it is exactly the
        # write another engine's copy absorbed — the dedupe metric
        self.ssd_pool.put(key, payload, nbytes, now)

    # ------------------------------------------------------- schedule
    def schedule(self, now: float) -> ScheduleOutput:
        """One scheduler iteration, declaratively.

        Mixed batching (default): admit up to ``max_prefills`` requests
        into PREFILLING, then emit ONE fused pass carrying every decode
        token plus a budget-trimmed chunk per in-flight prefill.
        Legacy (``mixed_batching=False``): one prefill at a time, decode
        only when no prefill is in flight.
        """
        scfg = self.scfg
        self._try_resume(now)   # swapped victims outrank new admissions
        self._maybe_checkpoint(now)
        if not scfg.mixed_batching:
            return self._schedule_two_phase(now)
        self._admit_prefills(now)
        if scfg.slo_aware and self.waiting and self._slo_preempt(now):
            self._admit_prefills(now)   # the freed slot admits the
            # urgent request in the same iteration, not the next one
        if not self.prefills:
            if not self.running:
                return ScheduleOutput(mode="idle")
            dec = self.running[:scfg.max_batch]
            spec = self._assign_drafts(
                dec, scfg.step_token_budget - len(dec))
            return ScheduleOutput(mode="decode", decode=dec, spec=spec)
        dec = self.running[:scfg.max_batch]
        # decode tokens spend the budget first; floor of 1 guarantees an
        # in-flight prefill always progresses (liveness under a budget
        # tighter than the decode batch).
        budget = max(scfg.step_token_budget - len(dec), 1)
        if scfg.chunked_prefill:
            s = scfg.chunk_size
        else:
            s = max(max(p.prompt_len - p.prefill_done_tokens
                        for p in self.prefills), 1)
        # trim each in-flight prefill's chunk to the remaining budget
        # (whole-prompt prefill is budget-exempt by definition)
        works = []
        for p in self.prefills:
            c = min(s, p.prompt_len - p.prefill_done_tokens)
            if scfg.chunked_prefill:
                c = min(c, budget)
            budget -= c
            works.append(PrefillWork(p, p.prefill_done_tokens, c, s))
        if not dec and len(works) == 1:
            # a lone prefill with nothing decoding (a prefill-role pod,
            # or an engine's first step) skips the fused pass — it
            # would carry max_batch dummy decode lanes of compute
            return ScheduleOutput(mode="prefill", prefills=works,
                                  pad_len=s)
        # drafts spend whatever budget the prefill chunks left over —
        # prefill admission can never be starved by drafting
        spec = self._assign_drafts(dec, max(budget, 0))
        return ScheduleOutput(mode="mixed", decode=dec, prefills=works,
                              pad_len=s, spec=spec)

    def _assign_drafts(self, dec: List[Request],
                       budget: int) -> List[List[int]]:
        """Prompt-lookup drafts for the decode rows, spending at most
        the leftover ``budget`` (one token of budget per draft token).
        Returns [] — the non-spec fast path — when no row drafted."""
        if (self.drafter is None or not self.scfg.mixed_batching
                or not dec or budget <= 0):
            return []
        spec, any_draft = [], False
        for r in dec:
            if getattr(r, "_pending_toks", 0):
                # async loop: unresolved placeholder in the history —
                # this schedule pass is a provisional plan, don't draft
                spec.append([])
                continue
            d = self.drafter.propose(r, budget)
            budget -= len(d)
            any_draft = any_draft or bool(d)
            spec.append(d)
        return spec if any_draft else []

    def _admit_prefills(self, now: float) -> None:
        scfg = self.scfg
        while (len(self.prefills) < scfg.max_prefills
               and len(self.prefills) * scfg.chunk_size
               + min(len(self.running), scfg.max_batch)
               < scfg.step_token_budget):
            req = self.try_admit(now)
            if req is None:
                break
            if req.state is RequestState.RUNNING:
                continue    # crash-rewound continuation: already decoding
            self.prefills.append(req)

    def _slo_preempt(self, now: float) -> bool:
        """Priority-aware preemption: when the most urgent waiting
        request could not be admitted and its TTFT slack has shrunk
        below ``slo_preempt_headroom`` of its target, evict ONE
        strictly-lower-priority decode (highest rank first, then the
        one with the least generated work to throw away).  Rate-limited
        by ``slo_preempt_cooldown_s``; preemption only ever crosses
        class ranks downward, so it cannot livelock with the strict-
        priority admission order."""
        scfg = self.scfg
        if (not self.waiting or not self.running
                or now - self._last_preempt < scfg.slo_preempt_cooldown_s):
            return False
        if scfg.mixed_batching and len(self.prefills) >= scfg.max_prefills:
            return False    # a freed decode slot cannot admit anyway
        # SWAPPED waiters re-enter through _try_resume, never through
        # try_admit — preempting on their behalf would just swap one
        # victim out to resume another at the front of the queue (churn)
        admissible = [r for r in self.waiting
                      if r.state is not RequestState.SWAPPED]
        if not admissible:
            return False
        cand = min(admissible, key=self._admission_key(now))
        need = self.pages_for(cand.prompt_len + (
            0 if self.wants_handoff else cand.sampling.max_new_tokens))
        if (len(self.running) + len(self.prefills) < scfg.max_batch
                and self.alloc.num_free >= need):
            return False    # not capacity-blocked (a slot is open and
            # pages suffice even ignoring prefix hits, so the stall is
            # e.g. cache-aware deferral) — evicting a decode won't help
        ccls = self.slo_class(cand)
        if self.slack(cand, now) > scfg.slo_preempt_headroom * ccls.ttft_s:
            return False
        victims = [r for r in self.running
                   if self.slo_class(r).rank > ccls.rank]
        if not victims:
            return False
        victim = max(victims, key=lambda r: (self.slo_class(r).rank,
                                             -len(r.output_tokens),
                                             r.arrival_time))
        self.preempt(victim, now)
        self._last_preempt = now
        return True

    def _schedule_two_phase(self, now: float) -> ScheduleOutput:
        scfg = self.scfg
        if not self.prefills:
            req = self.try_admit(now)
            if (req is None and scfg.slo_aware and self.waiting
                    and self._slo_preempt(now)):
                req = self.try_admit(now)
            if req is not None and req.state is not RequestState.RUNNING:
                self.prefills.append(req)
        if self.prefills:
            req = self.prefills[0]
            s = scfg.chunk_size if scfg.chunked_prefill else \
                max(req.prompt_len, 1)
            start = req.prefill_done_tokens
            c = min(s, req.prompt_len - start)
            return ScheduleOutput(mode="prefill",
                                  prefills=[PrefillWork(req, start, c, s)],
                                  pad_len=s)
        if self.running:
            return ScheduleOutput(mode="decode",
                                  decode=self.running[:scfg.max_batch])
        return ScheduleOutput(mode="idle")

    # --------------------------------------------------- prefill bookkeeping
    def register_prompt_pages(self, req: Request, now: float) -> None:
        """Hash-register the finished prompt's pages for local reuse
        and publish them to the distributed pool.  One walk for every
        engine; only the payload differs, via the host's
        ``publish_page(page_id, block_hash, req, now)`` hook.
        Publishing happens even when engine-local prefix caching is off
        — cross-engine reuse is the pool's feature, not the engine's —
        and is skipped when the pool already knows the hash (a
        duplicate would be dropped at the metadata layer anyway, after
        the payload was materialized for nothing)."""
        if not self.scfg.prefix_caching and self.kv_pool is None:
            return
        hashes = chunk_hashes(req.prompt_tokens, self.scfg.page_size,
                              req.lora_adapter or "")
        for i, h in enumerate(hashes):
            pid = req.page_ids[i]
            if (self.scfg.prefix_caching
                    and self.alloc.pages[pid].block_hash is None):
                self.alloc.register_hash(pid, h)
            # the pool check runs even for blocks already registered
            # locally: the pool may have evicted them since their last
            # publish, and a handoff needs them present again
            if (self.kv_pool is not None and self.publish_page is not None
                    and not self.kv_pool.contains(h)):
                self._pool_publish(pid, h, req, now)

    def note_prefill_progress(self, req: Request, chunk_len: int) -> bool:
        """Advance a prefill by ``chunk_len`` tokens; True when the whole
        prompt is in the KV pages (the request leaves PREFILLING)."""
        req.prefill_done_tokens += chunk_len
        if req.prefill_done_tokens >= req.prompt_len:
            if req in self.prefills:
                self.prefills.remove(req)
            return True
        return False

    def finish_prefill(self, req: Request, tok: int, now: float) -> None:
        """Prefill complete on a mixed/decode engine: record the first
        sampled token and move the request to the decode batch."""
        req.output_tokens.append(int(tok))
        if req.first_token_time:
            req.token_times.append(now)      # migrated-in continuation
        else:
            req.first_token_time = now
        req.state = RequestState.RUNNING
        self.running.append(req)
        self.maybe_finish(req, now)

    def handoff_prefill(self, req: Request, now: float) -> None:
        """Disaggregated prefill complete: KV lives in the pool, so free
        this engine's pages and reset the request for re-admission on a
        decode engine.  The host delivers it (``deliver_handoff``) —
        synchronously for real engines, after the pool's metadata lag
        for the simulator, tracked so drain predicates don't observe a
        momentarily idle pair."""
        self.alloc.release(req.page_ids, now)
        req.page_ids = []
        req.state = RequestState.QUEUED
        req.prefill_done_tokens = 0
        self._pending_handoff += 1
        # a prefill pod's throughput IS prefilled prompt tokens — the
        # same accounting on the real engine and the simulator
        self.note_tokens(now, req.prompt_len)

    def deliver_handoff(self, req: Request) -> None:
        self._pending_handoff -= 1
        self.handoff(req)

    # ---------------------------------------------------- decode bookkeeping
    def on_decode_batch(self, reqs: List[Request], toks, now: float) -> None:
        """Record one decode token per request: grow pages across the
        page boundary (preempting on allocation failure), finish/stop."""
        for i, r in enumerate(reqs):
            r.output_tokens.append(int(toks[i]))
            r.token_times.append(now)
            nxt = r.prompt_len + len(r.output_tokens)
            if self.pages_for(nxt + 1) > len(r.page_ids):
                pid = self.alloc.allocate(1, now)
                if pid is None:
                    self.preempt(r, now)
                    continue
                r.page_ids += pid
            self.maybe_finish(r, now)
        self.note_tokens(now, len(reqs))

    def on_spec_batch(self, reqs: List[Request], spec: List[List[int]],
                      emitted: List[List[int]], now: float) -> int:
        """Record a speculative step's verified tokens.  Row ``i``
        drafted ``spec[i]`` and the runner's verification emitted
        ``emitted[i]`` model-sampled tokens (accepted prefix + the
        bonus/correction sample).  Tokens append one at a time through
        the same page-growth / finish checks as :meth:`on_decode_batch`
        — a stop token mid-emission finishes the request and drops the
        rest (byte-identity with step-by-step decoding); the rejected
        drafts' stale KV slots are never attended (lengths-bounded
        attention) and are overwritten when real tokens land there."""
        total = 0
        for r, drafts, toks in zip(reqs, spec, emitted):
            accepted = max(min(len(toks) - 1, len(drafts)), 0)
            if self.drafter is not None:
                self.drafter.observe(r, len(drafts), accepted)
            self._m["spec_drafted_tokens"] += len(drafts)
            if drafts:
                self._m["spec_steps"] += 1
            appended = 0
            for t in toks:
                r.output_tokens.append(int(t))
                r.token_times.append(now)
                appended += 1
                if self.maybe_finish(r, now):
                    break
                nxt = r.prompt_len + len(r.output_tokens)
                if self.pages_for(nxt + 1) > len(r.page_ids):
                    pid = self.alloc.allocate(1, now)
                    if pid is None:
                        self.preempt(r, now)
                        break
                    r.page_ids += pid
            # only tokens that actually landed count as accepted work
            self._m["spec_accepted_tokens"] += max(
                min(appended - 1, accepted), 0)
            total += appended
        self.note_tokens(now, total)
        return total

    # ------------------------------------------- async overlapped loop
    def on_decode_provisional(self, reqs: List[Request],
                              now: float) -> List[int]:
        """Bookkeeping for a decode step dispatched but not yet read
        back (the async loop schedules step N+1 while N runs on
        device).  Appends a :data:`PENDING_TOKEN` placeholder per row —
        patched with the real token at readback — so page growth,
        max_new_tokens finishes and the next schedule() pass all see
        the correct sequence LENGTH immediately.  Stop-token finishes
        cannot be predicted from a placeholder; the engine resolves
        them retroactively at readback.  Returns each request's
        placeholder index into ``output_tokens``."""
        idxs = []
        for r in reqs:
            r.output_tokens.append(PENDING_TOKEN)
            r.token_times.append(now)
            r._pending_toks = getattr(r, "_pending_toks", 0) + 1  # type: ignore
            idxs.append(len(r.output_tokens) - 1)
            nxt = r.prompt_len + len(r.output_tokens)
            if self.pages_for(nxt + 1) > len(r.page_ids):
                pid = self.alloc.allocate(1, now)
                if pid is None:
                    self.preempt(r, now)
                    continue
                r.page_ids += pid
            # max_new_tokens is count-predictable even on placeholders;
            # stop tokens are handled at readback by the engine
            self.maybe_finish(r, now)
        self.note_tokens(now, len(reqs))
        return idxs

    def maybe_finish(self, req: Request, now: float) -> bool:
        if not self.request_done(req):
            return False
        if req in self.running:
            self.running.remove(req)
        self._record_session_pages(req)
        self.alloc.release(req.page_ids, now)
        req.page_ids = []
        self.note_finished(req, now)
        return True

    # ------------------------------------------------ predictive promotion
    MAX_SESSION_PAGES = 4096    # sessions remembered for the promoter
    PROMOTE_MAX_PAGES = 64      # per-promotion page budget

    def _record_session_pages(self, req: Request) -> None:
        """Remember a finishing session turn's full-page block hashes —
        exactly what the NEXT turn's admission walk will ask for (the
        next prompt extends this turn's prompt + output), so the
        promoter knows which SSD pages to pull back ahead of it.  Only
        tracked when both lower tiers exist (no tiers => nothing to
        promote), LRU-bounded so a million-session trace cannot grow
        it without limit."""
        sid = getattr(req, "session_id", None)
        if sid is None or self.host_pool is None \
                or self.ssd_pool is None:
            return
        ps = self.scfg.page_size
        seq = list(req.prompt_tokens) + [int(t) for t in
                                         req.output_tokens]
        if len(seq) < ps:
            return
        self._session_pages[sid] = chunk_hashes(
            seq, ps, req.lora_adapter or "")
        self._session_pages.move_to_end(sid)
        while len(self._session_pages) > self.MAX_SESSION_PAGES:
            self._session_pages.popitem(last=False)

    def session_promotable(self, session_id: str) -> List[str]:
        """The session's recorded pages currently SSD-resident but NOT
        host-resident — the promoter's shopping list, bounded by
        ``PROMOTE_MAX_PAGES``."""
        if self.host_pool is None or self.ssd_pool is None:
            return []
        out = []
        for h in self._session_pages.get(session_id, ()):
            if not self.host_pool.contains(h) \
                    and self.ssd_pool.contains(h):
                out.append(h)
                if len(out) >= self.PROMOTE_MAX_PAGES:
                    break
        return out

    def complete_promotion(self, key: str, payload, nbytes: int,
                           now: float, session_id: str = "") -> bool:
        """Land one prefetched page in host DRAM (called by the host's
        promotion machinery once the SSD read has been paid for — at
        modelled cost by the simulator, on a background thread by the
        real engine).  The key is marked so a later host hit counts as
        ``promote_hits`` and an untouched eviction as
        ``promote_wasted``."""
        if self.host_pool is None or self.host_pool.contains(key):
            return False
        if self.host_pool.put(key, payload,
                              int(nbytes) or self.page_bytes, now):
            self._promoted[key] = session_id
            return True
        return False

    def promote_session(self, session_id: str, now: float) -> int:
        """Synchronous promotion: read each promotable page from SSD
        and park it in host DRAM.  Hosts with their own latency story
        (sim cost events, the real engine's promoter thread) drive
        ``session_promotable`` + ``complete_promotion`` directly."""
        n = 0
        for key in self.session_promotable(session_id):
            payload = self.ssd_pool.get(key, now)
            if payload is None:
                continue
            if self.complete_promotion(
                    key, payload,
                    payload_nbytes(payload, self.page_bytes), now,
                    session_id):
                n += 1
        return n

    def preempt(self, req: Request, now: float) -> None:
        """Evict a RUNNING request.  With a host tier attached the
        victim's pages are *swapped out* (offloaded under per-request
        keys; resume continues decoding from where it stopped —
        byte-identical to the never-preempted run); without one — or
        when the tier cannot hold the pages — the legacy path drops
        everything and re-prefills from token 0."""
        if req in self.running:
            self.running.remove(req)
        req.preempt_count += 1
        self._m["preemptions"] += 1
        if self._swap_out(req, now):
            return
        self.alloc.release(req.page_ids, now)
        req.page_ids = []
        self._reset_recompute(req)
        self.waiting.insert(0, req)

    def _reset_recompute(self, req: Request) -> None:
        # every discarded generated token is paid-for decode compute
        # the fleet re-runs — the figure bench_chaos compares across
        # recovery modes
        self._m["wasted_tokens"] += len(req.output_tokens)
        req.output_tokens = []
        # the discarded tokens' timestamps go with them — ITL is then
        # measured over the re-run (plus the one real requeue stall
        # from first_token_time, which stays: TTFT already happened)
        req.token_times = []
        req.prefill_done_tokens = 0
        # any in-flight async placeholder died with the tokens; the
        # engine's readback patch guard skips the vanished index
        req._pending_toks = 0               # type: ignore[attr-defined]
        req.state = RequestState.QUEUED

    # ----------------------------------------------------- swap preemption
    @staticmethod
    def _swap_key(req: Request, i: int) -> str:
        return f"swap/{req.request_id}/{i}"

    def _swap_out(self, req: Request, now: float) -> bool:
        """Offload a decode-phase victim's pages (prompt AND generated
        KV) into the host tier.  Returns False — caller falls back to
        drop-and-recompute — when no tier/payload hook is attached, the
        request is still prefilling, or the pages can't ever fit."""
        scfg = self.scfg
        if (not scfg.swap_preemption or self.host_pool is None
                or self.page_payload is None or not req.page_ids
                or req.prefill_done_tokens < req.prompt_len
                or getattr(req, "_pending_toks", 0)):
            # a victim with unresolved async placeholders can't swap —
            # the parked tokens would contain PENDING_TOKEN sentinels a
            # resume could feed back to the model; drop-and-recompute
            return False
        n = len(req.page_ids)
        if not self.host_pool.can_hold(n * self.page_bytes):
            return False
        for i, pid in enumerate(req.page_ids):
            self.host_pool.put(self._swap_key(req, i),
                               self.page_payload(pid), self.page_bytes,
                               now)
        self.alloc.release(req.page_ids, now)
        req.page_ids = []
        req._swap_pages = n                 # type: ignore[attr-defined]
        req.state = RequestState.SWAPPED
        self.waiting.insert(0, req)
        self._m["swap_out"] += 1
        self._m["kv_bytes_offloaded"] += n * self.page_bytes
        return True

    def _drop_swap(self, req: Request) -> None:
        for i in range(getattr(req, "_swap_pages", 0)):
            key = self._swap_key(req, i)
            self.host_pool.discard(key)
            if self.ssd_pool is not None:
                self.ssd_pool.discard(key)
        req._swap_pages = 0                 # type: ignore[attr-defined]

    def _try_resume(self, now: float) -> None:
        """Swap SWAPPED requests back in (preemption order — they sit
        at the front of ``waiting``): re-allocate their pages, install
        the parked payloads and rejoin the decode batch mid-sequence.
        Swap entries the host tier evicted under pressure are looked up
        in the SSD tier below it (host evictions cascade there), so an
        idle session's resume stays a transfer, not a recompute.  Only
        when an entry is gone from BOTH tiers does the request fall
        back to recompute admission (still byte-identical under greedy
        decoding — just slower)."""
        if self.host_pool is None:
            return
        for req in [r for r in self.waiting
                    if r.state is RequestState.SWAPPED]:
            if (len(self.running) + len(self.prefills)
                    >= self.scfg.max_batch):
                break
            need = getattr(req, "_swap_pages", 0)
            entries, sources = [], []
            for i in range(need):
                key = self._swap_key(req, i)
                payload = self.host_pool.get(key, now)
                source = "host"
                if payload is None and self.ssd_pool is not None:
                    payload = self.ssd_pool.get(key, now)
                    source = "ssd"
                entries.append(payload)
                sources.append(source)
            if not need or any(e is None for e in entries):
                self._drop_swap(req)
                self._reset_recompute(req)   # stays queued; try_admit
                continue                     # re-prefills it later
            fresh = self.alloc.allocate(need, now)
            if fresh is None:
                continue        # no memory yet — stay swapped
            for i, (pid, payload, source) in enumerate(
                    zip(fresh, entries, sources)):
                if self.install_page is not None:
                    self.install_page(
                        pid, payload, req, now, source=source,
                        stream=False,
                        nbytes=payload_nbytes(payload, self.page_bytes))
                if source == "ssd":
                    self._m["ssd_hit_tokens"] += self.scfg.page_size
                key = self._swap_key(req, i)
                self.host_pool.discard(key)
                if self.ssd_pool is not None:
                    self.ssd_pool.discard(key)
            req._swap_pages = 0             # type: ignore[attr-defined]
            req.page_ids = fresh
            req.state = RequestState.RUNNING
            self.waiting.remove(req)
            self.running.append(req)
            self._m["swap_in"] += 1
            self._m["kv_bytes_fetched"] += need * self.page_bytes
            # a victim preempted on its very last token is already done
            self.maybe_finish(req, now)

    def drop_running(self, req: Request, now: float) -> None:
        """Remove a RUNNING request without finishing it (migration)."""
        if req in self.running:
            self.running.remove(req)
        self.alloc.release(req.page_ids, now)
        req.page_ids = []

    # --------------------------------------------- crash recovery log
    def _maybe_checkpoint(self, now: float) -> None:
        """The recovery log: periodically publish a running decode's
        full KV blocks — prompt AND generated — to the distributed
        pool under their content hashes.  ``req.ckpt_tokens`` records
        how many sequence tokens the log covers; after a crash,
        :meth:`crash_takeover` rewinds the request to that point and
        the replacement engine's admission walk fetches the
        checkpointed blocks back instead of re-prefilling from token
        0.  Publish volume is bounded per pass by
        ``ckpt_budget_bytes`` and skips blocks the pool already holds
        (prompt blocks usually entered at prefill time)."""
        iv = self.scfg.ckpt_interval_tokens
        if (not iv or self.kv_pool is None
                or self.publish_page is None):
            return
        ps = self.scfg.page_size
        budget = self.scfg.ckpt_budget_bytes or float("inf")
        for req in self.running:
            if getattr(req, "_pending_toks", 0):
                # async loop: unresolved PENDING_TOKEN placeholders —
                # hashing them would poison the recovery log; the next
                # resolved pass checkpoints the real tokens
                continue
            total = req.prompt_len + len(req.output_tokens)
            full = (total // ps) * ps
            if full - req.ckpt_tokens < iv:
                continue
            hashes = chunk_hashes(
                req.prompt_tokens + req.output_tokens, ps,
                req.lora_adapter or "")
            for i in range(req.ckpt_tokens // ps, full // ps):
                if budget <= 0:
                    return
                if not self.kv_pool.contains(hashes[i]):
                    if not self._pool_publish(req.page_ids[i], hashes[i],
                                              req, now):
                        return      # partitioned: retry next pass
                    self._m["ckpt_pages"] += 1
                    budget -= max(self.page_bytes, 1)
                req.ckpt_tokens = (i + 1) * ps

    def crash_takeover(self, now: float) -> List[Request]:
        """Harvest EVERY request a dead engine owns so the control
        plane can re-deliver them to surviving pool members.  Queued
        requests come back untouched (``takeover_waiting`` semantics);
        in-flight prefills reset to recompute; running decodes rewind
        to their last recovery-log checkpoint when one exists — the
        surviving engine's continuation admission pulls every
        checkpointed block (prompt AND generated) back from the pool
        and resumes decoding mid-sequence — and reset to full
        recompute otherwise.  The local pages are released either way:
        this engine is gone."""
        out = self.takeover_waiting()
        for req in list(self.prefills):
            self.prefills.remove(req)
            self.alloc.release(req.page_ids, now)
            req.page_ids = []
            self._reset_recompute(req)
            out.append(req)
        for req in list(self.running):
            self.running.remove(req)
            self.alloc.release(req.page_ids, now)
            req.page_ids = []
            if not self._rewind_to_checkpoint(req):
                self._reset_recompute(req)
            out.append(req)
        return out

    def _rewind_to_checkpoint(self, req: Request) -> bool:
        """Rewind a decode-phase victim onto its recovery log: keep the
        generated tokens the log covers, drop the uncovered tail and
        re-queue flagged for continuation admission
        (:meth:`_admit_continuation` pulls the checkpointed blocks and
        rejoins decode directly — the prompt is NOT folded, because the
        covered tokens' KV must come back verbatim, never be
        re-prefilled).  False (caller falls back to full recompute)
        when the log never got past the prompt."""
        gen_covered = min(req.ckpt_tokens - req.prompt_len,
                          len(req.output_tokens))
        if gen_covered <= 0:
            return False
        self._m["wasted_tokens"] += len(req.output_tokens) - gen_covered
        req.output_tokens = list(req.output_tokens[:gen_covered])
        # inter-token gaps for the kept tokens stay; the gap spanning
        # the crash shows up against the first resumed token
        req.token_times = list(req.token_times[:max(gen_covered - 1, 0)])
        req.prefill_done_tokens = 0
        req.cached_prefix_tokens = 0
        req._resume_decode = True           # type: ignore[attr-defined]
        req.state = RequestState.QUEUED
        return True

    # ---------------------------------------------------------- metrics
    def match_prefix_len(self, tokens) -> int:
        """Prefix-cache coverage for router scoring (non-mutating)."""
        return self.alloc.match_len(tokens)

    @property
    def queue_depth(self) -> int:
        """Admitted-but-unfinished load, equal to the metrics()
        num_running + num_waiting sum — a cheap accessor so routing
        policies scoring load per request don't pay for a full
        EngineMetrics build (windowed throughput, SLO stats) per
        engine per route."""
        return (len(self.running) + len(self.prefills)
                + len(self.waiting))

    def metrics(self, now: float,
                loaded_adapters: tuple = ()) -> EngineMetrics:
        return EngineMetrics(
            num_running=len(self.running) + len(self.prefills),
            num_waiting=len(self.waiting),
            kv_utilization=self.alloc.utilization,
            tokens_per_sec=self.throughput(now),
            avg_latency=self.avg_latency,
            avg_queue_time=self.avg_queue_time,
            admitted_requests=self.admitted_count,
            finished_requests=self.finished_count,
            preemptions=self._m["preemptions"],
            prefix_hit_tokens=self._m["prefix_hit_tokens"],
            remote_hit_tokens=self._m["remote_hit_tokens"],
            loaded_adapters=loaded_adapters,
            lora_miss=self._m["lora_miss"],
            lora_shed=self._m["lora_shed"],
            slo_attainment=self.slo_attainment(now),
            slo_by_class=self.slo_class_stats(now),
            slo_itl_attainment=self.slo_itl_attainment(now),
            host_hit_tokens=self._m["host_hit_tokens"],
            ssd_hit_tokens=self._m["ssd_hit_tokens"],
            ssd_cross_hit_tokens=self._m["ssd_cross_hit_tokens"],
            ssd_dropped_puts=(self.ssd_pool.stats.dropped_puts
                              if self.ssd_pool is not None else 0),
            promote_hits=self._m["promote_hits"],
            promote_wasted=self._m["promote_wasted"],
            kv_bytes_offloaded=self._m["kv_bytes_offloaded"],
            kv_bytes_fetched=self._m["kv_bytes_fetched"],
            swap_out=self._m["swap_out"],
            swap_in=self._m["swap_in"],
            kv_fetch_failures=self._m["kv_fetch_failures"],
            wasted_tokens=self._m["wasted_tokens"],
            ckpt_pages=self._m["ckpt_pages"],
            spec_drafted_tokens=self._m["spec_drafted_tokens"],
            spec_accepted_tokens=self._m["spec_accepted_tokens"],
            spec_steps=self._m["spec_steps"],
            spec_acceptance=(self._m["spec_accepted_tokens"]
                             / max(self._m["spec_drafted_tokens"], 1)))
