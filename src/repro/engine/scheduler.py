"""Unified scheduler core shared by every engine in the system.

One scheduling semantics, written once: the real JAX ``InferenceEngine``
(repro.engine.engine), the analytic cluster-simulator ``SimEngine``
(repro.core.sim.sim_engine) and the ``SlotEngine`` all drive the classes
in this module instead of carrying their own drifting copies of
admission / budget / finish logic.

Layers
------
``SchedulerCore``
    The engine-shape-agnostic request bookkeeping: arrival queue,
    admission accounting (queue-time EWMA, admitted counter), the
    finish/stop predicate (max_new_tokens + stop_token), latency EWMA
    and the sliding token-throughput window.  The slot engine builds
    directly on this.

``Scheduler``
    The paged-KV scheduler: cache-aware admission (prefix match, pool
    fetch, deferral of a prompt whose leading block hash matches an
    in-flight prefill), per-step token budget with chunk trimming,
    preemption, decode bookkeeping, and P/D roles.  ``schedule(now)``
    is *declarative*: it returns a :class:`ScheduleOutput` naming the
    decode rows and budget-trimmed prefill chunks for this iteration
    and mutates nothing but admission state — the host's "runner"
    (jitted forward passes for the real engine, the roofline cost model
    for the simulator) executes it and reports back through the
    ``note_* / finish_* / on_decode_batch`` bookkeeping calls.

Roles (paper §3.2.5, DistServe-style P/D disaggregation)
--------------------------------------------------------
``role="mixed"`` is a normal colocated engine.  ``role="prefill"``
prefills, publishes KV through the distributed pool, then hands the
request off (``handoff_prefill`` releases the pages and re-queues the
request for the decode side; the host delivers it via its ``handoff``
callable — synchronously for real engines, after the pool's metadata
lag for the simulator).  ``role="decode"`` engines admit handed-off
requests whose KV they pull from the pool by block hash, so they only
recompute the tail block before decoding.

All bookkeeping methods take an explicit ``now`` so the same code runs
under wall clock (real engines) and forward-dated discrete-event time
(the simulator).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

from repro.engine.page_table import PageAllocator, chunk_hashes
from repro.engine.request import Request, RequestState


def window_throughput(events, now: float, horizon: float = 10.0) -> float:
    """tokens/sec over the span actually observed within ``horizon``.

    ``events`` is a list of (timestamp, token_count).  A fixed-horizon
    divisor deflated early/low-traffic readings (skewing gateway routing
    and autoscaler signals); the 1 s floor keeps a single post-idle
    burst from reading as a huge rate spike when polled within the same
    instant.  Shared by InferenceEngine, SlotEngine and SimEngine so
    their tokens_per_sec semantics cannot drift apart.
    """
    window = [(t, c) for t, c in events if t >= now - horizon]
    if not window:
        return 0.0
    span = max(now - window[0][0], 1.0)
    return sum(c for _, c in window) / span


@dataclass
class EngineMetrics:
    """Snapshot consumed by gateway routing + autoscaler."""
    num_running: int = 0
    num_waiting: int = 0
    kv_utilization: float = 0.0
    tokens_per_sec: float = 0.0
    avg_latency: float = 0.0        # EWMA of per-request total latency
    avg_queue_time: float = 0.0
    admitted_requests: int = 0
    finished_requests: int = 0
    preemptions: int = 0
    prefix_hit_tokens: int = 0
    remote_hit_tokens: int = 0
    loaded_adapters: tuple = ()


@dataclass
class SchedulerConfig:
    page_size: int = 16
    max_batch: int = 8              # decode slots / admission capacity
    max_pages_per_seq: int = 0      # 0 => unlimited (the simulator)
    chunk_size: int = 64            # chunked-prefill chunk
    chunked_prefill: bool = True
    prefix_caching: bool = True
    # -- fused mixed-batch scheduler --
    mixed_batching: bool = True     # False => legacy two-phase scheduler
    max_prefills: int = 2           # concurrent PREFILLING requests
    token_budget: int = 0           # 0 => max_batch + max_prefills*chunk
    # False => finish on max_new_tokens only (the simulator's decode
    # tokens are synthetic zeros, which a real EOS id could match)
    honor_stop_token: bool = True
    # -- P/D disaggregation --
    role: str = "mixed"             # mixed | prefill | decode

    @property
    def step_token_budget(self) -> int:
        """Per-step budget charged decode-first; it trims prefill chunks
        only — the decode batch itself is bounded by ``max_batch``, not
        the budget (a budget below ``max_batch`` + 1 cannot throttle
        decode, it just starves prefill down to its 1-token floor)."""
        return self.token_budget or (
            self.max_batch + self.max_prefills * self.chunk_size)


@dataclass
class PrefillWork:
    """One in-flight prefill's chunk for this step."""
    req: Request
    start: int          # prefill_done_tokens at schedule time
    chunk_len: int      # budget-trimmed valid tokens (0 = starved)
    pad_len: int        # padded chunk width the runner should build


@dataclass
class ScheduleOutput:
    """Declarative description of one scheduler iteration."""
    mode: str                                   # mixed|prefill|decode|idle
    decode: List[Request] = field(default_factory=list)
    prefills: List[PrefillWork] = field(default_factory=list)
    pad_len: int = 0                            # chunk width (mixed)


class SchedulerCore:
    """Request bookkeeping shared by every engine shape (paged or slot):
    arrival queue, admission/finish accounting, stop predicate, EWMAs
    and the token-throughput window."""

    def __init__(self, honor_stop_token: bool = True):
        self.honor_stop_token = honor_stop_token
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        self._m = dict(admitted=0, finished=0, preemptions=0,
                       prefix_hit_tokens=0, remote_hit_tokens=0)
        self._lat_ewma = 0.0
        self._q_ewma = 0.0
        self._tok_events: List[tuple] = []

    # ---------------------------------------------------------- queue
    def enqueue(self, req: Request, now: float) -> None:
        if req.arrival_time == 0.0:
            req.arrival_time = now
        self.waiting.append(req)

    def note_admitted(self, req: Request, now: float) -> None:
        req.schedule_time = now
        self._m["admitted"] += 1
        self._q_ewma = 0.9 * self._q_ewma + 0.1 * req.queue_time

    # ---------------------------------------------------------- finish
    def request_done(self, req: Request) -> bool:
        sp = req.sampling
        if len(req.output_tokens) >= sp.max_new_tokens:
            return True
        return (self.honor_stop_token and sp.stop_token is not None
                and bool(req.output_tokens)
                and req.output_tokens[-1] == sp.stop_token)

    def note_finished(self, req: Request, now: float) -> None:
        req.finish_time = now
        req.state = RequestState.FINISHED
        self.finished.append(req)
        self._m["finished"] += 1
        self._lat_ewma = (0.9 * self._lat_ewma + 0.1 * req.total_latency
                          if self._lat_ewma else req.total_latency)

    # ---------------------------------------------------------- accessors
    @property
    def avg_latency(self) -> float:
        return self._lat_ewma

    @property
    def avg_queue_time(self) -> float:
        return self._q_ewma

    @property
    def admitted_count(self) -> int:
        return self._m["admitted"]

    @property
    def finished_count(self) -> int:
        return self._m["finished"]

    # ---------------------------------------------------------- tokens
    def note_tokens(self, now: float, n: int) -> None:
        self._tok_events.append((now, n))
        cutoff = now - 10.0
        while self._tok_events and self._tok_events[0][0] < cutoff:
            self._tok_events.pop(0)

    def throughput(self, now: float) -> float:
        return window_throughput(self._tok_events, now)


class Scheduler(SchedulerCore):
    """The paged-KV scheduler: one admission/budget/role implementation
    for the real JAX engine AND the cluster simulator.

    The distributed KV pool is consulted by the scheduler itself
    (``kv_pool``/``engine_id``): the page walk — which blocks to ask
    for, where to stop, allocation and hash registration — lives here,
    once.  Only the payload handling differs per host, via
    ``install_page(page_id, payload, req, now)``: the real engine
    writes the fetched arrays into a device page, the simulator
    attributes a transfer-time cost.
    """

    ROLES = ("mixed", "prefill", "decode")

    def __init__(self, scfg: SchedulerConfig, alloc: PageAllocator,
                 kv_pool=None, engine_id: str = "engine-0",
                 install_page: Optional[Callable] = None,
                 publish_page: Optional[Callable] = None):
        super().__init__(honor_stop_token=scfg.honor_stop_token)
        if scfg.role not in self.ROLES:
            raise ValueError(f"unknown scheduler role {scfg.role!r}; "
                             f"expected one of {self.ROLES}")
        self.scfg = scfg
        self.alloc = alloc
        self.kv_pool = kv_pool
        self.engine_id = engine_id
        self.install_page = install_page
        self.publish_page = publish_page
        self.prefills: List[Request] = []      # concurrent PREFILLING
        self.running: List[Request] = []
        # P/D handoff: host-provided delivery callable (a decode engine's
        # submit, or a load-balancing shim over several)
        self.handoff: Optional[Callable[[Request], None]] = None
        self._pending_handoff = 0

    # ---------------------------------------------------------- views
    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.prefills
                    or self._pending_handoff)

    @property
    def wants_handoff(self) -> bool:
        return self.scfg.role == "prefill" and self.handoff is not None

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.scfg.page_size)

    def _first_hash(self, req: Request) -> Optional[str]:
        hs = chunk_hashes(req.prompt_tokens[:self.scfg.page_size],
                          self.scfg.page_size)
        return hs[0] if hs else None

    # ------------------------------------------------------- admission
    def try_admit(self, now: float) -> Optional[Request]:
        scfg = self.scfg
        if not self.waiting or (len(self.running) + len(self.prefills)
                                >= scfg.max_batch):
            return None
        inflight_hashes = set()
        if scfg.prefix_caching and self.prefills:
            inflight_hashes = {self._first_hash(p) for p in self.prefills}
            inflight_hashes.discard(None)
        req = None
        idx = 0
        while idx < len(self.waiting):
            cand = self.waiting[idx]
            total = cand.prompt_len + cand.sampling.max_new_tokens
            if (scfg.max_pages_per_seq
                    and self.pages_for(total) > scfg.max_pages_per_seq):
                cand.state = RequestState.FAILED
                self.waiting.pop(idx)
                continue
            if (inflight_hashes
                    and cand.prompt_len > scfg.page_size
                    and self._first_hash(cand) in inflight_hashes
                    and self.alloc.match_len(cand.prompt_tokens) == 0):
                # cache-aware admission: a prompt sharing its leading
                # block with an in-flight prefill waits for those pages
                # to register so it can reuse them instead of
                # recomputing the prefix — but only THAT request waits
                # (later waiters with distinct prefixes still get the
                # slot), and only when the wait can pay off: not when a
                # registered prefix already matches, nor when the prompt
                # is too short for match_prefix to ever reuse the block.
                idx += 1
                continue
            req = cand
            break
        if req is None:
            return None
        # a handoff-bound prefill engine never decodes: reserving pages
        # for the decode tokens would only shrink its prefill capacity
        # (the decode side allocates them at re-admission)
        total = req.prompt_len + (
            0 if self.wants_handoff else req.sampling.max_new_tokens)
        matched_pages: List[int] = []
        matched_tokens = 0
        if scfg.prefix_caching:
            matched_pages, matched_tokens = self.alloc.match_prefix(
                req.prompt_tokens, now)
        local_tokens = matched_tokens
        # the distributed pool works even when engine-local prefix
        # caching is off (the paper's "KV cache + Default" rows):
        # cross-engine reuse is the pool's, not the engine's, feature
        fetched: List[tuple] = []
        if self.kv_pool is not None:
            rp, rt, fetched = self._pool_walk(req, matched_tokens, now)
            matched_pages += rp
            matched_tokens += rt
        need = self.pages_for(total) - len(matched_pages)
        fresh = self.alloc.allocate(need, now)
        if fresh is None:
            if scfg.prefix_caching and fetched:
                # keep the paid-for transfers: install + register the
                # fetched pages, then release them into the evictable
                # cache so the retry hits them locally via match_prefix
                # instead of re-fetching from the pool every step
                self._apply_fetched(fetched, req, now)
            self.alloc.release(matched_pages, now)
            return None     # no memory — stay queued
        # admission succeeded: only now install the fetched payloads
        # and count the remote hits (a retry after memory pressure must
        # not double-count them)
        self._apply_fetched(fetched, req, now)
        self.waiting.remove(req)
        req.page_ids = matched_pages + fresh
        req.cached_prefix_tokens = matched_tokens
        req.prefill_done_tokens = matched_tokens
        req.state = RequestState.PREFILLING
        self.note_admitted(req, now)
        self._m["prefix_hit_tokens"] += local_tokens
        return req

    def _pool_walk(self, req: Request, have_tokens: int, now: float
                   ) -> Tuple[List[int], int, List[tuple]]:
        """Extend a local prefix hit with pages from the distributed
        pool: walk the prompt's block hashes past the locally covered
        prefix, fetching and allocating a local page per hit.  The tail
        block is never fetched (prefill must produce at least one new
        token), and the walk stops at the first miss.

        Payload installation and hash registration are DEFERRED — the
        (page, hash, payload) triples are returned for the caller to
        apply only once admission succeeds.  (Hash registration with
        local prefix caching off would also let a re-fetch of the same
        hash clobber hash_index while the stale page's eviction later
        deletes the live entry, so it is additionally gated on
        ``prefix_caching``.)"""
        ps = self.scfg.page_size
        hashes = chunk_hashes(req.prompt_tokens, ps)
        pages, tokens, fetched = [], 0, []
        for i in range(have_tokens // ps, len(hashes)):
            if (i + 1) * ps >= req.prompt_len:
                break
            payload = self.kv_pool.fetch(hashes[i], self.engine_id, now)
            if payload is None:
                break
            pids = self.alloc.allocate(1, now)
            if not pids:
                break
            fetched.append((pids[0], hashes[i], payload))
            pages.append(pids[0])
            tokens += ps
        return pages, tokens, fetched

    def _apply_fetched(self, fetched: List[tuple], req: Request,
                       now: float) -> None:
        """Install the walk's deferred payloads, register their hashes
        (when locally cacheable) and count the remote hits."""
        for pid, h, payload in fetched:
            if self.install_page is not None:
                self.install_page(pid, payload, req, now)
            if self.scfg.prefix_caching:
                self.alloc.register_hash(pid, h)
        self._m["remote_hit_tokens"] += len(fetched) * self.scfg.page_size

    # ------------------------------------------------------- schedule
    def schedule(self, now: float) -> ScheduleOutput:
        """One scheduler iteration, declaratively.

        Mixed batching (default): admit up to ``max_prefills`` requests
        into PREFILLING, then emit ONE fused pass carrying every decode
        token plus a budget-trimmed chunk per in-flight prefill.
        Legacy (``mixed_batching=False``): one prefill at a time, decode
        only when no prefill is in flight.
        """
        scfg = self.scfg
        if not scfg.mixed_batching:
            return self._schedule_two_phase(now)
        while (len(self.prefills) < scfg.max_prefills
               and len(self.prefills) * scfg.chunk_size
               + min(len(self.running), scfg.max_batch)
               < scfg.step_token_budget):
            req = self.try_admit(now)
            if req is None:
                break
            self.prefills.append(req)
        if not self.prefills:
            if not self.running:
                return ScheduleOutput(mode="idle")
            return ScheduleOutput(mode="decode",
                                  decode=self.running[:scfg.max_batch])
        dec = self.running[:scfg.max_batch]
        # decode tokens spend the budget first; floor of 1 guarantees an
        # in-flight prefill always progresses (liveness under a budget
        # tighter than the decode batch).
        budget = max(scfg.step_token_budget - len(dec), 1)
        if scfg.chunked_prefill:
            s = scfg.chunk_size
        else:
            s = max(max(p.prompt_len - p.prefill_done_tokens
                        for p in self.prefills), 1)
        # trim each in-flight prefill's chunk to the remaining budget
        # (whole-prompt prefill is budget-exempt by definition)
        works = []
        for p in self.prefills:
            c = min(s, p.prompt_len - p.prefill_done_tokens)
            if scfg.chunked_prefill:
                c = min(c, budget)
            budget -= c
            works.append(PrefillWork(p, p.prefill_done_tokens, c, s))
        if not dec and len(works) == 1:
            # a lone prefill with nothing decoding (a prefill-role pod,
            # or an engine's first step) skips the fused pass — it
            # would carry max_batch dummy decode lanes of compute
            return ScheduleOutput(mode="prefill", prefills=works,
                                  pad_len=s)
        return ScheduleOutput(mode="mixed", decode=dec, prefills=works,
                              pad_len=s)

    def _schedule_two_phase(self, now: float) -> ScheduleOutput:
        scfg = self.scfg
        if not self.prefills:
            req = self.try_admit(now)
            if req is not None:
                self.prefills.append(req)
        if self.prefills:
            req = self.prefills[0]
            s = scfg.chunk_size if scfg.chunked_prefill else \
                max(req.prompt_len, 1)
            start = req.prefill_done_tokens
            c = min(s, req.prompt_len - start)
            return ScheduleOutput(mode="prefill",
                                  prefills=[PrefillWork(req, start, c, s)],
                                  pad_len=s)
        if self.running:
            return ScheduleOutput(mode="decode",
                                  decode=self.running[:scfg.max_batch])
        return ScheduleOutput(mode="idle")

    # --------------------------------------------------- prefill bookkeeping
    def register_prompt_pages(self, req: Request, now: float) -> None:
        """Hash-register the finished prompt's pages for local reuse
        and publish them to the distributed pool.  One walk for every
        engine; only the payload differs, via the host's
        ``publish_page(page_id, block_hash, req, now)`` hook.
        Publishing happens even when engine-local prefix caching is off
        — cross-engine reuse is the pool's feature, not the engine's —
        and is skipped when the pool already knows the hash (a
        duplicate would be dropped at the metadata layer anyway, after
        the payload was materialized for nothing)."""
        if not self.scfg.prefix_caching and self.kv_pool is None:
            return
        hashes = chunk_hashes(req.prompt_tokens, self.scfg.page_size)
        for i, h in enumerate(hashes):
            pid = req.page_ids[i]
            if (self.scfg.prefix_caching
                    and self.alloc.pages[pid].block_hash is None):
                self.alloc.register_hash(pid, h)
            # the pool check runs even for blocks already registered
            # locally: the pool may have evicted them since their last
            # publish, and a handoff needs them present again
            if (self.kv_pool is not None and self.publish_page is not None
                    and not self.kv_pool.contains(h)):
                self.publish_page(pid, h, req, now)

    def note_prefill_progress(self, req: Request, chunk_len: int) -> bool:
        """Advance a prefill by ``chunk_len`` tokens; True when the whole
        prompt is in the KV pages (the request leaves PREFILLING)."""
        req.prefill_done_tokens += chunk_len
        if req.prefill_done_tokens >= req.prompt_len:
            if req in self.prefills:
                self.prefills.remove(req)
            return True
        return False

    def finish_prefill(self, req: Request, tok: int, now: float) -> None:
        """Prefill complete on a mixed/decode engine: record the first
        sampled token and move the request to the decode batch."""
        req.output_tokens.append(int(tok))
        if req.first_token_time:
            req.token_times.append(now)      # migrated-in continuation
        else:
            req.first_token_time = now
        req.state = RequestState.RUNNING
        self.running.append(req)
        self.maybe_finish(req, now)

    def handoff_prefill(self, req: Request, now: float) -> None:
        """Disaggregated prefill complete: KV lives in the pool, so free
        this engine's pages and reset the request for re-admission on a
        decode engine.  The host delivers it (``deliver_handoff``) —
        synchronously for real engines, after the pool's metadata lag
        for the simulator, tracked so drain predicates don't observe a
        momentarily idle pair."""
        self.alloc.release(req.page_ids, now)
        req.page_ids = []
        req.state = RequestState.QUEUED
        req.prefill_done_tokens = 0
        self._pending_handoff += 1
        # a prefill pod's throughput IS prefilled prompt tokens — the
        # same accounting on the real engine and the simulator
        self.note_tokens(now, req.prompt_len)

    def deliver_handoff(self, req: Request) -> None:
        self._pending_handoff -= 1
        self.handoff(req)

    # ---------------------------------------------------- decode bookkeeping
    def on_decode_batch(self, reqs: List[Request], toks, now: float) -> None:
        """Record one decode token per request: grow pages across the
        page boundary (preempting on allocation failure), finish/stop."""
        for i, r in enumerate(reqs):
            r.output_tokens.append(int(toks[i]))
            r.token_times.append(now)
            nxt = r.prompt_len + len(r.output_tokens)
            if self.pages_for(nxt + 1) > len(r.page_ids):
                pid = self.alloc.allocate(1, now)
                if pid is None:
                    self.preempt(r, now)
                    continue
                r.page_ids += pid
            self.maybe_finish(r, now)
        self.note_tokens(now, len(reqs))

    def maybe_finish(self, req: Request, now: float) -> bool:
        if not self.request_done(req):
            return False
        if req in self.running:
            self.running.remove(req)
        self.alloc.release(req.page_ids, now)
        req.page_ids = []
        self.note_finished(req, now)
        return True

    def preempt(self, req: Request, now: float) -> None:
        if req in self.running:
            self.running.remove(req)
        self.alloc.release(req.page_ids, now)
        req.page_ids = []
        req.output_tokens = []
        req.prefill_done_tokens = 0
        req.state = RequestState.QUEUED
        self.waiting.insert(0, req)
        self._m["preemptions"] += 1

    def drop_running(self, req: Request, now: float) -> None:
        """Remove a RUNNING request without finishing it (migration)."""
        if req in self.running:
            self.running.remove(req)
        self.alloc.release(req.page_ids, now)
        req.page_ids = []

    # ---------------------------------------------------------- metrics
    def match_prefix_len(self, tokens) -> int:
        """Prefix-cache coverage for router scoring (non-mutating)."""
        return self.alloc.match_len(tokens)

    def metrics(self, now: float,
                loaded_adapters: tuple = ()) -> EngineMetrics:
        return EngineMetrics(
            num_running=len(self.running) + len(self.prefills),
            num_waiting=len(self.waiting),
            kv_utilization=self.alloc.utilization,
            tokens_per_sec=self.throughput(now),
            avg_latency=self.avg_latency,
            avg_queue_time=self.avg_queue_time,
            admitted_requests=self.admitted_count,
            finished_requests=self.finished_count,
            preemptions=self._m["preemptions"],
            prefix_hit_tokens=self._m["prefix_hit_tokens"],
            remote_hit_tokens=self._m["remote_hit_tokens"],
            loaded_adapters=loaded_adapters)
