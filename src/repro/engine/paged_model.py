"""Jitted paged-KV forward passes for serving (uniform-attention archs).

This is the engine's "vLLM model runner" role: prefill writes K/V into a
global page pool through per-request block tables; decode batches one
token per sequence through the Pallas paged-attention kernel.  Both are
``lax.scan``s over the stacked layer parameters of a single-run config
(DENSE or MOE pattern), reusing the substrate's MoE/MLP/norm code.

High-density LoRA (paper §3.2.1) is applied in-batch: every request
carries an adapter id into a gathered (adapter, d, r) x (adapter, r, out)
pair on the q/v projections — adapter 0 is the zero (base-model) adapter,
so mixed batches of base + N adapters run in one step.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers, moe
from repro.models import model as M
from repro.models.config import DENSE, MOE, ModelConfig
from repro.models.params import Spec, abstract_params, init_params


def pageable(cfg: ModelConfig) -> bool:
    """True when the paged path supports this config (uniform attn run)."""
    return (len(cfg.layer_runs) == 1
            and cfg.layer_runs[0][0] in (DENSE, MOE)
            and cfg.num_codebooks == 0)


class PagePool(NamedTuple):
    k: jax.Array            # (L, P, page, Hkv, D)
    v: jax.Array


def init_pool(cfg: ModelConfig, num_pages: int, page_size: int,
              dtype=jnp.float32) -> PagePool:
    shape = (cfg.n_layers, num_pages, page_size, cfg.n_kv_heads,
             cfg.head_dim)
    return PagePool(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


# ---------------------------------------------------------------- LoRA
def lora_specs(cfg: ModelConfig, n_adapters: int, rank: int) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "A_q": Spec((n_adapters, d, rank), (None, None, None), "zeros"),
        "B_q": Spec((n_adapters, rank, h * hd), (None, None, None), "zeros"),
        "A_v": Spec((n_adapters, d, rank), (None, None, None), "zeros"),
        "B_v": Spec((n_adapters, rank, hkv * hd), (None, None, None),
                    "zeros"),
    }


def init_lora(cfg: ModelConfig, n_adapters: int, rank: int,
              dtype=jnp.float32):
    return init_params(lora_specs(cfg, n_adapters, rank), jax.random.PRNGKey(7),
                       dtype)


def make_adapter(cfg: ModelConfig, rank: int, key: jax.Array,
                 dtype=jnp.float32):
    """A single random (non-zero) adapter's weights."""
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    a_scale = 1.0 / (d ** 0.5)
    b_scale = 0.5 / (rank ** 0.5)       # strong enough to alter outputs
    return {
        "A_q": jax.random.normal(k1, (d, rank), dtype) * a_scale,
        "B_q": jax.random.normal(k2, (rank, h * hd), dtype) * b_scale,
        "A_v": jax.random.normal(k3, (d, rank), dtype) * a_scale,
        "B_v": jax.random.normal(k4, (rank, hkv * hd), dtype) * b_scale,
    }


def _lora_delta(lora, which, x, adapter_ids):
    """x: (B, S, d); adapter_ids: (B,) -> (B, S, out)."""
    a = lora[f"A_{which}"][adapter_ids]          # (B, d, r)
    b_ = lora[f"B_{which}"][adapter_ids]         # (B, r, out)
    return jnp.einsum("bsr,bro->bso", jnp.einsum("bsd,bdr->bsr", x, a), b_)


def _qkv_lora(p_attn, cfg, x, positions, lora, adapter_ids):
    q, k, v = layers.attn_qkv(p_attn, cfg, x, positions)
    if lora is not None:
        b, s = x.shape[:2]
        dq = _lora_delta(lora, "q", x, adapter_ids).reshape(
            b, s, cfg.n_heads, cfg.head_dim)
        dv = _lora_delta(lora, "v", x, adapter_ids).reshape(
            b, s, cfg.n_kv_heads, cfg.head_dim)
        # note: LoRA delta applied post-rope on q is an approximation we
        # avoid — recompute rope on the delta instead (rope is linear).
        sin, cos = layers.rope_freqs(positions, cfg.head_dim, cfg.rope_theta)
        q = q + layers.apply_rope(dq, sin, cos)
        v = v + dv
    return q, k, v


# ---------------------------------------------------------------- prefill
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "page_size", "impl"),
    donate_argnums=(1,))
def prefill_step(params, pool: PagePool, tokens: jax.Array,
                 block_table: jax.Array, ctx_len: jax.Array,
                 chunk_len: jax.Array, lora=None,
                 adapter_ids: Optional[jax.Array] = None, *,
                 cfg: ModelConfig, page_size: int, impl: str = "pallas"
                 ) -> Tuple[jax.Array, PagePool]:
    """One (possibly chunked) prefill for ONE request.

    tokens:      (1, s) current chunk (padded; ``chunk_len`` valid)
    block_table: (1, NB) pages covering [0, ctx+s)
    ctx_len:     scalar — tokens already in the pages (prefix cache +
                 earlier chunks)
    Returns (last-token logits (1, V), updated pool).
    """
    s = tokens.shape[1]
    nb = block_table.shape[1]
    positions = ctx_len + jnp.arange(s)[None]                  # (1, s)
    x = M.embed(params, cfg, tokens)
    ltype = cfg.layer_runs[0][0]

    def body(x, xs):
        p_l, kp_l, vp_l = xs
        h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
        q, k, v = _qkv_lora(p_l["attn"], cfg, h, positions, lora,
                            adapter_ids)
        # scatter the chunk's K/V into this layer's pages
        tok_pos = (ctx_len + jnp.arange(s))                    # (s,)
        in_range = jnp.arange(s) < chunk_len
        pidx = jnp.where(in_range, block_table[0, tok_pos // page_size],
                         kp_l.shape[0])                        # OOB -> drop
        slot = tok_pos % page_size
        kp_l = kp_l.at[pidx, slot].set(k[0], mode="drop")
        vp_l = vp_l.at[pidx, slot].set(v[0], mode="drop")
        # gather full context (ctx + chunk) for flash attention
        k_all = kp_l[block_table[0]].reshape(1, nb * page_size,
                                             cfg.n_kv_heads, cfg.head_dim)
        v_all = vp_l[block_table[0]].reshape(1, nb * page_size,
                                             cfg.n_kv_heads, cfg.head_dim)
        o = _flash_dyn(q, k_all, v_all, ctx_len, chunk_len, impl)
        a = layers.attn_out(p_l["attn"], o)
        x = x + a
        h2 = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
        if ltype == MOE:
            f, _aux = moe.moe_ffn(p_l["moe"], cfg.moe, h2, cfg.act)
        else:
            f = layers.mlp(p_l["mlp"], h2, cfg.act)
        return x + f, (kp_l, vp_l)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["run_0"], pool.k,
                                               pool.v))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    # last valid token's logits
    last = jnp.take(x, jnp.maximum(chunk_len - 1, 0), axis=1)[:, None]
    logits = M.unembed(params, cfg, last)[:, 0]
    return logits, PagePool(k_new, v_new)


def _flash_dyn(q, k_all, v_all, ctx_len, chunk_len, impl):
    """flash attention where q sits at dynamic offset ctx_len.

    The kernel wants a static q_offset; we instead fold the offset into
    per-token positions by passing lengths = ctx+chunk and masking via
    the ref-style path: positions of q are [ctx, ctx+s) which equals a
    causal mask over k < ctx + 1 + i.  We reuse the kernel with
    q_offset=0 by shifting: causal over absolute positions requires
    q_offset=ctx (dynamic).  Pallas grid params must be static, so we
    use the oracle for dynamic offsets — on TPU the engine pads chunks
    to fixed boundaries making ctx static per compiled shape.
    """
    from repro.kernels import ref as kref
    s = q.shape[1]
    qpos = ctx_len + jnp.arange(s)
    kpos = jnp.arange(k_all.shape[1])
    mask = (kpos[None, :] <= qpos[:, None])[None]
    mask &= (kpos < ctx_len + chunk_len)[None, None]
    b, sq, h, d = q.shape
    hkv = k_all.shape[2]
    g = h // hkv
    qf = (q.astype(jnp.float32) * d ** -0.5).reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k_all.astype(jnp.float32))
    logits = jnp.where(mask[:, None, None], logits, kref.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_all.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------- decode
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "page_size", "impl"),
    donate_argnums=(1,))
def decode_batch(params, pool: PagePool, tokens: jax.Array,
                 positions: jax.Array, block_tables: jax.Array,
                 active: jax.Array, lora=None,
                 adapter_ids: Optional[jax.Array] = None, *,
                 cfg: ModelConfig, page_size: int, impl: str = "pallas"
                 ) -> Tuple[jax.Array, PagePool]:
    """One decode step for a batch.

    tokens: (B,) int32; positions: (B,) next position (== current length);
    block_tables: (B, NB); active: (B,) bool (padding slots excluded).
    Returns (logits (B, V), updated pool).
    """
    b = tokens.shape[0]
    positions_ = positions[:, None]                            # (B, 1)
    x = M.embed(params, cfg, tokens[:, None])
    lengths = jnp.where(active, positions + 1, 0).astype(jnp.int32)
    bidx = jnp.arange(b)

    def body(x, xs):
        p_l, kp_l, vp_l = xs
        h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
        q, k, v = _qkv_lora(p_l["attn"], cfg, h, positions_, lora,
                            adapter_ids)
        pidx = jnp.where(active,
                         block_tables[bidx, positions // page_size],
                         kp_l.shape[0])                        # OOB -> drop
        slot = positions % page_size
        kp_l = kp_l.at[pidx, slot].set(k[:, 0], mode="drop")
        vp_l = vp_l.at[pidx, slot].set(v[:, 0], mode="drop")
        o = kops.paged_attention(q[:, 0], kp_l, vp_l, block_tables,
                                 lengths, impl=impl)
        a = layers.attn_out(p_l["attn"], o[:, None])
        x = x + a
        h2 = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
        if cfg.layer_runs[0][0] == MOE:
            f, _aux = moe.moe_ffn(p_l["moe"], cfg.moe, h2, cfg.act)
        else:
            f = layers.mlp(p_l["mlp"], h2, cfg.act)
        return x + f, (kp_l, vp_l)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["run_0"], pool.k,
                                               pool.v))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = M.unembed(params, cfg, x)[:, 0]
    return logits, PagePool(k_new, v_new)
