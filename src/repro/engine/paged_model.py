"""Jitted paged-KV forward passes for serving (uniform-attention archs).

This is the engine's "vLLM model runner" role.  Three entry points, all
``lax.scan``s over the stacked layer parameters of a single-run config
(DENSE or MOE pattern), reusing the substrate's MoE/MLP/norm code:

- ``prefill_step``: one (possibly chunked) prefill for one request.
  The chunk's K/V are scattered into the global page pool and the chunk
  attends *directly over the pages* via the paged flash-prefill Pallas
  kernel (``kernels/paged_prefill.py``) — no per-layer
  ``k_pages[block_table]`` materialization, no dense (S, NB*page) mask.
- ``decode_batch``: one token per sequence through the Pallas
  paged-attention decode kernel.
- ``mixed_step``: the fused continuous-batching step.  B decode tokens
  and K prefill chunks are flattened into ONE (1, B + K*S, d) token
  batch: embedding, norms, QKV/out projections, LoRA and the MLP/MoE
  all run over the unified token dim (so the MXU sees one big matmul
  per op instead of two small ones), and only attention forks — decode
  rows through the decode kernel, chunk rows through the paged-prefill
  kernel.  This is the vLLM-style mixed batch the engine's token-budget
  scheduler drives.

High-density LoRA (paper §3.2.1) is applied in-batch: every request
carries an adapter id into a gathered (adapter, d, r) x (adapter, r, out)
pair on the q/v projections — adapter 0 is the zero (base-model) adapter,
so mixed batches of base + N adapters run in one step.  ``mixed_step``
gathers one adapter pair per decode row and per chunk, so decode and
prefill rows of different adapters coexist in the same fused pass.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers, moe
from repro.models import model as M
from repro.models.config import DENSE, MOE, ModelConfig
from repro.models.params import Spec, abstract_params, init_params


def pageable(cfg: ModelConfig) -> bool:
    """True when the paged path supports this config (uniform attn run)."""
    return (len(cfg.layer_runs) == 1
            and cfg.layer_runs[0][0] in (DENSE, MOE)
            and cfg.num_codebooks == 0)


class PagePool(NamedTuple):
    k: jax.Array            # (L, P, page, Hkv, D)
    v: jax.Array


def init_pool(cfg: ModelConfig, num_pages: int, page_size: int,
              dtype=jnp.float32) -> PagePool:
    shape = (cfg.n_layers, num_pages, page_size, cfg.n_kv_heads,
             cfg.head_dim)
    return PagePool(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


# ---------------------------------------------------------------- LoRA
def lora_specs(cfg: ModelConfig, n_adapters: int, rank: int) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "A_q": Spec((n_adapters, d, rank), (None, None, None), "zeros"),
        "B_q": Spec((n_adapters, rank, h * hd), (None, None, None), "zeros"),
        "A_v": Spec((n_adapters, d, rank), (None, None, None), "zeros"),
        "B_v": Spec((n_adapters, rank, hkv * hd), (None, None, None),
                    "zeros"),
    }


def init_lora(cfg: ModelConfig, n_adapters: int, rank: int,
              dtype=jnp.float32):
    return init_params(lora_specs(cfg, n_adapters, rank), jax.random.PRNGKey(7),
                       dtype)


def make_adapter(cfg: ModelConfig, rank: int, key: jax.Array,
                 dtype=jnp.float32):
    """A single random (non-zero) adapter's weights."""
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    a_scale = 1.0 / (d ** 0.5)
    b_scale = 0.5 / (rank ** 0.5)       # strong enough to alter outputs
    return {
        "A_q": jax.random.normal(k1, (d, rank), dtype) * a_scale,
        "B_q": jax.random.normal(k2, (rank, h * hd), dtype) * b_scale,
        "A_v": jax.random.normal(k3, (d, rank), dtype) * a_scale,
        "B_v": jax.random.normal(k4, (rank, hkv * hd), dtype) * b_scale,
    }


def _lora_delta(lora, which, x, adapter_ids):
    """x: (B, S, d); adapter_ids: (B,) -> (B, S, out)."""
    a = lora[f"A_{which}"][adapter_ids]          # (B, d, r)
    b_ = lora[f"B_{which}"][adapter_ids]         # (B, r, out)
    return jnp.einsum("bsr,bro->bso", jnp.einsum("bsd,bdr->bsr", x, a), b_)


def _qkv_lora(p_attn, cfg, x, positions, lora, adapter_ids):
    q, k, v = layers.attn_qkv(p_attn, cfg, x, positions)
    if lora is not None:
        b, s = x.shape[:2]
        dq = _lora_delta(lora, "q", x, adapter_ids).reshape(
            b, s, cfg.n_heads, cfg.head_dim)
        dv = _lora_delta(lora, "v", x, adapter_ids).reshape(
            b, s, cfg.n_kv_heads, cfg.head_dim)
        # note: LoRA delta applied post-rope on q is an approximation we
        # avoid — recompute rope on the delta instead (rope is linear).
        sin, cos = layers.rope_freqs(positions, cfg.head_dim, cfg.rope_theta)
        q = q + layers.apply_rope(dq, sin, cos)
        v = v + dv
    return q, k, v


def _qkv_lora_groups(p_attn, cfg, x, positions, lora, groups):
    """Like ``_qkv_lora_mixed`` for a flattened token batch made of
    chunk-shaped *groups*: ``groups`` is a static list of
    ``(adapter_ids, n_chunks, chunk_width)`` covering the token dim in
    order.  The adapter pair is gathered once per chunk, not per token
    (all rows of a chunk share one request's adapter)."""
    q, k, v = layers.attn_qkv(p_attn, cfg, x, positions)
    if lora is not None:
        d_model = x.shape[-1]

        def delta(which, heads):
            parts, off = [], 0
            for aids, n, s in groups:
                seg = x[0, off:off + n * s].reshape(n, s, d_model)
                d_seg = _lora_delta(lora, which, seg, aids)
                parts.append(d_seg.reshape(n * s, heads, cfg.head_dim))
                off += n * s
            return jnp.concatenate(parts)[None]
        dq = delta("q", cfg.n_heads)
        dv = delta("v", cfg.n_kv_heads)
        sin, cos = layers.rope_freqs(positions, cfg.head_dim, cfg.rope_theta)
        q = q + layers.apply_rope(dq, sin, cos)
        v = v + dv
    return q, k, v


def _qkv_lora_mixed(p_attn, cfg, x, positions, lora, dec_adapter_ids,
                    pre_adapter_ids, n_dec, n_pre, s):
    """Like ``_qkv_lora`` for the flattened (1, B + K*S, d) mixed batch.

    The adapter pair is gathered once per *request* — (B, d, r) for the
    decode rows and (K, d, r) for the chunks — not per token: all S rows
    of a chunk share one adapter, so a per-token gather would stream S
    duplicate copies of the same weights per projection per layer."""
    q, k, v = layers.attn_qkv(p_attn, cfg, x, positions)
    if lora is not None:
        d_model = x.shape[-1]

        def delta(which, heads):
            d_dec = _lora_delta(lora, which, x[0, :n_dec, None],
                                dec_adapter_ids)               # (B, 1, out)
            d_pre = _lora_delta(lora, which,
                                x[0, n_dec:].reshape(n_pre, s, d_model),
                                pre_adapter_ids)               # (K, S, out)
            return jnp.concatenate(
                [d_dec.reshape(n_dec, heads, cfg.head_dim),
                 d_pre.reshape(n_pre * s, heads, cfg.head_dim)])[None]
        dq = delta("q", cfg.n_heads)
        dv = delta("v", cfg.n_kv_heads)
        sin, cos = layers.rope_freqs(positions, cfg.head_dim, cfg.rope_theta)
        q = q + layers.apply_rope(dq, sin, cos)
        v = v + dv
    return q, k, v


# ---------------------------------------------------------------- prefill
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "page_size", "impl"),
    donate_argnums=(1,))
def prefill_step(params, pool: PagePool, tokens: jax.Array,
                 block_table: jax.Array, ctx_len: jax.Array,
                 chunk_len: jax.Array, lora=None,
                 adapter_ids: Optional[jax.Array] = None, *,
                 cfg: ModelConfig, page_size: int, impl: str = "pallas"
                 ) -> Tuple[jax.Array, PagePool]:
    """One (possibly chunked) prefill for ONE request.

    tokens:      (1, s) current chunk (padded; ``chunk_len`` valid)
    block_table: (1, NB) pages covering [0, ctx+s)
    ctx_len:     scalar — tokens already in the pages (prefix cache +
                 earlier chunks)
    Returns (last-token logits (1, V), updated pool).
    """
    s = tokens.shape[1]
    positions = ctx_len + jnp.arange(s)[None]                  # (1, s)
    x = M.embed(params, cfg, tokens)
    ltype = cfg.layer_runs[0][0]
    ctx1 = jnp.reshape(ctx_len, (1,)).astype(jnp.int32)
    chunk1 = jnp.reshape(chunk_len, (1,)).astype(jnp.int32)

    def body(x, xs):
        p_l, kp_l, vp_l = xs
        h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
        q, k, v = _qkv_lora(p_l["attn"], cfg, h, positions, lora,
                            adapter_ids)
        # scatter the chunk's K/V into this layer's pages
        tok_pos = (ctx_len + jnp.arange(s))                    # (s,)
        in_range = jnp.arange(s) < chunk_len
        pidx = jnp.where(in_range, block_table[0, tok_pos // page_size],
                         kp_l.shape[0])                        # OOB -> drop
        slot = tok_pos % page_size
        kp_l = kp_l.at[pidx, slot].set(k[0], mode="drop")
        vp_l = vp_l.at[pidx, slot].set(v[0], mode="drop")
        # chunk attends directly over the pages (ctx + chunk), no gather
        o = kops.paged_prefill(q, kp_l, vp_l, block_table, ctx1, chunk1,
                               impl=impl)
        a = layers.attn_out(p_l["attn"], o)
        x = x + a
        h2 = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
        if ltype == MOE:
            f, _aux = moe.moe_ffn(p_l["moe"], cfg.moe, h2, cfg.act)
        else:
            f = layers.mlp(p_l["mlp"], h2, cfg.act)
        return x + f, (kp_l, vp_l)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["run_0"], pool.k,
                                               pool.v))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    # last valid token's logits
    last = jnp.take(x, jnp.maximum(chunk_len - 1, 0), axis=1)[:, None]
    logits = M.unembed(params, cfg, last)[:, 0]
    return logits, PagePool(k_new, v_new)


# ---------------------------------------------------------------- decode
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "page_size", "impl"),
    donate_argnums=(1,))
def decode_batch(params, pool: PagePool, tokens: jax.Array,
                 positions: jax.Array, block_tables: jax.Array,
                 active: jax.Array, lora=None,
                 adapter_ids: Optional[jax.Array] = None, *,
                 cfg: ModelConfig, page_size: int, impl: str = "pallas"
                 ) -> Tuple[jax.Array, PagePool]:
    """One decode step for a batch.

    tokens: (B,) int32; positions: (B,) next position (== current length);
    block_tables: (B, NB); active: (B,) bool (padding slots excluded).
    Returns (logits (B, V), updated pool).
    """
    b = tokens.shape[0]
    positions_ = positions[:, None]                            # (B, 1)
    x = M.embed(params, cfg, tokens[:, None])
    lengths = jnp.where(active, positions + 1, 0).astype(jnp.int32)
    bidx = jnp.arange(b)

    def body(x, xs):
        p_l, kp_l, vp_l = xs
        h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
        q, k, v = _qkv_lora(p_l["attn"], cfg, h, positions_, lora,
                            adapter_ids)
        pidx = jnp.where(active,
                         block_tables[bidx, positions // page_size],
                         kp_l.shape[0])                        # OOB -> drop
        slot = positions % page_size
        kp_l = kp_l.at[pidx, slot].set(k[:, 0], mode="drop")
        vp_l = vp_l.at[pidx, slot].set(v[:, 0], mode="drop")
        o = kops.paged_attention(q[:, 0], kp_l, vp_l, block_tables,
                                 lengths, impl=impl)
        a = layers.attn_out(p_l["attn"], o[:, None])
        x = x + a
        h2 = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
        if cfg.layer_runs[0][0] == MOE:
            f, _aux = moe.moe_ffn(p_l["moe"], cfg.moe, h2, cfg.act)
        else:
            f = layers.mlp(p_l["mlp"], h2, cfg.act)
        return x + f, (kp_l, vp_l)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["run_0"], pool.k,
                                               pool.v))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = M.unembed(params, cfg, x)[:, 0]
    return logits, PagePool(k_new, v_new)


# ---------------------------------------------------------------- mixed step
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "page_size", "impl"),
    donate_argnums=(1,))
def mixed_step(params, pool: PagePool,
               dec_tokens: jax.Array, dec_positions: jax.Array,
               dec_block_tables: jax.Array, dec_active: jax.Array,
               pre_tokens: jax.Array, pre_block_tables: jax.Array,
               pre_ctx: jax.Array, pre_chunk: jax.Array,
               lora=None, dec_adapter_ids: Optional[jax.Array] = None,
               pre_adapter_ids: Optional[jax.Array] = None, *,
               cfg: ModelConfig, page_size: int, impl: str = "pallas"
               ) -> Tuple[jax.Array, jax.Array, PagePool]:
    """One fused continuous-batching step: B decode tokens + K prefill
    chunks in a single forward pass over one flattened token batch.

    dec_tokens:       (B,) int32; dec_positions: (B,) next position
    dec_block_tables: (B, NBd); dec_active: (B,) bool
    pre_tokens:       (K, S) chunk tokens (padded; ``pre_chunk`` valid)
    pre_block_tables: (K, NBp); pre_ctx/pre_chunk: (K,) int32
                      (pre_chunk == 0 marks an idle prefill slot)
    Returns (decode logits (B, V), prefill last-token logits (K, V),
    updated pool).  The token budget of the pass is B + K*S.
    """
    b = dec_tokens.shape[0]
    kk, s = pre_tokens.shape
    h_, hkv = cfg.n_heads, cfg.n_kv_heads
    hd = cfg.head_dim
    ltype = cfg.layer_runs[0][0]

    pre_positions = pre_ctx[:, None] + jnp.arange(s)[None]     # (K, S)
    tokens_flat = jnp.concatenate([dec_tokens, pre_tokens.reshape(-1)])
    positions_flat = jnp.concatenate(
        [dec_positions, pre_positions.reshape(-1)])            # (T,)
    x = M.embed(params, cfg, tokens_flat[None])                # (1, T, d)
    dec_lengths = jnp.where(dec_active, dec_positions + 1, 0).astype(
        jnp.int32)
    bidx = jnp.arange(b)
    kidx = jnp.arange(kk)
    in_range = jnp.arange(s)[None] < pre_chunk[:, None]        # (K, S)

    def body(x, xs):
        p_l, kp_l, vp_l = xs
        oob = kp_l.shape[0]
        h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
        q, k, v = _qkv_lora_mixed(p_l["attn"], cfg, h,
                                  positions_flat[None], lora,
                                  dec_adapter_ids, pre_adapter_ids,
                                  b, kk, s)
        # scatter all new K/V (decode tokens + prefill chunks) at once
        pidx_d = jnp.where(dec_active,
                           dec_block_tables[bidx,
                                            dec_positions // page_size],
                           oob)
        pidx_p = jnp.where(
            in_range,
            pre_block_tables[kidx[:, None], pre_positions // page_size],
            oob)
        pidx = jnp.concatenate([pidx_d, pidx_p.reshape(-1)])
        slot = jnp.concatenate([dec_positions % page_size,
                                (pre_positions % page_size).reshape(-1)])
        kp_l = kp_l.at[pidx, slot].set(k[0], mode="drop")
        vp_l = vp_l.at[pidx, slot].set(v[0], mode="drop")
        # attention forks: decode rows vs chunk rows, both over pages
        o_dec = kops.paged_attention(q[0, :b], kp_l, vp_l,
                                     dec_block_tables, dec_lengths,
                                     impl=impl)                # (B, H, D)
        o_pre = kops.paged_prefill(q[0, b:].reshape(kk, s, h_, hd),
                                   kp_l, vp_l, pre_block_tables,
                                   pre_ctx, pre_chunk, impl=impl)
        o = jnp.concatenate([o_dec, o_pre.reshape(kk * s, h_, hd)])[None]
        a = layers.attn_out(p_l["attn"], o)
        x = x + a
        h2 = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
        if ltype == MOE:
            f, _aux = moe.moe_ffn(p_l["moe"], cfg.moe, h2, cfg.act)
        else:
            f = layers.mlp(p_l["mlp"], h2, cfg.act)
        return x + f, (kp_l, vp_l)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["run_0"], pool.k,
                                               pool.v))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    # only unembed the rows that produce tokens: every decode row plus
    # each chunk's last valid row
    sel = jnp.concatenate(
        [bidx, b + kidx * s + jnp.maximum(pre_chunk - 1, 0)])
    logits = M.unembed(params, cfg, x[0, sel][None])[0]        # (B+K, V)
    return logits[:b], logits[b:], PagePool(k_new, v_new)


# ------------------------------------------------- speculative verification
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "page_size", "impl"),
    donate_argnums=(1,))
def spec_decode_step(params, pool: PagePool, spec_tokens: jax.Array,
                     spec_ctx: jax.Array, spec_len: jax.Array,
                     spec_block_tables: jax.Array, lora=None,
                     adapter_ids: Optional[jax.Array] = None, *,
                     cfg: ModelConfig, page_size: int, impl: str = "pallas"
                     ) -> Tuple[jax.Array, PagePool]:
    """One speculative decode step: every decode row is a short
    multi-query chunk ``[last_token, draft_1..draft_d]`` verified in a
    single pass.

    spec_tokens: (B, SD) int32 — row i feeds its last sampled token at
                 position ``spec_ctx[i]`` followed by the drafter's
                 proposals (padded; ``spec_len`` valid, 0 = idle slot)
    spec_ctx:    (B,) tokens already in the pages (== the last sampled
                 token's position)
    Returns logits for EVERY chunk row, (B, SD, V): row j is the
    model's distribution after consuming drafts[:j], which is exactly
    what acceptance needs — unlike ``mixed_step``, which only unembeds
    each chunk's last row.  KV for all fed tokens (drafts included) is
    scattered into the pages; rejected drafts leave stale slots past
    the accepted length that attention masks out (lengths-bounded) and
    the next step's real tokens overwrite in place — rollback costs
    nothing.
    """
    b, sd = spec_tokens.shape
    positions = spec_ctx[:, None] + jnp.arange(sd)[None]       # (B, SD)
    positions_flat = positions.reshape(-1)
    x = M.embed(params, cfg, spec_tokens.reshape(-1)[None])    # (1, B*SD, d)
    bidx = jnp.arange(b)
    in_range = jnp.arange(sd)[None] < spec_len[:, None]        # (B, SD)
    ltype = cfg.layer_runs[0][0]

    def body(x, xs):
        p_l, kp_l, vp_l = xs
        oob = kp_l.shape[0]
        h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
        q, k, v = _qkv_lora_groups(p_l["attn"], cfg, h,
                                   positions_flat[None], lora,
                                   [(adapter_ids, b, sd)])
        pidx = jnp.where(in_range,
                         spec_block_tables[bidx[:, None],
                                           positions // page_size],
                         oob)                                  # OOB -> drop
        slot = positions % page_size
        kp_l = kp_l.at[pidx.reshape(-1), slot.reshape(-1)].set(
            k[0], mode="drop")
        vp_l = vp_l.at[pidx.reshape(-1), slot.reshape(-1)].set(
            v[0], mode="drop")
        o = kops.paged_verify(
            q[0].reshape(b, sd, cfg.n_heads, cfg.head_dim), kp_l, vp_l,
            spec_block_tables, spec_ctx, spec_len, impl=impl)
        a = layers.attn_out(p_l["attn"],
                            o.reshape(b * sd, cfg.n_heads,
                                      cfg.head_dim)[None])
        x = x + a
        h2 = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
        if ltype == MOE:
            f, _aux = moe.moe_ffn(p_l["moe"], cfg.moe, h2, cfg.act)
        else:
            f = layers.mlp(p_l["mlp"], h2, cfg.act)
        return x + f, (kp_l, vp_l)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["run_0"], pool.k,
                                               pool.v))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = M.unembed(params, cfg, x)[0]                      # (B*SD, V)
    return logits.reshape(b, sd, -1), PagePool(k_new, v_new)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "page_size", "impl"),
    donate_argnums=(1,))
def spec_mixed_step(params, pool: PagePool, spec_tokens: jax.Array,
                    spec_ctx: jax.Array, spec_len: jax.Array,
                    spec_block_tables: jax.Array,
                    pre_tokens: jax.Array, pre_block_tables: jax.Array,
                    pre_ctx: jax.Array, pre_chunk: jax.Array,
                    lora=None,
                    spec_adapter_ids: Optional[jax.Array] = None,
                    pre_adapter_ids: Optional[jax.Array] = None, *,
                    cfg: ModelConfig, page_size: int, impl: str = "pallas"
                    ) -> Tuple[jax.Array, jax.Array, PagePool]:
    """``spec_decode_step`` fused with prefill chunks: B speculative
    decode chunks + K prefill chunks flattened into ONE pass (the
    spec-enabled sibling of ``mixed_step``).  Both groups ride the
    paged-prefill kernel — speculative lanes are just short chunks at a
    dynamic context offset — and only the unembed differs: ALL spec
    rows produce logits (verification needs every draft position), one
    last-row logit per prefill chunk.  Returns
    (spec logits (B, SD, V), prefill last-token logits (K, V), pool).
    """
    b, sd = spec_tokens.shape
    kk, s = pre_tokens.shape
    h_, hd = cfg.n_heads, cfg.head_dim
    ltype = cfg.layer_runs[0][0]

    spec_positions = spec_ctx[:, None] + jnp.arange(sd)[None]  # (B, SD)
    pre_positions = pre_ctx[:, None] + jnp.arange(s)[None]     # (K, S)
    tokens_flat = jnp.concatenate([spec_tokens.reshape(-1),
                                   pre_tokens.reshape(-1)])
    positions_flat = jnp.concatenate([spec_positions.reshape(-1),
                                      pre_positions.reshape(-1)])
    x = M.embed(params, cfg, tokens_flat[None])                # (1, T, d)
    bidx = jnp.arange(b)
    kidx = jnp.arange(kk)
    in_spec = jnp.arange(sd)[None] < spec_len[:, None]         # (B, SD)
    in_pre = jnp.arange(s)[None] < pre_chunk[:, None]          # (K, S)

    def body(x, xs):
        p_l, kp_l, vp_l = xs
        oob = kp_l.shape[0]
        h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
        q, k, v = _qkv_lora_groups(p_l["attn"], cfg, h,
                                   positions_flat[None], lora,
                                   [(spec_adapter_ids, b, sd),
                                    (pre_adapter_ids, kk, s)])
        pidx_s = jnp.where(in_spec,
                           spec_block_tables[bidx[:, None],
                                             spec_positions // page_size],
                           oob)
        pidx_p = jnp.where(
            in_pre,
            pre_block_tables[kidx[:, None], pre_positions // page_size],
            oob)
        pidx = jnp.concatenate([pidx_s.reshape(-1), pidx_p.reshape(-1)])
        slot = jnp.concatenate(
            [(spec_positions % page_size).reshape(-1),
             (pre_positions % page_size).reshape(-1)])
        kp_l = kp_l.at[pidx, slot].set(k[0], mode="drop")
        vp_l = vp_l.at[pidx, slot].set(v[0], mode="drop")
        o_spec = kops.paged_verify(
            q[0, :b * sd].reshape(b, sd, h_, hd), kp_l, vp_l,
            spec_block_tables, spec_ctx, spec_len, impl=impl)
        o_pre = kops.paged_prefill(
            q[0, b * sd:].reshape(kk, s, h_, hd), kp_l, vp_l,
            pre_block_tables, pre_ctx, pre_chunk, impl=impl)
        o = jnp.concatenate([o_spec.reshape(b * sd, h_, hd),
                             o_pre.reshape(kk * s, h_, hd)])[None]
        a = layers.attn_out(p_l["attn"], o)
        x = x + a
        h2 = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
        if ltype == MOE:
            f, _aux = moe.moe_ffn(p_l["moe"], cfg.moe, h2, cfg.act)
        else:
            f = layers.mlp(p_l["mlp"], h2, cfg.act)
        return x + f, (kp_l, vp_l)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["run_0"], pool.k,
                                               pool.v))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    # every spec row + each prefill chunk's last valid row
    sel = jnp.concatenate(
        [jnp.arange(b * sd),
         b * sd + kidx * s + jnp.maximum(pre_chunk - 1, 0)])
    logits = M.unembed(params, cfg, x[0, sel][None])[0]        # (B*SD+K, V)
    return (logits[:b * sd].reshape(b, sd, -1), logits[b * sd:],
            PagePool(k_new, v_new))
