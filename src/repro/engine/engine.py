"""Continuous-batching inference engine (the "vLLM" role in the paper).

One ``InferenceEngine`` = one serving pod's engine process.  Since the
scheduler-core refactor it is a thin composition of two layers behind
the unchanged ``submit/step/metrics/match_prefix_len`` handle contract:

- :class:`repro.engine.scheduler.Scheduler` — the pure-Python unified
  scheduler (admission incl. cache-aware deferral, per-step token
  budget with chunk trimming, preemption, finish/stop bookkeeping, and
  P/D roles).  The SAME class drives the cluster simulator's SimEngine,
  so scheduling semantics cannot drift between the real data plane and
  the simulator.
- :class:`repro.engine.runner.ModelRunner` — the JAX data plane: jitted
  ``mixed_step``/``decode_batch``/``prefill_step`` calls over donated
  ``PagePool`` state, persistent preallocated host input buffers, the
  LoRA bank and the sampling PRNG stream.

Scheduling is a vLLM-style **fused mixed batch** under a per-step token
budget: every ``step()`` packs up to ``max_batch`` decode tokens plus
chunks from up to ``max_prefills`` concurrently-PREFILLING requests
into one jitted forward pass (``paged_model.mixed_step``), so long
prefills no longer stall decoding.  ``mixed_batching=False`` restores
the legacy two-phase scheduler.

P/D disaggregation (paper §3.2.5): ``role="prefill"`` engines prefill,
publish KV pages through the distributed pool and hand each request to
a decode engine via the ``handoff`` callable; ``role="decode"`` engines
pull the prefilled pages from the pool by block hash at admission and
only recompute the tail block.  ``python -m repro.launch.serve --roles
2P2D`` wires a real disaggregated pod group end-to-end.

The engine takes an injectable ``clock`` so it runs identically under
wall-clock (CPU examples/tests) and under the discrete-event cluster
simulator (repro.core.sim).  A ``kv_pool_client`` hook connects it to
the distributed KV cache pool (repro.core.kvcache): local prefix misses
consult the pool by block hash; newly filled pages are published back.
"""
from __future__ import annotations

import queue
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Callable, List, Optional

from repro.core.kvcache.tiers import (CompressedPage, HostPagePool,
                                      SSDPagePool, compress_page,
                                      decompress_page, payload_nbytes,
                                      validate_wire_dtype)
from repro.engine import paged_model as PM
from repro.engine.page_table import PageAllocator, chunk_hashes
from repro.engine.request import Request, RequestState
from repro.engine.runner import ModelRunner
from repro.engine.scheduler import (EngineMetrics, PENDING_TOKEN,  # noqa: F401
                                    ScheduleOutput, Scheduler,
                                    SchedulerConfig, window_throughput)
from repro.models.config import ModelConfig


@dataclass
class EngineConfig:
    page_size: int = 16
    num_pages: int = 512
    max_batch: int = 8              # decode slots
    max_pages_per_seq: int = 32     # block-table width
    chunk_size: int = 64            # chunked-prefill chunk
    chunked_prefill: bool = True
    prefix_caching: bool = True
    impl: str = "pallas"            # pallas | ref
    dtype: str = "float32"
    lora_rank: int = 8
    max_adapters: int = 8
    # -- high-density multi-LoRA serving --
    # auto-register unknown adapters at submit (single-engine/dev
    # ergonomics).  False => residency is the control plane's job
    # (LoRAController.sync): requests queue behind the scheduler's
    # adapter_ready gate until the adapter is loaded, or shed after
    # lora_queue_timeout_s — never silently serving base-model outputs.
    lora_autoload: bool = True
    lora_queue_timeout_s: float = 30.0
    # bounded host-DRAM adapter tier backing the HBM bank's LRU
    # cascade (entries, not bytes — adapters are tiny next to KV);
    # 0 disables (evictions drop to the name-keyed artifact store)
    host_adapter_slots: int = 32
    # -- fused mixed-batch scheduler --
    mixed_batching: bool = True     # False => legacy two-phase scheduler
    max_prefills: int = 2           # concurrent PREFILLING requests
    token_budget: int = 0           # 0 => max_batch + max_prefills*chunk
    # -- P/D disaggregation --
    role: str = "mixed"             # mixed | prefill | decode
    # -- tiered KV cache --
    # host-DRAM tier capacity; 0 disables the tier (no eviction
    # cascade, drop-and-recompute preemption — the pre-tier engine)
    host_cache_gb: float = 0.0
    # SSD third tier below host DRAM; 0 disables.  Host-tier evictions
    # cascade into it via asynchronous write-behind (a daemon thread
    # pickling payloads under ``ssd_dir``), and the admission walk /
    # swap resume consult it after host, before the distributed pool.
    # Payloads are never quantized — SSD resume is byte-identical.
    ssd_cache_gb: float = 0.0
    ssd_dir: Optional[str] = None   # None => a per-engine temp dir
    # wire format for distributed-pool page payloads: "fp" publishes
    # the raw arrays (byte-exact), "int8" quantizes with per-layer
    # scales (≈4x fewer handoff bytes, parity within
    # tiers.INT8_WIRE_MAX_REL_ERR of the per-layer max-abs)
    wire_dtype: str = "fp"
    # pool-handoff transfers stream in groups of this many pages
    # (0 => eager whole-payload, the pre-tier behavior)
    handoff_chunk_pages: int = 4
    swap_preemption: bool = True    # swap to host tier when available
    # -- SLO-aware scheduling (scheduler.DEFAULT_SLO_CLASSES targets) --
    slo_aware: bool = False         # deadline-aware admission/preemption
    slo_classes: Optional[dict] = None      # None => scheduler defaults
    slo_preempt_headroom: float = 0.25
    slo_preempt_cooldown_s: float = 1.0
    # -- crash-recovery checkpoint policy (the recovery log) --
    # publish a running decode's full KV blocks to the pool every
    # this-many new sequence tokens (0 disables), bounded per pass by
    # ckpt_budget_bytes (0 => unbounded)
    ckpt_interval_tokens: int = 0
    ckpt_budget_bytes: int = 0
    # -- speculative n-gram decoding --
    # max prompt-lookup draft tokens verified per decode row in one
    # fused pass (0 disables).  Drafts spend step budget LAST and the
    # per-request acceptance EWMA backs them off on low-acceptance
    # outputs — see scheduler.SchedulerConfig.
    spec_tokens: int = 0
    spec_ngram_max: int = 3
    spec_ngram_min: int = 1
    spec_probe_interval: int = 50
    # -- async overlapped step loop --
    # dispatch step N+1's host scheduling + input prep while step N
    # still runs on device (decode-only steps overlap; prefill/mixed/
    # speculative steps resolve the in-flight tokens first).  Output
    # tokens stay byte-identical; only readback is deferred one step.
    async_loop: bool = False

    @property
    def step_token_budget(self) -> int:
        return self.scheduler_config().step_token_budget

    def scheduler_config(self) -> SchedulerConfig:
        kw = {}
        if self.slo_classes is not None:
            kw["slo_classes"] = dict(self.slo_classes)
        return SchedulerConfig(
            page_size=self.page_size, max_batch=self.max_batch,
            max_pages_per_seq=self.max_pages_per_seq,
            chunk_size=self.chunk_size,
            chunked_prefill=self.chunked_prefill,
            prefix_caching=self.prefix_caching,
            mixed_batching=self.mixed_batching,
            max_prefills=self.max_prefills,
            token_budget=self.token_budget, role=self.role,
            lora_queue_timeout_s=self.lora_queue_timeout_s,
            handoff_chunk_pages=self.handoff_chunk_pages,
            swap_preemption=self.swap_preemption,
            slo_aware=self.slo_aware,
            slo_preempt_headroom=self.slo_preempt_headroom,
            slo_preempt_cooldown_s=self.slo_preempt_cooldown_s,
            ckpt_interval_tokens=self.ckpt_interval_tokens,
            ckpt_budget_bytes=self.ckpt_budget_bytes,
            spec_tokens=self.spec_tokens,
            spec_ngram_max=self.spec_ngram_max,
            spec_ngram_min=self.spec_ngram_min,
            spec_probe_interval=self.spec_probe_interval, **kw)


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig = None,
                 params=None, clock: Callable[[], float] = time.monotonic,
                 kv_pool_client=None, engine_id: str = "engine-0",
                 seed: int = 0, ssd_pool=None):
        ecfg = ecfg or EngineConfig()
        if not PM.pageable(cfg):
            raise ValueError(
                f"{cfg.name}: paged engine requires a uniform dense/moe "
                "attention pattern; use the slot engine for hybrid/SSM")
        self.cfg, self.ecfg = cfg, ecfg
        self.engine_id = engine_id
        self.clock = clock
        self.kv_pool = kv_pool_client
        validate_wire_dtype(ecfg.wire_dtype)
        self.runner = ModelRunner(cfg, ecfg, params=params, seed=seed)
        # host-DRAM KV tier: device evictions cascade into it and
        # preemption swaps to it instead of recomputing
        self.host_pool = None
        if ecfg.host_cache_gb > 0:
            self.host_pool = HostPagePool(
                capacity_bytes=int(ecfg.host_cache_gb * (1 << 30)))
        # SSD third tier (write-behind, file-backed): host evictions
        # cascade here so idle-session prefixes and parked swap entries
        # survive host pressure and resume byte-identically
        self.ssd_pool = None
        if ssd_pool is not None and self.host_pool is not None:
            # host-shared SSD tier: the launcher passes one
            # SharedSSDPool per host; this engine attaches a per-engine
            # accounting view (same interface as a private pool, plus
            # cross-engine hit classification)
            self.ssd_pool = ssd_pool.view(engine_id) \
                if hasattr(ssd_pool, "view") else ssd_pool
        elif ecfg.ssd_cache_gb > 0 and self.host_pool is not None:
            ssd_dir = ecfg.ssd_dir or tempfile.mkdtemp(
                prefix=f"kv-ssd-{engine_id}-")
            self.ssd_pool = SSDPagePool(
                capacity_bytes=int(ecfg.ssd_cache_gb * (1 << 30)),
                directory=ssd_dir)
        self.sched = Scheduler(
            ecfg.scheduler_config(),
            PageAllocator(ecfg.num_pages, ecfg.page_size),
            kv_pool=kv_pool_client, engine_id=engine_id,
            install_page=self._install_page,
            publish_page=self._publish_page,
            host_pool=self.host_pool,
            page_payload=self.runner.page_payload,
            page_bytes=self.runner.page_bytes,
            adapter_ready=lambda name: name in self.runner.adapter_ids,
            ssd_pool=self.ssd_pool)
        # unloads requested while the adapter still serves an in-flight
        # batch are deferred (applied at the next step() once the last
        # user drains) — the control plane must never disturb a batch
        self._deferred_unloads: set = set()
        # async overlapped loop: the ONE in-flight dispatch record —
        # {reqs, tok_dev (device), idxs (placeholder positions)};
        # resolved when the next step is dispatched (or at drain)
        self._pending: Optional[dict] = None
        # wall time spent inside step(): with runner.device_wait_s it
        # yields host_overhead_frac — the gap the async loop hides
        self._step_wall_s = 0.0
        # predictive promotion: a daemon thread reads SSD pages off the
        # critical path; landed payloads queue here and are installed
        # into the host pool at step boundaries by the engine thread
        self._promote_req_q: Optional[queue.Queue] = None
        self._promote_q: Optional[queue.Queue] = None
        self._promoter: Optional[threading.Thread] = None

    # ----------------------------------------------------------- views
    @property
    def params(self):
        return self.runner.params

    @property
    def pool(self):
        return self.runner.pool

    @property
    def alloc(self) -> PageAllocator:
        return self.sched.alloc

    @property
    def waiting(self) -> List[Request]:
        return self.sched.waiting

    @property
    def prefills(self) -> List[Request]:
        return self.sched.prefills

    @property
    def running(self) -> List[Request]:
        return self.sched.running

    @property
    def finished(self) -> List[Request]:
        return self.sched.finished

    @property
    def prefilling(self) -> Optional[Request]:
        """Back-compat view of the (first) in-flight prefill."""
        return self.sched.prefills[0] if self.sched.prefills else None

    @property
    def handoff(self) -> Optional[Callable[[Request], None]]:
        return self.sched.handoff

    @handoff.setter
    def handoff(self, fn) -> None:
        self.sched.handoff = fn

    # ------------------------------------------------------------- LoRA
    def _adapters_in_use(self) -> set:
        """Adapters pinned by admitted (in-flight) requests."""
        return {r.lora_adapter
                for r in self.sched.running + self.sched.prefills
                if r.lora_adapter}

    def register_adapter(self, name: str, weights: dict = None) -> int:
        self._deferred_unloads.discard(name)   # re-wanted before unload
        return self.runner.register_adapter(
            name, weights, pinned=self._adapters_in_use())

    def unregister_adapter(self, name: str) -> None:
        if name in self._adapters_in_use():
            self._deferred_unloads.add(name)
            return
        self.runner.unregister_adapter(name)

    def _flush_deferred_unloads(self) -> None:
        if not self._deferred_unloads:
            return
        in_use = self._adapters_in_use()
        for name in list(self._deferred_unloads):
            if name not in in_use:
                self._deferred_unloads.discard(name)
                self.runner.unregister_adapter(name)

    @property
    def adapters(self) -> List[str]:
        return self.runner.adapters

    # ------------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        if (req.lora_adapter and self.ecfg.lora_autoload
                and req.lora_adapter not in self.runner.adapter_ids):
            try:
                self.register_adapter(req.lora_adapter)
            except RuntimeError:
                pass    # all slots pinned: queue behind adapter_ready
        self.sched.enqueue(req, self.clock())

    @property
    def has_work(self) -> bool:
        return self.sched.has_work

    # ------------------------------------------------------------- pool
    def _install_page(self, pid: int, payload, req: Request,
                      now: float, source: str = "pool",
                      stream: bool = False, nbytes: int = 0) -> None:
        """Payload hook for the Scheduler's page walk (pool OR host
        tier): write the fetched (k_page, v_page) arrays into a local
        device page, dequantizing compressed wire payloads first.  The
        synchronous real data plane installs streamed chunks in place;
        ``stream`` only changes the simulator's cost attribution."""
        if isinstance(payload, CompressedPage):
            payload = decompress_page(payload)
        self.runner.write_remote_page(pid, *payload)

    def _publish_page(self, pid: int, block_hash: str, req: Request,
                      now: float) -> None:
        """Payload hook for the Scheduler's prompt-page registration:
        copy the page off-device and publish it under its block hash —
        quantized to int8 with per-layer scales when the wire format
        asks for it, so a handoff moves ~4x fewer bytes."""
        payload = self.runner.page_payload(pid)
        size = self.runner.page_bytes
        if self.ecfg.wire_dtype == "int8":
            payload = compress_page(*payload)
            size = payload.nbytes
        self.kv_pool.publish(block_hash, payload, self.engine_id, now,
                             size_bytes=size)

    # -------------------------------------------------------- promotion
    def promote_session(self, session_id: str) -> int:
        """Prefetch a session's SSD-resident pages back into host DRAM
        ahead of its predicted next turn.  The SSD reads happen on a
        background daemon thread; payloads land in the host pool at the
        next step boundary, so promotion never stalls the data plane.
        Returns the number of pages queued for promotion."""
        if self.ssd_pool is None or self.host_pool is None:
            return 0
        keys = self.sched.session_promotable(session_id)
        if not keys:
            return 0
        self._ensure_promoter()
        self._promote_req_q.put((session_id, keys))
        return len(keys)

    def _ensure_promoter(self) -> None:
        if self._promoter is not None:
            return
        self._promote_req_q = queue.Queue()
        self._promote_q = queue.Queue()
        self._promoter = threading.Thread(
            target=self._promote_worker, daemon=True,
            name=f"kv-promote-{self.engine_id}")
        self._promoter.start()

    def _promote_worker(self) -> None:
        while True:
            sid, keys = self._promote_req_q.get()
            try:
                for key in keys:
                    payload = self.ssd_pool.get(key, self.clock())
                    if payload is not None:
                        self._promote_q.put((key, payload, sid))
            finally:
                self._promote_req_q.task_done()

    def _land_promotions(self) -> None:
        """Engine-thread drain: install prefetched pages in host DRAM."""
        if self._promote_q is None:
            return
        while True:
            try:
                key, payload, sid = self._promote_q.get_nowait()
            except queue.Empty:
                return
            self.sched.complete_promotion(
                key, payload,
                payload_nbytes(payload, self.runner.page_bytes),
                self.clock(), sid)

    def drain_promotions(self) -> None:
        """Block until all queued promotions have been read off SSD,
        then land them (deterministic tests / shutdown)."""
        if self._promote_req_q is not None:
            self._promote_req_q.join()
        self._land_promotions()

    # ------------------------------------------------------------- step
    def step(self) -> int:
        """One scheduler iteration.  Returns #tokens produced (sampled
        output tokens: one per decode row — several per row when a
        speculative step verified drafts — one per *completed* prefill;
        an unfinished prefill chunk produces none).  With
        ``async_loop`` the count for an overlapped decode step is the
        number DISPATCHED (read back when the next step is issued)."""
        t0 = time.perf_counter()
        try:
            self._flush_deferred_unloads()
            self._land_promotions()
            if self.ecfg.async_loop:
                return self._step_async()
            return self._exec(self.sched.schedule(self.clock()))
        finally:
            self._step_wall_s += time.perf_counter() - t0

    def _exec(self, out: ScheduleOutput) -> int:
        """Execute one declarative schedule synchronously."""
        if out.mode == "idle":
            return 0
        if out.mode == "decode":
            if out.spec:
                return self._step_spec(out)
            self._postprocess_decode(out.decode,
                                     self.runner.run_decode(out.decode))
            return len(out.decode)
        if out.mode == "prefill":      # legacy two-phase chunk
            work = out.prefills[0]
            logits = self.runner.run_prefill(work)
            return 1 if self._advance_prefill(work, logits) else 0
        if out.spec:
            return self._step_spec(out)
        # mixed: one fused decode+prefill pass under the token budget
        dec_logits, pre_logits = self.runner.run_mixed(out)
        produced = 0
        # prefill bookkeeping first (their chunks are already in the pool)
        for i, work in enumerate(out.prefills):
            if work.chunk_len == 0:
                continue            # budget-starved this step
            if self._advance_prefill(work, pre_logits[i][None]):
                produced += 1
        if out.decode:
            self._postprocess_decode(out.decode,
                                     dec_logits[:len(out.decode)])
            produced += len(out.decode)
        return produced

    def _step_spec(self, out: ScheduleOutput) -> int:
        """One speculative verification step: every decode row carries
        its drafts as a short multi-query chunk, prefill chunks (when
        live) ride the same fused pass; acceptance appends the model's
        own samples so the output stream is byte-identical to plain
        decoding."""
        spec_logits, pre_logits = self.runner.run_spec(out)
        produced = 0
        if pre_logits is not None:
            for i, work in enumerate(out.prefills):
                if work.chunk_len == 0:
                    continue
                if self._advance_prefill(work, pre_logits[i][None]):
                    produced += 1
        emitted = self.runner.verify_drafts(spec_logits, out.decode,
                                            out.spec)
        produced += self.sched.on_spec_batch(out.decode, out.spec,
                                             emitted, self.clock())
        return produced

    # ------------------------------------------------ async overlapped loop
    def _step_async(self) -> int:
        """Overlap host scheduling with device compute: a decode-only
        step is dispatched (input prep + forward + on-device sampling)
        WITHOUT waiting for the previous step's tokens — the scheduler
        plans on PENDING placeholders and the previous dispatch is
        resolved only after the new one is queued.  Any other step
        shape (prefill chunks, speculative drafts, idle) is a sync
        point: resolve first, re-plan on the real history, run the
        normal path."""
        out = self.sched.schedule(self.clock())
        if self._overlappable(out):
            return self._dispatch_async(out.decode)
        if self._pending is not None:
            self.drain_async()
            # resolution patched real tokens (and may have finished or
            # truncated requests) — the plan must be rebuilt on it
            out = self.sched.schedule(self.clock())
            if self._overlappable(out):
                return self._dispatch_async(out.decode)
        return self._exec(out)

    @staticmethod
    def _overlappable(out: ScheduleOutput) -> bool:
        return out.mode == "decode" and not out.spec and bool(out.decode)

    def _dispatch_async(self, reqs: List[Request]) -> int:
        reqs = list(reqs)
        tok_dev = self.runner.run_decode_async(reqs, self._pending)
        idxs = self.sched.on_decode_provisional(reqs, self.clock())
        prev, self._pending = self._pending, dict(
            reqs=reqs, tok_dev=tok_dev, idxs=idxs)
        if prev is not None:
            self._resolve_async(prev)
        return len(reqs)

    def _resolve_async(self, rec: dict) -> None:
        """Read back one dispatched step's sampled tokens and patch
        them over the PENDING placeholders.  Stop-token finishes are
        retroactive: the stop lands at its true position and anything
        dispatched past it (at most the one in-flight step) is
        truncated — output streams match the sync loop byte for byte.
        A placeholder that vanished meanwhile (preempt reset, stop
        truncation) is skipped by the guard."""
        toks = self.runner.readback(rec["tok_dev"])
        now = self.clock()
        for i, (r, idx) in enumerate(zip(rec["reqs"], rec["idxs"])):
            if (idx >= len(r.output_tokens)
                    or r.output_tokens[idx] != PENDING_TOKEN):
                continue
            tok = int(toks[i])
            r.output_tokens[idx] = tok
            r._pending_toks = max(
                getattr(r, "_pending_toks", 1) - 1, 0)
            sp = r.sampling
            if (self.sched.honor_stop_token and sp.stop_token is not None
                    and tok == sp.stop_token):
                if len(r.output_tokens) > idx + 1:
                    # over-dispatched past the stop: drop the tail
                    # (its placeholders die here; the later record's
                    # patch guard skips the vanished indices)
                    del r.output_tokens[idx + 1:]
                    del r.token_times[idx:]
                    r._pending_toks = 0
                if r.state is RequestState.RUNNING:
                    self.sched.maybe_finish(r, now)

    def drain_async(self) -> None:
        """Resolve the in-flight async dispatch (no-op when none)."""
        rec, self._pending = self._pending, None
        if rec is not None:
            self._resolve_async(rec)

    def _advance_prefill(self, work, logits) -> bool:
        """Advance one prefill chunk; True when it produced a token
        (prefill completed and its first token was sampled)."""
        req = work.req
        if not self.sched.note_prefill_progress(req, work.chunk_len):
            return False
        now = self.clock()
        self.sched.register_prompt_pages(req, now)
        if self.sched.wants_handoff:
            # disaggregated: KV is in the pool; hand the request to a
            # decode engine and free this engine for the next prefill.
            # The handoff is a synchronization point: the simulator
            # delays delivery past the pool's metadata lag, and the
            # synchronous real data plane instead flushes exactly the
            # records it just published (other engines' pending records
            # keep their lag) so the decode engine's admission walk
            # sees them rather than recomputing the whole prompt.
            self.sched.handoff_prefill(req, now)
            if self.kv_pool is not None:
                self.kv_pool.flush_hashes(
                    chunk_hashes(req.prompt_tokens, self.ecfg.page_size,
                                 req.lora_adapter or ""),
                    now)
            self.sched.deliver_handoff(req)
            return False
        tok = self.runner.sample(
            logits, [req],
            positions=[req.prompt_len + len(req.output_tokens)])[0]
        self.sched.finish_prefill(req, int(tok), now)
        self.sched.note_tokens(now, req.prompt_len + 1)
        return True

    def _postprocess_decode(self, reqs, logits) -> None:
        # per-position sampling keys: the sample for a given (seed,
        # absolute position) is the same whether this row is decoded
        # alone, in any batch order, or as part of a speculative
        # verification pass — the invariant byte-identity rests on
        new = self.runner.sample(
            logits, reqs,
            positions=[r.prompt_len + len(r.output_tokens)
                       for r in reqs])
        self.sched.on_decode_batch(reqs, new, self.clock())

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.has_work:
                # async loop: the final dispatch may still be in
                # flight after the last request "finished" on a
                # placeholder — resolve it before declaring idle
                self.drain_async()
                if not self.has_work:
                    return
            self.step()
        raise RuntimeError("engine did not drain")

    # ------------------------------------------------------------- metrics
    def metrics(self) -> EngineMetrics:
        m = self.sched.metrics(self.clock(),
                               loaded_adapters=tuple(self.adapters))
        m.device_wait_s = self.runner.device_wait_s
        m.lora_cold_loads = self.runner.adapter_loads
        m.lora_cold_load_s = self.runner.adapter_load_s
        m.lora_evictions = self.runner.adapter_evictions
        m.lora_host_hits = self.runner.adapter_host_hits
        if self._step_wall_s > 0:
            m.host_overhead_frac = min(max(
                1.0 - self.runner.device_wait_s / self._step_wall_s,
                0.0), 1.0)
        return m

    def match_prefix_len(self, tokens) -> int:
        """Prefix-cache coverage for router scoring (non-mutating)."""
        return self.sched.match_prefix_len(tokens)

    @property
    def queue_depth(self) -> int:
        """Cheap routing-load accessor (== metrics() num_running +
        num_waiting) — see SchedulerCore.queue_depth."""
        return self.sched.queue_depth
