"""Continuous-batching inference engine (the "vLLM" role in the paper).

One ``InferenceEngine`` = one serving pod's engine process: paged KV
cache + hash-indexed prefix cache, chunked prefill, batched decode,
high-density multi-LoRA, and the metric surface the AIBrix control
plane consumes (queue depth, KV utilization, token throughput, latency).

The engine takes an injectable ``clock`` so it runs identically under
wall-clock (CPU examples/tests) and under the discrete-event cluster
simulator (repro.core.sim).  A ``kv_pool_client`` hook connects it to
the distributed KV cache pool (repro.core.kvcache): local prefix misses
consult the pool by block hash; newly filled pages are published back.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import paged_model as PM
from repro.engine.page_table import PageAllocator, chunk_hashes
from repro.engine.request import Request, RequestState
from repro.engine.sampling import sample
from repro.models import model as M
from repro.models.config import ModelConfig


@dataclass
class EngineConfig:
    page_size: int = 16
    num_pages: int = 512
    max_batch: int = 8              # decode slots
    max_pages_per_seq: int = 32     # block-table width
    chunk_size: int = 64            # chunked-prefill chunk
    chunked_prefill: bool = True
    prefix_caching: bool = True
    impl: str = "pallas"            # pallas | ref
    dtype: str = "float32"
    lora_rank: int = 8
    max_adapters: int = 8


@dataclass
class EngineMetrics:
    """Snapshot consumed by gateway routing + autoscaler."""
    num_running: int = 0
    num_waiting: int = 0
    kv_utilization: float = 0.0
    tokens_per_sec: float = 0.0
    avg_latency: float = 0.0        # EWMA of per-request total latency
    avg_queue_time: float = 0.0
    admitted_requests: int = 0
    finished_requests: int = 0
    preemptions: int = 0
    prefix_hit_tokens: int = 0
    remote_hit_tokens: int = 0
    loaded_adapters: tuple = ()


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig = None,
                 params=None, clock: Callable[[], float] = time.monotonic,
                 kv_pool_client=None, engine_id: str = "engine-0",
                 seed: int = 0):
        ecfg = ecfg or EngineConfig()
        if not PM.pageable(cfg):
            raise ValueError(
                f"{cfg.name}: paged engine requires a uniform dense/moe "
                "attention pattern; use the slot engine for hybrid/SSM")
        self.cfg, self.ecfg = cfg, ecfg
        self.engine_id = engine_id
        self.clock = clock
        self.kv_pool = kv_pool_client
        dtype = jnp.dtype(ecfg.dtype)
        self.params = params if params is not None else M.init(
            cfg, jax.random.PRNGKey(seed), dtype)
        self.pool = PM.init_pool(cfg, ecfg.num_pages + 1, ecfg.page_size,
                                 dtype)  # +1: OOB scratch page for drops
        self.alloc = PageAllocator(ecfg.num_pages, ecfg.page_size)
        self.lora = PM.init_lora(cfg, ecfg.max_adapters, ecfg.lora_rank,
                                 dtype)
        self._adapter_ids: Dict[str, int] = {}
        self._free_adapter_slots = list(range(1, ecfg.max_adapters))
        self.waiting: List[Request] = []
        self.prefilling: Optional[Request] = None
        self.running: List[Request] = []
        self.finished: List[Request] = []
        self._key = jax.random.PRNGKey(seed + 1)
        self._m = EngineMetrics()
        self._tok_window: List[tuple] = []      # (t, ntokens)
        self._lat_ewma = 0.0
        self._q_ewma = 0.0

    # ------------------------------------------------------------- LoRA
    def register_adapter(self, name: str, weights: dict = None) -> int:
        """Dynamic high-density LoRA registration (paper §3.2.1)."""
        if name in self._adapter_ids:
            return self._adapter_ids[name]
        if not self._free_adapter_slots:
            raise RuntimeError("adapter slots exhausted")
        idx = self._free_adapter_slots.pop(0)
        if weights is None:
            weights = PM.make_adapter(self.cfg, self.ecfg.lora_rank,
                                      jax.random.fold_in(self._key, idx))
        self.lora = {k: self.lora[k].at[idx].set(weights[k])
                     for k in self.lora}
        self._adapter_ids[name] = idx
        return idx

    def unregister_adapter(self, name: str) -> None:
        idx = self._adapter_ids.pop(name, None)
        if idx is not None:
            self.lora = {k: self.lora[k].at[idx].set(0.0) for k in self.lora}
            self._free_adapter_slots.append(idx)

    @property
    def adapters(self) -> List[str]:
        return sorted(self._adapter_ids)

    # ------------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        if req.arrival_time == 0.0:
            req.arrival_time = self.clock()
        if req.lora_adapter and req.lora_adapter not in self._adapter_ids:
            self.register_adapter(req.lora_adapter)
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.prefilling)

    # ------------------------------------------------------------- helpers
    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.ecfg.page_size)

    def _try_admit(self) -> Optional[Request]:
        if not self.waiting or len(self.running) >= self.ecfg.max_batch:
            return None
        req = self.waiting[0]
        total = req.prompt_len + req.sampling.max_new_tokens
        if self._pages_for(total) > self.ecfg.max_pages_per_seq:
            req.state = RequestState.FAILED
            self.waiting.pop(0)
            return None
        now = self.clock()
        matched_pages: List[int] = []
        matched_tokens = 0
        if self.ecfg.prefix_caching:
            matched_pages, matched_tokens = self.alloc.match_prefix(
                req.prompt_tokens, now)
            if self.kv_pool is not None:
                rp, rt = self._pool_fetch(req, matched_tokens)
                matched_pages += rp
                matched_tokens += rt
        need = self._pages_for(total) - len(matched_pages)
        fresh = self.alloc.allocate(need, now)
        if fresh is None:
            self.alloc.release(matched_pages, now)
            return None     # no memory — stay queued
        self.waiting.pop(0)
        req.page_ids = matched_pages + fresh
        req.cached_prefix_tokens = matched_tokens
        req.prefill_done_tokens = matched_tokens
        req.state = RequestState.PREFILLING
        req.schedule_time = now
        self._m.admitted_requests += 1
        self._m.prefix_hit_tokens += matched_tokens
        self._q_ewma = 0.9 * self._q_ewma + 0.1 * req.queue_time
        return req

    def _pool_fetch(self, req: Request, have_tokens: int):
        """Extend a local prefix hit with pages from the distributed pool."""
        ps = self.ecfg.page_size
        hashes = chunk_hashes(req.prompt_tokens, ps)
        start = have_tokens // ps
        pages, tokens = [], 0
        for i in range(start, len(hashes)):
            if (i + 1) * ps >= req.prompt_len:
                break
            payload = self.kv_pool.fetch(hashes[i], self.engine_id)
            if payload is None:
                break
            pids = self.alloc.allocate(1, self.clock())
            if not pids:
                break
            k_page, v_page = payload
            self.pool = PM.PagePool(
                self.pool.k.at[:, pids[0]].set(k_page),
                self.pool.v.at[:, pids[0]].set(v_page))
            self.alloc.register_hash(pids[0], hashes[i])
            pages.append(pids[0])
            tokens += ps
            self._m.remote_hit_tokens += ps
        return pages, tokens

    # ------------------------------------------------------------- prefill
    def _prefill_one(self, req: Request) -> None:
        ecfg = self.ecfg
        s = ecfg.chunk_size if ecfg.chunked_prefill else \
            max(req.prompt_len, 1)
        start = req.prefill_done_tokens
        chunk = req.prompt_tokens[start:start + s]
        chunk_len = len(chunk)
        toks = np.zeros((1, s), np.int32)
        toks[0, :chunk_len] = chunk
        nb = ecfg.max_pages_per_seq
        bt = np.full((1, nb), ecfg.num_pages, np.int32)  # OOB scratch page
        bt[0, :len(req.page_ids)] = req.page_ids
        aid = self._adapter_ids.get(req.lora_adapter or "", 0)
        logits, self.pool = PM.prefill_step(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(bt),
            jnp.int32(start), jnp.int32(chunk_len),
            self.lora, jnp.asarray([aid], jnp.int32),
            cfg=self.cfg, page_size=ecfg.page_size, impl=ecfg.impl)
        req.prefill_done_tokens += chunk_len
        if req.prefill_done_tokens >= req.prompt_len:
            # register full prompt pages for prefix reuse + publish
            self._register_prompt_pages(req)
            tok = self._sample(logits, [req])[0]
            now = self.clock()
            req.output_tokens.append(int(tok))
            req.first_token_time = now
            req.state = RequestState.RUNNING
            self.running.append(req)
            self._note_tokens(req.prompt_len + 1)
            self._maybe_finish(req)

    def _register_prompt_pages(self, req: Request) -> None:
        if not self.ecfg.prefix_caching:
            return
        ps = self.ecfg.page_size
        hashes = chunk_hashes(req.prompt_tokens, ps)
        for i, h in enumerate(hashes):
            pid = req.page_ids[i]
            if self.alloc.pages[pid].block_hash is None:
                self.alloc.register_hash(pid, h)
                if self.kv_pool is not None:
                    self.kv_pool.publish(
                        h, (np.asarray(self.pool.k[:, pid]),
                            np.asarray(self.pool.v[:, pid])),
                        self.engine_id, self.clock())

    # ------------------------------------------------------------- decode
    def _decode(self) -> None:
        ecfg = self.ecfg
        b = ecfg.max_batch
        reqs = self.running[:b]
        toks = np.zeros(b, np.int32)
        pos = np.zeros(b, np.int32)
        bts = np.full((b, ecfg.max_pages_per_seq), ecfg.num_pages, np.int32)
        active = np.zeros(b, bool)
        aids = np.zeros(b, np.int32)
        for i, r in enumerate(reqs):
            toks[i] = r.output_tokens[-1]
            pos[i] = r.prompt_len + len(r.output_tokens) - 1
            bts[i, :len(r.page_ids)] = r.page_ids
            active[i] = True
            aids[i] = self._adapter_ids.get(r.lora_adapter or "", 0)
        logits, self.pool = PM.decode_batch(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(bts), jnp.asarray(active), self.lora,
            jnp.asarray(aids), cfg=self.cfg, page_size=ecfg.page_size,
            impl=ecfg.impl)
        new = self._sample(logits, reqs)
        now = self.clock()
        for i, r in enumerate(reqs):
            r.output_tokens.append(int(new[i]))
            r.token_times.append(now)
            # grow pages if the next token crosses a page boundary
            nxt = r.prompt_len + len(r.output_tokens)
            if self._pages_for(nxt + 1) > len(r.page_ids):
                pid = self.alloc.allocate(1, now)
                if pid is None:
                    self._preempt(r)
                    continue
                r.page_ids += pid
            self._maybe_finish(r)
        self._note_tokens(len(reqs))

    def _sample(self, logits, reqs) -> np.ndarray:
        b = logits.shape[0]
        temps = np.zeros(b, np.float32)
        tops = np.ones(b, np.float32)
        for i, r in enumerate(reqs[:b]):
            temps[i] = r.sampling.temperature
            tops[i] = r.sampling.top_p
        self._key, sub = jax.random.split(self._key)
        return np.asarray(sample(logits, sub, jnp.asarray(temps),
                                 top_k=0, top_p=jnp.asarray(tops)))

    def _maybe_finish(self, req: Request) -> None:
        sp = req.sampling
        done = len(req.output_tokens) >= sp.max_new_tokens or (
            sp.stop_token is not None
            and req.output_tokens[-1] == sp.stop_token)
        if not done:
            return
        now = self.clock()
        req.finish_time = now
        req.state = RequestState.FINISHED
        if req in self.running:
            self.running.remove(req)
        self.alloc.release(req.page_ids, now)
        req.page_ids = []
        self.finished.append(req)
        self._m.finished_requests += 1
        self._lat_ewma = (0.9 * self._lat_ewma + 0.1 * req.total_latency
                          if self._lat_ewma else req.total_latency)

    def _preempt(self, req: Request) -> None:
        self.running.remove(req)
        self.alloc.release(req.page_ids, self.clock())
        req.page_ids = []
        req.output_tokens = []
        req.prefill_done_tokens = 0
        req.state = RequestState.QUEUED
        self.waiting.insert(0, req)
        self._m.preemptions += 1

    # ------------------------------------------------------------- step
    def step(self) -> int:
        """One scheduler iteration.  Returns #tokens produced."""
        if self.prefilling is None:
            self.prefilling = self._try_admit()
        if self.prefilling is not None:
            req = self.prefilling
            self._prefill_one(req)
            if req.state != RequestState.PREFILLING:
                self.prefilling = None
            return 1
        if self.running:
            n = len(self.running[:self.ecfg.max_batch])
            self._decode()
            return n
        return 0

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.has_work:
                return
            self.step()
        raise RuntimeError("engine did not drain")

    # ------------------------------------------------------------- metrics
    def _note_tokens(self, n: int) -> None:
        self._tok_window.append((self.clock(), n))
        cutoff = self.clock() - 10.0
        self._tok_window = [(t, c) for t, c in self._tok_window
                            if t >= cutoff]

    def metrics(self) -> EngineMetrics:
        span = 10.0
        tput = sum(c for _, c in self._tok_window) / span
        return EngineMetrics(
            num_running=len(self.running),
            num_waiting=len(self.waiting),
            kv_utilization=self.alloc.utilization,
            tokens_per_sec=tput,
            avg_latency=self._lat_ewma,
            avg_queue_time=self._q_ewma,
            admitted_requests=self._m.admitted_requests,
            finished_requests=self._m.finished_requests,
            preemptions=self._m.preemptions,
            prefix_hit_tokens=self._m.prefix_hit_tokens,
            remote_hit_tokens=self._m.remote_hit_tokens,
            loaded_adapters=tuple(self.adapters))

    def match_prefix_len(self, tokens) -> int:
        """Prefix-cache coverage for router scoring (non-mutating)."""
        return self.alloc.match_len(tokens)
