"""Continuous-batching inference engine (the "vLLM" role in the paper).

One ``InferenceEngine`` = one serving pod's engine process: paged KV
cache + hash-indexed prefix cache, chunked prefill, batched decode,
high-density multi-LoRA, and the metric surface the AIBrix control
plane consumes (queue depth, KV utilization, token throughput, latency).

Scheduling is a vLLM-style **fused mixed batch** under a per-step token
budget: every ``step()`` packs up to ``max_batch`` decode tokens plus
chunks from up to ``max_prefills`` concurrently-PREFILLING requests
into one jitted forward pass (``paged_model.mixed_step``), so long
prefills no longer stall decoding.  The budget
(``token_budget``, default ``max_batch + max_prefills * chunk_size``)
governs *prefill* work: decode tokens (at most ``max_batch``, never
trimmed — decode latency has priority) are charged against it first
and prefill chunks are trimmed to what remains, with a 1-token floor
so an in-flight prefill always progresses.  Admission defers a request
whose prompt shares its leading block hash with an in-flight prefill so
it can reuse the prefix pages once they register (cache-aware
admission).  ``mixed_batching=False`` restores the legacy two-phase
scheduler (one prefill at a time, separate decode batches).

The engine takes an injectable ``clock`` so it runs identically under
wall-clock (CPU examples/tests) and under the discrete-event cluster
simulator (repro.core.sim).  A ``kv_pool_client`` hook connects it to
the distributed KV cache pool (repro.core.kvcache): local prefix misses
consult the pool by block hash; newly filled pages are published back.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import paged_model as PM
from repro.engine.page_table import PageAllocator, chunk_hashes
from repro.engine.request import Request, RequestState
from repro.engine.sampling import sample
from repro.models import model as M
from repro.models.config import ModelConfig


@dataclass
class EngineConfig:
    page_size: int = 16
    num_pages: int = 512
    max_batch: int = 8              # decode slots
    max_pages_per_seq: int = 32     # block-table width
    chunk_size: int = 64            # chunked-prefill chunk
    chunked_prefill: bool = True
    prefix_caching: bool = True
    impl: str = "pallas"            # pallas | ref
    dtype: str = "float32"
    lora_rank: int = 8
    max_adapters: int = 8
    # -- fused mixed-batch scheduler --
    mixed_batching: bool = True     # False => legacy two-phase scheduler
    max_prefills: int = 2           # concurrent PREFILLING requests
    token_budget: int = 0           # 0 => max_batch + max_prefills*chunk

    @property
    def step_token_budget(self) -> int:
        """Per-step budget charged decode-first; it trims prefill chunks
        only — the decode batch itself is bounded by ``max_batch``, not
        the budget (a budget below ``max_batch`` + 1 cannot throttle
        decode, it just starves prefill down to its 1-token floor)."""
        return self.token_budget or (
            self.max_batch + self.max_prefills * self.chunk_size)


def window_throughput(events, now: float, horizon: float = 10.0) -> float:
    """tokens/sec over the span actually observed within ``horizon``.

    ``events`` is a list of (timestamp, token_count).  A fixed-horizon
    divisor deflated early/low-traffic readings (skewing gateway routing
    and autoscaler signals); the 1 s floor keeps a single post-idle
    burst from reading as a huge rate spike when polled within the same
    instant.  Shared by InferenceEngine, SlotEngine and SimEngine so
    their tokens_per_sec semantics cannot drift apart.
    """
    window = [(t, c) for t, c in events if t >= now - horizon]
    if not window:
        return 0.0
    span = max(now - window[0][0], 1.0)
    return sum(c for _, c in window) / span


@dataclass
class EngineMetrics:
    """Snapshot consumed by gateway routing + autoscaler."""
    num_running: int = 0
    num_waiting: int = 0
    kv_utilization: float = 0.0
    tokens_per_sec: float = 0.0
    avg_latency: float = 0.0        # EWMA of per-request total latency
    avg_queue_time: float = 0.0
    admitted_requests: int = 0
    finished_requests: int = 0
    preemptions: int = 0
    prefix_hit_tokens: int = 0
    remote_hit_tokens: int = 0
    loaded_adapters: tuple = ()


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig = None,
                 params=None, clock: Callable[[], float] = time.monotonic,
                 kv_pool_client=None, engine_id: str = "engine-0",
                 seed: int = 0):
        ecfg = ecfg or EngineConfig()
        if not PM.pageable(cfg):
            raise ValueError(
                f"{cfg.name}: paged engine requires a uniform dense/moe "
                "attention pattern; use the slot engine for hybrid/SSM")
        self.cfg, self.ecfg = cfg, ecfg
        self.engine_id = engine_id
        self.clock = clock
        self.kv_pool = kv_pool_client
        dtype = jnp.dtype(ecfg.dtype)
        self.params = params if params is not None else M.init(
            cfg, jax.random.PRNGKey(seed), dtype)
        self.pool = PM.init_pool(cfg, ecfg.num_pages + 1, ecfg.page_size,
                                 dtype)  # +1: OOB scratch page for drops
        self.alloc = PageAllocator(ecfg.num_pages, ecfg.page_size)
        self.lora = PM.init_lora(cfg, ecfg.max_adapters, ecfg.lora_rank,
                                 dtype)
        self._adapter_ids: Dict[str, int] = {}
        self._free_adapter_slots = list(range(1, ecfg.max_adapters))
        self.waiting: List[Request] = []
        self.prefills: List[Request] = []      # concurrent PREFILLING
        self.running: List[Request] = []
        self.finished: List[Request] = []
        self._key = jax.random.PRNGKey(seed + 1)
        self._m = EngineMetrics()
        self._tok_window: List[tuple] = []      # (t, ntokens)
        self._lat_ewma = 0.0
        self._q_ewma = 0.0

    # ------------------------------------------------------------- LoRA
    def register_adapter(self, name: str, weights: dict = None) -> int:
        """Dynamic high-density LoRA registration (paper §3.2.1)."""
        if name in self._adapter_ids:
            return self._adapter_ids[name]
        if not self._free_adapter_slots:
            raise RuntimeError("adapter slots exhausted")
        idx = self._free_adapter_slots.pop(0)
        if weights is None:
            weights = PM.make_adapter(self.cfg, self.ecfg.lora_rank,
                                      jax.random.fold_in(self._key, idx))
        self.lora = {k: self.lora[k].at[idx].set(weights[k])
                     for k in self.lora}
        self._adapter_ids[name] = idx
        return idx

    def unregister_adapter(self, name: str) -> None:
        idx = self._adapter_ids.pop(name, None)
        if idx is not None:
            self.lora = {k: self.lora[k].at[idx].set(0.0) for k in self.lora}
            self._free_adapter_slots.append(idx)

    @property
    def adapters(self) -> List[str]:
        return sorted(self._adapter_ids)

    # ------------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        if req.arrival_time == 0.0:
            req.arrival_time = self.clock()
        if req.lora_adapter and req.lora_adapter not in self._adapter_ids:
            self.register_adapter(req.lora_adapter)
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.prefills)

    @property
    def prefilling(self) -> Optional[Request]:
        """Back-compat view of the (first) in-flight prefill."""
        return self.prefills[0] if self.prefills else None

    # ------------------------------------------------------------- helpers
    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.ecfg.page_size)

    def _first_hash(self, req: Request) -> Optional[str]:
        hs = chunk_hashes(req.prompt_tokens[:self.ecfg.page_size],
                          self.ecfg.page_size)
        return hs[0] if hs else None

    def _try_admit(self) -> Optional[Request]:
        if not self.waiting or (len(self.running) + len(self.prefills)
                                >= self.ecfg.max_batch):
            return None
        inflight_hashes = set()
        if self.ecfg.prefix_caching and self.prefills:
            inflight_hashes = {self._first_hash(p) for p in self.prefills}
            inflight_hashes.discard(None)
        req = None
        idx = 0
        while idx < len(self.waiting):
            cand = self.waiting[idx]
            total = cand.prompt_len + cand.sampling.max_new_tokens
            if self._pages_for(total) > self.ecfg.max_pages_per_seq:
                cand.state = RequestState.FAILED
                self.waiting.pop(idx)
                continue
            if (inflight_hashes
                    and cand.prompt_len > self.ecfg.page_size
                    and self._first_hash(cand) in inflight_hashes
                    and self.alloc.match_len(cand.prompt_tokens) == 0):
                # cache-aware admission: a prompt sharing its leading
                # block with an in-flight prefill waits for those pages
                # to register so it can reuse them instead of
                # recomputing the prefix — but only THAT request waits
                # (later waiters with distinct prefixes still get the
                # slot), and only when the wait can pay off: not when a
                # registered prefix already matches, nor when the prompt
                # is too short for match_prefix to ever reuse the block.
                idx += 1
                continue
            req = cand
            break
        if req is None:
            return None
        total = req.prompt_len + req.sampling.max_new_tokens
        now = self.clock()
        matched_pages: List[int] = []
        matched_tokens = 0
        if self.ecfg.prefix_caching:
            matched_pages, matched_tokens = self.alloc.match_prefix(
                req.prompt_tokens, now)
            if self.kv_pool is not None:
                rp, rt = self._pool_fetch(req, matched_tokens)
                matched_pages += rp
                matched_tokens += rt
        need = self._pages_for(total) - len(matched_pages)
        fresh = self.alloc.allocate(need, now)
        if fresh is None:
            self.alloc.release(matched_pages, now)
            return None     # no memory — stay queued
        self.waiting.remove(req)
        req.page_ids = matched_pages + fresh
        req.cached_prefix_tokens = matched_tokens
        req.prefill_done_tokens = matched_tokens
        req.state = RequestState.PREFILLING
        req.schedule_time = now
        self._m.admitted_requests += 1
        self._m.prefix_hit_tokens += matched_tokens
        self._q_ewma = 0.9 * self._q_ewma + 0.1 * req.queue_time
        return req

    def _pool_fetch(self, req: Request, have_tokens: int):
        """Extend a local prefix hit with pages from the distributed pool."""
        ps = self.ecfg.page_size
        hashes = chunk_hashes(req.prompt_tokens, ps)
        start = have_tokens // ps
        pages, tokens = [], 0
        for i in range(start, len(hashes)):
            if (i + 1) * ps >= req.prompt_len:
                break
            payload = self.kv_pool.fetch(hashes[i], self.engine_id)
            if payload is None:
                break
            pids = self.alloc.allocate(1, self.clock())
            if not pids:
                break
            k_page, v_page = payload
            self.pool = PM.PagePool(
                self.pool.k.at[:, pids[0]].set(k_page),
                self.pool.v.at[:, pids[0]].set(v_page))
            self.alloc.register_hash(pids[0], hashes[i])
            pages.append(pids[0])
            tokens += ps
            self._m.remote_hit_tokens += ps
        return pages, tokens

    # ------------------------------------------------------------- prefill
    def _prefill_one(self, req: Request) -> None:
        ecfg = self.ecfg
        s = ecfg.chunk_size if ecfg.chunked_prefill else \
            max(req.prompt_len, 1)
        start = req.prefill_done_tokens
        chunk = req.prompt_tokens[start:start + s]
        chunk_len = len(chunk)
        toks = np.zeros((1, s), np.int32)
        toks[0, :chunk_len] = chunk
        nb = self._bt_width(self._pages_for(start + chunk_len))
        bt = np.full((1, nb), ecfg.num_pages, np.int32)  # OOB scratch page
        n = min(len(req.page_ids), nb)
        bt[0, :n] = req.page_ids[:n]
        aid = self._adapter_ids.get(req.lora_adapter or "", 0)
        logits, self.pool = PM.prefill_step(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(bt),
            jnp.int32(start), jnp.int32(chunk_len),
            self.lora, jnp.asarray([aid], jnp.int32),
            cfg=self.cfg, page_size=ecfg.page_size, impl=ecfg.impl)
        req.prefill_done_tokens += chunk_len
        if req.prefill_done_tokens >= req.prompt_len:
            self._finish_prefill(req, logits)

    def _finish_prefill(self, req: Request, logits) -> None:
        """Prefill complete: register pages, sample the first token, move
        the request to the decode batch."""
        self._register_prompt_pages(req)
        tok = self._sample(logits, [req])[0]
        now = self.clock()
        req.output_tokens.append(int(tok))
        req.first_token_time = now
        req.state = RequestState.RUNNING
        self.running.append(req)
        self._note_tokens(req.prompt_len + 1)
        self._maybe_finish(req)

    def _register_prompt_pages(self, req: Request) -> None:
        if not self.ecfg.prefix_caching:
            return
        ps = self.ecfg.page_size
        hashes = chunk_hashes(req.prompt_tokens, ps)
        for i, h in enumerate(hashes):
            pid = req.page_ids[i]
            if self.alloc.pages[pid].block_hash is None:
                self.alloc.register_hash(pid, h)
                if self.kv_pool is not None:
                    self.kv_pool.publish(
                        h, (np.asarray(self.pool.k[:, pid]),
                            np.asarray(self.pool.v[:, pid])),
                        self.engine_id, self.clock())

    # ------------------------------------------------------------- decode
    def _bt_width(self, pages_needed: int) -> int:
        """Bucketed block-table width: bounds the decode kernel's page
        grid by what the batch actually uses (multiples of 4 to limit
        recompiles) instead of the full ``max_pages_per_seq``."""
        cap = -(-max(pages_needed, 1) // 4) * 4
        return min(cap, self.ecfg.max_pages_per_seq)

    def _decode_inputs(self, reqs):
        ecfg = self.ecfg
        b = ecfg.max_batch
        nb = self._bt_width(max((self._pages_for(
            r.prompt_len + len(r.output_tokens)) for r in reqs),
            default=1))
        toks = np.zeros(b, np.int32)
        pos = np.zeros(b, np.int32)
        bts = np.full((b, nb), ecfg.num_pages, np.int32)
        active = np.zeros(b, bool)
        aids = np.zeros(b, np.int32)
        for i, r in enumerate(reqs):
            toks[i] = r.output_tokens[-1]
            pos[i] = r.prompt_len + len(r.output_tokens) - 1
            n = min(len(r.page_ids), nb)
            bts[i, :n] = r.page_ids[:n]
            active[i] = True
            aids[i] = self._adapter_ids.get(r.lora_adapter or "", 0)
        return toks, pos, bts, active, aids

    def _decode(self) -> None:
        ecfg = self.ecfg
        reqs = self.running[:ecfg.max_batch]
        toks, pos, bts, active, aids = self._decode_inputs(reqs)
        logits, self.pool = PM.decode_batch(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(bts), jnp.asarray(active), self.lora,
            jnp.asarray(aids), cfg=self.cfg, page_size=ecfg.page_size,
            impl=ecfg.impl)
        self._postprocess_decode(reqs, logits)

    def _postprocess_decode(self, reqs, logits) -> None:
        new = self._sample(logits, reqs)
        now = self.clock()
        for i, r in enumerate(reqs):
            r.output_tokens.append(int(new[i]))
            r.token_times.append(now)
            # grow pages if the next token crosses a page boundary
            nxt = r.prompt_len + len(r.output_tokens)
            if self._pages_for(nxt + 1) > len(r.page_ids):
                pid = self.alloc.allocate(1, now)
                if pid is None:
                    self._preempt(r)
                    continue
                r.page_ids += pid
            self._maybe_finish(r)
        self._note_tokens(len(reqs))

    def _sample(self, logits, reqs) -> np.ndarray:
        b = logits.shape[0]
        temps = np.zeros(b, np.float32)
        tops = np.ones(b, np.float32)
        for i, r in enumerate(reqs[:b]):
            temps[i] = r.sampling.temperature
            tops[i] = r.sampling.top_p
        self._key, sub = jax.random.split(self._key)
        return np.asarray(sample(logits, sub, jnp.asarray(temps),
                                 top_k=0, top_p=jnp.asarray(tops)))

    def _maybe_finish(self, req: Request) -> None:
        sp = req.sampling
        done = len(req.output_tokens) >= sp.max_new_tokens or (
            sp.stop_token is not None
            and req.output_tokens[-1] == sp.stop_token)
        if not done:
            return
        now = self.clock()
        req.finish_time = now
        req.state = RequestState.FINISHED
        if req in self.running:
            self.running.remove(req)
        self.alloc.release(req.page_ids, now)
        req.page_ids = []
        self.finished.append(req)
        self._m.finished_requests += 1
        self._lat_ewma = (0.9 * self._lat_ewma + 0.1 * req.total_latency
                          if self._lat_ewma else req.total_latency)

    def _preempt(self, req: Request) -> None:
        self.running.remove(req)
        self.alloc.release(req.page_ids, self.clock())
        req.page_ids = []
        req.output_tokens = []
        req.prefill_done_tokens = 0
        req.state = RequestState.QUEUED
        self.waiting.insert(0, req)
        self._m.preemptions += 1

    # ------------------------------------------------------------- step
    def step(self) -> int:
        """One scheduler iteration.  Returns #tokens produced.

        Mixed batching (default): admit up to ``max_prefills`` requests
        into PREFILLING, then run ONE fused forward pass carrying every
        decode token plus a budget-trimmed chunk per in-flight prefill.
        Legacy (``mixed_batching=False``): one prefill at a time, decode
        only when no prefill is in flight.
        """
        if not self.ecfg.mixed_batching:
            return self._step_two_phase()
        while (len(self.prefills) < self.ecfg.max_prefills
               and len(self.prefills) * self.ecfg.chunk_size
               + min(len(self.running), self.ecfg.max_batch)
               < self.ecfg.step_token_budget):
            req = self._try_admit()
            if req is None:
                break
            self.prefills.append(req)
        if not self.prefills:
            if not self.running:
                return 0
            n = len(self.running[:self.ecfg.max_batch])
            self._decode()
            return n
        return self._mixed_step()

    def _step_two_phase(self) -> int:
        if not self.prefills:
            req = self._try_admit()
            if req is not None:
                self.prefills.append(req)
        if self.prefills:
            req = self.prefills[0]
            self._prefill_one(req)
            if req.state != RequestState.PREFILLING:
                self.prefills.remove(req)
            return 1
        if self.running:
            n = len(self.running[:self.ecfg.max_batch])
            self._decode()
            return n
        return 0

    def _mixed_step(self) -> int:
        """One fused decode+prefill pass under the step token budget."""
        ecfg = self.ecfg
        b = ecfg.max_batch
        kk = ecfg.max_prefills
        dec_reqs = self.running[:b]
        # decode tokens spend the budget first; floor of 1 guarantees an
        # in-flight prefill always progresses (liveness under a budget
        # tighter than the decode batch).
        budget = max(ecfg.step_token_budget - len(dec_reqs), 1)
        if ecfg.chunked_prefill:
            s = ecfg.chunk_size
        else:
            s = max(max(p.prompt_len - p.prefill_done_tokens
                        for p in self.prefills), 1)
        # trim each in-flight prefill's chunk to the remaining budget
        # (whole-prompt prefill is budget-exempt by definition)
        chunk_lens = []
        for p in self.prefills:
            c = min(s, p.prompt_len - p.prefill_done_tokens)
            if ecfg.chunked_prefill:
                c = min(c, budget)
            chunk_lens.append(c)
            budget -= c
        pre_toks = np.zeros((kk, s), np.int32)
        pre_ctx = np.zeros(kk, np.int32)
        pre_chunk = np.zeros(kk, np.int32)
        pre_aids = np.zeros(kk, np.int32)
        nb_pre = self._bt_width(max((self._pages_for(
            p.prefill_done_tokens + c) for p, c in
            zip(self.prefills, chunk_lens)), default=1))
        pre_bts = np.full((kk, nb_pre), ecfg.num_pages, np.int32)
        for i, (p, c) in enumerate(zip(self.prefills, chunk_lens)):
            start = p.prefill_done_tokens
            pre_toks[i, :c] = p.prompt_tokens[start:start + c]
            pre_ctx[i] = start
            pre_chunk[i] = c
            n = min(len(p.page_ids), nb_pre)
            pre_bts[i, :n] = p.page_ids[:n]
            pre_aids[i] = self._adapter_ids.get(p.lora_adapter or "", 0)
        toks, pos, bts, active, aids = self._decode_inputs(dec_reqs)
        dec_logits, pre_logits, self.pool = PM.mixed_step(
            self.params, self.pool, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(bts), jnp.asarray(active), jnp.asarray(pre_toks),
            jnp.asarray(pre_bts), jnp.asarray(pre_ctx),
            jnp.asarray(pre_chunk), self.lora, jnp.asarray(aids),
            jnp.asarray(pre_aids), cfg=self.cfg,
            page_size=ecfg.page_size, impl=ecfg.impl)
        produced = 0
        # prefill bookkeeping first (their chunks are already in the pool)
        for i, (p, c) in enumerate(list(zip(self.prefills, chunk_lens))):
            if c == 0:
                continue            # budget-starved this step
            p.prefill_done_tokens += c
            if p.prefill_done_tokens >= p.prompt_len:
                self.prefills.remove(p)
                self._finish_prefill(p, pre_logits[i][None])
                produced += 1
        if dec_reqs:
            self._postprocess_decode(dec_reqs, dec_logits[:len(dec_reqs)])
            produced += len(dec_reqs)
        return produced

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.has_work:
                return
            self.step()
        raise RuntimeError("engine did not drain")

    # ------------------------------------------------------------- metrics
    def _note_tokens(self, n: int) -> None:
        self._tok_window.append((self.clock(), n))
        cutoff = self.clock() - 10.0
        self._tok_window = [(t, c) for t, c in self._tok_window
                            if t >= cutoff]

    def metrics(self) -> EngineMetrics:
        tput = window_throughput(self._tok_window, self.clock())
        return EngineMetrics(
            num_running=len(self.running) + len(self.prefills),
            num_waiting=len(self.waiting),
            kv_utilization=self.alloc.utilization,
            tokens_per_sec=tput,
            avg_latency=self._lat_ewma,
            avg_queue_time=self._q_ewma,
            admitted_requests=self._m.admitted_requests,
            finished_requests=self._m.finished_requests,
            preemptions=self._m.preemptions,
            prefix_hit_tokens=self._m.prefix_hit_tokens,
            remote_hit_tokens=self._m.remote_hit_tokens,
            loaded_adapters=tuple(self.adapters))

    def match_prefix_len(self, tokens) -> int:
        """Prefix-cache coverage for router scoring (non-mutating)."""
        return self.alloc.match_len(tokens)
