"""Continuous-batching inference engine (the "vLLM" role in the paper).

One ``InferenceEngine`` = one serving pod's engine process.  Since the
scheduler-core refactor it is a thin composition of two layers behind
the unchanged ``submit/step/metrics/match_prefix_len`` handle contract:

- :class:`repro.engine.scheduler.Scheduler` — the pure-Python unified
  scheduler (admission incl. cache-aware deferral, per-step token
  budget with chunk trimming, preemption, finish/stop bookkeeping, and
  P/D roles).  The SAME class drives the cluster simulator's SimEngine,
  so scheduling semantics cannot drift between the real data plane and
  the simulator.
- :class:`repro.engine.runner.ModelRunner` — the JAX data plane: jitted
  ``mixed_step``/``decode_batch``/``prefill_step`` calls over donated
  ``PagePool`` state, persistent preallocated host input buffers, the
  LoRA bank and the sampling PRNG stream.

Scheduling is a vLLM-style **fused mixed batch** under a per-step token
budget: every ``step()`` packs up to ``max_batch`` decode tokens plus
chunks from up to ``max_prefills`` concurrently-PREFILLING requests
into one jitted forward pass (``paged_model.mixed_step``), so long
prefills no longer stall decoding.  ``mixed_batching=False`` restores
the legacy two-phase scheduler.

P/D disaggregation (paper §3.2.5): ``role="prefill"`` engines prefill,
publish KV pages through the distributed pool and hand each request to
a decode engine via the ``handoff`` callable; ``role="decode"`` engines
pull the prefilled pages from the pool by block hash at admission and
only recompute the tail block.  ``python -m repro.launch.serve --roles
2P2D`` wires a real disaggregated pod group end-to-end.

The engine takes an injectable ``clock`` so it runs identically under
wall-clock (CPU examples/tests) and under the discrete-event cluster
simulator (repro.core.sim).  A ``kv_pool_client`` hook connects it to
the distributed KV cache pool (repro.core.kvcache): local prefix misses
consult the pool by block hash; newly filled pages are published back.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional

from repro.core.kvcache.tiers import (CompressedPage, HostPagePool,
                                      compress_page, decompress_page,
                                      validate_wire_dtype)
from repro.engine import paged_model as PM
from repro.engine.page_table import PageAllocator, chunk_hashes
from repro.engine.request import Request
from repro.engine.runner import ModelRunner
from repro.engine.scheduler import (EngineMetrics, ScheduleOutput,  # noqa: F401
                                    Scheduler, SchedulerConfig,
                                    window_throughput)
from repro.models.config import ModelConfig


@dataclass
class EngineConfig:
    page_size: int = 16
    num_pages: int = 512
    max_batch: int = 8              # decode slots
    max_pages_per_seq: int = 32     # block-table width
    chunk_size: int = 64            # chunked-prefill chunk
    chunked_prefill: bool = True
    prefix_caching: bool = True
    impl: str = "pallas"            # pallas | ref
    dtype: str = "float32"
    lora_rank: int = 8
    max_adapters: int = 8
    # -- fused mixed-batch scheduler --
    mixed_batching: bool = True     # False => legacy two-phase scheduler
    max_prefills: int = 2           # concurrent PREFILLING requests
    token_budget: int = 0           # 0 => max_batch + max_prefills*chunk
    # -- P/D disaggregation --
    role: str = "mixed"             # mixed | prefill | decode
    # -- tiered KV cache --
    # host-DRAM tier capacity; 0 disables the tier (no eviction
    # cascade, drop-and-recompute preemption — the pre-tier engine)
    host_cache_gb: float = 0.0
    # wire format for distributed-pool page payloads: "fp" publishes
    # the raw arrays (byte-exact), "int8" quantizes with per-layer
    # scales (≈4x fewer handoff bytes, parity within
    # tiers.INT8_WIRE_MAX_REL_ERR of the per-layer max-abs)
    wire_dtype: str = "fp"
    # pool-handoff transfers stream in groups of this many pages
    # (0 => eager whole-payload, the pre-tier behavior)
    handoff_chunk_pages: int = 4
    swap_preemption: bool = True    # swap to host tier when available
    # -- SLO-aware scheduling (scheduler.DEFAULT_SLO_CLASSES targets) --
    slo_aware: bool = False         # deadline-aware admission/preemption
    slo_classes: Optional[dict] = None      # None => scheduler defaults
    slo_preempt_headroom: float = 0.25
    slo_preempt_cooldown_s: float = 1.0
    # -- crash-recovery checkpoint policy (the recovery log) --
    # publish a running decode's full KV blocks to the pool every
    # this-many new sequence tokens (0 disables), bounded per pass by
    # ckpt_budget_bytes (0 => unbounded)
    ckpt_interval_tokens: int = 0
    ckpt_budget_bytes: int = 0

    @property
    def step_token_budget(self) -> int:
        return self.scheduler_config().step_token_budget

    def scheduler_config(self) -> SchedulerConfig:
        kw = {}
        if self.slo_classes is not None:
            kw["slo_classes"] = dict(self.slo_classes)
        return SchedulerConfig(
            page_size=self.page_size, max_batch=self.max_batch,
            max_pages_per_seq=self.max_pages_per_seq,
            chunk_size=self.chunk_size,
            chunked_prefill=self.chunked_prefill,
            prefix_caching=self.prefix_caching,
            mixed_batching=self.mixed_batching,
            max_prefills=self.max_prefills,
            token_budget=self.token_budget, role=self.role,
            handoff_chunk_pages=self.handoff_chunk_pages,
            swap_preemption=self.swap_preemption,
            slo_aware=self.slo_aware,
            slo_preempt_headroom=self.slo_preempt_headroom,
            slo_preempt_cooldown_s=self.slo_preempt_cooldown_s,
            ckpt_interval_tokens=self.ckpt_interval_tokens,
            ckpt_budget_bytes=self.ckpt_budget_bytes, **kw)


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig = None,
                 params=None, clock: Callable[[], float] = time.monotonic,
                 kv_pool_client=None, engine_id: str = "engine-0",
                 seed: int = 0):
        ecfg = ecfg or EngineConfig()
        if not PM.pageable(cfg):
            raise ValueError(
                f"{cfg.name}: paged engine requires a uniform dense/moe "
                "attention pattern; use the slot engine for hybrid/SSM")
        self.cfg, self.ecfg = cfg, ecfg
        self.engine_id = engine_id
        self.clock = clock
        self.kv_pool = kv_pool_client
        validate_wire_dtype(ecfg.wire_dtype)
        self.runner = ModelRunner(cfg, ecfg, params=params, seed=seed)
        # host-DRAM KV tier: device evictions cascade into it and
        # preemption swaps to it instead of recomputing
        self.host_pool = None
        if ecfg.host_cache_gb > 0:
            self.host_pool = HostPagePool(
                capacity_bytes=int(ecfg.host_cache_gb * (1 << 30)))
        self.sched = Scheduler(
            ecfg.scheduler_config(),
            PageAllocator(ecfg.num_pages, ecfg.page_size),
            kv_pool=kv_pool_client, engine_id=engine_id,
            install_page=self._install_page,
            publish_page=self._publish_page,
            host_pool=self.host_pool,
            page_payload=self.runner.page_payload,
            page_bytes=self.runner.page_bytes)

    # ----------------------------------------------------------- views
    @property
    def params(self):
        return self.runner.params

    @property
    def pool(self):
        return self.runner.pool

    @property
    def alloc(self) -> PageAllocator:
        return self.sched.alloc

    @property
    def waiting(self) -> List[Request]:
        return self.sched.waiting

    @property
    def prefills(self) -> List[Request]:
        return self.sched.prefills

    @property
    def running(self) -> List[Request]:
        return self.sched.running

    @property
    def finished(self) -> List[Request]:
        return self.sched.finished

    @property
    def prefilling(self) -> Optional[Request]:
        """Back-compat view of the (first) in-flight prefill."""
        return self.sched.prefills[0] if self.sched.prefills else None

    @property
    def handoff(self) -> Optional[Callable[[Request], None]]:
        return self.sched.handoff

    @handoff.setter
    def handoff(self, fn) -> None:
        self.sched.handoff = fn

    # ------------------------------------------------------------- LoRA
    def register_adapter(self, name: str, weights: dict = None) -> int:
        return self.runner.register_adapter(name, weights)

    def unregister_adapter(self, name: str) -> None:
        self.runner.unregister_adapter(name)

    @property
    def adapters(self) -> List[str]:
        return self.runner.adapters

    # ------------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        if req.lora_adapter and \
                req.lora_adapter not in self.runner.adapter_ids:
            self.register_adapter(req.lora_adapter)
        self.sched.enqueue(req, self.clock())

    @property
    def has_work(self) -> bool:
        return self.sched.has_work

    # ------------------------------------------------------------- pool
    def _install_page(self, pid: int, payload, req: Request,
                      now: float, source: str = "pool",
                      stream: bool = False, nbytes: int = 0) -> None:
        """Payload hook for the Scheduler's page walk (pool OR host
        tier): write the fetched (k_page, v_page) arrays into a local
        device page, dequantizing compressed wire payloads first.  The
        synchronous real data plane installs streamed chunks in place;
        ``stream`` only changes the simulator's cost attribution."""
        if isinstance(payload, CompressedPage):
            payload = decompress_page(payload)
        self.runner.write_remote_page(pid, *payload)

    def _publish_page(self, pid: int, block_hash: str, req: Request,
                      now: float) -> None:
        """Payload hook for the Scheduler's prompt-page registration:
        copy the page off-device and publish it under its block hash —
        quantized to int8 with per-layer scales when the wire format
        asks for it, so a handoff moves ~4x fewer bytes."""
        payload = self.runner.page_payload(pid)
        size = self.runner.page_bytes
        if self.ecfg.wire_dtype == "int8":
            payload = compress_page(*payload)
            size = payload.nbytes
        self.kv_pool.publish(block_hash, payload, self.engine_id, now,
                             size_bytes=size)

    # ------------------------------------------------------------- step
    def step(self) -> int:
        """One scheduler iteration.  Returns #tokens produced (sampled
        output tokens: one per decode row, one per *completed* prefill —
        an unfinished prefill chunk produces none)."""
        out = self.sched.schedule(self.clock())
        if out.mode == "idle":
            return 0
        if out.mode == "decode":
            self._postprocess_decode(out.decode,
                                     self.runner.run_decode(out.decode))
            return len(out.decode)
        if out.mode == "prefill":      # legacy two-phase chunk
            work = out.prefills[0]
            logits = self.runner.run_prefill(work)
            return 1 if self._advance_prefill(work, logits) else 0
        # mixed: one fused decode+prefill pass under the token budget
        dec_logits, pre_logits = self.runner.run_mixed(out)
        produced = 0
        # prefill bookkeeping first (their chunks are already in the pool)
        for i, work in enumerate(out.prefills):
            if work.chunk_len == 0:
                continue            # budget-starved this step
            if self._advance_prefill(work, pre_logits[i][None]):
                produced += 1
        if out.decode:
            self._postprocess_decode(out.decode,
                                     dec_logits[:len(out.decode)])
            produced += len(out.decode)
        return produced

    def _advance_prefill(self, work, logits) -> bool:
        """Advance one prefill chunk; True when it produced a token
        (prefill completed and its first token was sampled)."""
        req = work.req
        if not self.sched.note_prefill_progress(req, work.chunk_len):
            return False
        now = self.clock()
        self.sched.register_prompt_pages(req, now)
        if self.sched.wants_handoff:
            # disaggregated: KV is in the pool; hand the request to a
            # decode engine and free this engine for the next prefill.
            # The handoff is a synchronization point: the simulator
            # delays delivery past the pool's metadata lag, and the
            # synchronous real data plane instead flushes exactly the
            # records it just published (other engines' pending records
            # keep their lag) so the decode engine's admission walk
            # sees them rather than recomputing the whole prompt.
            self.sched.handoff_prefill(req, now)
            if self.kv_pool is not None:
                self.kv_pool.flush_hashes(
                    chunk_hashes(req.prompt_tokens, self.ecfg.page_size),
                    now)
            self.sched.deliver_handoff(req)
            return False
        tok = self.runner.sample(logits, [req])[0]
        self.sched.finish_prefill(req, int(tok), now)
        self.sched.note_tokens(now, req.prompt_len + 1)
        return True

    def _postprocess_decode(self, reqs, logits) -> None:
        new = self.runner.sample(logits, reqs)
        self.sched.on_decode_batch(reqs, new, self.clock())

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.has_work:
                return
            self.step()
        raise RuntimeError("engine did not drain")

    # ------------------------------------------------------------- metrics
    def metrics(self) -> EngineMetrics:
        return self.sched.metrics(self.clock(),
                                  loaded_adapters=tuple(self.adapters))

    def match_prefix_len(self, tokens) -> int:
        """Prefix-cache coverage for router scoring (non-mutating)."""
        return self.sched.match_prefix_len(tokens)
