"""Prompt-lookup (n-gram) speculative drafting for the fused step.

Decode is bandwidth-bound on the roofline: every step streams the full
weights + per-sequence KV to emit ONE token per row.  Speculative
decoding does more work per HBM pass — a *drafter* proposes up to
``spec_tokens`` continuation tokens per decode row, the model verifies
all of them in one forward pass (the drafts ride the existing
``paged_prefill`` dynamic-context-offset path as a short multi-query
chunk of the decode row), and the longest draft prefix matching the
model's own sampled tokens is accepted.  Each verified step emits
``accepted + 1`` tokens (the bonus token is the model's sample at the
first divergence), so acceptance rate directly multiplies decode
throughput while staying *byte-identical* to the non-speculative run:
every emitted token is the model's own sample at its position.

The drafter here is prompt-lookup decoding (no draft model): match the
row's trailing n-gram against its own prompt + generated history and
propose the continuation of the most recent earlier occurrence.  Free
to compute, and very effective on repetitive workloads
(summarization, code edits, multi-turn chat with quoting).

:class:`DraftController` adds the adaptive backoff the scheduler
consults: a per-request acceptance EWMA shrinks the allowed draft
length (full -> 1 -> 0) when drafts keep missing, with a periodic
1-token probe so a request whose output turns repetitive later can
re-enable drafting.  Low-acceptance workloads therefore degrade to
plain decode steps (plus a rare probe) instead of paying verification
compute for nothing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

from repro.engine.request import Request


def ngram_propose(history: Sequence[int], max_draft: int,
                  ngram_max: int = 3, ngram_min: int = 1) -> List[int]:
    """Prompt-lookup draft: find the most recent earlier occurrence of
    the history's trailing n-gram (longest n first) and propose its
    continuation, up to ``max_draft`` tokens.

    Returns ``[]`` when no earlier occurrence with a continuation
    exists — the scheduler then runs a plain decode row.
    """
    n_hist = len(history)
    if max_draft <= 0 or n_hist < ngram_min + 1:
        return []
    for n in range(min(ngram_max, n_hist - 1), ngram_min - 1, -1):
        tail = list(history[-n:])
        # most recent earlier occurrence first (locality: recent
        # repetition predicts the continuation better than distant)
        for i in range(n_hist - n - 1, -1, -1):
            if list(history[i:i + n]) == tail:
                cont = list(history[i + n:i + n + max_draft])
                if cont:
                    return cont
    return []


@dataclass
class DraftController:
    """Adaptive per-request draft-length policy.

    Tracks an acceptance EWMA per request (stored on the request so it
    travels with migrations/handoffs) and maps it to an allowed draft
    length: ``max_draft`` while acceptance stays high, 1 in the
    marginal band, 0 when drafting keeps missing — with a 1-token probe
    every ``probe_interval`` scheduler passes so drafting can recover
    when the output turns repetitive again.  New requests start
    optimistic (EWMA 1.0): the first misses pay one short burst of
    wasted verify lanes, then the controller backs off.
    """
    max_draft: int
    ngram_max: int = 3
    ngram_min: int = 1
    ewma_alpha: float = 0.4         # update weight of the newest step
    full_threshold: float = 0.35    # EWMA >= this -> full-length drafts
    min_threshold: float = 0.15     # EWMA >= this -> 1-token drafts
    probe_interval: int = 50        # passes between probes when disabled

    def allowed(self, req: Request) -> int:
        ewma = getattr(req, "_spec_ewma", 1.0)
        if ewma >= self.full_threshold:
            return self.max_draft
        if ewma >= self.min_threshold:
            return 1
        cool = getattr(req, "_spec_cool", 0)
        if cool <= 0:
            req._spec_cool = self.probe_interval  # type: ignore
            return 1                # periodic probe re-tests acceptance
        req._spec_cool = cool - 1                 # type: ignore
        return 0

    def propose(self, req: Request, budget: int) -> List[int]:
        """The scheduler entry point: draft for one decode row, bounded
        by the adaptive allowance, the leftover token ``budget`` (drafts
        spend budget LAST, after decode rows and prefill chunks) and
        the tokens the request can still emit (a draft must never push
        KV writes past the pages ``max_new_tokens`` reserved)."""
        room = req.sampling.max_new_tokens - len(req.output_tokens) - 1
        d = min(self.allowed(req), budget, room)
        if d <= 0:
            return []
        history = list(req.prompt_tokens) + list(req.output_tokens)
        return ngram_propose(history, d, self.ngram_max, self.ngram_min)

    def observe(self, req: Request, drafted: int, accepted: int) -> None:
        if drafted <= 0:
            return
        ewma = getattr(req, "_spec_ewma", 1.0)
        a = self.ewma_alpha
        req._spec_ewma = (1 - a) * ewma + a * (accepted / drafted)  # type: ignore


@dataclass
class FixedLengthDrafter(DraftController):
    """Content-free drafter for the simulator: proposes the full
    allowed draft length regardless of history.  Sim token streams are
    synthetic zeros, which the n-gram matcher degenerates on (trailing
    overlap caps proposals at one token), so the sim swaps this in —
    the budget-last spending, EWMA backoff and accounting paths stay
    exactly the real engine's while ``spec_accept_rate`` shapes the
    synthetic acceptance."""

    def propose(self, req: Request, budget: int) -> List[int]:
        room = req.sampling.max_new_tokens - len(req.output_tokens) - 1
        d = min(self.allowed(req), budget, room)
        return [0] * d if d > 0 else []


def accept_length(drafts: Sequence[int], sampled: Sequence[int]) -> int:
    """Longest draft prefix the model's own samples confirm.  Row j of
    ``sampled`` is the model's token after consuming draft tokens
    ``drafts[:j]`` — a draft survives while it equals the sample at its
    position.  The emitted tokens are ``sampled[:m + 1]``: the ``m``
    confirmed drafts plus the bonus/correction sample at the first
    divergence (or past the last draft)."""
    m = 0
    while m < len(drafts) and m < len(sampled) - 1 \
            and int(sampled[m]) == int(drafts[m]):
        m += 1
    return m
