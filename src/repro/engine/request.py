"""Request model + per-request serving metrics (TTFT / ITL / queue time).

These metric fields are exactly what the AIBrix control plane consumes:
the gateway's least-latency policy reads ``total_latency``, the
autoscaler aggregates ``queue_time`` and token throughput, and the
benchmark harness reports the paper's Table-1 columns from them.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import List, Optional

_ids = itertools.count()


@dataclass
class SamplingParams:
    temperature: float = 0.0        # 0 => greedy
    top_k: int = 0                  # 0 => disabled
    top_p: float = 1.0
    max_new_tokens: int = 64
    stop_token: Optional[int] = None
    seed: int = 0


class RequestState(Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    RUNNING = "running"
    FINISHED = "finished"
    PREEMPTED = "preempted"
    # preempted with its KV pages parked in the host-DRAM tier: resume
    # swaps them back in and continues decoding from where it stopped
    # instead of recomputing from token 0
    SWAPPED = "swapped"
    FAILED = "failed"


@dataclass
class Request:
    prompt_tokens: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    lora_adapter: Optional[str] = None
    user: str = "default"
    # multi-turn conversation id: the gateway's session routing policy
    # pins every turn of a session to the engine holding its KV prefix
    # (None => single-shot request, routed by the configured policy)
    session_id: Optional[str] = None
    arrival_time: float = 0.0
    # SLO priority class (scheduler.DEFAULT_SLO_CLASSES keys):
    # interactive | standard | batch — picks the TTFT/ITL targets the
    # SLO-aware scheduler and gateway hold for this request
    priority_class: str = "standard"
    request_id: int = field(default_factory=lambda: next(_ids))

    # runtime state
    state: RequestState = RequestState.QUEUED
    output_tokens: List[int] = field(default_factory=list)
    prefill_done_tokens: int = 0          # chunked-prefill progress
    cached_prefix_tokens: int = 0         # tokens served from prefix cache
    page_ids: List[int] = field(default_factory=list)
    slot: int = -1                        # slot-engine binding
    preempt_count: int = 0                # times preempted (swap OR drop)
    # crash-recovery log coverage: sequence tokens (prompt + generated)
    # whose KV blocks the scheduler has checkpointed into the
    # distributed pool — crash_takeover resumes from here
    ckpt_tokens: int = 0

    # timestamps (engine clock)
    schedule_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0
    token_times: List[float] = field(default_factory=list)

    # ------------------------------------------------------------- metrics
    @property
    def prompt_len(self) -> int:
        return len(self.prompt_tokens)

    @property
    def queue_time(self) -> float:
        return max(self.schedule_time - self.arrival_time, 0.0)

    @property
    def ttft(self) -> float:
        return (self.first_token_time - self.arrival_time
                if self.first_token_time else 0.0)

    @property
    def itl(self) -> List[float]:
        ts = [self.first_token_time] + self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]

    @property
    def total_latency(self) -> float:
        return (self.finish_time - self.arrival_time
                if self.finish_time else 0.0)

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + len(self.output_tokens)
