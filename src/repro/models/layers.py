"""Shared NN building blocks: norms, RoPE, activations, GQA attention, MLP.

All functions are pure; parameters arrive as pytrees built from
``repro.models.params.Spec`` trees.  Attention supports full-causal,
sliding-window, and decode-over-cache modes with fp32 softmax.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import Spec

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale) + bias).astype(dtype)


def norm_spec(d: int) -> Spec:
    return Spec((d,), (None,), "zeros")


# ---------------------------------------------------------------- RoPE
def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """(sin, cos) of shape positions.shape + (head_dim//2,)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., S, H, D); sin/cos: (..., S, D//2) broadcast over heads."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------- masks
def causal_mask(sq: int, sk: int, q_offset=0, window: int = 0) -> jax.Array:
    """(sq, sk) boolean mask. ``q_offset`` shifts query positions (chunked
    prefill); ``window`` > 0 restricts to a sliding window."""
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    m = kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


def decode_mask(positions: jax.Array, sk: int, window: int = 0) -> jax.Array:
    """(B, 1, sk) mask for decoding one token at ``positions`` (B,)."""
    kpos = jnp.arange(sk)
    m = kpos[None, :] <= positions[:, None]
    if window:
        m &= kpos[None, :] > (positions[:, None] - window)
    return m[:, None, :]


# ---------------------------------------------------------------- attention
def attention(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
              *, softcap: float = 0.0, scale: Optional[float] = None):
    """Grouped-query attention core.

    q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D); mask broadcastable to
    (B, Sq, Sk).  Returns (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    dv = v.shape[-1]
    g = h // hkv
    if scale is None:
        scale = d ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    mask_b = jnp.broadcast_to(mask[:, None, None, :, :] if mask.ndim == 3
                              else mask[None, None, None, :, :],
                              logits.shape)
    logits = jnp.where(mask_b, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def attn_specs(cfg) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    em = "embed"          # 2D (model x data/FSDP) in both regimes
    specs = {
        "wq": Spec((d, h, hd), (em, "heads", None), "scaled", 0),
        "wk": Spec((d, hkv, hd), (em, "kv_heads", None), "scaled", 0),
        "wv": Spec((d, hkv, hd), (em, "kv_heads", None), "scaled", 0),
        "wo": Spec((h, hd, d), ("heads", None, em), "scaled", 0),
    }
    if cfg.qkv_bias:
        specs["bq"] = Spec((h, hd), ("heads", None), "zeros")
        specs["bk"] = Spec((hkv, hd), ("kv_heads", None), "zeros")
        specs["bv"] = Spec((hkv, hd), ("kv_heads", None), "zeros")
    if cfg.qk_norm:
        specs["q_norm"] = norm_spec(hd)
        specs["k_norm"] = norm_spec(hd)
    return specs


def attn_qkv(p: dict, cfg, x: jax.Array, positions: jax.Array):
    """Project to roped (q, k, v).  x: (B, S, d); positions: (B, S)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    sin, cos = rope_freqs(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    return q, k, v


def attn_out(p: dict, o: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ------------------------------------------------------- blockwise attention
def attn_causal(q: jax.Array, k: jax.Array, v: jax.Array, *,
                window: int = 0, softcap: float = 0.0,
                scale: Optional[float] = None, q_offset: int = 0,
                block_q: int = 512, block_k: int = 512,
                force_blockwise: bool = False) -> jax.Array:
    """Causal (optionally windowed) attention that never materializes the
    (Sq, Sk) score matrix for long sequences — the pure-JAX flash used by
    train/prefill paths (the Pallas kernel is the serving-engine analogue;
    this one must lower for the multi-pod dry-run on any backend).
    """
    sq, sk = q.shape[1], k.shape[1]
    if sq * sk <= 2048 * 2048 and not force_blockwise:
        mask = causal_mask(sq, sk, q_offset=q_offset, window=window)[None]
        return attention(q, k, v, mask, softcap=softcap, scale=scale)
    return _blockwise(q, k, v, scale=scale, q_offset=q_offset,
                      window=window, softcap=softcap, norm="softmax",
                      block_q=block_q, block_k=block_k)


def mlstm_parallel(q: jax.Array, k: jax.Array, v: jax.Array,
                   bias_q: jax.Array, bias_k: jax.Array,
                   block_q: int = 512, block_k: int = 512) -> jax.Array:
    """Parallel (quadratic) mLSTM form: gate-biased blockwise attention
    with signed-sum normalization (xLSTM eq. 25-27).  bias_q = F_t
    (cumulative log-forget), bias_k = i_s - F_s; entry bias = F_t-F_s+i_s.
    k must arrive pre-scaled by 1/sqrt(dh) (as in the recurrent form)."""
    return _blockwise(q, k, v, scale=1.0, q_offset=0, window=0, softcap=0.0,
                      norm="mlstm", bias_q=bias_q, bias_k=bias_k,
                      block_q=block_q, block_k=block_k)


def _blockwise(q, k, v, *, scale, q_offset, window, softcap, norm,
               bias_q=None, bias_k=None, block_q=512, block_k=512):
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // hkv
    if scale is None:
        scale = d ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    sq_p = -(-sq // bq) * bq
    sk_p = -(-sk // bk) * bk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
        if bias_q is not None:
            bias_q = jnp.pad(bias_q, ((0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
        if bias_k is not None:
            bias_k = jnp.pad(bias_k, ((0, 0), (0, sk_p - sk), (0, 0)),
                             constant_values=NEG_INF)
    nq, nk = sq_p // bq, sk_p // bk
    # stay in input dtype until per-block compute (f32 upfront doubles
    # the scan-carried working set); shard heads over model when the
    # count divides, else fall back to sequence-sharded q blocks.
    from repro.models import sharding as _sh
    qb = q.reshape(b, nq, bq, hkv, g, d)
    qb = _sh.constrain(qb, ("batch", None, "seq", "kv_heads", None, None))
    kb = k.reshape(b, nk, bk, hkv, d)
    kb = _sh.constrain(kb, ("batch", None, None, "kv_heads", None))
    vb = v.reshape(b, nk, bk, hkv, dv)
    vb = _sh.constrain(vb, ("batch", None, None, "kv_heads", None))
    bqb = (bias_q.reshape(b, nq, bq, hkv, g).astype(jnp.float32)
           if bias_q is not None else None)
    bkb = (bias_k.reshape(b, nk, bk, hkv).astype(jnp.float32)
           if bias_k is not None else None)

    def kv_body(carry, xs):
        acc, m, l, qi, q_blk, bq_blk = carry
        k_blk, v_blk, bk_blk, ki = xs
        q32 = q_blk.astype(jnp.float32) * scale
        k32 = k_blk.astype(jnp.float32)
        v_blk = v_blk.astype(jnp.float32)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", q32, k32)
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        qpos = q_offset + qi * bq + jnp.arange(bq)
        kpos = ki * bk + jnp.arange(bk)
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < sk)
        if window:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        mask = mask[None, None, None]                    # (1,1,1,bq,bk)
        if norm == "softmax":
            score = jnp.where(mask, logits, NEG_INF)
            m_cur = jnp.max(score, axis=-1)
            m_new = jnp.maximum(m, m_cur)
            alpha = jnp.exp(m - m_new)
            p = jnp.where(mask, jnp.exp(score - m_new[..., None]), 0.0)
        else:                                            # mlstm
            bias = (bq_blk.transpose(0, 2, 3, 1)[..., :, None]
                    + bk_blk.transpose(0, 2, 1)[:, :, None, None, :])
            bias = jnp.where(mask, bias, NEG_INF)        # (b,hkv,g,bq,bk)
            m_cur = jnp.max(bias, axis=-1)
            m_new = jnp.maximum(m, m_cur)
            alpha = jnp.exp(m - m_new)
            p = jnp.where(mask, logits * jnp.exp(bias - m_new[..., None]),
                          0.0)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, v_blk)
        return (acc, m_new, l, qi, q_blk, bq_blk), None

    def q_body(_, xs):
        q_blk, bq_blk, qi = xs
        acc0 = jnp.zeros((b, hkv, g, bq, dv), jnp.float32)
        m0 = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        (acc, m, l, *_), _ = jax.lax.scan(
            kv_body, (acc0, m0, l0, qi, q_blk, bq_blk),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1),
             (bkb.swapaxes(0, 1) if bkb is not None
              else jnp.zeros((nk, b, bk, hkv), jnp.float32)),
             jnp.arange(nk)))
        if norm == "softmax":
            denom = jnp.maximum(l, 1e-30)
        else:
            denom = jnp.maximum(jnp.abs(l), 1.0)
        return None, (acc / denom[..., None])

    # remat the whole per-q-block kv sweep: scan-of-scan backward would
    # otherwise store the (b,hkv,g,bq,dv) f32 accumulator for every
    # (q_block, kv_block) pair — O(Sq·Sk) residuals.
    q_body = jax.checkpoint(q_body, prevent_cse=False)
    _, out = jax.lax.scan(
        q_body, None,
        (qb.swapaxes(0, 1),
         (bqb.swapaxes(0, 1) if bqb is not None
          else jnp.zeros((nq, b, bq, hkv, g), jnp.float32)),
         jnp.arange(nq)))
    # out: (nq, b, hkv, g, bq, dv) -> (b, sq, h, dv)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq_p, h, dv)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------- MLP
def mlp_specs(d: int, f: int, inference: bool = False) -> dict:
    em = "embed"          # dense weights stay 2D-sharded (see moe.py
    # for the expert-bank inference layout, where the win lives)
    return {
        "w_gate": Spec((d, f), (em, "mlp"), "scaled", 0),
        "w_in": Spec((d, f), (em, "mlp"), "scaled", 0),
        "w_out": Spec((f, d), ("mlp", em), "scaled", 0),
    }


def mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    g = a(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    return jnp.einsum("bsf,fd->bsd", g * h, p["w_out"])
