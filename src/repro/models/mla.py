"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed to a per-token latent ``c_kv`` of rank ``kv_lora_rank``
plus a shared roped key ``k_rope``; the decode cache stores only
(c_kv, k_rope) — ~9x smaller than a GQA cache for deepseek-v2-236b.

Two decode paths:
  * ``absorb=False`` (paper-faithful / vLLM-v0.7-era): expand K/V from the
    latent every step, run standard MHA.
  * ``absorb=True`` (beyond-paper optimization, used by the perf loop):
    fold W_uk into the query and W_uv into the output so attention runs
    in latent space — per-step FLOPs drop from O(S·H·d_nope) expansion
    to O(S·rank), and no (S, H, d) tensors are materialized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import MLAConfig, ModelConfig
from repro.models.params import Spec


def mla_specs(cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    em = "embed"
    specs = {
        "w_dkv": Spec((d, m.kv_lora_rank), (em, None), "scaled", 0),
        "kv_norm": layers.norm_spec(m.kv_lora_rank),
        "w_krope": Spec((d, m.qk_rope_head_dim), (em, None), "scaled", 0),
        "w_uk": Spec((m.kv_lora_rank, h, m.qk_nope_head_dim),
                     (None, "heads", None), "scaled", 0),
        "w_uv": Spec((m.kv_lora_rank, h, m.v_head_dim),
                     (None, "heads", None), "scaled", 0),
        "w_o": Spec((h, m.v_head_dim, d), ("heads", None, em), "scaled", 0),
    }
    if m.q_lora_rank:
        specs["w_dq"] = Spec((d, m.q_lora_rank), (em, None), "scaled", 0)
        specs["q_norm"] = layers.norm_spec(m.q_lora_rank)
        specs["w_uq"] = Spec((m.q_lora_rank, h, qk),
                             (None, "heads", None), "scaled", 0)
    else:
        specs["w_q"] = Spec((d, h, qk), (em, "heads", None), "scaled", 0)
    return specs


def _queries(p, cfg, x, positions):
    """-> q_nope (B,S,H,dn), q_rope (B,S,H,dr)."""
    m = cfg.mla
    if m.q_lora_rank:
        cq = layers.rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]),
                             p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    sin, cos = layers.rope_freqs(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, sin, cos)
    return q_nope, q_rope


def _latents(p, cfg, x, positions):
    """-> c_kv (B,S,rank), k_rope (B,S,dr)   (the decode-cache contents)."""
    m = cfg.mla
    c_kv = layers.rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]),
                           p["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_krope"])
    sin, cos = layers.rope_freqs(positions, m.qk_rope_head_dim, cfg.rope_theta)
    k_rope = layers.apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention(p, cfg: ModelConfig, x, positions, c_kv, k_rope, mask,
                  *, absorb: bool = False):
    """Attention of queries from ``x`` against latents (c_kv, k_rope).

    c_kv: (B, Sk, rank); k_rope: (B, Sk, dr); mask: (B, Sq, Sk).
    """
    m = cfg.mla
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _queries(p, cfg, x, positions)

    if absorb:
        # fold W_uk into q:  logits = (q W_uk^T) c_kv + q_rope k_rope
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, p["w_uk"])
        logits = (jnp.einsum("bshr,bkr->bhsk", q_lat, c_kv)
                  + jnp.einsum("bshr,bkr->bhsk", q_rope, k_rope))
        logits = (logits * scale).astype(jnp.float32)
        logits = jnp.where(mask[:, None, :, :], logits, layers.NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        ctx_lat = jnp.einsum("bhsk,bkr->bshr", probs.astype(x.dtype), c_kv)
        o = jnp.einsum("bshr,rhv->bshv", ctx_lat, p["w_uv"])
    else:
        k_nope = jnp.einsum("bkr,rhn->bkhn", c_kv, p["w_uk"])
        v = jnp.einsum("bkr,rhv->bkhv", c_kv, p["w_uv"])
        k_rope_b = jnp.broadcast_to(
            k_rope[:, :, None, :],
            k_rope.shape[:2] + (cfg.n_heads, m.qk_rope_head_dim))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        o = layers.attention(q, k, v, mask, scale=scale)
    return jnp.einsum("bshv,hvd->bsd", o, p["w_o"])


def mla_full(p, cfg, x, positions, cache=None, *, absorb=False):
    """Train/prefill path: compute latents from x, optionally fill cache.

    Uses the expanded-KV blockwise-causal path (never materializes the
    S x S score matrix); ``absorb`` only changes the decode path.
    """
    m = cfg.mla
    c_kv, k_rope = _latents(p, cfg, x, positions)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _queries(p, cfg, x, positions)
    k_nope = jnp.einsum("bkr,rhn->bkhn", c_kv, p["w_uk"])
    v = jnp.einsum("bkr,rhv->bkhv", c_kv, p["w_uv"])
    k_rope_b = jnp.broadcast_to(
        k_rope[:, :, None, :],
        k_rope.shape[:2] + (cfg.n_heads, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    o = layers.attn_causal(q, k, v, scale=scale)
    out = jnp.einsum("bshv,hvd->bsd", o, p["w_o"])
    if cache is not None:
        s = c_kv.shape[1]
        cache = {"c_kv": cache["c_kv"].at[:, :s].set(c_kv),
                 "k_rope": cache["k_rope"].at[:, :s].set(k_rope)}
    return out, cache


def mla_decode(p, cfg, x, positions, cache, *, absorb=False):
    """One-token decode: write latent at ``positions``, attend over cache."""
    b = x.shape[0]
    c_new, kr_new = _latents(p, cfg, x, positions[:, None])
    bidx = jnp.arange(b)
    cache = {"c_kv": cache["c_kv"].at[bidx, positions].set(c_new[:, 0]),
             "k_rope": cache["k_rope"].at[bidx, positions].set(kr_new[:, 0])}
    mask = layers.decode_mask(positions, cache["c_kv"].shape[1])
    out = mla_attention(p, cfg, x, positions[:, None], cache["c_kv"],
                        cache["k_rope"], mask, absorb=absorb)
    return out, cache
