"""Per-layer-type blocks: spec builders, cache shapes, and apply fns.

Every layer type exposes:
  * ``layer_specs(cfg, ltype)``            parameter Spec tree
  * ``cache_shape(cfg, ltype, B, S)``      dict name -> (shape, axes) or {}
  * ``apply_layer(p, cfg, ltype, x, ...)`` residual block forward

``apply_layer`` runs in two modes:
  * mode="full":   x (B, S, d), positions (B, S) — train / prefill.
                   Fills ``cache`` (if given) for subsequent decode.
  * mode="decode": x (B, 1, d), positions (B,) — one token against cache.

Sliding-window layers keep a ring-buffer cache of size ``window`` with an
explicit per-slot absolute-position array (keys are roped at write time,
so RoPE stays consistent across ring wraparound).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import config as C
from repro.models import layers, mla, moe, ssm
from repro.models.params import Spec


# ------------------------------------------------------------------ specs
def layer_specs(cfg: C.ModelConfig, ltype: str) -> dict:
    d = cfg.d_model
    inf = cfg.inference_weight_layout
    ln = layers.norm_spec(d)
    if ltype in (C.DENSE, C.SWA):
        return {"ln1": ln, "attn": layers.attn_specs(cfg),
                "ln2": ln, "mlp": layers.mlp_specs(d, cfg.d_ff, inf)}
    if ltype == C.MOE:
        return {"ln1": ln, "attn": layers.attn_specs(cfg),
                "ln2": ln, "moe": moe.moe_specs(d, cfg.moe, inf)}
    if ltype == C.MLA_DENSE:
        # deepseek-v2 layer 0: dense FFN sized like shared+routed width
        f = cfg.d_ff if cfg.d_ff else cfg.moe.d_expert * 4
        return {"ln1": ln, "mla": mla.mla_specs(cfg),
                "ln2": ln, "mlp": layers.mlp_specs(d, f, inf)}
    if ltype == C.MLA_MOE:
        return {"ln1": ln, "mla": mla.mla_specs(cfg),
                "ln2": ln, "moe": moe.moe_specs(d, cfg.moe, inf)}
    if ltype in (C.HYMBA, C.HYMBA_GLOBAL):
        return {"ln1": ln, "attn": layers.attn_specs(cfg),
                "mamba": ssm.mamba_specs(cfg),
                "ln2": ln, "mlp": layers.mlp_specs(d, cfg.d_ff, inf)}
    if ltype == C.MLSTM:
        return {"ln1": ln, "mlstm": ssm.mlstm_specs(cfg)}
    if ltype == C.SLSTM:
        f = int(cfg.xlstm.slstm_proj_factor * d)
        return {"ln1": ln, "slstm": ssm.slstm_specs(cfg),
                "ln2": ln, "mlp": layers.mlp_specs(d, f, inf)}
    raise ValueError(f"unknown layer type {ltype}")


# ------------------------------------------------------------------ caches
def _kv_cache(cfg, batch, length) -> Dict[str, Tuple[tuple, tuple]]:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    axes = ("batch", "cache_seq", "kv_heads", None)
    return {"k": ((batch, length, hkv, hd), axes),
            "v": ((batch, length, hkv, hd), axes)}


def _window_cache(cfg, batch) -> Dict[str, Tuple[tuple, tuple]]:
    w = cfg.sliding_window
    c = _kv_cache(cfg, batch, w)
    c["pos"] = ((batch, w), ("batch", None))
    return c


def cache_shape(cfg: C.ModelConfig, ltype: str, batch: int,
                cache_len: int) -> Dict[str, Tuple[tuple, tuple]]:
    """dict of cache field -> ((shape), (logical axes)).  {} = stateless."""
    if ltype in (C.DENSE, C.MOE):
        return _kv_cache(cfg, batch, cache_len)
    if ltype == C.SWA:
        return _window_cache(cfg, batch)
    if ltype in (C.MLA_DENSE, C.MLA_MOE):
        m = cfg.mla
        return {"c_kv": ((batch, cache_len, m.kv_lora_rank),
                         ("batch", "cache_seq", None)),
                "k_rope": ((batch, cache_len, m.qk_rope_head_dim),
                           ("batch", "cache_seq", None))}
    if ltype == C.HYMBA:
        return {**_window_cache(cfg, batch),
                **ssm.mamba_state_shape(cfg, batch)}
    if ltype == C.HYMBA_GLOBAL:
        return {**_kv_cache(cfg, batch, cache_len),
                **ssm.mamba_state_shape(cfg, batch)}
    if ltype == C.MLSTM:
        return ssm.mlstm_state_shape(cfg, batch)
    if ltype == C.SLSTM:
        return ssm.slstm_state_shape(cfg, batch)
    raise ValueError(f"unknown layer type {ltype}")


def init_cache(cfg: C.ModelConfig, ltype: str, batch: int, cache_len: int,
               dtype=jnp.float32) -> dict:
    out = {}
    for name, (shape, _axes) in cache_shape(cfg, ltype, batch, cache_len).items():
        if name == "pos":
            out[name] = jnp.full(shape, -1, jnp.int32)
        else:
            out[name] = jnp.zeros(shape, dtype)
    return out


# ------------------------------------------------------------------ attention paths
def _attn_full(p, cfg, x, positions, cache, window: int):
    """Full-sequence attention (train/prefill); optionally fill cache."""
    s = x.shape[1]
    q, k, v = layers.attn_qkv(p, cfg, x, positions)
    o = layers.attn_causal(q, k, v, window=window,
                           softcap=cfg.attn_logit_softcap)
    if cache is not None:
        if "pos" in cache:  # ring buffer: scatter the last `window` tokens
            w = cfg.sliding_window
            slots = positions % w                              # (B, S)
            keep_from = jnp.maximum(s - w, 0)
            b = x.shape[0]
            bidx = jnp.arange(b)[:, None]
            # only the last w tokens may land in the ring; earlier tokens
            # would collide on slots — mask them out of the scatter.
            sel = jnp.arange(s)[None, :] >= keep_from
            tgt = jnp.where(sel, slots, w)                     # w = OOB drop
            cache = dict(cache)
            cache["k"] = cache["k"].at[bidx, tgt].set(k, mode="drop")
            cache["v"] = cache["v"].at[bidx, tgt].set(v, mode="drop")
            cache["pos"] = cache["pos"].at[bidx, tgt].set(
                positions, mode="drop")
        else:
            cache = dict(cache)
            cache["k"] = cache["k"].at[:, :s].set(k)
            cache["v"] = cache["v"].at[:, :s].set(v)
    return layers.attn_out(p, o), cache


def _attn_decode(p, cfg, x, positions, cache, window: int):
    """One-token decode against a (possibly ring-buffer) cache."""
    b = x.shape[0]
    q, k, v = layers.attn_qkv(p, cfg, x, positions[:, None])
    bidx = jnp.arange(b)
    cache = dict(cache)
    if "pos" in cache:
        w = cfg.sliding_window
        slot = positions % w
        cache["k"] = cache["k"].at[bidx, slot].set(k[:, 0])
        cache["v"] = cache["v"].at[bidx, slot].set(v[:, 0])
        cache["pos"] = cache["pos"].at[bidx, slot].set(positions)
        kpos = cache["pos"]                                    # (B, w)
        valid = (kpos >= 0) & (kpos <= positions[:, None]) \
            & (kpos > positions[:, None] - w)
        mask = valid[:, None, :]
    else:
        cache["k"] = cache["k"].at[bidx, positions].set(k[:, 0])
        cache["v"] = cache["v"].at[bidx, positions].set(v[:, 0])
        mask = layers.decode_mask(positions, cache["k"].shape[1],
                                  window=window)
    o = layers.attention(q, cache["k"], cache["v"], mask,
                         softcap=cfg.attn_logit_softcap)
    return layers.attn_out(p, o), cache


# ------------------------------------------------------------------ apply
def apply_layer(p: dict, cfg: C.ModelConfig, ltype: str, x: jax.Array,
                positions: jax.Array, cache: Optional[dict],
                mode: str) -> Tuple[jax.Array, Optional[dict], dict]:
    """Residual block.  Returns (x, new_cache, aux_losses)."""
    aux: dict = {}
    full = mode == "full"

    # ---- token mixer sublayer
    if ltype in (C.DENSE, C.SWA, C.MOE):
        window = cfg.sliding_window if ltype == C.SWA else 0
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        if full:
            a, cache = _attn_full(p["attn"], cfg, h, positions, cache, window)
        else:
            a, cache = _attn_decode(p["attn"], cfg, h, positions, cache, window)
        x = x + a
    elif ltype in (C.MLA_DENSE, C.MLA_MOE):
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        if full:
            a, cache = mla.mla_full(p["mla"], cfg, h, positions, cache,
                                    absorb=cfg.mla_absorb)
        else:
            a, cache = mla.mla_decode(p["mla"], cfg, h, positions, cache,
                                      absorb=cfg.mla_absorb)
        x = x + a
    elif ltype in (C.HYMBA, C.HYMBA_GLOBAL):
        window = cfg.sliding_window if ltype == C.HYMBA else 0
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        attn_cache = {k: v for k, v in (cache or {}).items()
                      if k in ("k", "v", "pos")} or None
        ssm_state = {k: v for k, v in (cache or {}).items()
                     if k in ("conv", "h")}
        if full:
            a, attn_cache = _attn_full(p["attn"], cfg, h, positions,
                                       attn_cache, window)
            m_out, ssm_state = ssm.mamba_seq(p["mamba"], cfg, h, ssm_state
                                             or _fresh_mamba(cfg, h))
        else:
            a, attn_cache = _attn_decode(p["attn"], cfg, h, positions,
                                         attn_cache, window)
            m_out, ssm_state = ssm.mamba_step(p["mamba"], cfg, h[:, 0],
                                              ssm_state)
            m_out = m_out[:, None, :]
        x = x + 0.5 * (a + m_out)
        cache = {**(attn_cache or {}), **ssm_state} if cache is not None else None
    elif ltype == C.MLSTM:
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        st = cache if cache else _fresh_mlstm(cfg, h)
        if full:
            a, st = ssm.mlstm_seq(p["mlstm"], cfg, h, st)
        else:
            a, st = ssm.mlstm_step(p["mlstm"], cfg, h[:, 0], st)
            a = a[:, None, :]
        x = x + a
        cache = st if cache is not None else None
        return x, cache, aux                      # no FFN sublayer
    elif ltype == C.SLSTM:
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        st = cache if cache else _fresh_slstm(cfg, h)
        if full:
            a, st = ssm.slstm_seq(p["slstm"], cfg, h, st)
        else:
            a, st = ssm.slstm_step(p["slstm"], cfg, h[:, 0], st)
            a = a[:, None, :]
        x = x + a
        cache = st if cache is not None else None
    else:
        raise ValueError(f"unknown layer type {ltype}")

    # ---- FFN sublayer
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, aux = moe.moe_ffn(p["moe"], cfg.moe, h, cfg.act)
    else:
        f = layers.mlp(p["mlp"], h, cfg.act)
    return x + f, cache, aux


def _fresh_mamba(cfg, x):
    return {k: jnp.zeros(s, x.dtype) if k != "pos" else None
            for k, (s, _) in ssm.mamba_state_shape(cfg, x.shape[0]).items()}


def _fresh_mlstm(cfg, x):
    return {k: jnp.zeros(s, jnp.float32)
            for k, (s, _) in ssm.mlstm_state_shape(cfg, x.shape[0]).items()}


def _fresh_slstm(cfg, x):
    return {k: jnp.zeros(s, jnp.float32)
            for k, (s, _) in ssm.slstm_state_shape(cfg, x.shape[0]).items()}
