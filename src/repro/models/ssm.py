"""Recurrent token mixers: Mamba selective SSM (Hymba's parallel head),
and xLSTM's mLSTM (matrix memory) / sLSTM (scalar memory) blocks.

All three expose a *sequence* form (lax.scan over time — used for train
and prefill) and a *single-step* form (O(1) state update — used by
decode).  State pytrees double as the "KV cache" for these layers: they
are constant-size, which is what makes the SSM/hybrid architectures
eligible for the long_500k decode shape.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig, SSMConfig, XLSTMConfig
from repro.models.params import Spec


def _causal_conv_seq(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x: (B, S, D); w: (CW, D) -> (B, S, D)."""
    cw = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = b
    s = x.shape[1]
    for i in range(cw):
        out = out + pad[:, i:i + s] * w[i]
    return out


def _causal_conv_step(x: jax.Array, buf: jax.Array, w: jax.Array,
                      b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token conv.  x: (B, D); buf: (B, CW-1, D) previous inputs."""
    window = jnp.concatenate([buf, x[:, None, :]], axis=1)      # (B, CW, D)
    out = jnp.einsum("bcd,cd->bd", window, w) + b
    return out, window[:, 1:]


# ===================================================================== Mamba
def mamba_specs(cfg: ModelConfig) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    n = s.state_size
    r = s.dt_rank or max(1, math.ceil(d / 16))
    return {
        "in_proj": Spec((d, 2 * di), ("embed", "mlp"), "scaled", 0),
        "conv_w": Spec((s.conv_width, di), (None, "mlp"), "normal"),
        "conv_b": Spec((di,), ("mlp",), "zeros"),
        "x_proj": Spec((di, r + 2 * n), ("mlp", None), "scaled", 0),
        "dt_w": Spec((r, di), (None, "mlp"), "scaled", 0),
        "dt_b": Spec((di,), ("mlp",), "ones"),
        "A_log": Spec((di, n), ("mlp", None), "ones"),
        "D": Spec((di,), ("mlp",), "ones"),
        "out_proj": Spec((di, d), ("mlp", "embed"), "scaled", 0),
    }


def mamba_state_shape(cfg: ModelConfig, batch: int) -> dict:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {"conv": ((batch, s.conv_width - 1, di), ("batch", None, None)),
            "h": ((batch, di, s.state_size), ("batch", None, None))}


def _mamba_core(p, scfg, x_c, x_in, h0, chunk: int = 128):
    """Selective-scan recurrence, chunked for sqrt-memory training.

    Outer scan over chunks is rematerialized (only the inter-chunk state
    is saved for backward); padded steps carry dt=0, which is an exact
    no-op on the state (exp(0)=1 decay, 0 input).
    """
    n = scfg.state_size
    r = p["dt_w"].shape[0]
    s = x_c.shape[1]
    dbc = jnp.einsum("bsd,dk->bsk", x_c, p["x_proj"])
    dt_r, bmat, cmat = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_r, p["dt_w"]) + p["dt_b"])
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                # (di, N)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs                                # (B,di),(B,di),(B,N),(B,N)
        da = jnp.exp(dtt.astype(jnp.float32)[..., None] * a)    # (B, di, N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct) + p["D"] * xt
        return h, y.astype(xt.dtype)

    ck = min(chunk, s)
    s_p = -(-s // ck) * ck
    pad = s_p - s

    def tpad(x):  # (B, S, ...) -> (nc, ck, B, ...)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        x = x.swapaxes(0, 1)
        return x.reshape((s_p // ck, ck) + x.shape[1:])

    xs = (tpad(x_c), tpad(dt), tpad(bmat), tpad(cmat))

    def chunk_body(h, xs_c):
        return jax.lax.scan(step, h, xs_c)

    chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)
    h_final, ys = jax.lax.scan(chunk_body, h0.astype(jnp.float32), xs)
    ys = ys.reshape((s_p,) + ys.shape[2:]).swapaxes(0, 1)
    return ys[:, :s], h_final


def mamba_seq(p, cfg: ModelConfig, x: jax.Array, state: dict):
    """x: (B, S, d) -> (out, new_state)."""
    scfg = cfg.ssm
    xz = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    # seed the conv with the carried buffer (supports chunked prefill)
    cw = scfg.conv_width
    padded = jnp.concatenate([state["conv"].astype(x_in.dtype), x_in], axis=1)
    x_c = jax.nn.silu(_causal_conv_seq(padded, p["conv_w"], p["conv_b"])
                      [:, cw - 1:])
    y, h = _mamba_core(p, scfg, x_c, x_in, state["h"])
    out = jnp.einsum("bsd,dk->bsk", y * jax.nn.silu(z), p["out_proj"])
    new_state = {"conv": padded[:, -(cw - 1):], "h": h}
    return out, new_state


def mamba_step(p, cfg: ModelConfig, x: jax.Array, state: dict):
    """x: (B, d) one token -> (out (B, d), new_state)."""
    scfg = cfg.ssm
    xz = jnp.einsum("bd,dk->bk", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, conv_buf = _causal_conv_step(x_in, state["conv"].astype(x_in.dtype),
                                     p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    y, h = _mamba_core(p, scfg, xc[:, None], x_in[:, None], state["h"])
    out = jnp.einsum("bd,dk->bk", y[:, 0] * jax.nn.silu(z), p["out_proj"])
    return out, {"conv": conv_buf, "h": h}


# ===================================================================== mLSTM
def mlstm_specs(cfg: ModelConfig) -> dict:
    xc: XLSTMConfig = cfg.xlstm
    d = cfg.d_model
    di = int(xc.mlstm_proj_factor * d)
    h = xc.num_heads
    return {
        "up_proj": Spec((d, 2 * di), ("embed", "mlp"), "scaled", 0),
        "conv_w": Spec((xc.conv_width, di), (None, "mlp"), "normal"),
        "conv_b": Spec((di,), ("mlp",), "zeros"),
        # block-diagonal per head (the xLSTM paper's BlockDiagonal
        # projections): (H, dh, dh) instead of dense (di, di)
        "wq": Spec((h, di // h, di // h), ("heads", None, None),
                   "scaled", 1),
        "wk": Spec((h, di // h, di // h), ("heads", None, None),
                   "scaled", 1),
        "wv": Spec((h, di // h, di // h), ("heads", None, None),
                   "scaled", 1),
        "igate_w": Spec((di, h), (None, None), "scaled", 0),
        "igate_b": Spec((h,), (None,), "zeros"),
        "fgate_w": Spec((di, h), (None, None), "scaled", 0),
        "fgate_b": Spec((h,), (None,), "zeros"),
        "out_norm": layers.norm_spec(di),
        "down_proj": Spec((di, d), ("mlp", "embed"), "scaled", 0),
    }


def mlstm_state_shape(cfg: ModelConfig, batch: int) -> dict:
    xc = cfg.xlstm
    di = int(xc.mlstm_proj_factor * cfg.d_model)
    h = xc.num_heads
    dh = di // h
    return {
        "conv": ((batch, xc.conv_width - 1, di), ("batch", None, None)),
        # matrix memory is the big decode state (B,H,dh,dh); its v-dim
        # shards over the model axis (heads=4 never divides 16) — the
        # per-step update k v^T and readout q·C stay shard-local
        "C": ((batch, h, dh, dh), ("batch", "heads", None, "mlp")),
        "n": ((batch, h, dh), ("batch", "heads", None)),
        "m": ((batch, h), ("batch", "heads")),
    }


def _mlstm_core(p, nheads, q, k, v, i_raw, f_raw, state):
    """q,k,v: (B, S, H, dh) f32; gates (B, S, H).  Scan over S."""
    def step(carry, inputs):
        c_mat, n_vec, m = carry
        qt, kt, vt, it, ft = inputs
        f_log = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(f_log + m, it)
        i_sc = jnp.exp(it - m_new)
        f_sc = jnp.exp(f_log + m - m_new)
        c_mat = (f_sc[..., None, None] * c_mat
                 + i_sc[..., None, None] * kt[..., :, None] * vt[..., None, :])
        n_vec = f_sc[..., None] * n_vec + i_sc[..., None] * kt
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", n_vec, qt)), 1.0)
        h = jnp.einsum("bhd,bhdv->bhv", qt, c_mat) / denom[..., None]
        return (c_mat, n_vec, m_new), h

    xs = tuple(t.swapaxes(0, 1) for t in (q, k, v, i_raw, f_raw))
    carry = (state["C"].astype(jnp.float32), state["n"].astype(jnp.float32),
             state["m"].astype(jnp.float32))
    (c_mat, n_vec, m), hs = jax.lax.scan(step, carry, xs)
    return hs.swapaxes(0, 1), {"C": c_mat, "n": n_vec, "m": m}


def mlstm_seq(p, cfg: ModelConfig, x: jax.Array, state: dict,
              parallel: bool = None):
    """Sequence mLSTM.  parallel=True (default for S>1) uses the
    quadratic gate-biased attention form (xLSTM's 'fully parallelizable'
    mode) — O(S^2) compute, O(S) memory via blockwise accumulation, and
    critically no per-step (dh x dh) matrix state saved for backward.
    The final recurrent state is reconstructed in closed form so decode
    can continue from a parallel prefill.  parallel assumes a fresh
    (zero) initial state; decode uses the recurrent step."""
    xc = cfg.xlstm
    b, s, _ = x.shape
    hn = xc.num_heads
    if parallel is None:
        parallel = s > 1
    xz = jnp.einsum("bsd,dk->bsk", x, p["up_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    di = x_in.shape[-1]
    dh = di // hn
    cw = xc.conv_width
    padded = jnp.concatenate([state["conv"].astype(x_in.dtype), x_in], axis=1)
    x_c = jax.nn.silu(_causal_conv_seq(padded, p["conv_w"], p["conv_b"])
                      [:, cw - 1:])
    x_ch = x_c.reshape(b, s, hn, dh)
    x_inh = x_in.reshape(b, s, hn, dh)
    q = jnp.einsum("bshd,hde->bshe", x_ch, p["wq"])
    k = jnp.einsum("bshd,hde->bshe", x_ch, p["wk"]) / math.sqrt(dh)
    v = jnp.einsum("bshd,hde->bshe", x_inh, p["wv"])
    i_raw = (jnp.einsum("bsd,dh->bsh", x_in, p["igate_w"])
             + p["igate_b"]).astype(jnp.float32)
    f_raw = (jnp.einsum("bsd,dh->bsh", x_in, p["fgate_w"])
             + p["fgate_b"] + 3.0).astype(jnp.float32)
    if parallel:
        f_log = jax.nn.log_sigmoid(f_raw)                  # (B,S,H)
        f_cum = jnp.cumsum(f_log, axis=1)                  # F_t
        bias_q = f_cum
        bias_k = i_raw - f_cum                             # i_s - F_s
        hs = layers.mlstm_parallel(
            q.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), bias_q, bias_k)         # (B,S,H,dh)
        # closed-form final state for decode continuation
        f_total = f_cum[:, -1]                             # F_S (B,H)
        log_w = f_total[:, None] - f_cum + i_raw           # F_S-F_s+i_s
        m_new = jnp.max(log_w, axis=1)                     # (B,H)
        w = jnp.exp(log_w - m_new[:, None])                # (B,S,H)
        c_mat = jnp.einsum("bsh,bshd,bshe->bhde", w,
                           k.astype(jnp.float32), v.astype(jnp.float32))
        n_vec = jnp.einsum("bsh,bshd->bhd", w, k.astype(jnp.float32))
        new_core = {"C": c_mat, "n": n_vec, "m": m_new}
    else:
        hs, new_core = _mlstm_core(
            p, hn, *(t.astype(jnp.float32) for t in (q, k, v)),
            i_raw, f_raw, state)
    y = hs.reshape(b, s, di).astype(x.dtype)
    y = layers.rms_norm(y, p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,dk->bsk", y * jax.nn.silu(z), p["down_proj"])
    new_core["conv"] = padded[:, -(cw - 1):]
    return out, new_core


def mlstm_step(p, cfg, x, state):
    out, new_state = mlstm_seq(p, cfg, x[:, None, :], {**state},
                               parallel=False)
    return out[:, 0], new_state


# ===================================================================== sLSTM
def slstm_specs(cfg: ModelConfig) -> dict:
    xc: XLSTMConfig = cfg.xlstm
    d = cfg.d_model
    h = xc.num_heads
    dh = d // h
    return {
        "w_gates": Spec((d, 4, h, dh), ("embed", None, "heads", None),
                        "scaled", 0),
        "r_gates": Spec((h, dh, 4, dh), ("heads", None, None, None),
                        "scaled", 1),
        "b_gates": Spec((4, h, dh), (None, "heads", None), "zeros"),
        "out_norm": layers.norm_spec(d),
    }


def slstm_state_shape(cfg: ModelConfig, batch: int) -> dict:
    h = cfg.xlstm.num_heads
    dh = cfg.d_model // h
    shp = ((batch, h, dh), ("batch", "heads", None))
    return {"c": shp, "n": shp, "m": shp, "h": shp}


def _slstm_scan(p, cfg, wx, state, chunk: int = 128):
    """wx: (B, S, 4, H, dh) input contributions; recurrent h feedback.

    Chunked with remat like the mamba core; padded steps are masked to
    exact no-ops (sLSTM's h-feedback makes zero-input steps non-neutral).
    """
    def step(carry, inputs):
        wx_t, valid = inputs
        c, n, m, h_prev = carry
        rec = jnp.einsum("bhd,hdgk->bghk", h_prev, p["r_gates"])
        pre = wx_t + rec + p["b_gates"]                     # (B, 4, H, dh)
        z_t = jnp.tanh(pre[:, 0])
        i_t = pre[:, 1]
        f_t = jax.nn.log_sigmoid(pre[:, 2])
        o_t = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(f_t + m, i_t)
        i_sc = jnp.exp(i_t - m_new)
        f_sc = jnp.exp(f_t + m - m_new)
        c_new = f_sc * c + i_sc * z_t
        n_new = f_sc * n + i_sc
        h = o_t * c_new / jnp.maximum(n_new, 1e-6)
        sel = lambda a, b_: jnp.where(valid, a, b_)  # noqa: E731
        out = (sel(c_new, c), sel(n_new, n), sel(m_new, m),
               sel(h, h_prev))
        return out, h

    s = wx.shape[1]
    ck = min(chunk, s)
    s_p = -(-s // ck) * ck
    pad = s_p - s
    wx_t = wx.swapaxes(0, 1)                                # (S,B,4,H,dh)
    valid = jnp.ones((s,), bool)
    if pad:
        wx_t = jnp.pad(wx_t, ((0, pad),) + ((0, 0),) * (wx_t.ndim - 1))
        valid = jnp.pad(valid, (0, pad))
    wx_c = wx_t.reshape((s_p // ck, ck) + wx_t.shape[1:])
    valid_c = valid.reshape(s_p // ck, ck, 1, 1, 1)

    def chunk_body(carry, xs):
        return jax.lax.scan(step, carry, xs)

    chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)
    carry = tuple(state[k].astype(jnp.float32) for k in ("c", "n", "m", "h"))
    (c, n, m, h), hs = jax.lax.scan(chunk_body, carry, (wx_c, valid_c))
    hs = hs.reshape((s_p,) + hs.shape[2:]).swapaxes(0, 1)[:, :s]
    return hs, {"c": c, "n": n, "m": m, "h": h}


def slstm_seq(p, cfg: ModelConfig, x: jax.Array, state: dict):
    b, s, d = x.shape
    wx = jnp.einsum("bsd,dghk->bsghk", x.astype(jnp.float32),
                    p["w_gates"].astype(jnp.float32))
    hs, new_state = _slstm_scan(p, cfg, wx, state)
    y = hs.reshape(b, s, d).astype(x.dtype)
    return layers.rms_norm(y, p["out_norm"], cfg.norm_eps), new_state


def slstm_step(p, cfg, x, state):
    out, new_state = slstm_seq(p, cfg, x[:, None, :], state)
    return out[:, 0], new_state
