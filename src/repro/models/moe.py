"""Mixture-of-experts FFN with shard-local, sort-based capacity dispatch.

Tokens are routed top-k, sorted by expert id *within each data shard*,
packed into (shards, E, C_local, d) buffers (capacity overflow dropped —
Switch/GShard semantics), batch-matmul'd through the experts, and
combined with router weights.  FLOPs scale with *active* experts, which
keeps the roofline honest for MoE archs.

Sharding design (the §Perf fix over a naive global sort, which forces
XLA SPMD to replicate the dispatch buffers — observed 566 GB/device on
granite-moe):
  * every dispatch tensor carries an explicit leading shard dim mapped
    to the data mesh axis, so sorts/scatters stay shard-local;
  * the buffer's expert dim is constrained to the model axis (expert
    parallelism); XLA materializes the token exchange as an
    all-to-all — the EP dispatch pattern — instead of replicating;
  * expert weights are (expert -> model, d_model -> data) 2D-sharded so
    236B-scale MoE fits per-device HBM (deepseek-v2: 29.5 GB -> 1.8 GB).

DeepSeek-style shared experts run densely on every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, sharding
from repro.models.config import MoEConfig
from repro.models.params import Spec


def moe_specs(d_model: int, mcfg: MoEConfig, inference: bool = False
              ) -> dict:
    e, f = mcfg.num_experts, mcfg.d_expert
    if inference:
        # shard expert->model, f->data: contraction dim d stays
        # unsharded so the expert matmuls need NO weight gathers
        gate_axes = ("expert", None, "embed")
        out_axes = ("expert", "embed", None)
    else:
        gate_axes = ("expert", "embed", "mlp")
        out_axes = ("expert", "mlp", "embed")
    specs = {
        "router": Spec((d_model, e), (None, "expert"), "scaled", 0),
        "experts": {
            "w_gate": Spec((e, d_model, f), gate_axes, "scaled", 1),
            "w_in": Spec((e, d_model, f), gate_axes, "scaled", 1),
            "w_out": Spec((e, f, d_model), out_axes, "scaled", 1),
        },
    }
    if mcfg.num_shared_experts:
        fs = mcfg.d_shared_expert * mcfg.num_shared_experts
        specs["shared"] = layers.mlp_specs(d_model, fs, inference)
    return specs


def _capacity(tokens_per_shard: int, mcfg: MoEConfig) -> int:
    c = int(tokens_per_shard * mcfg.top_k / mcfg.num_experts
            * mcfg.capacity_factor)
    return max(c, mcfg.top_k)


def moe_ffn(p: dict, mcfg: MoEConfig, x: jax.Array, act: str = "silu"):
    """x: (B, S, d) -> (out (B, S, d), aux_losses dict of scalars)."""
    b, s, d = x.shape
    t = b * s
    e, k = mcfg.num_experts, mcfg.top_k
    ctx = sharding.current()
    n_sh = ctx.data_shards if ctx is not None else 1
    while t % n_sh:                       # safety for odd test shapes
        n_sh //= 2
    tl = t // n_sh                        # tokens per data shard
    cap = _capacity(tl, mcfg)
    xf = x.reshape(n_sh, tl, d)
    xf = sharding.constrain(xf, ("batch", None, None))

    router_logits = jnp.einsum(
        "gtd,de->gte", xf.astype(jnp.float32),
        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate, expert_ids = jax.lax.top_k(probs, k)                # (g, tl, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance + z auxiliary losses (Switch-style, global)
    one_hot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)
    frac_tokens = one_hot.sum((0, 1, 2)) / (t * k)            # f_e
    frac_probs = probs.mean((0, 1))                           # P_e
    aux = {
        "moe_load_balance": e * jnp.sum(frac_tokens * frac_probs)
                            * mcfg.router_aux_coef,
        "moe_router_z": jnp.mean(
            jax.scipy.special.logsumexp(router_logits, -1) ** 2)
            * mcfg.router_z_coef,
    }

    # ---- shard-local sort-based dispatch (GATHER-only: XLA SPMD lowers
    # scatters with sharded operands via replicated expanded indices —
    # a 206 GB/dev all-gather on granite train — gathers stay local)
    flat_expert = expert_ids.reshape(n_sh, tl * k)            # (g, tl·k)
    sort_idx = jnp.argsort(flat_expert, axis=-1)              # stable
    sorted_expert = jnp.take_along_axis(flat_expert, sort_idx, -1)
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(
        sorted_expert)                                        # (g, E)
    ends = jnp.concatenate(
        [starts[:, 1:], jnp.full((n_sh, 1), tl * k)], axis=1)
    rank = (jnp.arange(tl * k)[None]
            - jnp.take_along_axis(starts, sorted_expert, -1))  # pos in expert
    token_idx = sort_idx // k                                 # (g, tl·k)

    # slot (e, c) reads sorted position starts[e] + c (gather, not scatter)
    slot_pos = starts[:, :, None] + jnp.arange(cap)[None, None]   # (g,E,cap)
    slot_valid = slot_pos < ends[:, :, None]
    slot_tok = jnp.take_along_axis(
        token_idx, jnp.minimum(slot_pos, tl * k - 1).reshape(n_sh, -1), -1)
    buf = jnp.take_along_axis(xf, slot_tok[..., None], axis=1)    # (g,E·cap,d)
    buf = (buf * slot_valid.reshape(n_sh, -1, 1)).reshape(n_sh, e, cap, d)
    buf = sharding.constrain(buf, ("batch", "expert", None, None))

    # ---- expert compute (batched GLU), expert dim model-sharded
    ep = p["experts"]
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    g_act = actf(jnp.einsum("gecd,edf->gecf", buf, ep["w_gate"]))
    h_act = jnp.einsum("gecd,edf->gecf", buf, ep["w_in"])
    out_buf = jnp.einsum("gecf,efd->gecd", g_act * h_act, ep["w_out"])
    out_buf = sharding.constrain(out_buf, ("batch", "expert", None, None))

    # ---- combine (inverse-permutation gather)
    gidx = jnp.arange(n_sh)[:, None]
    inv = jnp.argsort(sort_idx, axis=-1)                      # (g, tl·k)
    rank_orig = jnp.take_along_axis(rank, inv, -1)            # rank of j
    keep = rank_orig < cap
    flat_slot = flat_expert * cap + jnp.minimum(rank_orig, cap - 1)
    # sharded indices make the combine gather emit a sharded result
    # directly (constraining only the output leaves an unsharded
    # (tl·k, d) transient in the gather's wake)
    flat_slot = sharding.constrain(flat_slot, ("batch", "seq"))
    vals = jnp.take_along_axis(
        out_buf.reshape(n_sh, e * cap, d), flat_slot[..., None], axis=1)
    vals = jnp.where(keep[..., None], vals, 0.0)              # (g, tl·k, d)
    # the (tl·k, d) combine tensor is 6x the residual stream — shard its
    # token dim over the model axis (sequence-parallel combine)
    vals = sharding.constrain(vals, ("batch", "seq", None))
    combined = (vals.reshape(n_sh, tl, k, d)
                * gate.astype(x.dtype)[..., None]).sum(axis=2)
    combined = sharding.constrain(combined, ("batch", None, None))

    out = combined.reshape(b, s, d)
    if mcfg.num_shared_experts:
        out = out + layers.mlp(p["shared"], x, act)
    return out, aux
