"""Composable decoder-only model over run-grouped scanned layers.

The model executes ``cfg.layer_runs`` — maximal runs of identical layer
types — each as one ``lax.scan`` over stacked per-layer params (and
stacked caches).  This keeps compile units small even for 60-layer
models and heterogeneous patterns (gemma3's 5:1 local:global, hymba's
3 global layers, xlstm's 7:1 mLSTM:sLSTM).

Entry points:
  * ``loss_fn``       train_4k           (full causal, no cache)
  * ``prefill``       prefill_32k        (full causal, fills cache)
  * ``decode_step``   decode_32k/long_500k (1 token against cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks, layers, sharding
from repro.models import config as C
from repro.models.params import (Spec, abstract_params, axes_tree,
                                 init_params, map_specs_with_path,
                                 param_count, stack_specs)

AUX_KEYS = {
    C.MOE: ("moe_load_balance", "moe_router_z"),
    C.MLA_MOE: ("moe_load_balance", "moe_router_z"),
}


# ===================================================================== specs
def model_specs(cfg: C.ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    specs: Dict[str, Any] = {}
    if cfg.num_codebooks:
        specs["embedding"] = Spec((cfg.num_codebooks, v, d),
                                  (None, "vocab", None), "embed")
    else:
        specs["embedding"] = Spec((v, d), ("vocab", "embed"), "embed")
    for i, (ltype, n) in enumerate(cfg.layer_runs):
        specs[f"run_{i}"] = stack_specs(blocks.layer_specs(cfg, ltype), n,
                                        "layers")
    specs["final_norm"] = layers.norm_spec(d)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks:
            specs["lm_head"] = Spec((d, cfg.num_codebooks, v),
                                    ("embed", None, "vocab"), "scaled", 0)
        else:
            specs["lm_head"] = Spec((d, v), ("embed", "vocab"), "scaled", 0)
    return specs


def init(cfg: C.ModelConfig, key: jax.Array, dtype=jnp.float32):
    return init_params(model_specs(cfg), key, dtype)


def abstract(cfg: C.ModelConfig, dtype=jnp.float32):
    return abstract_params(model_specs(cfg), dtype)


def param_axes(cfg: C.ModelConfig):
    return axes_tree(model_specs(cfg))


def count_params(cfg: C.ModelConfig, active_only: bool = False) -> int:
    """Total (or MoE-active) parameter count, from the spec tree."""
    total = 0

    def visit(path: str, s: Spec):
        nonlocal total
        n = 1
        for dim in s.shape:
            n *= dim
        if active_only and cfg.moe is not None and "/experts/" in path:
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
        return None

    map_specs_with_path(visit, model_specs(cfg))
    return total


# ===================================================================== cache
def cache_struct(cfg: C.ModelConfig, batch: int, cache_len: int):
    """[(run cache shapes dict) ...] — name -> ((n, *shape), (axes))."""
    out = []
    for ltype, n in cfg.layer_runs:
        shapes = blocks.cache_shape(cfg, ltype, batch, cache_len)
        out.append({name: ((n,) + shape, ("layers",) + axes)
                    for name, (shape, axes) in shapes.items()})
    return out


def init_cache(cfg, batch, cache_len, dtype=jnp.float32) -> List[dict]:
    caches = []
    for run in cache_struct(cfg, batch, cache_len):
        c = {}
        for name, (shape, _axes) in run.items():
            if name == "pos":
                c[name] = jnp.full(shape, -1, jnp.int32)
            elif name in ("C", "n", "m", "h", "c"):   # recurrent states: f32
                c[name] = jnp.zeros(shape, jnp.float32)
            else:
                c[name] = jnp.zeros(shape, dtype)
        caches.append(c)
    return caches


def abstract_cache(cfg, batch, cache_len, dtype=jnp.float32) -> List[dict]:
    out = []
    for run in cache_struct(cfg, batch, cache_len):
        c = {}
        for name, (shape, _axes) in run.items():
            if name == "pos":
                c[name] = jax.ShapeDtypeStruct(shape, jnp.int32)
            elif name in ("C", "n", "m", "h", "c"):
                c[name] = jax.ShapeDtypeStruct(shape, jnp.float32)
            else:
                c[name] = jax.ShapeDtypeStruct(shape, dtype)
        out.append(c)
    return out


def cache_axes(cfg, batch, cache_len) -> List[dict]:
    return [{name: axes for name, (_s, axes) in run.items()}
            for run in cache_struct(cfg, batch, cache_len)]


# ===================================================================== embed
def embed(params, cfg: C.ModelConfig, tokens: jax.Array) -> jax.Array:
    emb = params["embedding"]
    if cfg.num_codebooks:
        # tokens: (B, S, K) — sum the K codebook embeddings (MusicGen).
        parts = [emb[k][tokens[..., k]] for k in range(cfg.num_codebooks)]
        return functools.reduce(jnp.add, parts)
    return emb[tokens]


def unembed(params, cfg: C.ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        emb = params["embedding"]
        if cfg.num_codebooks:
            return jnp.einsum("bsd,kvd->bskv", x, emb)
        return jnp.einsum("bsd,vd->bsv", x, emb)
    head = params["lm_head"]
    if cfg.num_codebooks:
        return jnp.einsum("bsd,dkv->bskv", x, head)
    return jnp.einsum("bsd,dv->bsv", x, head)


# ===================================================================== forward
def _apply_run(run_p, cfg, ltype, x, positions, run_cache, mode, remat):
    """Scan one run of identical layers.  run_cache: stacked dict or None.

    The cache rides in the scan CARRY and is updated in place with
    dynamic_update_slice — XLA aliases carried while-loop buffers, so the
    cache is single-buffered.  (Passing it as xs/ys double-buffers the
    whole KV cache: +16.4 GB/dev temp on musicgen decode_32k.)
    """
    aux0 = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS.get(ltype, ())}
    has_cache = run_cache is not None

    def body(carry, p_l):
        xc, aux_acc, cache, i = carry
        c_l = (jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
            cache) if has_cache else None)
        xc, c_new, aux = blocks.apply_layer(p_l, cfg, ltype, xc, positions,
                                            c_l, mode)
        if has_cache:
            cache = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), i, 0), cache, c_new)
        aux_acc = {k: aux_acc[k] + aux.get(k, 0.0) for k in aux_acc}
        return (xc, aux_acc, cache, i + 1), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux, new_cache, _), _ = jax.lax.scan(
        body, (x, aux0, run_cache, jnp.int32(0)), run_p)
    return x, (new_cache if has_cache else None), aux


def forward(params, cfg: C.ModelConfig, tokens: jax.Array,
            positions: Optional[jax.Array] = None,
            caches: Optional[List[dict]] = None, mode: str = "full",
            remat: bool = False):
    """Returns (hidden (B,S,d), new_caches, aux dict)."""
    if mode == "full":
        b, s = tokens.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        x = embed(params, cfg, tokens)
        x = sharding.constrain(x, ("batch", "seq", None))
    else:
        # decode: tokens (B,) (or (B, K) for codebooks), positions (B,)
        x = embed(params, cfg, tokens[:, None] if tokens.ndim == 1
                  else tokens[:, None, :])
        x = sharding.constrain(x, ("batch", None, None))
    aux_total: Dict[str, jax.Array] = {}
    new_caches = [] if caches is not None else None
    for i, (ltype, _n) in enumerate(cfg.layer_runs):
        run_cache = caches[i] if caches is not None else None
        x, c_new, aux = _apply_run(params[f"run_{i}"], cfg, ltype, x,
                                   positions, run_cache, mode, remat)
        if mode == "full":
            x = sharding.constrain(x, ("batch", "seq", None))
        if caches is not None:
            new_caches.append(c_new)
        for k, v in aux.items():
            aux_total[k] = aux_total.get(k, 0.0) + v
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_caches, aux_total


# ===================================================================== steps
def _nll_chunk(params, cfg, x_chunk, labels_chunk):
    """Cross-entropy over one sequence chunk (keeps the f32 logits
    working set at (B, chunk, V) — a 262k-vocab model would otherwise
    materialize multi-GB f32 logits for the full sequence)."""
    logits = unembed(params, cfg, x_chunk).astype(jnp.float32)
    logit_axes = (("batch", "seq", None, "vocab") if logits.ndim == 4
                  else ("batch", "seq", "vocab"))
    logits = sharding.constrain(logits, logit_axes)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_chunk[..., None], axis=-1)[..., 0]
    if cfg.num_codebooks:
        nll = nll.mean(-1)                      # average codebook losses
    return nll


def loss_fn(params, cfg: C.ModelConfig, batch: Dict[str, jax.Array],
            remat: bool = True, ce_chunk: int = 512):
    """batch: tokens, labels, weights.  Returns (loss, metrics)."""
    x, _, aux = forward(params, cfg, batch["tokens"], mode="full",
                        remat=remat)
    labels = batch["labels"]
    s = x.shape[1]
    ck = min(ce_chunk, s)
    if s % ck == 0 and s > ck:
        nc = s // ck
        xc = x.reshape((x.shape[0], nc, ck) + x.shape[2:]).swapaxes(0, 1)
        lc = labels.reshape((labels.shape[0], nc, ck)
                            + labels.shape[2:]).swapaxes(0, 1)

        def body(_, xs):
            return None, _nll_chunk(params, cfg, xs[0], xs[1])

        body = jax.checkpoint(body, prevent_cse=False)
        _, nll = jax.lax.scan(body, None, (xc, lc))
        nll = nll.swapaxes(0, 1).reshape(labels.shape[0], s)
    else:
        nll = _nll_chunk(params, cfg, x, labels)
    w = batch["weights"].astype(jnp.float32)
    loss = (nll * w).sum() / jnp.maximum(w.sum(), 1.0)
    metrics = {"nll": loss, **aux}
    total = loss + sum(aux.values(), jnp.zeros((), jnp.float32))
    return total, metrics


def prefill(params, cfg: C.ModelConfig, tokens: jax.Array,
            caches: List[dict]):
    """Full prefill; returns (last-token logits (B, ...), caches)."""
    x, caches, _ = forward(params, cfg, tokens, caches=caches, mode="full")
    logits = unembed(params, cfg, x[:, -1:])
    return logits[:, 0], caches


def decode_step(params, cfg: C.ModelConfig, caches: List[dict],
                tokens: jax.Array, positions: jax.Array):
    """One decode step.  tokens: (B,) or (B, K); positions: (B,)."""
    x, caches, _ = forward(params, cfg, tokens, positions=positions,
                           caches=caches, mode="decode")
    logits = unembed(params, cfg, x)
    return logits[:, 0], caches
