"""Minimal parameter-spec system (no flax).

Modules describe their parameters once as a nested dict of ``Spec``s
(shape + logical axes + init style).  From one spec tree we derive:

  * concrete parameters     (``init_params``)
  * abstract parameters     (``abstract_params`` — ShapeDtypeStruct only,
                             used by the multi-pod dry-run: no allocation)
  * logical-axis tree       (``axes_tree`` — consumed by
                             ``repro.models.sharding`` to build
                             NamedShardings for the production mesh)
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Spec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | embed | scaled
    scale_dim: int = -1               # fan-in dim index for "scaled"

    def __post_init__(self):  # pragma: no cover - NamedTuple has no post_init
        pass


SpecTree = Dict[str, Any]   # nested dict of Spec


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def map_specs(fn, tree: SpecTree):
    """Map ``fn`` over every Spec leaf, preserving dict structure."""
    if _is_spec(tree):
        return fn(tree)
    return {k: map_specs(fn, v) for k, v in tree.items()}


def map_specs_with_path(fn, tree: SpecTree, path: str = ""):
    if _is_spec(tree):
        return fn(path, tree)
    return {k: map_specs_with_path(fn, v, f"{path}/{k}") for k, v in tree.items()}


def _path_key(key: jax.Array, path: str) -> jax.Array:
    digest = hashlib.sha256(path.encode()).digest()
    fold = int.from_bytes(digest[:4], "little")
    return jax.random.fold_in(key, fold)


def init_params(specs: SpecTree, key: jax.Array, dtype=jnp.float32):
    """Materialize parameters for a spec tree (deterministic in path)."""
    def init_one(path: str, s: Spec):
        k = _path_key(key, path)
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        if s.init == "embed":
            return (jax.random.normal(k, s.shape) * 0.02).astype(dtype)
        # normal / scaled: truncated-normal with 1/sqrt(fan_in) scaling
        fan_in = s.shape[s.scale_dim] if s.init == "scaled" else (
            s.shape[0] if len(s.shape) > 1 else s.shape[-1])
        scale = 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.truncated_normal(k, -2.0, 2.0, s.shape)
                * scale).astype(dtype)

    return map_specs_with_path(init_one, specs)


def abstract_params(specs: SpecTree, dtype=jnp.float32):
    """ShapeDtypeStruct tree — NO device allocation (dry-run path)."""
    return map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs)


def axes_tree(specs: SpecTree):
    """Tree of logical-axis tuples matching the param tree structure."""
    return map_specs(lambda s: s.axes, specs)


def param_count(specs: SpecTree) -> int:
    total = 0

    def add(s: Spec):
        nonlocal total
        n = 1
        for d in s.shape:
            n *= d
        total += n
        return None

    map_specs(add, specs)
    return total


def stack_specs(specs: SpecTree, n: int, axis_name: Optional[str] = None) -> SpecTree:
    """Stack a per-layer spec tree ``n`` times along a new leading 'layers' dim.

    Used for run-grouped ``lax.scan`` execution: a run of ``n`` identical
    layers stores parameters as one stacked tree.
    """
    return map_specs(
        lambda s: Spec((n,) + s.shape, (axis_name,) + s.axes, s.init,
                       s.scale_dim if s.scale_dim < 0 else s.scale_dim + 1),
        specs)
