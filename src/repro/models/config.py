"""Model configuration for all assigned architectures.

A single decoder-only ``ModelConfig`` describes every architecture in the
assigned pool (dense / MoE / MLA-MoE / SSM / hybrid / audio / vlm).  The
per-layer ``layer_pattern`` drives the run-grouped scan execution in
``repro.models.model`` (maximal uniform segments of identical layer types
are stacked and executed with ``lax.scan``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Layer type identifiers (see repro.models.blocks).
DENSE = "dense"            # GQA attention + (Swi)GLU MLP
SWA = "swa"                # sliding-window GQA attention + MLP
MOE = "moe"                # GQA attention + mixture-of-experts FFN
MLA_DENSE = "mla_dense"    # multi-head latent attention + dense FFN
MLA_MOE = "mla_moe"        # multi-head latent attention + MoE FFN
HYMBA = "hymba"            # parallel (SWA attention ‖ mamba SSM) + MLP
HYMBA_GLOBAL = "hymba_g"   # parallel (full attention ‖ mamba SSM) + MLP
MLSTM = "mlstm"            # xLSTM matrix-memory block (pre-up projection)
SLSTM = "slstm"            # xLSTM scalar-memory block (post-up FFN)

ATTN_LAYER_TYPES = (DENSE, SWA, MOE, HYMBA, HYMBA_GLOBAL)
MLA_LAYER_TYPES = (MLA_DENSE, MLA_MOE)
SSM_ONLY_LAYER_TYPES = (MLSTM, SLSTM)
FULL_ATTN_LAYER_TYPES = (DENSE, MOE, HYMBA_GLOBAL, MLA_DENSE, MLA_MOE)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                  # hidden width of each routed expert
    num_shared_experts: int = 0    # deepseek-style always-on experts
    d_shared_expert: int = 0       # hidden width of the shared expert(s)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01  # load-balance auxiliary loss weight
    router_z_coef: float = 1e-3


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 => dense q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_size: int = 16
    conv_width: int = 4
    expand: int = 1                # d_inner = expand * d_model
    dt_rank: int = 0               # 0 => ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    mlstm_proj_factor: float = 2.0   # pre-up projection factor (mLSTM)
    slstm_proj_factor: float = 4.0 / 3.0  # post-up FFN factor (sLSTM)
    conv_width: int = 4
    num_heads: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // n_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # window size for SWA layers
    attn_logit_softcap: float = 0.0
    # sub-module configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # per-layer types; () => (DENSE,) * n_layers
    layer_pattern: Tuple[str, ...] = ()
    # embedding / head
    tie_embeddings: bool = True
    num_codebooks: int = 0         # musicgen: EnCodec codebooks (0 => text)
    norm_eps: float = 1e-6
    act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU)
    # MLA decode path: False = paper-faithful expand (vLLM v0.7-era),
    # True = absorbed latent-space attention (beyond-paper perf option).
    mla_absorb: bool = False
    # Weight sharding layout: False = training layout (2D FSDP: d_model
    # over data) — right when per-step compute amortizes weight
    # gathers.  True = inference layout (weights shard over model only;
    # expert banks shard expert->model, f->data with contraction dims
    # unsharded) — at decode the per-layer FSDP gathers dominate the
    # collective term (§Perf pair 2, iteration 4).
    inference_weight_layout: bool = False
    # provenance
    source: str = ""
    # serving hints
    max_seq_len: int = 131_072
    sub_quadratic: bool = False    # eligible for long_500k decode

    def __post_init__(self):
        if not self.layer_pattern:
            object.__setattr__(self, "layer_pattern", (DENSE,) * self.n_layers)
        if len(self.layer_pattern) != self.n_layers:
            raise ValueError(
                f"{self.name}: layer_pattern has {len(self.layer_pattern)} "
                f"entries, expected n_layers={self.n_layers}")
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: n_heads % n_kv_heads != 0")

    # ------------------------------------------------------------------
    @property
    def layer_runs(self) -> Tuple[Tuple[str, int], ...]:
        """Maximal runs of identical consecutive layer types."""
        runs = []
        for t in self.layer_pattern:
            if runs and runs[-1][0] == t:
                runs[-1][1] += 1
            else:
                runs.append([t, 1])
        return tuple((t, n) for t, n in runs)

    @property
    def uses_attention(self) -> bool:
        return any(t in ATTN_LAYER_TYPES or t in MLA_LAYER_TYPES
                   for t in self.layer_pattern)

    @property
    def is_pure_full_attention(self) -> bool:
        """True when every token-mixing layer is full (unwindowed) attention.

        Such architectures skip the long_500k decode shape (see DESIGN.md).
        """
        return all(t in FULL_ATTN_LAYER_TYPES for t in self.layer_pattern)

    def param_count(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        from repro.models import model as _model
        return _model.count_params(self)

    def active_param_count(self) -> int:
        from repro.models import model as _model
        return _model.count_params(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            vocab: int = 512, max_experts: int = 4) -> ModelConfig:
    """A tiny same-family variant of ``cfg`` for CPU smoke tests.

    Preserves the layer-type mix (first/last pattern entries survive) while
    shrinking every dimension per the assignment rules (≤2 layers,
    d_model ≤ 512, ≤4 experts).
    """
    # Keep a representative layer pattern: first layer + a "typical" layer.
    pattern = tuple(cfg.layer_pattern[i] for i in
                    _representative_indices(cfg.layer_pattern, n_layers))
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    moe = cfg.moe
    if moe is not None:
        n_exp = min(moe.num_experts, max_experts)
        top_k = min(moe.top_k, 2)
        moe = dataclasses.replace(
            moe, num_experts=n_exp, top_k=top_k,
            d_expert=min(moe.d_expert, d_model),
            num_shared_experts=min(moe.num_shared_experts, 1),
            d_shared_expert=min(moe.d_shared_expert, d_model),
            # dropless in tests: capacity == all tokens, so results are
            # independent of batch grouping (exact prefill/decode parity)
            capacity_factor=n_exp / top_k)
    mla = cfg.mla
    if mla is not None:
        mla = dataclasses.replace(mla, kv_lora_rank=64, q_lora_rank=0,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16,
                                  v_head_dim=32)
    return cfg.replace(
        name=cfg.name + "-reduced", n_layers=len(pattern),
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        d_ff=min(cfg.d_ff, d_model * 2) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, vocab),
        head_dim=d_model // n_heads,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        moe=moe, mla=mla, layer_pattern=pattern, max_seq_len=2048)


def _representative_indices(pattern, n):
    """Pick ``n`` indices covering as many distinct layer types as possible."""
    seen, idxs = set(), []
    for i, t in enumerate(pattern):
        if t not in seen:
            seen.add(t)
            idxs.append(i)
    while len(idxs) < n:
        idxs.append(len(pattern) - 1)
    return sorted(idxs[:n])
