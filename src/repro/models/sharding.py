"""Logical-axis -> mesh-axis sharding rules (MaxText-style, minimal).

Every parameter / cache / activation tensor carries a tuple of logical
axis names (see ``params.Spec``).  ``logical_to_spec`` maps those names
onto mesh axes by priority-ordered rules with two safety properties:

  * a rule only fires if the dim size is divisible by the mesh-axes
    product (e.g. kv_heads=2 on model=16 silently falls back to
    replicated instead of erroring);
  * no mesh axis is used twice within one tensor.

The active rule set is a context variable so model code can request
activation constraints (``constrain``) without threading a mesh through
every call — on CPU tests there is no context and constraints are no-ops.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Priority-ordered candidate mesh axes per logical axis.  Each candidate
# is a tuple of mesh axis names used jointly for that dim.
DEFAULT_RULES: Tuple[Tuple[str, Tuple[Tuple[str, ...], ...]], ...] = (
    ("batch", (("pod", "data"), ("data",))),
    ("vocab", (("model",),)),
    ("expert", (("model",),)),
    ("heads", (("model",),)),
    ("kv_heads", (("model",),)),
    ("mlp", (("model",),)),
    ("cache_seq", (("model",),)),          # decode: pooled-HBM KV sharding
    ("seq", (("model",),)),                # sequence parallelism (activations)
    ("embed", (("pod", "data"), ("data",))),  # FSDP weight sharding
    ("layers", ()),
)

# Variant used for long_500k: batch=1 so the data axis is free; the KV
# cache sequence dim spreads across BOTH axes = the whole pod's HBM
# (the TPU-native analogue of AIBrix's distributed KV cache pool).
LONG_CONTEXT_RULES = (
    ("batch", ()),
    ("vocab", (("model",),)),
    ("expert", (("model",),)),
    ("heads", (("model",),)),
    ("kv_heads", (("model",),)),
    ("mlp", (("model",),)),
    ("cache_seq", (("pod", "data"), ("data",))),
    ("seq", (("data",),)),
    ("embed", (("pod", "data"), ("data",))),
    ("layers", ()),
)


class ShardingCtx:
    def __init__(self, mesh: Mesh, rules=DEFAULT_RULES, fsdp: bool = True):
        self.mesh = mesh
        self.rules = dict(rules)
        self.fsdp = fsdp

    @property
    def data_shards(self) -> int:
        """Number of shards along the 'batch' logical axis (for
        shard-local MoE dispatch)."""
        for cand in self.rules.get("batch", ()):
            size = self.axis_size(cand)
            if size:
                return size
        return 1

    def axis_size(self, names: Tuple[str, ...]) -> Optional[int]:
        size = 1
        for n in names:
            if n not in self.mesh.shape:
                return None
            size *= self.mesh.shape[n]
        return size

    def spec_for(self, shape: Sequence[int],
                 axes: Sequence[Optional[str]]) -> P:
        """Build a PartitionSpec; priority order = DEFAULT_RULES order."""
        assign: dict = {}
        used: set = set()
        # evaluate logical axes in rule-priority order, not dim order
        for rule_name, candidates in self.rules.items():
            if rule_name == "embed" and not self.fsdp:
                continue
            for dim, ax in enumerate(axes):
                if ax != rule_name or dim in assign:
                    continue
                for cand in candidates:
                    if any(c in used for c in cand):
                        continue
                    size = self.axis_size(cand)
                    if size is None or size <= 1:
                        continue
                    if shape[dim] % size == 0:
                        assign[dim] = cand if len(cand) > 1 else cand[0]
                        used.update(cand)
                        break
        entries = [assign.get(d) for d in range(len(shape))]
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding_for(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, axes))


_CTX: contextvars.ContextVar[Optional[ShardingCtx]] = \
    contextvars.ContextVar("sharding_ctx", default=None)


@contextlib.contextmanager
def use_sharding(ctx: Optional[ShardingCtx]):
    token = _CTX.set(ctx)
    try:
        yield ctx
    finally:
        _CTX.reset(token)


def current() -> Optional[ShardingCtx]:
    return _CTX.get()


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Activation sharding constraint; no-op without an active context."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    spec = ctx.spec_for(x.shape, axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def tree_shardings(ctx: ShardingCtx, abstract_tree, axes_tree):
    """NamedSharding tree matching an abstract (ShapeDtypeStruct) tree."""
    return jax.tree.map(
        lambda a, ax: ctx.sharding_for(a.shape, ax),
        abstract_tree, axes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t))


def with_shardings(ctx: ShardingCtx, abstract_tree, axes_tree):
    """Attach shardings to a ShapeDtypeStruct tree (for .lower inputs)."""
    def attach(a, ax):
        return jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=ctx.sharding_for(a.shape, ax))
    return _tree_map_axes(attach, abstract_tree, axes_tree)


def _tree_map_axes(fn, tree, axes_tree):
    if hasattr(axes_tree, "_fields"):          # NamedTuple containers
        return type(axes_tree)(*(
            _tree_map_axes(fn, getattr(tree, f), getattr(axes_tree, f))
            for f in axes_tree._fields))
    if isinstance(axes_tree, tuple) and all(
            isinstance(e, (str, type(None))) for e in axes_tree):
        return fn(tree, axes_tree)             # axes leaf (possibly empty)
    if isinstance(axes_tree, dict):
        return {k: _tree_map_axes(fn, tree[k], axes_tree[k])
                for k in axes_tree}
    if isinstance(axes_tree, (list, tuple)):
        return type(axes_tree)(
            _tree_map_axes(fn, t, a) for t, a in zip(tree, axes_tree))
    raise TypeError(type(axes_tree))
