from repro.models.config import ModelConfig, reduced  # noqa: F401
from repro.models import model  # noqa: F401
