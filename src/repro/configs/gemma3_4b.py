"""Gemma-3-4B — 5:1 local(window 1024):global attention, 128k context.

head_dim 256 (decoupled from d_model/n_heads).  [hf:google/gemma-3-1b-pt]
"""
from repro.models.config import DENSE, SWA, ModelConfig


def config() -> ModelConfig:
    pattern = ((SWA,) * 5 + (DENSE,)) * 5 + (SWA,) * 4   # 34 layers
    return ModelConfig(
        name="gemma3-4b", family="dense",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
        d_ff=10_240, vocab_size=262_144,
        head_dim=256, qk_norm=True, rope_theta=1_000_000.0,
        sliding_window=1024,
        layer_pattern=pattern,
        tie_embeddings=True,
        source="[hf:google/gemma-3-1b-pt]",
        max_seq_len=131_072, sub_quadratic=True)
