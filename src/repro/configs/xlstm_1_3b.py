"""xLSTM-1.3B — sLSTM + mLSTM blocks at 7:1 (mLSTM:sLSTM).

No attention, no KV cache — constant-size recurrent state makes this the
canonical long_500k architecture.  [arXiv:2405.04517]
"""
from repro.models.config import MLSTM, SLSTM, ModelConfig, XLSTMConfig


def config() -> ModelConfig:
    pattern = ((MLSTM,) * 7 + (SLSTM,)) * 6           # 48 layers
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50_304,
        xlstm=XLSTMConfig(num_heads=4),
        layer_pattern=pattern,
        tie_embeddings=False,
        source="[arXiv:2405.04517]",
        max_seq_len=1_048_576, sub_quadratic=True)
