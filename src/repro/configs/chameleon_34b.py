"""Chameleon-34B — early-fusion VLM: text + VQ image tokens in one
unified 65536-way vocabulary; qk-norm for stability.  The VQ-GAN image
tokenizer is a STUB per the assignment carve-out: input_specs() supplies
pre-tokenized mixed-modal token ids.  [arXiv:2405.09818]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22_016, vocab_size=65_536,
        qk_norm=True,
        tie_embeddings=False,
        source="[arXiv:2405.09818]",
        max_seq_len=8_192)
