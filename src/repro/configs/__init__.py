"""Architecture registry + assigned input shapes.

``get_config(arch_id)`` resolves any of the 10 assigned architectures
(plus the paper's own eval model).  ``INPUT_SHAPES`` are the four
assigned (seq_len, global_batch, kind) workload shapes.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, Tuple

from repro.models.config import ModelConfig, reduced

_MODULES: Dict[str, str] = {
    "qwen3-0.6b": "qwen3_0_6b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen2-1.5b": "qwen2_1_5b",
    "hymba-1.5b": "hymba_1_5b",
    "glm4-9b": "glm4_9b",
    "xlstm-1.3b": "xlstm_1_3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "gemma3-4b": "gemma3_4b",
    "musicgen-large": "musicgen_large",
    "chameleon-34b": "chameleon_34b",
    # the paper's own evaluation model (not in the assigned pool)
    "deepseek-coder-7b": "deepseek_coder_7b",
}

ASSIGNED_ARCHS: Tuple[str, ...] = tuple(k for k in _MODULES
                                        if k != "deepseek-coder-7b")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.config()


def get_reduced_config(arch_id: str, **kw) -> ModelConfig:
    return reduced(get_config(arch_id), **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """(runs?, reason) — long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention architecture "
                       "(see DESIGN.md long_500k policy)")
    return True, ""
