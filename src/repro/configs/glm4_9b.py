"""GLM-4-9B — dense, RoPE, GQA kv=2.  [hf:THUDM/glm-4-9b]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13_696, vocab_size=151_552,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        source="[hf:THUDM/glm-4-9b]",
        max_seq_len=131_072)
