"""DeepSeek-V2-236B — MLA (kv_lora=512) + MoE (2 shared + 160 routed,
top-6); layer 0 has a dense FFN.  [arXiv:2405.04434]"""
from repro.models.config import (MLA_DENSE, MLA_MOE, MLAConfig, ModelConfig,
                                 MoEConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=12_288,                       # dense FFN width (layer 0)
        vocab_size=102_400,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536,
                      num_shared_experts=2, d_shared_expert=1536),
        layer_pattern=(MLA_DENSE,) + (MLA_MOE,) * 59,
        tie_embeddings=False,
        source="[arXiv:2405.04434]",
        max_seq_len=131_072)
