"""Granite-3.0 MoE 3B-A800M — 40 routed experts, top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base]  (assigned spec line says 40
experts top-8; the HF 1b card lists 32 — we follow the assigned spec.)
"""
from repro.models.config import MOE, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab_size=49_155,
        moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
        layer_pattern=(MOE,) * 32,
        tie_embeddings=True,
        source="[hf:ibm-granite/granite-3.0-1b-a400m-base]",
        max_seq_len=8_192)
