"""MusicGen-large — decoder-only over EnCodec tokens (4 codebooks,
2048-way each).  The EnCodec conv frontend is a STUB per the assignment
carve-out: input_specs() supplies (B, S, 4) codebook-token ids.
[arXiv:2306.05284]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=2048,
        num_codebooks=4, act="gelu",
        tie_embeddings=False,
        source="[arXiv:2306.05284]",
        max_seq_len=16_384)
