"""Qwen3-0.6B — dense, GQA, qk_norm.  [hf:Qwen/Qwen3-8B family card]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=3072, vocab_size=151_936,
        qk_norm=True, rope_theta=1_000_000.0,
        tie_embeddings=True,
        source="[hf:Qwen/Qwen3-8B]",
        max_seq_len=32_768)
