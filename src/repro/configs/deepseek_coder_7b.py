"""DeepSeek-Coder-7B analogue — the model the AIBrix paper itself uses
for the heterogeneous GPU-optimizer evaluation (Fig. 7).  Not part of
the assigned pool; used by benchmarks/bench_hetero.py."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-7b", family="dense",
        n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=11_008, vocab_size=102_400,
        tie_embeddings=False,
        source="[hf:deepseek-ai/deepseek-coder-6.7b-base]",
        max_seq_len=16_384)
