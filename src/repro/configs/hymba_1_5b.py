"""Hymba-1.5B — hybrid: parallel attention + Mamba heads per layer.

Full (global) attention in layers {0, 15, 31}; sliding-window attention
elsewhere; every layer carries an SSM state of 16.  [arXiv:2411.13676]
"""
from repro.models.config import HYMBA, HYMBA_GLOBAL, ModelConfig, SSMConfig

_GLOBAL = (0, 15, 31)


def config() -> ModelConfig:
    pattern = tuple(HYMBA_GLOBAL if i in _GLOBAL else HYMBA
                    for i in range(32))
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab_size=32_001,
        ssm=SSMConfig(state_size=16, conv_width=4, expand=1),
        sliding_window=1024,
        layer_pattern=pattern,
        tie_embeddings=True,
        source="[arXiv:2411.13676]",
        max_seq_len=1_048_576, sub_quadratic=True)
