"""Unified AI runtime sidecar + GPU streaming loader (paper §3.2.3).

One ``AIRuntime`` fronts each engine pod: it abstracts vendor-specific
engines behind a single management API (metrics standardization, model
and adapter lifecycle), and models the cold-start path the paper
optimizes — tiered artifact fetch (remote object store / local disk /
host DRAM) with a *streaming* loader that overlaps fetch with
host-to-device transfer instead of serializing them.

The ColdStartManager tracks artifact placement across nodes so the
orchestrator can schedule new pods where the model already sits (the
paper's "loaded on the fastest available node").
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

# artifact tier bandwidths (bytes/s)
TIER_BW = {
    "remote": 1.0e9,        # object store over network
    "local": 4.0e9,         # local NVMe
    "dram": 20.0e9,         # page cache / host memory
}
H2D_BW = 24.0e9             # host -> accelerator interconnect
ENGINE_INIT_S = 8.0         # process start + engine init overhead


@dataclass
class ModelArtifact:
    name: str
    size_bytes: float
    tier_by_node: Dict[str, str] = field(default_factory=dict)

    def tier_on(self, node: str) -> str:
        return self.tier_by_node.get(node, "remote")


def load_time_s(size_bytes: float, tier: str,
                streaming: bool = True) -> float:
    """Cold-start model load time from a given tier.

    Non-streaming (baseline): fetch fully to host, then copy to device.
    Streaming loader: chunks are fetched and copied in a pipeline, so
    wall time ≈ max(fetch, h2d) + one chunk of the slower stage.
    """
    fetch = size_bytes / TIER_BW[tier]
    h2d = size_bytes / H2D_BW
    if not streaming:
        return fetch + h2d
    chunk = size_bytes / 64.0
    pipe_fill = chunk / min(TIER_BW[tier], H2D_BW)
    return max(fetch, h2d) + pipe_fill


class ColdStartManager:
    """Tracks artifact tiers per node; picks the fastest node + predicts
    pod-ready latency (used by orchestration and autoscaler actuation)."""

    def __init__(self, streaming_loader: bool = True):
        self.artifacts: Dict[str, ModelArtifact] = {}
        self.streaming = streaming_loader

    def register_artifact(self, art: ModelArtifact) -> None:
        self.artifacts[art.name] = art

    def note_cached(self, model: str, node: str, tier: str) -> None:
        self.artifacts[model].tier_by_node[node] = tier

    def best_node(self, model: str, candidates: List[str]) -> str:
        art = self.artifacts[model]
        return min(candidates,
                   key=lambda n: TIER_BW[art.tier_on(n)] * -1.0)

    def cold_start_s(self, model: str, node: str) -> float:
        art = self.artifacts[model]
        t = load_time_s(art.size_bytes, art.tier_on(node), self.streaming)
        return ENGINE_INIT_S + t


class AIRuntime:
    """Vendor-agnostic sidecar: wraps any engine exposing the handle
    contract and presents the standardized management surface the
    control plane speaks (the paper's runtime abstracting vLLM /
    SGLang / TensorRT-LLM protocol differences)."""

    def __init__(self, engine, engine_kind: str = "jax",
                 pod_id: str = "pod-0", node: str = "node-0"):
        self.engine = engine
        self.engine_kind = engine_kind
        self.pod_id = pod_id
        self.node = node
        self._policies: Dict[str, float] = {}

    # ------------------------------------------------- standardized metrics
    def scrape(self) -> Dict[str, float]:
        m = self.engine.metrics()
        return {
            "running_requests": float(m.num_running),
            "waiting_requests": float(m.num_waiting),
            "concurrency": float(m.num_running + m.num_waiting),
            "kv_cache_utilization": float(m.kv_utilization),
            "tokens_per_sec": float(m.tokens_per_sec),
            "avg_latency_s": float(m.avg_latency),
            "queue_time_s": float(m.avg_queue_time),
            "preemptions": float(m.preemptions),
            # windowed TTFT/ITL-SLO attainment from the shared scheduler
            # core — the inverted metrics the autoscalers (and the
            # role-pool rebalancer) can target
            "slo_attainment": float(m.slo_attainment),
            "slo_itl_attainment": float(m.slo_itl_attainment),
            # tiered-KV transfer accounting: host-tier pressure signals
            # for the rebalancer and dashboards (device->host offload
            # bytes, host/pool->device fetch bytes, swap traffic)
            "kv_bytes_offloaded": float(m.kv_bytes_offloaded),
            "kv_bytes_fetched": float(m.kv_bytes_fetched),
            "swap_out": float(m.swap_out),
            "swap_in": float(m.swap_in),
            "host_hit_tokens": float(m.host_hit_tokens),
            # SSD tier: tokens resumed from SSD (total and the subset
            # written by ANOTHER engine on the shared host pool), puts
            # dropped by write-behind backpressure, and predictive-
            # promotion effectiveness (prefetched pages hit vs evicted
            # unused)
            "ssd_hit_tokens": float(m.ssd_hit_tokens),
            "ssd_cross_hit_tokens": float(m.ssd_cross_hit_tokens),
            "ssd_dropped_puts": float(m.ssd_dropped_puts),
            "promote_hits": float(m.promote_hits),
            "promote_wasted": float(m.promote_wasted),
            # failure handling: pool fetch/publish attempts lost to a
            # partition, recompute waste from drop-and-recompute
            # resets, recovery-log pages published
            "kv_fetch_failures": float(m.kv_fetch_failures),
            "wasted_tokens": float(m.wasted_tokens),
            "ckpt_pages": float(m.ckpt_pages),
            # speculative decoding: draft/accept counters + acceptance
            # fraction (dashboards watch it to tune spec_tokens)
            "spec_drafted_tokens": float(m.spec_drafted_tokens),
            "spec_accepted_tokens": float(m.spec_accepted_tokens),
            "spec_acceptance": float(m.spec_acceptance),
            # high-density multi-LoRA: requests that queued behind a
            # non-resident adapter (loud miss — never a silent base-
            # model fallback), requests shed after the queue timeout,
            # and the adapter-tier churn (cold loads, stall seconds,
            # HBM-bank evictions, host-tier hits, residency)
            "lora_miss": float(m.lora_miss),
            "lora_shed": float(m.lora_shed),
            "lora_cold_loads": float(m.lora_cold_loads),
            "lora_cold_load_s": float(m.lora_cold_load_s),
            "lora_evictions": float(m.lora_evictions),
            "lora_host_hits": float(m.lora_host_hits),
            "loaded_adapters": float(len(m.loaded_adapters)),
            # host/device overlap: seconds blocked on readback and the
            # non-overlapped host fraction of step wall time — the gap
            # the async engine loop hides
            "device_wait_s": float(m.device_wait_s),
            "host_overhead_frac": float(m.host_overhead_frac),
        }

    # ------------------------------------------------- engine management
    def load_adapter(self, name: str, weights=None) -> None:
        self.engine.register_adapter(name, weights)

    def unload_adapter(self, name: str) -> None:
        self.engine.unregister_adapter(name)

    def list_adapters(self) -> List[str]:
        return list(self.engine.metrics().loaded_adapters)

    def set_policy(self, key: str, value: float) -> None:
        self._policies[key] = value

    def healthy(self) -> bool:
        fn = getattr(self.engine, "healthy", None)
        return bool(fn()) if callable(fn) else True
