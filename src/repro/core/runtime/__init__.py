from repro.core.runtime.sidecar import (AIRuntime, ColdStartManager,  # noqa: F401
                                        ModelArtifact, load_time_s)
