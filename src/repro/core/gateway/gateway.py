"""AIBrix API gateway: admission control + fairness + routing dispatch.

The Envoy-extension role from the paper: every request passes token-
based rate limiting (TPM/RPM per user — the thing the paper notes
Knative-style circuit breakers cannot express), then the configured
routing policy picks a serving engine.  The gateway is engine-agnostic:
targets are handles registered by the orchestration layer.

Role-pool awareness: engines may be registered with a ``pool`` tag
(``prefill`` / ``decode`` / ``mixed`` / ``draining``, maintained by
``repro.core.orchestration.pools.RolePoolManager`` as it rebalances).
NEW requests only route to frontend pools (prefill/mixed) — decode
members receive work exclusively through the prefill handoff path, and
a draining member receives nothing at all.  ``deregister_engine`` and
``set_engine_pool`` also purge the engine from per-policy routing
state (attainment EWMAs, prefix-affinity maps) so a drained or
migrated pod can never be picked from stale state.
"""
from __future__ import annotations

import collections
import logging
import statistics
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.gateway.router import RoutingPolicy, make_policy
from repro.engine.scheduler import FRONTEND_ROLES

log = logging.getLogger("repro.gateway")


@dataclass
class RateLimit:
    rpm: float = 600.0            # requests / minute
    tpm: float = 600_000.0        # tokens / minute


class TokenBucket:
    def __init__(self, rate_per_min: float, burst: float = None):
        self.rate = rate_per_min / 60.0
        self.capacity = burst if burst is not None else rate_per_min / 6.0
        self.level = self.capacity
        self.t = 0.0

    def allow(self, amount: float, now: float) -> bool:
        self.level = min(self.capacity, self.level + (now - self.t) * self.rate)
        self.t = now
        if self.level >= amount:
            self.level -= amount
            return True
        return False


@dataclass
class GatewayStats:
    routed: int = 0
    rejected_rpm: int = 0
    rejected_tpm: int = 0
    # multi-LoRA routing: requests naming an adapter, and how many of
    # them landed on an engine that already had it resident (the
    # affinity hit rate is the headline routing metric of §3.2.1)
    lora_routed: int = 0
    lora_hits: int = 0
    per_engine: Dict[str, int] = field(default_factory=dict)
    # per-engine failure accounting: engine_id -> {failure kind -> n}
    # (crashes, quarantines, hedged re-routes) — the control plane's
    # evidence trail for replace-vs-readmit decisions
    engine_failures: Dict[str, Dict[str, int]] = field(default_factory=dict)

    @property
    def shed(self) -> int:
        """Requests the rate limiter dropped (they never reached an
        engine — a bench that ignores this under-reports its load)."""
        return self.rejected_rpm + self.rejected_tpm

    @property
    def lora_affinity_hit_rate(self) -> float:
        """Fraction of LoRA requests routed to an engine already
        holding their adapter (1.0 when none were routed)."""
        return self.lora_hits / self.lora_routed if self.lora_routed \
            else 1.0


class Gateway:
    FRONTEND_POOLS = FRONTEND_ROLES    # shared role taxonomy
    SHED_LOG_WINDOW_S = 10.0           # at most one shed log per window
    # process-wide shed counter across every Gateway instance —
    # benchmarks/run.py prints the per-suite delta so a bench whose
    # offered load the rate limiter silently halved cannot pass as
    # having served it (sim benches >10 rps must raise
    # ClusterConfig.rate_limit or their requests vanish here)
    total_shed: int = 0
    # process-wide LoRA routing counters (same contract): run.py prints
    # each suite's affinity hit rate next to its results
    total_lora_routed: int = 0
    total_lora_hits: int = 0

    def __init__(self, policy: str = "least-request",
                 default_limit: RateLimit = None,
                 clock: Callable[[], float] = None, **policy_kw):
        self.policy: RoutingPolicy = make_policy(policy, **policy_kw)
        self.default_limit = default_limit or RateLimit()
        self.clock = clock or (lambda: 0.0)
        if hasattr(self.policy, "attach_clock"):
            self.policy.attach_clock(self.clock)
        self.engines: Dict[str, object] = {}
        # cached routable view: ``route()`` runs per request, so the
        # frontend/cordon filter + id-ordering is computed once per
        # fleet change, not per call.  ``cache_routable=False`` restores
        # the rebuild-every-call behavior (bench_routing's baseline).
        self.cache_routable = True
        self._routable_cache: Optional[Dict[str, object]] = None
        self._routable_key = None
        self._fleet_version = 0
        self.engine_pool: Dict[str, str] = {}     # engine_id -> pool tag
        # quarantined engines: cordoned out of routable_engines() while
        # the DiagnosticMonitor's re-admit probe runs (in-flight work
        # keeps draining; only NEW routing is blocked)
        self.cordoned: set = set()
        self.user_limits: Dict[str, RateLimit] = {}
        # adapter registry (LoRAController): when attached, the gateway
        # feeds it per-adapter arrivals (demand-driven replanning) and
        # wires its endpoint view into the lora-affinity policy
        self.lora_controller = None
        # per-user rate-limit buckets, LRU-bounded: a million-session
        # trace brings a million distinct users, and an unbounded map
        # would hold two bucket objects per user forever.  Evicting the
        # least-recently-routed user resets their bucket to full on
        # return — indistinguishable from an idle user whose bucket
        # refilled, so only sustained >max_user_buckets populations
        # see any leniency.
        self.max_user_buckets = 1 << 18
        self._rpm: Dict[str, TokenBucket] = collections.OrderedDict()
        self._tpm: Dict[str, TokenBucket] = collections.OrderedDict()
        self.stats = GatewayStats()
        # workload histogram for the GPU optimizer's Load Monitor
        self.request_log: collections.deque = collections.deque(maxlen=4096)
        # loud load shedding: sheds accumulate here and are logged at
        # most once per SHED_LOG_WINDOW_S (first shed logs immediately;
        # _shed_t0 stamps the accumulation start so the log line
        # reports the real span even after an idle gap)
        self._shed_window = 0
        self._shed_t0 = 0.0
        self._shed_log_at = float("-inf")

    # -------------------------------------------------------------- admin
    def _fleet_changed(self) -> None:
        """Invalidate the cached routable view (any admin mutation)."""
        self._fleet_version += 1
        self._routable_cache = None

    def register_engine(self, engine_id: str, handle,
                        pool: Optional[str] = None) -> None:
        """Register a target.  ``pool`` tags the serving role; untagged
        engines route like 'mixed' (the pre-pool contract)."""
        self.engines[engine_id] = handle
        if pool is not None:
            self.engine_pool[engine_id] = pool
        self._fleet_changed()

    def deregister_engine(self, engine_id: str) -> None:
        """Scale-down/remediation: the engine must become unroutable
        IMMEDIATELY, including from any per-policy state (attainment
        EWMAs, prefix-affinity maps, session pins) that could still
        name it."""
        self.engines.pop(engine_id, None)
        self.engine_pool.pop(engine_id, None)
        self.cordoned.discard(engine_id)
        self.policy.forget(engine_id)
        self._fleet_changed()

    def cordon(self, engine_id: str, reason: str = "quarantine") -> None:
        """Quarantine: stop routing NEW work to the engine without
        deregistering it (it stays registered so telemetry and the
        re-admit probe keep flowing).  Policy state is purged — stale
        affinity must not re-earn routing the moment it is readmitted."""
        if engine_id in self.engines and engine_id not in self.cordoned:
            self.cordoned.add(engine_id)
            self.policy.forget(engine_id)
            self.note_failure(engine_id, reason)
            self._fleet_changed()

    def uncordon(self, engine_id: str) -> None:
        self.cordoned.discard(engine_id)
        self._fleet_changed()

    def note_failure(self, engine_id: str, kind: str) -> None:
        """Per-engine failure accounting (crash / quarantine / hedged)."""
        rec = self.stats.engine_failures.setdefault(engine_id, {})
        rec[kind] = rec.get(kind, 0) + 1

    def set_engine_pool(self, engine_id: str, pool: str) -> None:
        """Role migration: retag without a deregister/register cycle.
        Policy state is purged — affinity earned as a prefill member
        must not leak routing onto the same pod as a decode member."""
        self.engine_pool[engine_id] = pool
        self.policy.forget(engine_id)
        self._fleet_changed()

    def routable_engines(self) -> Dict[str, object]:
        """NEW requests go to frontend pools only (prefill/mixed) and
        never to a cordoned engine; untagged engines (no pool manager)
        keep the legacy behavior.

        The returned view is CACHED and id-ordered: it is rebuilt only
        when the fleet changes (register/deregister/retag/cordon — and
        a length check catches direct ``cordoned`` mutation), so the
        per-request routing path does no filtering or sorting.  Policies
        rely on the id-ordering for deterministic tie-breaks."""
        key = (self._fleet_version, len(self.engines),
               len(self.engine_pool), len(self.cordoned))
        if self.cache_routable and self._routable_cache is not None \
                and self._routable_key == key:
            return self._routable_cache
        if not self.engine_pool and not self.cordoned:
            view = {eid: self.engines[eid]
                    for eid in sorted(self.engines)}
        elif not self.engine_pool:
            view = {eid: self.engines[eid]
                    for eid in sorted(self.engines)
                    if eid not in self.cordoned}
        else:
            view = {eid: self.engines[eid]
                    for eid in sorted(self.engines)
                    if eid not in self.cordoned
                    and self.engine_pool.get(eid, "mixed")
                    in self.FRONTEND_POOLS}
        self._routable_cache = view
        self._routable_key = key
        return view

    def straggler_engines(self, ratio: float = 0.5) -> List[str]:
        """Fleet-relative straggler detection: routable engines whose
        windowed tokens/s sits below ``ratio`` x the fleet median while
        they still hold work (queued or running).  A silently degraded
        node looks exactly like this — slow, not dead — and the hedging
        loop re-routes its queued work before the DiagnosticMonitor's
        quarantine confirm window elapses."""
        mets = {eid: h.metrics() for eid, h in
                self.routable_engines().items()}
        rates = [m.tokens_per_sec for m in mets.values()
                 if m.tokens_per_sec > 0]
        if len(rates) < 2:
            return []
        med = statistics.median(rates)
        return [eid for eid, m in mets.items()
                if (m.num_waiting or m.num_running)
                and m.tokens_per_sec < ratio * med]

    def set_user_limit(self, user: str, limit: RateLimit) -> None:
        self.user_limits[user] = limit

    def set_policy(self, name: str, **kw) -> None:
        self.policy = make_policy(name, **kw)
        if hasattr(self.policy, "attach_clock"):
            self.policy.attach_clock(self.clock)
        if self.lora_controller is not None \
                and hasattr(self.policy, "set_endpoints"):
            self.policy.set_endpoints(self.lora_controller.endpoints)

    def attach_lora_controller(self, ctrl) -> None:
        """Back the gateway with an adapter registry: routed LoRA
        requests feed the controller's demand window, and the
        lora-affinity policy learns the controller's real endpoints."""
        self.lora_controller = ctrl
        if hasattr(self.policy, "set_endpoints"):
            self.policy.set_endpoints(ctrl.endpoints)

    # -------------------------------------------------------------- route
    def _buckets(self, user: str) -> Tuple[TokenBucket, TokenBucket]:
        if user not in self._rpm:
            lim = self.user_limits.get(user, self.default_limit)
            if len(self._rpm) >= self.max_user_buckets:
                old, _ = self._rpm.popitem(last=False)
                self._tpm.pop(old, None)
            self._rpm[user] = TokenBucket(lim.rpm)
            self._tpm[user] = TokenBucket(lim.tpm)
        else:
            self._rpm.move_to_end(user)
        return self._rpm[user], self._tpm[user]

    def route(self, tokens: Sequence[int], user: str = "default",
              lora_adapter: Optional[str] = None,
              est_output_tokens: int = 64,
              priority_class: str = "standard",
              session_id: Optional[str] = None) -> Optional[str]:
        """Admission + routing.  Returns engine id, or None if rejected
        (token-based rate limit) / no engine registered.
        ``priority_class`` is the request's SLO class — the slo-aware
        policy routes by its per-class attainment/slack; ``session_id``
        is the multi-turn conversation key — the session policy pins
        it to the engine holding the conversation's KV prefix; other
        policies ignore them."""
        now = self.clock()
        targets = self.routable_engines()
        if not targets:
            return None
        rpm, tpm = self._buckets(user)
        if not rpm.allow(1.0, now):
            self.stats.rejected_rpm += 1
            self._note_shed(user, now)
            return None
        if not tpm.allow(len(tokens) + est_output_tokens, now):
            self.stats.rejected_tpm += 1
            self._note_shed(user, now)
            return None
        eid = self.policy.select(targets, tokens, lora_adapter,
                                 priority_class=priority_class,
                                 session_id=session_id)
        if lora_adapter:
            # affinity accounting: did the chosen engine already hold
            # the adapter, or does this request pay a cold load?
            self.stats.lora_routed += 1
            Gateway.total_lora_routed += 1
            try:
                resident = lora_adapter in \
                    targets[eid].metrics().loaded_adapters
            except Exception:
                resident = False
            if resident:
                self.stats.lora_hits += 1
                Gateway.total_lora_hits += 1
            if self.lora_controller is not None:
                self.lora_controller.note_request(lora_adapter, now)
        self.stats.routed += 1
        self.stats.per_engine[eid] = self.stats.per_engine.get(eid, 0) + 1
        self.request_log.append(
            (now, len(tokens), est_output_tokens, user, eid))
        return eid

    def _note_shed(self, user: str, now: float) -> None:
        """Rate-limit drops must be LOUD: count them (instance +
        process-wide) and log once per window with the running totals,
        so a workload the limiter is silently halving shows up in bench
        output instead of just reading as light load."""
        Gateway.total_shed += 1
        if self._shed_window == 0:
            self._shed_t0 = now
        self._shed_window += 1
        if now >= self._shed_log_at:
            log.warning(
                "gateway shed %d request(s) over the last %.1fs "
                "(user=%s; totals: rpm=%d tpm=%d) — raise RateLimit if "
                "this load is intended",
                self._shed_window, max(now - self._shed_t0, 0.0), user,
                self.stats.rejected_rpm, self.stats.rejected_tpm)
            self._shed_window = 0
            self._shed_log_at = now + self.SHED_LOG_WINDOW_S

    # -------------------------------------------------------------- stats
    def workload_histogram(self, in_edges=(200, 1000, 4000),
                           out_edges=(100, 500)) -> Dict[tuple, int]:
        """Bucketed (input_len, output_len) histogram — the Load Monitor
        input for the SLO-driven GPU optimizer (paper §3.2.7)."""
        hist: Dict[tuple, int] = {}

        def bucket(v, edges):
            for i, e in enumerate(edges):
                if v < e:
                    return i
            return len(edges)

        for _, ilen, olen, _, _ in self.request_log:
            key = (bucket(ilen, in_edges), bucket(olen, out_edges))
            hist[key] = hist.get(key, 0) + 1
        return hist
