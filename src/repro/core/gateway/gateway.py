"""AIBrix API gateway: admission control + fairness + routing dispatch.

The Envoy-extension role from the paper: every request passes token-
based rate limiting (TPM/RPM per user — the thing the paper notes
Knative-style circuit breakers cannot express), then the configured
routing policy picks a serving engine.  The gateway is engine-agnostic:
targets are handles registered by the orchestration layer.

Role-pool awareness: engines may be registered with a ``pool`` tag
(``prefill`` / ``decode`` / ``mixed`` / ``draining``, maintained by
``repro.core.orchestration.pools.RolePoolManager`` as it rebalances).
NEW requests only route to frontend pools (prefill/mixed) — decode
members receive work exclusively through the prefill handoff path, and
a draining member receives nothing at all.  ``deregister_engine`` and
``set_engine_pool`` also purge the engine from per-policy routing
state (attainment EWMAs, prefix-affinity maps) so a drained or
migrated pod can never be picked from stale state.

Sharded core: the gateway's HOT mutable state — session pin tables,
per-user rate-limit buckets, per-shard routing stats and the cached
routable view — lives in N independent ``_GatewayShard`` objects,
picked per request by ``hash(session_id | user)``.  Every structure a
``route()`` call touches is shard-private, so (a) per-call cost is a
function of the shard's table sizes, not the gateway's (cache locality
— a 500k-pin table walks cold cache lines; 500k/16 stays hot), and
(b) shards share zero mutable state, so the layout maps 1:1 onto a
real multi-gateway deployment where each shard is its own process
behind a consistent-hash LB and aggregate capacity is per-shard rate x
N.  Fleet topology (engines, pools, cordons, user limit overrides)
stays global — it is read-mostly and admin-mutated only.  Stats merge
lazily: ``gateway.stats`` returns the single shard's live object when
``shards == 1`` (the historical contract) and a merged snapshot
otherwise.
"""
from __future__ import annotations

import collections
import logging
import statistics
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.gateway.router import RoutingPolicy, make_policy
from repro.engine.scheduler import FRONTEND_ROLES

log = logging.getLogger("repro.gateway")


@dataclass
class RateLimit:
    rpm: float = 600.0            # requests / minute
    tpm: float = 600_000.0        # tokens / minute


class TokenBucket:
    def __init__(self, rate_per_min: float, burst: float = None):
        self.rate = rate_per_min / 60.0
        self.capacity = burst if burst is not None else rate_per_min / 6.0
        self.level = self.capacity
        self.t = 0.0

    def allow(self, amount: float, now: float) -> bool:
        self.level = min(self.capacity, self.level + (now - self.t) * self.rate)
        self.t = now
        if self.level >= amount:
            self.level -= amount
            return True
        return False


@dataclass
class GatewayStats:
    routed: int = 0
    rejected_rpm: int = 0
    rejected_tpm: int = 0
    # multi-LoRA routing: requests naming an adapter, and how many of
    # them landed on an engine that already had it resident (the
    # affinity hit rate is the headline routing metric of §3.2.1)
    lora_routed: int = 0
    lora_hits: int = 0
    per_engine: Dict[str, int] = field(default_factory=dict)
    # per-engine failure accounting: engine_id -> {failure kind -> n}
    # (crashes, quarantines, hedged re-routes) — the control plane's
    # evidence trail for replace-vs-readmit decisions
    engine_failures: Dict[str, Dict[str, int]] = field(default_factory=dict)

    @property
    def shed(self) -> int:
        """Requests the rate limiter dropped (they never reached an
        engine — a bench that ignores this under-reports its load)."""
        return self.rejected_rpm + self.rejected_tpm

    @property
    def lora_affinity_hit_rate(self) -> float:
        """Fraction of LoRA requests routed to an engine already
        holding their adapter (1.0 when none were routed)."""
        return self.lora_hits / self.lora_routed if self.lora_routed \
            else 1.0

    @classmethod
    def merge(cls, parts) -> "GatewayStats":
        """Lazy cross-shard aggregation: counters sum, per-engine and
        failure maps merge key-wise.  Derived properties (``shed``,
        ``lora_affinity_hit_rate``) then read correctly off the sums."""
        out = cls()
        for s in parts:
            out.routed += s.routed
            out.rejected_rpm += s.rejected_rpm
            out.rejected_tpm += s.rejected_tpm
            out.lora_routed += s.lora_routed
            out.lora_hits += s.lora_hits
            for eid, n in s.per_engine.items():
                out.per_engine[eid] = out.per_engine.get(eid, 0) + n
            for eid, rec in s.engine_failures.items():
                dst = out.engine_failures.setdefault(eid, {})
                for kind, n in rec.items():
                    dst[kind] = dst.get(kind, 0) + n
        return out


class _GatewayShard:
    """One slice of the gateway's hot state.  Everything here is
    touched on the per-request path and NOTHING here is shared with a
    sibling shard — the independence is the whole point."""

    __slots__ = ("policy", "stats", "_rpm", "_tpm",
                 "_routable_cache", "_routable_key",
                 "_shed_window", "_shed_t0", "_shed_log_at")

    def __init__(self, policy: RoutingPolicy):
        self.policy = policy
        self.stats = GatewayStats()
        # per-user token buckets, LRU-bounded (see Gateway.max_user_buckets)
        self._rpm: "collections.OrderedDict[str, TokenBucket]" = \
            collections.OrderedDict()
        self._tpm: "collections.OrderedDict[str, TokenBucket]" = \
            collections.OrderedDict()
        # shard-local cached routable view (same content as every other
        # shard's — engines are global — but a private reference means
        # the route() path never touches shared mutable state)
        self._routable_cache: Optional[Dict[str, object]] = None
        self._routable_key = None
        # windowed shed logging state
        self._shed_window = 0
        self._shed_t0 = 0.0
        self._shed_log_at = float("-inf")


class Gateway:
    FRONTEND_POOLS = FRONTEND_ROLES    # shared role taxonomy
    SHED_LOG_WINDOW_S = 10.0           # at most one shed log per window
    # process-wide shed counter across every Gateway instance —
    # benchmarks/run.py prints the per-suite delta so a bench whose
    # offered load the rate limiter silently halved cannot pass as
    # having served it (sim benches >10 rps must raise
    # ClusterConfig.rate_limit or their requests vanish here)
    total_shed: int = 0
    # process-wide LoRA routing counters (same contract): run.py prints
    # each suite's affinity hit rate next to its results
    total_lora_routed: int = 0
    total_lora_hits: int = 0

    def __init__(self, policy: str = "least-request",
                 default_limit: RateLimit = None,
                 clock: Callable[[], float] = None,
                 shards: int = 1, **policy_kw):
        self.num_shards = max(1, int(shards))
        self.default_limit = default_limit or RateLimit()
        self.clock = clock or (lambda: 0.0)
        self.engines: Dict[str, object] = {}
        # cached routable view: ``route()`` runs per request, so the
        # frontend/cordon filter + id-ordering is computed once per
        # fleet change, not per call.  ``cache_routable=False`` restores
        # the rebuild-every-call behavior (bench_routing's baseline).
        self.cache_routable = True
        self._fleet_version = 0
        self.engine_pool: Dict[str, str] = {}     # engine_id -> pool tag
        # quarantined engines: cordoned out of routable_engines() while
        # the DiagnosticMonitor's re-admit probe runs (in-flight work
        # keeps draining; only NEW routing is blocked)
        self.cordoned: set = set()
        self.user_limits: Dict[str, RateLimit] = {}
        # adapter registry (LoRAController): when attached, the gateway
        # feeds it per-adapter arrivals (demand-driven replanning) and
        # wires its endpoint view into the lora-affinity policy
        self.lora_controller = None
        # per-user rate-limit bucket budget, LRU-bounded and split
        # evenly across shards: a million-session trace brings a
        # million distinct users, and an unbounded map would hold two
        # bucket objects per user forever.  Evicting the least-
        # recently-routed user resets their bucket to full on return —
        # indistinguishable from an idle user whose bucket refilled, so
        # only sustained >max_user_buckets populations see any leniency.
        self.max_user_buckets = 1 << 18
        self._policy_name = policy
        self._policy_kw = dict(policy_kw)
        self._shards: List[_GatewayShard] = [
            _GatewayShard(self._make_shard_policy(policy, **policy_kw))
            for _ in range(self.num_shards)]
        # workload histogram for the GPU optimizer's Load Monitor
        self.request_log: collections.deque = collections.deque(maxlen=4096)

    # ------------------------------------------------------------- shards
    def _shard_for(self, key: str) -> _GatewayShard:
        """Shard pick by ``hash(session_id | user)``.  crc32, not
        ``hash()``: Python salts str hashes per process, and the shard
        map must be deterministic so sharded-vs-monolithic equivalence
        holds run to run (and across the real deployment's LB)."""
        if self.num_shards == 1:
            return self._shards[0]
        return self._shards[zlib.crc32(key.encode()) % self.num_shards]

    def _policies(self) -> List[RoutingPolicy]:
        """Unique policy objects across shards (the ``policy`` setter
        aliases one object into every shard, so counters would double-
        count without the dedup)."""
        seen: Dict[int, RoutingPolicy] = {}
        for sh in self._shards:
            seen.setdefault(id(sh.policy), sh.policy)
        return list(seen.values())

    def _make_shard_policy(self, name: str, **kw) -> RoutingPolicy:
        pol = make_policy(name, **kw)
        if hasattr(pol, "attach_clock"):
            pol.attach_clock(self.clock)
        if self.lora_controller is not None \
                and hasattr(pol, "set_endpoints"):
            pol.set_endpoints(self.lora_controller.endpoints)
        return pol

    # --------------------------------------------- back-compat properties
    @property
    def policy(self) -> RoutingPolicy:
        """Shard 0's policy — THE policy when ``shards == 1`` (the
        historical single-shard contract)."""
        return self._shards[0].policy

    @policy.setter
    def policy(self, pol: RoutingPolicy) -> None:
        """Install one externally-built policy object into every shard
        (bench baselines swap hand-rolled policies in this way).  The
        object is aliased, not copied — cross-shard aggregation dedups
        by identity."""
        for sh in self._shards:
            sh.policy = pol
        if hasattr(pol, "attach_clock"):
            pol.attach_clock(self.clock)
        if self.lora_controller is not None \
                and hasattr(pol, "set_endpoints"):
            pol.set_endpoints(self.lora_controller.endpoints)

    @property
    def stats(self) -> GatewayStats:
        """Single-shard: the live stats object (writes through it are
        visible, tests rely on this).  Multi-shard: a merged SNAPSHOT —
        mutating it is meaningless."""
        if self.num_shards == 1:
            return self._shards[0].stats
        return GatewayStats.merge(sh.stats for sh in self._shards)

    @property
    def _rpm(self):
        return self._shards[0]._rpm

    @property
    def _tpm(self):
        return self._shards[0]._tpm

    @property
    def _routable_cache(self):
        return self._shards[0]._routable_cache

    @_routable_cache.setter
    def _routable_cache(self, value) -> None:
        for sh in self._shards:
            sh._routable_cache = value

    def clear_user_buckets(self) -> None:
        """Drop every user's rate-limit bucket (gateway restart: the
        replacement comes up with empty admission state)."""
        for sh in self._shards:
            sh._rpm.clear()
            sh._tpm.clear()

    # -------------------------------------------------------------- admin
    def _fleet_changed(self) -> None:
        """Invalidate every shard's cached routable view (any admin
        mutation).  Bumping the global version is enough — each shard
        revalidates lazily on its next route()."""
        self._fleet_version += 1
        for sh in self._shards:
            sh._routable_cache = None

    def _forget_all(self, engine_id: str) -> None:
        """Purge the engine from EVERY shard's policy state.  A pin
        that survives in any one shard is exactly the stale-routing bug
        sharding must not reintroduce."""
        for pol in self._policies():
            pol.forget(engine_id)

    def register_engine(self, engine_id: str, handle,
                        pool: Optional[str] = None) -> None:
        """Register a target.  ``pool`` tags the serving role; untagged
        engines route like 'mixed' (the pre-pool contract).

        Re-registration with a DIFFERENT pool tag is a retag: policy
        state earned under the old role is purged, same as
        ``set_engine_pool`` — without this, a pod re-registered
        straight into a decode pool keeps its session pins and those
        sessions route into a black hole until TTL expiry."""
        retag = engine_id in self.engines and pool is not None \
            and self.engine_pool.get(engine_id, "mixed") != pool
        self.engines[engine_id] = handle
        if pool is not None:
            self.engine_pool[engine_id] = pool
        if retag:
            self._forget_all(engine_id)
        self._fleet_changed()

    def deregister_engine(self, engine_id: str) -> None:
        """Scale-down/remediation: the engine must become unroutable
        IMMEDIATELY, including from any per-policy state (attainment
        EWMAs, prefix-affinity maps, session pins) that could still
        name it."""
        self.engines.pop(engine_id, None)
        self.engine_pool.pop(engine_id, None)
        self.cordoned.discard(engine_id)
        self._forget_all(engine_id)
        self._fleet_changed()

    def cordon(self, engine_id: str, reason: str = "quarantine") -> None:
        """Quarantine: stop routing NEW work to the engine without
        deregistering it (it stays registered so telemetry and the
        re-admit probe keep flowing).  Policy state is purged — stale
        affinity must not re-earn routing the moment it is readmitted."""
        if engine_id in self.engines and engine_id not in self.cordoned:
            self.cordoned.add(engine_id)
            self._forget_all(engine_id)
            self.note_failure(engine_id, reason)
            self._fleet_changed()

    def uncordon(self, engine_id: str) -> None:
        self.cordoned.discard(engine_id)
        self._fleet_changed()

    def note_failure(self, engine_id: str, kind: str) -> None:
        """Per-engine failure accounting (crash / quarantine / hedged).
        Recorded on the engine's home shard (by engine-id hash) so
        concurrent recorders never contend; the merged view re-unifies
        per engine."""
        rec = self._shard_for(engine_id).stats.engine_failures \
            .setdefault(engine_id, {})
        rec[kind] = rec.get(kind, 0) + 1

    def set_engine_pool(self, engine_id: str, pool: str) -> None:
        """Role migration: retag without a deregister/register cycle.
        Policy state is purged — affinity earned as a prefill member
        must not leak routing onto the same pod as a decode member."""
        self.engine_pool[engine_id] = pool
        self._forget_all(engine_id)
        self._fleet_changed()

    def _build_routable(self) -> Dict[str, object]:
        if not self.engine_pool and not self.cordoned:
            return {eid: self.engines[eid]
                    for eid in sorted(self.engines)}
        if not self.engine_pool:
            return {eid: self.engines[eid]
                    for eid in sorted(self.engines)
                    if eid not in self.cordoned}
        return {eid: self.engines[eid]
                for eid in sorted(self.engines)
                if eid not in self.cordoned
                and self.engine_pool.get(eid, "mixed")
                in self.FRONTEND_POOLS}

    def _shard_routable(self, shard: _GatewayShard) -> Dict[str, object]:
        key = (self._fleet_version, len(self.engines),
               len(self.engine_pool), len(self.cordoned))
        if self.cache_routable and shard._routable_cache is not None \
                and shard._routable_key == key:
            return shard._routable_cache
        view = self._build_routable()
        shard._routable_cache = view
        shard._routable_key = key
        return view

    def routable_engines(self) -> Dict[str, object]:
        """NEW requests go to frontend pools only (prefill/mixed) and
        never to a cordoned engine; untagged engines (no pool manager)
        keep the legacy behavior.

        The returned view is CACHED per shard and id-ordered: it is
        rebuilt only when the fleet changes (register/deregister/retag/
        cordon — and a length check catches direct ``cordoned``
        mutation), so the per-request routing path does no filtering or
        sorting.  Policies rely on the id-ordering for deterministic
        tie-breaks.  This admin-facing accessor reads through shard 0."""
        return self._shard_routable(self._shards[0])

    def straggler_engines(self, ratio: float = 0.5) -> List[str]:
        """Fleet-relative straggler detection: routable engines whose
        windowed tokens/s sits below ``ratio`` x the fleet median while
        they still hold work (queued or running).  A silently degraded
        node looks exactly like this — slow, not dead — and the hedging
        loop re-routes its queued work before the DiagnosticMonitor's
        quarantine confirm window elapses."""
        mets = {eid: h.metrics() for eid, h in
                self.routable_engines().items()}
        rates = [m.tokens_per_sec for m in mets.values()
                 if m.tokens_per_sec > 0]
        if len(rates) < 2:
            return []
        med = statistics.median(rates)
        return [eid for eid, m in mets.items()
                if (m.num_waiting or m.num_running)
                and m.tokens_per_sec < ratio * med]

    def set_user_limit(self, user: str, limit: RateLimit) -> None:
        self.user_limits[user] = limit

    def set_policy(self, name: str, **kw) -> None:
        """Swap the routing policy fleet-wide: every shard gets its own
        fresh instance (independent pin tables — sharing one would
        serialize them again)."""
        self._policy_name, self._policy_kw = name, dict(kw)
        for sh in self._shards:
            sh.policy = self._make_shard_policy(name, **kw)

    def attach_lora_controller(self, ctrl) -> None:
        """Back the gateway with an adapter registry: routed LoRA
        requests feed the controller's demand window, and the
        lora-affinity policy learns the controller's real endpoints."""
        self.lora_controller = ctrl
        for pol in self._policies():
            if hasattr(pol, "set_endpoints"):
                pol.set_endpoints(ctrl.endpoints)

    # ---------------------------------------------------------- sessions
    def session_stats(self) -> Optional[Dict[str, int]]:
        """Merged session-affinity counters across shards, or None when
        the active policy is not session-based."""
        pols = [p for p in self._policies()
                if getattr(p, "name", "") == "session"]
        if not pols:
            return None
        return {
            "session_hits": sum(p.hits for p in pols),
            "session_misses": sum(p.misses for p in pols),
            "session_rehomed": sum(p.rehomed for p in pols),
            "session_pins": sum(len(p._sessions) for p in pols),
            "promote_skipped": sum(p.promote_skipped for p in pols),
        }

    def due_promotions(self, now: Optional[float] = None,
                       limit: int = 256) -> List[Tuple[str, str]]:
        """Drain due predictive promotions across every shard's session
        policy: ``(session_id, engine_id)`` pairs whose predicted turn
        arrival is within the promote lead.  The per-shard ``limit``
        bounds promoter work per poll."""
        if now is None:
            now = self.clock()
        out: List[Tuple[str, str]] = []
        for pol in self._policies():
            if hasattr(pol, "due_promotions"):
                out.extend(pol.due_promotions(now, limit))
        return out

    # -------------------------------------------------------------- route
    def _buckets(self, shard: _GatewayShard,
                 user: str) -> Tuple[TokenBucket, TokenBucket]:
        rpm = shard._rpm
        if user not in rpm:
            lim = self.user_limits.get(user, self.default_limit)
            cap = max(self.max_user_buckets // self.num_shards, 1)
            if len(rpm) >= cap:
                old, _ = rpm.popitem(last=False)
                shard._tpm.pop(old, None)
            rpm[user] = TokenBucket(lim.rpm)
            shard._tpm[user] = TokenBucket(lim.tpm)
        else:
            rpm.move_to_end(user)
        return rpm[user], shard._tpm[user]

    def route(self, tokens: Sequence[int], user: str = "default",
              lora_adapter: Optional[str] = None,
              est_output_tokens: int = 64,
              priority_class: str = "standard",
              session_id: Optional[str] = None) -> Optional[str]:
        """Admission + routing.  Returns engine id, or None if rejected
        (token-based rate limit) / no engine registered.
        ``priority_class`` is the request's SLO class — the slo-aware
        policy routes by its per-class attainment/slack; ``session_id``
        is the multi-turn conversation key — the session policy pins
        it to the engine holding the conversation's KV prefix; other
        policies ignore them.  The whole call runs against ONE shard
        (picked by session, falling back to user), so its cost tracks
        the shard's table sizes, not the gateway's."""
        now = self.clock()
        shard = self._shard_for(
            session_id if session_id is not None else user)
        targets = self._shard_routable(shard)
        if not targets:
            return None
        rpm, tpm = self._buckets(shard, user)
        if not rpm.allow(1.0, now):
            shard.stats.rejected_rpm += 1
            self._note_shed(shard, user, now)
            return None
        if not tpm.allow(len(tokens) + est_output_tokens, now):
            shard.stats.rejected_tpm += 1
            self._note_shed(shard, user, now)
            return None
        eid = shard.policy.select(targets, tokens, lora_adapter,
                                  priority_class=priority_class,
                                  session_id=session_id)
        if lora_adapter:
            # affinity accounting: did the chosen engine already hold
            # the adapter, or does this request pay a cold load?
            shard.stats.lora_routed += 1
            Gateway.total_lora_routed += 1
            try:
                resident = lora_adapter in \
                    targets[eid].metrics().loaded_adapters
            except Exception:
                resident = False
            if resident:
                shard.stats.lora_hits += 1
                Gateway.total_lora_hits += 1
            if self.lora_controller is not None:
                self.lora_controller.note_request(lora_adapter, now)
        shard.stats.routed += 1
        shard.stats.per_engine[eid] = \
            shard.stats.per_engine.get(eid, 0) + 1
        self.request_log.append(
            (now, len(tokens), est_output_tokens, user, eid))
        return eid

    def _note_shed(self, shard: _GatewayShard, user: str,
                   now: float) -> None:
        """Rate-limit drops must be LOUD: count them (instance +
        process-wide) and log once per window with the running totals,
        so a workload the limiter is silently halving shows up in bench
        output instead of just reading as light load.  The window state
        is shard-local (no cross-shard write), so a hot shard logs at
        most once per window regardless of sibling traffic."""
        Gateway.total_shed += 1
        if shard._shed_window == 0:
            shard._shed_t0 = now
        shard._shed_window += 1
        if now >= shard._shed_log_at:
            st = shard.stats
            log.warning(
                "gateway shed %d request(s) over the last %.1fs "
                "(user=%s; shard totals: rpm=%d tpm=%d) — raise "
                "RateLimit if this load is intended",
                shard._shed_window, max(now - shard._shed_t0, 0.0),
                user, st.rejected_rpm, st.rejected_tpm)
            shard._shed_window = 0
            shard._shed_log_at = now + self.SHED_LOG_WINDOW_S

    # -------------------------------------------------------------- stats
    def workload_histogram(self, in_edges=(200, 1000, 4000),
                           out_edges=(100, 500)) -> Dict[tuple, int]:
        """Bucketed (input_len, output_len) histogram — the Load Monitor
        input for the SLO-driven GPU optimizer (paper §3.2.7)."""
        hist: Dict[tuple, int] = {}

        def bucket(v, edges):
            for i, e in enumerate(edges):
                if v < e:
                    return i
            return len(edges)

        for _, ilen, olen, _, _ in self.request_log:
            key = (bucket(ilen, in_edges), bucket(olen, out_edges))
            hist[key] = hist.get(key, 0) + 1
        return hist
