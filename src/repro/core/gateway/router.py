"""LLM-aware routing strategies (paper §3.2.2, Figure 3).

Implements exactly the paper's six policies over live engine metrics:

  random | throughput | least-request | least-kv-cache | least-latency |
  prefix-cache-aware

plus three beyond-paper composites:

  * ``prefix-load`` — prefix affinity scored jointly with load (the
    direction the gateway-api-inference-extension work took); used in
    benchmarks as the "optimized" router.  Knob: ``load_weight``.
  * ``slo-aware`` — routes by per-priority-class SLO slack/attainment
    instead of raw latency: engines report per-class TTFT attainment
    (``EngineMetrics.slo_by_class``, produced by the shared scheduler
    core) and the policy sends a request where its class's SLO has the
    most headroom.  Knobs: ``load_weight`` (queue-depth penalty),
    ``classes`` (TTFT/ITL target table, defaults to the scheduler's
    ``DEFAULT_SLO_CLASSES``).
  * ``session`` — sticky multi-turn routing (production-stack's
    ``routingLogic: "session"``): a bounded, TTL'd ``session_id ->
    engine`` map pins every turn of a conversation to the engine
    already holding its KV prefix; first turns, expired sessions and
    sessions whose engine retired re-home through prefix affinity.
    Knobs: ``max_sessions``, ``ttl_s``, ``load_weight``.

Every ``select`` takes the request's ``priority_class`` and
``session_id`` keywords (the gateway forwards them); policies that
don't differentiate simply ignore them.  Engines are anything exposing
``metrics() -> EngineMetrics`` and ``match_prefix_len(tokens) -> int``
— the real JAX engine, the slot engine and the cluster simulator's
analytic engine all qualify.

Hot-path note: ``select`` runs once per request, for every request, so
no policy may sort the engine view per call.  The gateway hands
policies a *cached, id-ordered* engine dict (rebuilt only when the
fleet changes — see ``Gateway.routable_engines``) and the scoring
loops below are single-pass argmin/argmax with an explicit
``(score, engine_id)`` tie-break, which keeps selection deterministic
for any insertion order of the dict.
"""
from __future__ import annotations

import collections
import heapq
import random as _random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.engine.engine import EngineMetrics  # metric surface contract
from repro.engine.scheduler import DEFAULT_SLO_CLASSES


class RoutingPolicy:
    name = "base"

    def select(self, engines: Dict[str, object], tokens: Sequence[int],
               lora_adapter: Optional[str] = None,
               priority_class: str = "standard",
               session_id: Optional[str] = None) -> str:
        raise NotImplementedError

    def forget(self, engine_id: str) -> None:
        """Purge any per-engine policy state.  The gateway calls this
        on deregistration AND on role migration so a drained/retagged
        pod can never be picked from stale EWMAs or affinity maps.
        Stateless policies inherit the no-op."""


class RandomPolicy(RoutingPolicy):
    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = _random.Random(seed)

    def select(self, engines, tokens, lora_adapter=None,
               priority_class="standard", session_id=None):
        # the gateway's cached routable view is id-ordered, so indexing
        # into the dict directly is both O(n) and deterministic
        keys = list(engines)
        return keys[self.rng.randrange(len(keys))]


class _MetricArgmin(RoutingPolicy):
    metric: Callable = None

    def select(self, engines, tokens, lora_adapter=None,
               priority_class="standard", session_id=None):
        # single pass, deterministic (score, id) tie-break — no sort
        best, best_s = None, None
        metric = self.metric
        for eid, e in engines.items():
            s = metric(e.metrics())
            if best_s is None or s < best_s \
                    or (s == best_s and eid < best):
                best, best_s = eid, s
        return best


class ThroughputPolicy(_MetricArgmin):
    """Lowest current token throughput (tokens/s)."""
    name = "throughput"
    metric = staticmethod(lambda m: m.tokens_per_sec)


def _queue_depth(e) -> int:
    """Engine load for routing scores, off the cheap accessor when the
    engine exposes one (the shared scheduler core does) — a full
    metrics() build per engine per route is the single largest
    per-request cost at large fleet sizes."""
    qd = getattr(e, "queue_depth", None)
    if qd is not None:
        return qd
    m = e.metrics()
    return m.num_running + m.num_waiting


class LeastRequestPolicy(_MetricArgmin):
    """Lowest number of admitted-but-unfinished requests."""
    name = "least-request"
    metric = staticmethod(lambda m: m.num_running + m.num_waiting)

    def select(self, engines, tokens, lora_adapter=None,
               priority_class="standard", session_id=None):
        best, best_s = None, None
        for eid, e in engines.items():
            s = _queue_depth(e)
            if best_s is None or s < best_s \
                    or (s == best_s and eid < best):
                best, best_s = eid, s
        return best


class LeastKVCachePolicy(_MetricArgmin):
    """Lowest KV cache utilization."""
    name = "least-kv-cache"
    metric = staticmethod(lambda m: m.kv_utilization)


class LeastLatencyPolicy(_MetricArgmin):
    """Lowest (queue + serve) latency EWMA."""
    name = "least-latency"
    metric = staticmethod(lambda m: m.avg_queue_time + m.avg_latency)


class PrefixCacheAwarePolicy(RoutingPolicy):
    """Prefer engines whose prefix cache covers > threshold of the
    prompt; fall back to least-request among the rest (paper text)."""
    name = "prefix-cache-aware"

    def __init__(self, threshold: float = 0.5):
        self.threshold = threshold
        self._fallback = LeastRequestPolicy()

    def select(self, engines, tokens, lora_adapter=None,
               priority_class="standard", session_id=None):
        n = max(len(tokens), 1)
        best_eid, best_cov = None, 0.0
        for eid, e in engines.items():
            cov = e.match_prefix_len(tokens) / n
            if cov > best_cov or (cov == best_cov and cov > 0.0
                                  and eid < best_eid):
                best_eid, best_cov = eid, cov
        if best_eid is not None and best_cov >= self.threshold:
            return best_eid
        return self._fallback.select(engines, tokens, lora_adapter)


class PrefixLoadPolicy(RoutingPolicy):
    """Beyond-paper composite: score = prefix_coverage − load_penalty.

    Captures the failure mode of pure prefix affinity (hot prefix
    hot-spots one engine) by trading coverage against queue depth.
    Keeps a bounded prefix-affinity map (leading block -> last engine
    chosen) as a deterministic TIE-BREAK: when scores are otherwise
    equal (fresh engines, prefix not yet registered anywhere) a
    repeated prefix sticks to the engine already picked for it instead
    of drifting to the lowest id — the epsilon bonus is far below one
    unit of load or coverage, so it can never override either.
    ``forget`` purges an engine from the map on scale-down/migration.
    """
    name = "prefix-load"

    AFFINITY_BLOCK = 16          # leading tokens keying the affinity map
    MAX_AFFINITY = 4096

    def __init__(self, load_weight: float = 0.02,
                 affinity_bonus: float = 1e-6):
        self.load_weight = load_weight
        self.affinity_bonus = affinity_bonus
        self._affinity: Dict[tuple, str] = {}

    def select(self, engines, tokens, lora_adapter=None,
               priority_class="standard", session_id=None):
        n = max(len(tokens), 1)
        key = tuple(tokens[:self.AFFINITY_BLOCK])
        hint = self._affinity.get(key)
        best, best_score = None, -1e18
        for eid, e in engines.items():
            cov = e.match_prefix_len(tokens) / n
            score = cov - self.load_weight * _queue_depth(e)
            if eid == hint:
                score += self.affinity_bonus
            if score > best_score \
                    or (score == best_score and eid < best):
                best, best_score = eid, score
        if (key not in self._affinity
                and len(self._affinity) >= self.MAX_AFFINITY):
            self._affinity.pop(next(iter(self._affinity)))
        self._affinity[key] = best
        return best

    def forget(self, engine_id: str) -> None:
        self._affinity = {k: v for k, v in self._affinity.items()
                          if v != engine_id}


class SLOAwarePolicy(RoutingPolicy):
    """SLO-aware routing: pick the engine with the most SLO headroom
    for the request's priority class, instead of raw latency.

    Score per engine = the class's recent TTFT attainment (how well
    this engine is currently holding that class's SLO — falling back
    to the engine-wide ``slo_attainment`` before the class has any
    finishes there) minus the engine's queue-time pressure normalized
    by the class TTFT target (an engine whose queue already eats most
    of an interactive budget is hopeless for interactive work but fine
    for batch) minus ``load_weight`` × queue depth (tie-break toward
    emptier engines).  Works against any engine whose metrics come
    from the shared scheduler core (real, sim and slot engines).
    """
    name = "slo-aware"

    def __init__(self, load_weight: float = 0.02, classes: dict = None,
                 ewma_alpha: float = 0.3):
        self.load_weight = load_weight
        self.classes = dict(classes or DEFAULT_SLO_CLASSES)
        # per-(engine, class) attainment EWMA: smooths the windowed
        # reading so one noisy scrape can't flip-flop routing; purged
        # by ``forget`` when the engine leaves or changes role
        self.ewma_alpha = ewma_alpha
        self._att_ewma: Dict[tuple, float] = {}

    def select(self, engines, tokens, lora_adapter=None,
               priority_class="standard", session_id=None):
        cls = self.classes.get(priority_class) \
            or self.classes.get("standard") \
            or DEFAULT_SLO_CLASSES["standard"]
        best, best_score = None, -1e18
        for eid, eng in engines.items():
            m = eng.metrics()
            att = m.slo_attainment
            for name, ttft_att, _itl_att, _n in m.slo_by_class:
                if name == priority_class:
                    att = ttft_att
                    break
            key = (eid, priority_class)
            prev = self._att_ewma.get(key)
            if prev is not None:
                att = (1 - self.ewma_alpha) * prev + self.ewma_alpha * att
            self._att_ewma[key] = att
            slack_pressure = m.avg_queue_time / max(cls.ttft_s, 1e-9)
            load = m.num_running + m.num_waiting
            score = att - slack_pressure - self.load_weight * load
            if score > best_score \
                    or (score == best_score and eid < best):
                best, best_score = eid, score
        return best

    def forget(self, engine_id: str) -> None:
        self._att_ewma = {k: v for k, v in self._att_ewma.items()
                          if k[0] != engine_id}


class LoRAAffinityPolicy(RoutingPolicy):
    """LoRA-aware routing (paper §3.2.1): pack requests for co-resident
    adapters onto the same engine; tie-break least-request.

    Two discovery sources, in order: the ``LoRAController``'s endpoint
    view when a registry is attached (``set_endpoints`` — the
    EndpointSlice analogue, wired by ``Gateway.attach_lora_controller``,
    so the policy learns the controller's REAL placements instead of
    static tags), then the engines' live ``loaded_adapters`` metrics
    (which also cover adapters an engine auto-loaded past the plan).
    A request whose adapter is resident nowhere falls back to
    least-request — the chosen engine cold-loads it, and subsequent
    requests find it through the metrics path."""
    name = "lora-affinity"

    def __init__(self):
        self._fallback = LeastRequestPolicy()
        self._endpoints_fn: Optional[Callable[[str], List[str]]] = None

    def set_endpoints(self, fn: Callable[[str], List[str]]) -> None:
        """Attach the adapter-registry discovery view
        (``LoRAController.endpoints``)."""
        self._endpoints_fn = fn

    def select(self, engines, tokens, lora_adapter=None,
               priority_class="standard", session_id=None):
        if lora_adapter:
            having = {}
            if self._endpoints_fn is not None:
                having = {eid: engines[eid]
                          for eid in self._endpoints_fn(lora_adapter)
                          if eid in engines}
            if not having:
                having = {eid: e for eid, e in engines.items()
                          if lora_adapter in e.metrics().loaded_adapters}
            if having:
                return self._fallback.select(having, tokens, lora_adapter)
        return self._fallback.select(engines, tokens, lora_adapter)


class SessionAffinityPolicy(RoutingPolicy):
    """Sticky session routing for multi-turn serving (production-stack's
    ``routingLogic: "session"`` / ``sessionKey: "x-user-id"`` shape).

    A bounded, TTL'd ``session_id -> engine_id`` map pins every turn of
    a conversation to the engine that served its previous turns — where
    the session's KV prefix is already resident in the device cache or
    its host/SSD tiers, so turn N admits with a warm prefix instead of
    recomputing the whole growing history.  The map is only a routing
    *hint*, never correctness state:

    * first turn / expired TTL / map evicted under ``max_sessions`` —
      the request routes through the :class:`PrefixLoadPolicy` fallback
      (prefix affinity traded against load) and the winner is recorded;
    * engine retired or migrated — ``forget`` purges every session
      pinned to it, so the next turn re-homes through prefix affinity
      with zero lost requests (a gateway restart, which loses the whole
      map, degrades the same way: one fallback route per session).

    All map operations are O(1); ``forget`` is O(sessions) but only
    runs on fleet changes.

    Predictive promotion: each pin also carries a think-time EWMA (the
    observed turn-to-turn arrival gap), so the tier promoter can
    prefetch a returning session's SSD pages back into host DRAM
    *before* the predicted turn lands.  When ``promote_lead_s > 0``,
    every turn with a usable EWMA pushes ``(predicted_arrival - lead,
    session, engine)`` onto a bounded schedule heap;
    :meth:`due_promotions` pops the entries whose fire time has passed,
    lazily dropping stale ones (session re-touched, expired or
    re-homed since the push — the recorded ``last_seen`` stamp no
    longer matches the pin).  The heap is capacity-bounded: under
    overload new predictions are skipped (``promote_skipped``), never
    queued without limit.
    """
    name = "session"

    EWMA_ALPHA = 0.4             # think-time smoothing
    MAX_PROMOTE_HEAP = 1 << 16   # bounded promoter schedule

    def __init__(self, max_sessions: int = 1 << 20,
                 ttl_s: float = 1800.0, load_weight: float = 0.02,
                 promote_lead_s: float = 0.0):
        self.max_sessions = max_sessions
        self.ttl_s = ttl_s
        self.promote_lead_s = promote_lead_s
        self._fallback = PrefixLoadPolicy(load_weight=load_weight)
        # session_id -> (engine_id, last_seen, think_ewma_or_None);
        # dict order == LRU order
        self._sessions: "collections.OrderedDict[str, tuple]" = \
            collections.OrderedDict()
        # promotion schedule: (fire_at, session_id, last_seen_stamp)
        self._promote_heap: list = []
        self._clock = None
        self.hits = 0          # routed by the sticky map
        self.misses = 0        # first turn of a session
        self.rehomed = 0       # mapping stale/retired -> prefix fallback
        self.promote_skipped = 0   # heap full => prediction dropped

    def attach_clock(self, clock) -> None:
        """The gateway wires its clock in so TTL expiry shares the
        cluster's notion of time (sim or wall)."""
        self._clock = clock

    def think_ewma(self, session_id: str) -> Optional[float]:
        """The session's smoothed turn-to-turn gap (None before the
        second turn) — the promoter's arrival predictor."""
        ent = self._sessions.get(session_id)
        return ent[2] if ent is not None else None

    def _schedule_promotion(self, session_id: str, now: float,
                            ewma: float) -> None:
        if len(self._promote_heap) >= self.MAX_PROMOTE_HEAP:
            self.promote_skipped += 1
            return
        fire_at = max(now, now + ewma - self.promote_lead_s)
        heapq.heappush(self._promote_heap, (fire_at, session_id, now))

    def due_promotions(self, now: float,
                       limit: int = 256) -> List[Tuple[str, str]]:
        """Pop up to ``limit`` due ``(session_id, engine_id)`` pairs.
        An entry is live only while its recorded ``last_seen`` stamp
        still matches the pin — a session that was touched again,
        evicted or re-homed since the push is silently dropped."""
        out: List[Tuple[str, str]] = []
        heap = self._promote_heap
        while heap and heap[0][0] <= now and len(out) < limit:
            _, sid, stamp = heapq.heappop(heap)
            ent = self._sessions.get(sid)
            if ent is not None and ent[1] == stamp:
                out.append((sid, ent[0]))
        return out

    def select(self, engines, tokens, lora_adapter=None,
               priority_class="standard", session_id=None):
        if session_id is None:
            return self._fallback.select(engines, tokens, lora_adapter,
                                         priority_class)
        now = self._clock() if self._clock is not None else 0.0
        ent = self._sessions.get(session_id)
        if ent is not None:
            eid, last, ewma = ent
            if eid in engines and (self.ttl_s <= 0
                                   or now - last <= self.ttl_s):
                gap = now - last
                ewma = gap if ewma is None else \
                    ((1 - self.EWMA_ALPHA) * ewma
                     + self.EWMA_ALPHA * gap)
                self._sessions[session_id] = (eid, now, ewma)
                self._sessions.move_to_end(session_id)
                self.hits += 1
                if self.promote_lead_s > 0:
                    self._schedule_promotion(session_id, now, ewma)
                return eid
            del self._sessions[session_id]
            self.rehomed += 1
        else:
            self.misses += 1
        eid = self._fallback.select(engines, tokens, lora_adapter,
                                    priority_class)
        while len(self._sessions) >= self.max_sessions:
            self._sessions.popitem(last=False)
        self._sessions[session_id] = (eid, now, None)
        return eid

    def forget(self, engine_id: str) -> None:
        stale = [sid for sid, ent in self._sessions.items()
                 if ent[0] == engine_id]
        for sid in stale:
            del self._sessions[sid]
        self._fallback.forget(engine_id)


POLICIES = {p.name: p for p in (
    RandomPolicy, ThroughputPolicy, LeastRequestPolicy, LeastKVCachePolicy,
    LeastLatencyPolicy, PrefixCacheAwarePolicy, PrefixLoadPolicy,
    SLOAwarePolicy, LoRAAffinityPolicy, SessionAffinityPolicy)}


def make_policy(name: str, **kw) -> RoutingPolicy:
    if name not in POLICIES:
        raise KeyError(f"unknown routing policy {name!r}: {sorted(POLICIES)}")
    return POLICIES[name](**kw)
