"""LLM-aware routing strategies (paper §3.2.2, Figure 3).

Implements exactly the paper's six policies over live engine metrics:

  random | throughput | least-request | least-kv-cache | least-latency |
  prefix-cache-aware

plus a composite ``prefix-load`` (beyond-paper: prefix affinity scored
jointly with load, the direction the gateway-api-inference-extension
work took) — used in benchmarks as the "optimized" router.

Engines are anything exposing ``metrics() -> EngineMetrics`` and
``match_prefix_len(tokens) -> int`` — the real JAX engine and the
cluster simulator's analytic engine both qualify.
"""
from __future__ import annotations

import random as _random
from typing import Callable, Dict, List, Optional, Sequence

from repro.engine.engine import EngineMetrics  # metric surface contract


class RoutingPolicy:
    name = "base"

    def select(self, engines: Dict[str, object], tokens: Sequence[int],
               lora_adapter: Optional[str] = None) -> str:
        raise NotImplementedError


class RandomPolicy(RoutingPolicy):
    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = _random.Random(seed)

    def select(self, engines, tokens, lora_adapter=None):
        return self.rng.choice(sorted(engines))


class _MetricArgmin(RoutingPolicy):
    metric: Callable = None

    def select(self, engines, tokens, lora_adapter=None):
        scored = {eid: self.metric(e.metrics())
                  for eid, e in engines.items()}
        lo = min(scored.values())
        # deterministic tie-break on id
        return min(eid for eid, s in scored.items() if s == lo)


class ThroughputPolicy(_MetricArgmin):
    """Lowest current token throughput (tokens/s)."""
    name = "throughput"
    metric = staticmethod(lambda m: m.tokens_per_sec)


class LeastRequestPolicy(_MetricArgmin):
    """Lowest number of admitted-but-unfinished requests."""
    name = "least-request"
    metric = staticmethod(lambda m: m.num_running + m.num_waiting)


class LeastKVCachePolicy(_MetricArgmin):
    """Lowest KV cache utilization."""
    name = "least-kv-cache"
    metric = staticmethod(lambda m: m.kv_utilization)


class LeastLatencyPolicy(_MetricArgmin):
    """Lowest (queue + serve) latency EWMA."""
    name = "least-latency"
    metric = staticmethod(lambda m: m.avg_queue_time + m.avg_latency)


class PrefixCacheAwarePolicy(RoutingPolicy):
    """Prefer engines whose prefix cache covers > threshold of the
    prompt; fall back to least-request among the rest (paper text)."""
    name = "prefix-cache-aware"

    def __init__(self, threshold: float = 0.5):
        self.threshold = threshold
        self._fallback = LeastRequestPolicy()

    def select(self, engines, tokens, lora_adapter=None):
        n = max(len(tokens), 1)
        best_eid, best_cov = None, 0.0
        for eid in sorted(engines):
            cov = engines[eid].match_prefix_len(tokens) / n
            if cov > best_cov:
                best_eid, best_cov = eid, cov
        if best_eid is not None and best_cov >= self.threshold:
            return best_eid
        return self._fallback.select(engines, tokens, lora_adapter)


class PrefixLoadPolicy(RoutingPolicy):
    """Beyond-paper composite: score = prefix_coverage − load_penalty.

    Captures the failure mode of pure prefix affinity (hot prefix
    hot-spots one engine) by trading coverage against queue depth.
    """
    name = "prefix-load"

    def __init__(self, load_weight: float = 0.02):
        self.load_weight = load_weight

    def select(self, engines, tokens, lora_adapter=None):
        n = max(len(tokens), 1)
        best, best_score = None, -1e18
        for eid in sorted(engines):
            e = engines[eid]
            m = e.metrics()
            cov = e.match_prefix_len(tokens) / n
            load = m.num_running + m.num_waiting
            score = cov - self.load_weight * load
            if score > best_score:
                best, best_score = eid, score
        return best


class LoRAAffinityPolicy(RoutingPolicy):
    """LoRA-aware routing (paper §3.2.1): prefer engines that already
    have the adapter loaded; tie-break least-request."""
    name = "lora-affinity"

    def __init__(self):
        self._fallback = LeastRequestPolicy()

    def select(self, engines, tokens, lora_adapter=None):
        if lora_adapter:
            having = {eid: e for eid, e in engines.items()
                      if lora_adapter in e.metrics().loaded_adapters}
            if having:
                return self._fallback.select(having, tokens, lora_adapter)
        return self._fallback.select(engines, tokens, lora_adapter)


POLICIES = {p.name: p for p in (
    RandomPolicy, ThroughputPolicy, LeastRequestPolicy, LeastKVCachePolicy,
    LeastLatencyPolicy, PrefixCacheAwarePolicy, PrefixLoadPolicy,
    LoRAAffinityPolicy)}


def make_policy(name: str, **kw) -> RoutingPolicy:
    if name not in POLICIES:
        raise KeyError(f"unknown routing policy {name!r}: {sorted(POLICIES)}")
    return POLICIES[name](**kw)
