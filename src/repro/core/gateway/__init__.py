from repro.core.gateway.gateway import Gateway, RateLimit  # noqa: F401
from repro.core.gateway.router import POLICIES, make_policy  # noqa: F401
