"""Sliding-window metric aggregation (paper §3.2.4).

The paper's optimization: AIBrix "bypasses the custom metrics path and
maintains sliding window metric aggregation directly in the autoscaler"
— i.e. instead of a scrape->adapter->metrics-server pipeline adding tens
of seconds of propagation delay, the autoscaler ingests raw samples and
aggregates over stable/panic windows locally.  We model both paths so
benchmarks can show the reaction-latency difference.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, Optional, Tuple


class SlidingWindow:
    """Time-bucketed sliding window with O(1) mean over the window."""

    def __init__(self, window_s: float, granularity_s: float = 1.0):
        self.window_s = window_s
        self.granularity = granularity_s
        self._buckets: Deque[Tuple[float, float, int]] = collections.deque()
        # (bucket_start, value_sum, count)

    def record(self, t: float, value: float) -> None:
        start = (t // self.granularity) * self.granularity
        if self._buckets and self._buckets[-1][0] == start:
            s, v, c = self._buckets[-1]
            self._buckets[-1] = (s, v + value, c + 1)
        else:
            self._buckets.append((start, value, 1))
        self._trim(t)

    def _trim(self, now: float) -> None:
        while self._buckets and self._buckets[0][0] < now - self.window_s:
            self._buckets.popleft()

    def mean(self, now: float) -> Optional[float]:
        self._trim(now)
        rows = [(v, c) for s, v, c in self._buckets if s <= now]
        total = sum(v for v, _ in rows)
        count = sum(c for _, c in rows)
        return total / count if count else None

    def max(self, now: float) -> Optional[float]:
        self._trim(now)
        vals = [v / c for s, v, c in self._buckets if c and s <= now]
        return max(vals) if vals else None


class MetricStore:
    """Per-(engine, metric) windows, with an optional propagation delay
    emulating the legacy custom-metrics path (delay=0 == AIBrix path)."""

    def __init__(self, stable_window_s: float = 60.0,
                 panic_window_s: float = 6.0,
                 propagation_delay_s: float = 0.0):
        self.stable_window_s = stable_window_s
        self.panic_window_s = panic_window_s
        self.delay = propagation_delay_s
        self._stable: Dict[str, SlidingWindow] = {}
        self._panic: Dict[str, SlidingWindow] = {}
        self._inflight: Deque[Tuple[float, str, float]] = collections.deque()

    def record(self, t: float, key: str, value: float) -> None:
        if self.delay > 0:
            self._inflight.append((t + self.delay, key, value))
        else:
            self._ingest(t, key, value)

    def _ingest(self, t: float, key: str, value: float) -> None:
        if key not in self._stable:
            self._stable[key] = SlidingWindow(self.stable_window_s)
            self._panic[key] = SlidingWindow(self.panic_window_s)
        self._stable[key].record(t, value)
        self._panic[key].record(t, value)

    def flush(self, now: float) -> None:
        while self._inflight and self._inflight[0][0] <= now:
            t, key, v = self._inflight.popleft()
            self._ingest(t, key, v)

    def stable(self, now: float, key: str) -> Optional[float]:
        self.flush(now)
        w = self._stable.get(key)
        return w.mean(now) if w else None

    def panic(self, now: float, key: str) -> Optional[float]:
        self.flush(now)
        w = self._panic.get(key)
        return w.mean(now) if w else None
