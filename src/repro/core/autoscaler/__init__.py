from repro.core.autoscaler.metrics import MetricStore, SlidingWindow  # noqa: F401
from repro.core.autoscaler.policies import (APA, AUTOSCALERS, HPA, KPA,  # noqa: F401
                                            make_autoscaler)
