"""LLM-specific autoscaling policies (paper §3.2.4).

Three autoscalers over one MetricStore:

  * HPA — the Kubernetes baseline the paper compares against: periodic
    sync (15s), tolerance dead-band, 5-min scale-down stabilization.
    Slow to react and oscillation-prone on LLM metrics.
  * KPA — Knative-style: stable window + panic window; panic mode scales
    on the 6s window when load bursts >2x capacity and holds the max.
  * APA — AIBrix Pod Autoscaler: tolerance-band scaling on inference
    metrics (KV utilization / concurrency) aggregated directly in the
    autoscaler (zero propagation delay), fluctuation tolerance both ways.

All return a desired replica count; actuation (pod cold start etc.) is
the orchestrator's job, so policy quality and actuation latency can be
measured separately — this mirrors the paper's claim structure
(latency/throughput/oscillation vs native HPA).

Config knobs shared by every policy: ``metric`` (the MetricStore key
to scale on — load metrics such as ``concurrency`` / ``kv_cache_
utilization``, or the *inverted* ``slo_attainment`` signal the shared
scheduler core emits), ``target`` (per-replica target value for load
metrics; desired attainment fraction, e.g. 0.95, for slo_attainment),
``min_replicas``/``max_replicas`` bounds, and ``invert`` (force the
higher-is-better interpretation; auto-detected for metrics in
``INVERTED_METRICS``).  Inverted pressure is the miss-rate ratio
(1-measured)/(1-target), so all three policies scale UP when the
measured value drops below target — e.g. KPA targeting
``slo_attainment`` at 0.95 adds replicas while interactive TTFT
misses pile up — and back DOWN once attainment holds above it (the
SLO path from scheduler to autoscaler).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.autoscaler.metrics import MetricStore

# metrics where HIGHER is better (pressure = target / measured):
# scaling must react to the value falling below target, not above it.
# The pool_* keys are the per-role signals the RolePoolManager
# rebalancer records (fleet TTFT attainment sizes the prefill pool,
# fleet ITL attainment the decode pool).
INVERTED_METRICS = frozenset({"slo_attainment", "slo_itl_attainment",
                              "pool_ttft_attainment",
                              "pool_itl_attainment"})


@dataclass
class ScaleDecision:
    desired: int
    reason: str = ""
    panic: bool = False


class Autoscaler:
    name = "base"

    def __init__(self, metric: str = "concurrency", target: float = 4.0,
                 min_replicas: int = 1, max_replicas: int = 64,
                 invert: Optional[bool] = None):
        self.metric = metric
        self.target = target
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.invert = (metric in INVERTED_METRICS) if invert is None \
            else invert

    def _clamp(self, n: float) -> int:
        return int(min(max(math.ceil(n), self.min_replicas),
                       self.max_replicas))

    def _pressure(self, m: float) -> float:
        """Scaling pressure: > 1 means underprovisioned.  Load metrics:
        measured/target.  Inverted metrics (higher-is-better, e.g.
        slo_attainment): the miss-rate ratio (1-measured)/(1-target) —
        "SLO misses as a multiple of the allowed miss budget".  Unlike
        target/measured (which is floored at ``target`` because
        attainment cannot exceed 1.0, leaving scale-down unreachable
        and replica counts ratcheting up after every dip), the miss
        ratio spans the full range: attainment at target -> 1.0,
        perfect attainment -> 0.0 (scale down toward min_replicas),
        heavy misses -> >> 1."""
        if self.invert:
            return (1.0 - m) / max(1.0 - self.target, 1e-6)
        return m / self.target

    def desired(self, now: float, store: MetricStore, current: int
                ) -> ScaleDecision:
        raise NotImplementedError


class HPA(Autoscaler):
    """Native Kubernetes HPA semantics (the paper's baseline).

    Knobs: ``sync_period_s`` (reconcile interval), ``tolerance``
    (dead-band around pressure 1.0), ``scale_down_stabilization_s``
    (hold the max desired over this window before shrinking).
    """
    name = "hpa"

    def __init__(self, *a, sync_period_s: float = 15.0, tolerance: float = 0.1,
                 scale_down_stabilization_s: float = 300.0, **kw):
        super().__init__(*a, **kw)
        self.sync_period_s = sync_period_s
        self.tolerance = tolerance
        self.down_stab = scale_down_stabilization_s
        self._last_sync = -1e18
        self._last = None
        self._down_candidates: list = []

    def desired(self, now, store, current) -> ScaleDecision:
        if now - self._last_sync < self.sync_period_s and self._last:
            return self._last
        self._last_sync = now
        m = store.stable(now, self.metric)
        if m is None:
            self._last = ScaleDecision(current, "no metric")
            return self._last
        ratio = self._pressure(m)
        if abs(ratio - 1.0) <= self.tolerance:
            desired = current
        else:
            desired = self._clamp(current * ratio)
        # scale-down stabilization: use max desired over the window
        self._down_candidates.append((now, desired))
        self._down_candidates = [(t, d) for t, d in self._down_candidates
                                 if t >= now - self.down_stab]
        if desired < current:
            desired = max(d for _, d in self._down_candidates)
        self._last = ScaleDecision(self._clamp(desired),
                                   f"ratio={ratio:.2f}")
        return self._last


class KPA(Autoscaler):
    """Knative Pod Autoscaler: stable/panic windows (paper: one of the
    'advanced autoscaling algorithms' AIBrix leverages).

    Knobs: ``panic_threshold`` (burst ratio entering panic mode),
    ``max_scale_up_rate``/``max_scale_down_rate`` (per-decision rate
    limits).  Panic mode scales on the 6s window and holds the peak.
    """
    name = "kpa"

    def __init__(self, *a, panic_threshold: float = 2.0,
                 max_scale_up_rate: float = 10.0,
                 max_scale_down_rate: float = 2.0, **kw):
        super().__init__(*a, **kw)
        self.panic_threshold = panic_threshold
        self.up_rate = max_scale_up_rate
        self.down_rate = max_scale_down_rate
        self._panic_until = -1.0
        self._panic_peak = 0

    def _replicas_needed(self, m: float, current: int) -> float:
        """Window aggregate -> replica demand.  Load metrics: aggregate
        load over per-replica target.  Inverted metrics: scale the
        current fleet by the attainment shortfall."""
        if self.invert:
            return max(current, 1) * self._pressure(m)
        return m / self.target

    def desired(self, now, store, current) -> ScaleDecision:
        stable = store.stable(now, self.metric)
        panic = store.panic(now, self.metric)
        if stable is None:
            return ScaleDecision(current, "no metric")
        want_stable = self._replicas_needed(stable, current)
        desired = want_stable
        in_panic = False
        if panic is not None and current > 0:
            need_panic = self._replicas_needed(panic, current)
            if self.invert:
                burst = self._pressure(panic) >= self.panic_threshold
            else:
                capacity = current * self.target
                burst = (panic / max(capacity, 1e-9)
                         >= self.panic_threshold / 2.0)
            if burst and need_panic > current:
                # enter/extend panic mode for 60s; scale on panic window
                self._panic_until = max(self._panic_until, now + 60.0)
            if now <= self._panic_until:
                in_panic = True
                desired = max(want_stable, need_panic,
                              self._panic_peak)
                self._panic_peak = max(self._panic_peak,
                                       math.ceil(desired))
            else:
                self._panic_peak = 0
        # rate limits
        hi = max(current * self.up_rate, current + 1)
        lo = current / self.down_rate
        desired = min(max(desired, lo), hi)
        return ScaleDecision(self._clamp(desired),
                             f"stable={stable:.2f} panic={panic}",
                             panic=in_panic)


class APA(Autoscaler):
    """AIBrix Pod Autoscaler: symmetric fluctuation tolerance on
    real-time (zero-delay) inference metrics.

    Knobs: ``up_fluctuation``/``down_fluctuation`` — the tolerance
    band (as a fraction of capacity, or of pressure 1.0 for inverted
    metrics) that must be exceeded before any scaling move.
    """
    name = "apa"

    def __init__(self, *a, up_fluctuation: float = 0.1,
                 down_fluctuation: float = 0.2, **kw):
        super().__init__(*a, **kw)
        self.up_f = up_fluctuation
        self.down_f = down_fluctuation

    def desired(self, now, store, current) -> ScaleDecision:
        m = store.panic(now, self.metric)       # freshest window
        stable = store.stable(now, self.metric)
        if m is None or stable is None:
            return ScaleDecision(current, "no metric")
        if self.invert:
            # attainment-style metric: pressure >1 = SLO misses piling
            # up on the fresh window -> scale the fleet by the shortfall
            pm, ps = self._pressure(m), self._pressure(stable)
            if pm > 1 + self.up_f:
                desired = math.ceil(max(current, 1) * pm)
            elif ps < 1 - self.down_f:
                desired = math.ceil(max(current, 1) * ps)
            else:
                desired = current
            return ScaleDecision(self._clamp(desired),
                                 f"m={m:.2f} pressure={pm:.2f}")
        capacity = max(current, 1) * self.target
        if m > capacity * (1 + self.up_f):
            desired = math.ceil(m / self.target)
        elif stable < capacity * (1 - self.down_f):
            desired = math.ceil(stable / self.target)
        else:
            desired = current
        return ScaleDecision(self._clamp(desired),
                             f"m={m:.2f} cap={capacity:.1f}")


AUTOSCALERS: Dict[str, type] = {c.name: c for c in (HPA, KPA, APA)}


def make_autoscaler(name: str, **kw) -> Autoscaler:
    return AUTOSCALERS[name](**kw)
