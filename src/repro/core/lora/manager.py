"""High-density LoRA management (paper §3.2.1, Figure 2).

Cluster-level adapter control plane: a registry with lineage (adapters
are versioned artifacts derived from a base model), a density-aware
placement controller that packs many adapters per engine pod while
respecting per-pod slot budgets and spreading replicas for availability,
and the discovery view the gateway's LoRA-affinity routing reads
(the Kubernetes Service/EndpointSlice role in the paper).
"""
from __future__ import annotations

import collections
import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set


@dataclass
class AdapterSpec:
    name: str
    base_model: str
    rank: int = 8
    artifact_uri: str = ""
    parent: Optional[str] = None       # lineage: fine-tuned from another
    requests_per_s: float = 0.0        # observed demand (long-tail aware)


@dataclass
class PodSlots:
    pod_id: str
    capacity: int                      # adapter slots on this pod
    loaded: Set[str] = field(default_factory=set)

    @property
    def free(self) -> int:
        return self.capacity - len(self.loaded)


class LoRAController:
    """Registry + placement.  ``sync`` drives engines to match the plan
    via their register/unregister_adapter hooks."""

    def __init__(self, min_replicas: int = 1, max_replicas: int = 4,
                 demand_window_s: float = 30.0):
        self.adapters: Dict[str, AdapterSpec] = {}
        self.pods: Dict[str, PodSlots] = {}
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.stats = {"loads": 0, "unloads": 0, "placement_runs": 0}
        # demand-driven replanning: the gateway feeds per-adapter
        # arrivals (note_request); refresh_demand turns the windowed
        # rate into each spec's requests_per_s before the next plan
        self.demand_window_s = demand_window_s
        self._arrivals: Dict[str, collections.deque] = {}

    # ------------------------------------------------------------ registry
    def register(self, spec: AdapterSpec) -> None:
        if spec.parent and spec.parent not in self.adapters:
            raise KeyError(f"lineage parent {spec.parent!r} not registered")
        self.adapters[spec.name] = spec

    def deregister(self, name: str) -> None:
        children = [a.name for a in self.adapters.values()
                    if a.parent == name]
        if children:
            raise ValueError(f"{name} has dependent adapters {children}")
        self.adapters.pop(name, None)
        for pod in self.pods.values():
            pod.loaded.discard(name)

    def lineage(self, name: str) -> List[str]:
        out = [name]
        while self.adapters[out[-1]].parent:
            out.append(self.adapters[out[-1]].parent)
        return out

    # ------------------------------------------------------------ pods
    def add_pod(self, pod_id: str, capacity: int = 8) -> None:
        self.pods[pod_id] = PodSlots(pod_id, capacity)

    def remove_pod(self, pod_id: str) -> None:
        self.pods.pop(pod_id, None)

    # ------------------------------------------------------------ demand
    def note_request(self, name: str, now: float) -> None:
        """Gateway hook: record one arrival for ``name`` (called on
        every routed LoRA request — the paper's 'observed demand')."""
        dq = self._arrivals.setdefault(name, collections.deque())
        dq.append(now)
        cutoff = now - self.demand_window_s
        while dq and dq[0] < cutoff:
            dq.popleft()

    def observed_rps(self, name: str, now: float) -> float:
        dq = self._arrivals.get(name)
        if not dq:
            return 0.0
        return len(dq) / max(now - dq[0], 1.0)

    def refresh_demand(self, now: float) -> None:
        """Fold gateway-observed arrival rates into the specs so the
        next plan reflects live demand, not registration-time guesses.
        Adapters with no observations yet keep their prior."""
        for spec in self.adapters.values():
            dq = self._arrivals.get(spec.name)
            if dq is None:
                continue        # never observed: keep the prior
            # prune the window here too — an adapter that went quiet
            # must decay even though note_request no longer fires
            cutoff = now - self.demand_window_s
            while dq and dq[0] < cutoff:
                dq.popleft()
            spec.requests_per_s = self.observed_rps(spec.name, now)

    # ------------------------------------------------------------ placement
    def _replicas(self, spec: AdapterSpec, total_rps: float) -> int:
        share = (spec.requests_per_s / total_rps) if total_rps else 0.0
        return max(self.min_replicas,
                   min(self.max_replicas,
                       round(share * len(self.pods) * 2)))

    def plan_placement(self) -> Dict[str, Set[str]]:
        """Desired pod -> adapters.  Coverage-first, then density: pass
        one gives EVERY adapter a slot (whenever total capacity
        suffices, no adapter is unservable), pass two spends leftover
        slots replicating hot adapters up to max_replicas.  Cold
        (long-tail) adapters therefore pack single-replica onto few
        pods — that's where the cost win is.  Both passes prefer pods
        that already hold the adapter, so re-planning under unchanged
        heat is churn-free (stickiness)."""
        self.stats["placement_runs"] += 1
        plan: Dict[str, Set[str]] = {p: set() for p in self.pods}
        if not self.pods:
            return plan
        by_heat = sorted(self.adapters.values(),
                         key=lambda a: (-a.requests_per_s, a.name))
        budget = {p: self.pods[p].capacity for p in self.pods}
        total_rps = sum(a.requests_per_s for a in self.adapters.values())

        def order(a):   # sticky pods first, then most-free, then id
            return sorted(self.pods,
                          key=lambda p: (a.name not in self.pods[p].loaded,
                                         -budget[p], p))

        for a in by_heat:               # pass 1: cover every adapter
            for p in order(a):
                if budget[p] > 0:
                    plan[p].add(a.name)
                    budget[p] -= 1
                    break
        for a in by_heat:               # pass 2: replicate the hot ones
            placed = sum(1 for p in plan if a.name in plan[p])
            for p in order(a):
                if placed >= self._replicas(a, total_rps):
                    break
                if budget[p] > 0 and a.name not in plan[p]:
                    plan[p].add(a.name)
                    budget[p] -= 1
                    placed += 1
        return plan

    def required_slots(self) -> int:
        """Total adapter slots the current demand wants (coverage +
        hot replication) — the adapter-count-aware autoscaling signal."""
        total_rps = sum(a.requests_per_s for a in self.adapters.values())
        return sum(max(self._replicas(a, total_rps), 1)
                   for a in self.adapters.values())

    def desired_pods(self, slots_per_pod: int) -> int:
        """Minimum pod count whose slot budget covers required_slots().
        The cluster autoscaler takes max(load-based, this) so scale-in
        can never strand registered adapters without a slot."""
        if not self.adapters or slots_per_pod <= 0:
            return 0
        return math.ceil(self.required_slots() / slots_per_pod)

    def sync(self, engines: Dict[str, object]) -> Dict[str, List[str]]:
        """Apply the plan to live engines.  Returns per-pod load/unload
        actions (for observability/tests).

        Before planning, each pod's view is reconciled against the
        engine's actual residency (``adapters`` attribute, when the
        handle exposes one): routed requests may have auto-loaded
        adapters past the plan and the engine's LRU bank may have
        evicted planned ones — sync restores the desired state either
        way instead of drifting.  Unloads go through the engine's
        deferred-unregister path, so an adapter serving an in-flight
        batch is never yanked mid-step."""
        for pod_id, pod in self.pods.items():
            eng = engines.get(pod_id)
            actual = getattr(eng, "adapters", None)
            if actual is not None:
                pod.loaded = set(actual() if callable(actual) else actual)
        plan = self.plan_placement()
        actions: Dict[str, List[str]] = {}
        for pod_id, want in plan.items():
            eng = engines.get(pod_id)
            pod = self.pods[pod_id]
            acts = []
            for name in sorted(pod.loaded - want):
                if eng is not None:
                    eng.unregister_adapter(name)
                pod.loaded.discard(name)
                acts.append(f"unload:{name}")
                self.stats["unloads"] += 1
            for name in sorted(want - pod.loaded):
                if eng is not None:
                    eng.register_adapter(name)
                pod.loaded.add(name)
                acts.append(f"load:{name}")
                self.stats["loads"] += 1
            actions[pod_id] = acts
        return actions

    def replan(self, engines: Dict[str, object],
               now: float) -> Dict[str, List[str]]:
        """Demand-driven replanning: refresh observed rates, then sync."""
        self.refresh_demand(now)
        return self.sync(engines)

    # ------------------------------------------------------------ discovery
    def endpoints(self, adapter: str) -> List[str]:
        """Pods currently serving an adapter (EndpointSlice analogue)."""
        return sorted(p for p, s in self.pods.items() if adapter in s.loaded)
