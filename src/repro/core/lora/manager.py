"""High-density LoRA management (paper §3.2.1, Figure 2).

Cluster-level adapter control plane: a registry with lineage (adapters
are versioned artifacts derived from a base model), a density-aware
placement controller that packs many adapters per engine pod while
respecting per-pod slot budgets and spreading replicas for availability,
and the discovery view the gateway's LoRA-affinity routing reads
(the Kubernetes Service/EndpointSlice role in the paper).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set


@dataclass
class AdapterSpec:
    name: str
    base_model: str
    rank: int = 8
    artifact_uri: str = ""
    parent: Optional[str] = None       # lineage: fine-tuned from another
    requests_per_s: float = 0.0        # observed demand (long-tail aware)


@dataclass
class PodSlots:
    pod_id: str
    capacity: int                      # adapter slots on this pod
    loaded: Set[str] = field(default_factory=set)

    @property
    def free(self) -> int:
        return self.capacity - len(self.loaded)


class LoRAController:
    """Registry + placement.  ``sync`` drives engines to match the plan
    via their register/unregister_adapter hooks."""

    def __init__(self, min_replicas: int = 1, max_replicas: int = 4):
        self.adapters: Dict[str, AdapterSpec] = {}
        self.pods: Dict[str, PodSlots] = {}
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.stats = {"loads": 0, "unloads": 0, "placement_runs": 0}

    # ------------------------------------------------------------ registry
    def register(self, spec: AdapterSpec) -> None:
        if spec.parent and spec.parent not in self.adapters:
            raise KeyError(f"lineage parent {spec.parent!r} not registered")
        self.adapters[spec.name] = spec

    def deregister(self, name: str) -> None:
        children = [a.name for a in self.adapters.values()
                    if a.parent == name]
        if children:
            raise ValueError(f"{name} has dependent adapters {children}")
        self.adapters.pop(name, None)
        for pod in self.pods.values():
            pod.loaded.discard(name)

    def lineage(self, name: str) -> List[str]:
        out = [name]
        while self.adapters[out[-1]].parent:
            out.append(self.adapters[out[-1]].parent)
        return out

    # ------------------------------------------------------------ pods
    def add_pod(self, pod_id: str, capacity: int = 8) -> None:
        self.pods[pod_id] = PodSlots(pod_id, capacity)

    def remove_pod(self, pod_id: str) -> None:
        self.pods.pop(pod_id, None)

    # ------------------------------------------------------------ placement
    def plan_placement(self) -> Dict[str, Set[str]]:
        """Desired pod -> adapters.  Density-first: hot adapters get up
        to max_replicas spread across pods; cold (long-tail) adapters
        pack onto the fewest pods (that's where the cost win is)."""
        self.stats["placement_runs"] += 1
        plan: Dict[str, Set[str]] = {p: set() for p in self.pods}
        if not self.pods:
            return plan
        by_heat = sorted(self.adapters.values(),
                         key=lambda a: -a.requests_per_s)
        budget = {p: self.pods[p].capacity for p in self.pods}
        total_rps = sum(a.requests_per_s for a in self.adapters.values())
        for a in by_heat:
            share = (a.requests_per_s / total_rps) if total_rps else 0.0
            replicas = max(self.min_replicas,
                           min(self.max_replicas,
                               round(share * len(self.pods) * 2)))
            # prefer pods that already have it (stickiness), then most-free
            order = sorted(self.pods,
                           key=lambda p: (a.name not in self.pods[p].loaded,
                                          -budget[p]))
            placed = 0
            for p in order:
                if placed >= replicas:
                    break
                if budget[p] > 0:
                    plan[p].add(a.name)
                    budget[p] -= 1
                    placed += 1
        return plan

    def sync(self, engines: Dict[str, object]) -> Dict[str, List[str]]:
        """Apply the plan to live engines.  Returns per-pod load/unload
        actions (for observability/tests)."""
        plan = self.plan_placement()
        actions: Dict[str, List[str]] = {}
        for pod_id, want in plan.items():
            eng = engines.get(pod_id)
            pod = self.pods[pod_id]
            acts = []
            for name in sorted(pod.loaded - want):
                if eng is not None:
                    eng.unregister_adapter(name)
                pod.loaded.discard(name)
                acts.append(f"unload:{name}")
                self.stats["unloads"] += 1
            for name in sorted(want - pod.loaded):
                if eng is not None:
                    eng.register_adapter(name)
                pod.loaded.add(name)
                acts.append(f"load:{name}")
                self.stats["loads"] += 1
            actions[pod_id] = acts
        return actions

    # ------------------------------------------------------------ discovery
    def endpoints(self, adapter: str) -> List[str]:
        """Pods currently serving an adapter (EndpointSlice analogue)."""
        return sorted(p for p, s in self.pods.items() if adapter in s.loaded)
