from repro.core.lora.manager import AdapterSpec, LoRAController  # noqa: F401
