"""Accelerator diagnostics + failure mockup tools (paper §3.2.8, Fig 9).

* ``FailureInjector`` — the mock-up tool: deterministically injects
  hardware fault modes (ECC error, thermal throttle, link flap, silent
  degradation, device loss) into engine handles / telemetry streams so
  recovery paths can be exercised in tests (the paper supports NVIDIA
  GPUs and Ascend NPUs; our telemetry interface is vendor-neutral and
  would bind to libtpu health counters on the deployment target).

* ``DiagnosticMonitor`` — the detection tool: consumes standardized
  telemetry snapshots and flags anomalies with a rule set per fault
  mode; emits remediation actions the orchestrator applies (cordon,
  restart pod, drain).
"""
from __future__ import annotations

import collections
import statistics
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Deque, Dict, List, Optional


class FaultKind(Enum):
    ECC_ERROR = "ecc_error"
    THERMAL_THROTTLE = "thermal_throttle"
    LINK_FLAP = "link_flap"
    SILENT_DEGRADATION = "silent_degradation"
    DEVICE_LOST = "device_lost"


@dataclass
class Telemetry:
    """One telemetry sample per device (DCGM-field analogue)."""
    pod_id: str
    t: float
    temperature_c: float = 60.0
    ecc_sbe: int = 0                # single-bit errors (corrected)
    ecc_dbe: int = 0                # double-bit errors (fatal)
    sm_clock_mhz: float = 1500.0
    link_up: bool = True
    tokens_per_sec: float = 0.0
    heartbeat_ok: bool = True


@dataclass
class ActiveFault:
    kind: FaultKind
    pod_id: str
    started: float
    severity: float = 1.0


class FailureInjector:
    """Mock-up tool: wraps per-pod telemetry generation + engine effects."""

    def __init__(self):
        self.active: Dict[str, List[ActiveFault]] = {}

    def inject(self, pod_id: str, kind: FaultKind, now: float,
               severity: float = 1.0) -> ActiveFault:
        f = ActiveFault(kind, pod_id, now, severity)
        self.active.setdefault(pod_id, []).append(f)
        return f

    def clear(self, pod_id: str, kind: Optional[FaultKind] = None) -> None:
        if kind is None:
            self.active.pop(pod_id, None)
            return
        left = [f for f in self.active.get(pod_id, []) if f.kind != kind]
        if left:
            self.active[pod_id] = left
        else:
            # no empty-list tombstones: long chaos runs inject/clear
            # thousands of times and `active` must not grow unbounded
            self.active.pop(pod_id, None)

    # ---------------------------------------------------------- effects
    def perturb(self, sample: Telemetry) -> Telemetry:
        """Apply active faults to a clean telemetry sample."""
        for f in self.active.get(sample.pod_id, []):
            if f.kind == FaultKind.ECC_ERROR:
                sample.ecc_sbe += int(10 * f.severity)
                if f.severity >= 1.0:
                    sample.ecc_dbe += 1
            elif f.kind == FaultKind.THERMAL_THROTTLE:
                sample.temperature_c = 92.0 + 5 * f.severity
                sample.sm_clock_mhz *= (1 - 0.4 * f.severity)
                sample.tokens_per_sec *= (1 - 0.4 * f.severity)
            elif f.kind == FaultKind.LINK_FLAP:
                sample.link_up = (int(sample.t * 10) % 3) != 0
            elif f.kind == FaultKind.SILENT_DEGRADATION:
                sample.tokens_per_sec *= (1 - 0.5 * f.severity)
            elif f.kind == FaultKind.DEVICE_LOST:
                sample.heartbeat_ok = False
                sample.tokens_per_sec = 0.0
        return sample

    def slowdown_factor(self, pod_id: str) -> float:
        """Engine-visible speed multiplier (for the cluster simulator)."""
        s = 1.0
        for f in self.active.get(pod_id, []):
            if f.kind in (FaultKind.THERMAL_THROTTLE,
                          FaultKind.SILENT_DEGRADATION):
                s *= (1 - 0.4 * f.severity)
            if f.kind == FaultKind.DEVICE_LOST:
                s = 0.0
        return s


@dataclass
class Diagnosis:
    pod_id: str
    t: float
    fault: FaultKind
    evidence: str
    action: str         # cordon | restart | drain | observe | quarantine | readmit


class DiagnosticMonitor:
    """Rule-based detector over telemetry history (per pod), with
    hysteresis between detection and action.

    Hard faults (missed heartbeat, double-bit ECC) act on a single
    sample — there is no recovering from those in place.  Soft faults
    (thermal throttle, link flaps, silent degradation) must persist
    for ``confirm_n`` consecutive samples before the pod is
    *quarantined* (the orchestrator cordons it out of routing but
    keeps it alive).  A quarantined pod is re-admitted only after a
    probe passes: at least ``quarantine_s`` seconds cordoned AND
    ``readmit_n`` consecutive clean samples.  A pod still anomalous
    ``escalate_s`` seconds into quarantine escalates to ``restart``
    (replacement).  This keeps a flapping engine from oscillating
    between cordon and re-admit on every scrape.
    """

    def __init__(self, window: int = 30, tput_drop_ratio: float = 0.6,
                 confirm_n: int = 3, quarantine_s: float = 10.0,
                 readmit_n: int = 5, escalate_s: float = 60.0):
        self.window = window
        self.tput_drop = tput_drop_ratio
        self.confirm_n = confirm_n
        self.quarantine_s = quarantine_s
        self.readmit_n = readmit_n
        self.escalate_s = escalate_s
        self.history: Dict[str, Deque[Telemetry]] = {}
        self.baseline_tput: Dict[str, float] = {}
        self._streak: Dict[str, int] = {}       # consecutive anomalous samples
        self._clean: Dict[str, int] = {}        # consecutive clean samples
        self.quarantined: Dict[str, float] = {}  # pod -> quarantine start t
        self._qfault: Dict[str, FaultKind] = {}  # pod -> quarantining fault

    # ------------------------------------------------------------- rules
    def _rules(self, sample: Telemetry,
               h: "Deque[Telemetry]") -> List[Diagnosis]:
        """Raw per-sample findings (no hysteresis applied)."""
        out: List[Diagnosis] = []
        pid, t = sample.pod_id, sample.t
        if not sample.heartbeat_ok:
            out.append(Diagnosis(pid, t, FaultKind.DEVICE_LOST,
                                 "heartbeat missed", "restart"))
            return out
        if sample.ecc_dbe > 0:
            out.append(Diagnosis(pid, t, FaultKind.ECC_ERROR,
                                 f"{sample.ecc_dbe} double-bit ECC",
                                 "cordon"))
        elif sample.ecc_sbe > 50:
            out.append(Diagnosis(pid, t, FaultKind.ECC_ERROR,
                                 f"{sample.ecc_sbe} single-bit ECC (rate)",
                                 "observe"))
        if sample.temperature_c > 88 and sample.sm_clock_mhz < 1200:
            out.append(Diagnosis(pid, t, FaultKind.THERMAL_THROTTLE,
                                 f"{sample.temperature_c:.0f}C + clocks down",
                                 "drain"))
        flaps = sum(1 for s in h if not s.link_up)
        if flaps >= 3:
            out.append(Diagnosis(pid, t, FaultKind.LINK_FLAP,
                                 f"{flaps} link drops in window", "cordon"))
        # silent degradation: sustained throughput drop vs own baseline
        tputs = [s.tokens_per_sec for s in h if s.tokens_per_sec > 0]
        if len(tputs) >= 10:
            base = self.baseline_tput.setdefault(
                pid, statistics.median(tputs[:5]))
            recent = statistics.median(tputs[-5:])
            if base > 0 and recent < base * self.tput_drop:
                out.append(Diagnosis(
                    pid, t, FaultKind.SILENT_DEGRADATION,
                    f"tput {recent:.0f} < {self.tput_drop:.0%} of "
                    f"baseline {base:.0f}", "restart"))
        return out

    # ----------------------------------------------------- state machine
    def observe(self, sample: Telemetry) -> List[Diagnosis]:
        h = self.history.setdefault(
            sample.pod_id, collections.deque(maxlen=self.window))
        h.append(sample)
        pid, t = sample.pod_id, sample.t
        raw = self._rules(sample, h)

        hard = [d for d in raw if d.fault in (FaultKind.DEVICE_LOST,)
                or (d.fault == FaultKind.ECC_ERROR and d.action == "cordon")]
        soft = [d for d in raw if d not in hard]
        if hard:
            # terminal: the pod is being replaced, drop quarantine state
            self._forget(pid)
            return hard

        out: List[Diagnosis] = []
        since = self.quarantined.get(pid)
        if soft:
            self._clean[pid] = 0
            if since is None:
                streak = self._streak.get(pid, 0) + 1
                self._streak[pid] = streak
                if streak >= self.confirm_n:
                    self.quarantined[pid] = t
                    self._streak[pid] = 0
                    lead = soft[0]
                    self._qfault[pid] = lead.fault
                    out.append(Diagnosis(
                        pid, t, lead.fault,
                        f"{lead.evidence} ({streak} consecutive scrapes)",
                        "quarantine"))
            elif t - since >= self.escalate_s:
                # probe keeps failing well into quarantine: replace it
                self._forget(pid)
                out.append(Diagnosis(
                    pid, t, soft[0].fault,
                    f"still anomalous {t - since:.0f}s into quarantine",
                    "restart"))
        else:
            self._streak[pid] = 0
            if since is not None:
                clean = self._clean.get(pid, 0) + 1
                self._clean[pid] = clean
                if clean >= self.readmit_n and t - since >= self.quarantine_s:
                    fault = self._qfault.get(pid, FaultKind.SILENT_DEGRADATION)
                    self._forget(pid)
                    out.append(Diagnosis(
                        pid, t, fault,
                        f"probe passed: {clean} clean scrapes after "
                        f"{t - since:.0f}s quarantined", "readmit"))
        return out

    def _forget(self, pod_id: str) -> None:
        self.quarantined.pop(pod_id, None)
        self._qfault.pop(pod_id, None)
        self._streak.pop(pod_id, None)
        self._clean.pop(pod_id, None)
