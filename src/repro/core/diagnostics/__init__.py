from repro.core.diagnostics.tools import (DiagnosticMonitor, FailureInjector,  # noqa: F401
                                          FaultKind, Telemetry)
