"""Discrete-event simulation core: virtual clock + event heap."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Optional, Tuple


class SimClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now


class EventLoop:
    # process-wide fired-event counter across every EventLoop instance —
    # benchmarks/run.py prints each suite's sim events/wall-second from
    # the per-suite delta, the scalability headline of the event core
    total_events: int = 0

    def __init__(self, clock: Optional[SimClock] = None):
        self.clock = clock or SimClock()
        self._heap: List[Tuple[float, int, Callable]] = []
        self._seq = itertools.count()
        self.events_fired = 0

    def schedule(self, t: float, fn: Callable) -> None:
        heapq.heappush(self._heap, (max(t, self.clock.now),
                                    next(self._seq), fn))

    def after(self, dt: float, fn: Callable) -> None:
        self.schedule(self.clock.now + dt, fn)

    def every(self, period: float, fn: Callable,
              until: float = float("inf")) -> None:
        def tick():
            fn()
            if self.clock.now + period <= until:
                self.after(period, tick)
        self.after(period, tick)

    def run(self, until: float = float("inf"),
            stop_when: Callable[[], bool] = None) -> float:
        while self._heap:
            t, _, fn = heapq.heappop(self._heap)
            if t > until:
                heapq.heappush(self._heap, (t, next(self._seq), fn))
                break
            self.clock.now = t
            self.events_fired += 1
            EventLoop.total_events += 1
            fn()
            if stop_when is not None and stop_when():
                break
        return self.clock.now

    def run_until(self, pred: Callable[[], bool],
                  max_t: float = 1e9) -> None:
        self.run(until=max_t, stop_when=pred)

    @property
    def pending(self) -> int:
        return len(self._heap)
