"""Workload generators for the cluster benchmarks.

Statistically matched stand-ins for the paper's datasets:

  * ``sharegpt_like``  — chat traffic: lognormal prompt/output lengths,
    Poisson arrivals, light prefix sharing (conversation turns).
  * ``birdsql_like``   — the Table-1 workload: text-to-SQL over a set of
    database schemas.  Prompts are dominated by a large schema prefix
    shared across all questions on the same database; outputs are short
    SQL.  Token ratio tuned to the paper's Table 1 (~1.08M prompt vs
    ~12.7k decode tokens ⇒ ~85:1).
  * ``multiturn_chat`` — growing shared-prefix conversations (the
    KV-reuse-friendly case motivating the distributed pool).
  * ``burst``          — step/burst arrival pattern for autoscaler tests.
  * ``slo_mixed``      — interleaved interactive (short, latency-bound)
    and batch (long, throughput-bound) arrivals with priority classes
    set — the SLO-aware-scheduling testbed (bench_slo).
  * ``phase_shift``    — prefill-heavy half then decode-heavy half: the
    role-pool rebalancing testbed (bench_pd_pools) — any static P:D
    split is mis-sized for one of the two phases.
  * ``lora_zipf``      — high-density multi-LoRA traffic: every request
    tags one of N adapters with zipf-distributed popularity (a few hot
    adapters, a long cold tail) — the adapter-tiering + LoRA-aware
    routing testbed (bench_lora).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.engine.request import Request, SamplingParams

VOCAB = 32_000


@dataclass
class TimedRequest:
    arrival: float
    request: Request


def _toks(rng: np.random.Generator, n: int) -> List[int]:
    return rng.integers(0, VOCAB, size=max(n, 1)).tolist()


def _lognormal_len(rng, mean: float, sigma: float, lo: int, hi: int) -> int:
    mu = math.log(mean) - sigma ** 2 / 2
    return int(np.clip(rng.lognormal(mu, sigma), lo, hi))


def sharegpt_like(rate_rps: float, duration_s: float, seed: int = 0,
                  mean_prompt: float = 220.0, mean_output: float = 180.0
                  ) -> List[TimedRequest]:
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while t < duration_s:
        t += rng.exponential(1.0 / rate_rps)
        plen = _lognormal_len(rng, mean_prompt, 0.9, 8, 4096)
        olen = _lognormal_len(rng, mean_output, 0.8, 4, 1024)
        req = Request(prompt_tokens=_toks(rng, plen),
                      sampling=SamplingParams(max_new_tokens=olen),
                      arrival_time=t)
        out.append(TimedRequest(t, req))
    return out


def birdsql_like(n_requests: int, rate_rps: float, seed: int = 0,
                 n_databases: int = 12, schema_tokens: int = 1600,
                 question_tokens: int = 120, output_tokens: int = 20
                 ) -> List[TimedRequest]:
    """Shared-schema-prefix Text2SQL traffic (Table 1 workload)."""
    rng = np.random.default_rng(seed)
    schemas = [_toks(rng, schema_tokens) for _ in range(n_databases)]
    # zipf-ish database popularity (some DBs are hot)
    popularity = 1.0 / (np.arange(n_databases) + 1.0)
    popularity /= popularity.sum()
    out, t = [], 0.0
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate_rps)
        db = rng.choice(n_databases, p=popularity)
        q = _lognormal_len(rng, question_tokens, 0.6, 16, 512)
        o = _lognormal_len(rng, output_tokens, 0.5, 4, 96)
        prompt = schemas[db] + _toks(rng, q)
        req = Request(prompt_tokens=prompt,
                      sampling=SamplingParams(max_new_tokens=o),
                      arrival_time=t, user=f"db-{db}")
        out.append(TimedRequest(t, req))
    return out


def multiturn_chat(n_conversations: int, turns: int, rate_rps: float,
                   seed: int = 0, sys_prompt: int = 400,
                   turn_tokens: int = 80, output_tokens: int = 120
                   ) -> List[TimedRequest]:
    rng = np.random.default_rng(seed)
    sys_tok = _toks(rng, sys_prompt)
    out, t = [], 0.0
    convs = [list(sys_tok) for _ in range(n_conversations)]
    order = []
    for turn in range(turns):
        for c in range(n_conversations):
            order.append(c)
    for c in order:
        t += rng.exponential(1.0 / rate_rps)
        convs[c] = convs[c] + _toks(rng, turn_tokens)
        o = _lognormal_len(rng, output_tokens, 0.6, 8, 512)
        req = Request(prompt_tokens=list(convs[c]),
                      sampling=SamplingParams(max_new_tokens=o),
                      arrival_time=t, user=f"conv-{c}")
        convs[c] = convs[c] + _toks(rng, o)   # model reply joins context
        out.append(TimedRequest(t, req))
    return out


def burst(base_rps: float, burst_rps: float, duration_s: float,
          burst_at: float, burst_len: float, seed: int = 0,
          mean_prompt: float = 220.0, mean_output: float = 120.0
          ) -> List[TimedRequest]:
    """Step-burst arrivals: autoscaler reaction testbed."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while t < duration_s:
        rate = burst_rps if burst_at <= t < burst_at + burst_len \
            else base_rps
        t += rng.exponential(1.0 / rate)
        plen = _lognormal_len(rng, mean_prompt, 0.8, 8, 2048)
        olen = _lognormal_len(rng, mean_output, 0.7, 4, 512)
        req = Request(prompt_tokens=_toks(rng, plen),
                      sampling=SamplingParams(max_new_tokens=olen),
                      arrival_time=t)
        out.append(TimedRequest(t, req))
    return out


def slo_mixed(rate_rps: float, duration_s: float, seed: int = 0,
              interactive_frac: float = 0.5,
              interactive_prompt: float = 128.0,
              interactive_output: float = 48.0,
              batch_prompt: float = 1800.0,
              batch_output: float = 200.0) -> List[TimedRequest]:
    """Mixed-class arrivals for SLO-aware scheduling benchmarks:
    interactive chat turns (short prompt/output, tight TTFT target)
    Poisson-interleaved with batch jobs (long prompts, long outputs,
    loose TTFT).  Each request carries its ``priority_class`` so the
    scheduler/gateway/autoscaler SLO path sees real class labels."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while t < duration_s:
        t += rng.exponential(1.0 / rate_rps)
        if rng.random() < interactive_frac:
            cls, mp, mo = "interactive", interactive_prompt, \
                interactive_output
        else:
            cls, mp, mo = "batch", batch_prompt, batch_output
        plen = _lognormal_len(rng, mp, 0.5, 8, 4096)
        olen = _lognormal_len(rng, mo, 0.5, 4, 1024)
        req = Request(prompt_tokens=_toks(rng, plen),
                      sampling=SamplingParams(max_new_tokens=olen),
                      arrival_time=t, priority_class=cls)
        out.append(TimedRequest(t, req))
    return out


def phase_shift(duration_s: float, seed: int = 0,
                interactive_frac: float = 1.0,
                prefill_rate_rps: float = 15.0,
                prefill_prompt: float = 512.0,
                prefill_output: float = 16.0,
                decode_rate_rps: float = 2.5,
                decode_prompt: float = 256.0,
                decode_output: float = 400.0) -> List[TimedRequest]:
    """Phase-shifting P/D load: the first half is prefill-heavy (high
    arrival rate of long prompts with short outputs — the TTFT-bound
    phase), the second half decode-heavy (fewer, short prompts with
    long outputs — decode residency and ITL bound).  A static
    prefill:decode split tuned for either phase starves in the other;
    the attainment-driven RolePoolManager rebalance migrates members
    between pools when the phase flips
    (``benchmarks/bench_pd_pools.py``).  Requests default to the
    'interactive' priority class so per-class attainment is the metric
    under test."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while t < duration_s:
        if t < duration_s / 2:
            rate, mp, mo = prefill_rate_rps, prefill_prompt, \
                prefill_output
        else:
            rate, mp, mo = decode_rate_rps, decode_prompt, decode_output
        t += rng.exponential(1.0 / rate)
        cls = ("interactive" if rng.random() < interactive_frac
               else "standard")
        plen = _lognormal_len(rng, mp, 0.35, 8, 4096)
        olen = _lognormal_len(rng, mo, 0.35, 4, 1024)
        req = Request(prompt_tokens=_toks(rng, plen),
                      sampling=SamplingParams(max_new_tokens=olen),
                      arrival_time=t, priority_class=cls)
        out.append(TimedRequest(t, req))
    return out


def lora_zipf(n_adapters: int, rate_rps: float, duration_s: float,
              seed: int = 0, zipf_s: float = 1.1,
              mean_prompt: float = 160.0, mean_output: float = 48.0,
              prefix: str = "lora-") -> List[TimedRequest]:
    """Thousand-adapter zipf trace: Poisson arrivals where each request
    targets adapter ``{prefix}{i}`` drawn from a zipf(s) popularity
    curve — a handful of hot adapters take most traffic while the long
    tail stays cold, so adapter placement/tiering and affinity routing
    (not raw capacity) decide hit rates and cold-load stalls."""
    rng = np.random.default_rng(seed)
    heat = 1.0 / (np.arange(1, n_adapters + 1) ** zipf_s)
    heat /= heat.sum()
    out, t = [], 0.0
    while t < duration_s:
        t += rng.exponential(1.0 / rate_rps)
        a = int(rng.choice(n_adapters, p=heat))
        plen = _lognormal_len(rng, mean_prompt, 0.6, 8, 1024)
        olen = _lognormal_len(rng, mean_output, 0.5, 4, 256)
        req = Request(prompt_tokens=_toks(rng, plen),
                      sampling=SamplingParams(max_new_tokens=olen),
                      arrival_time=t, user=f"u-{a}",
                      lora_adapter=f"{prefix}{a}")
        out.append(TimedRequest(t, req))
    return out


# ------------------------------------------------------------------ summary
def percentile(vals: List[float], p: float) -> float:
    if not vals:
        return 0.0
    return float(np.percentile(np.asarray(vals), p))


def summarize(requests: List[Request], span_s: Optional[float] = None
              ) -> dict:
    done = [r for r in requests if r.finish_time > 0]
    if not done:
        return {"finished": 0}
    t0 = min(r.arrival_time for r in done)
    t1 = max(r.finish_time for r in done)
    span = span_s or max(t1 - t0, 1e-9)
    prompt_toks = sum(r.prompt_len for r in done)
    out_toks = sum(len(r.output_tokens) for r in done)
    ttfts = [r.ttft * 1000 for r in done]
    itls = [x * 1000 for r in done for x in r.itl]
    return {
        "finished": len(done),
        "prompt_tokens": prompt_toks,
        "decode_tokens": out_toks,
        "total_tput_tok_s": (prompt_toks + out_toks) / span,
        "decode_tput_tok_s": out_toks / span,
        "ttft_avg_ms": float(np.mean(ttfts)),
        "ttft_p99_ms": percentile(ttfts, 99),
        "itl_avg_ms": float(np.mean(itls)) if itls else 0.0,
        "itl_p99_ms": percentile(itls, 99),
        "latency_avg_s": float(np.mean([r.total_latency for r in done])),
        "latency_p99_s": percentile([r.total_latency for r in done], 99),
        "completion_time_s": span,
    }
