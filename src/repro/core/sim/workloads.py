"""Workload generators for the cluster benchmarks.

Statistically matched stand-ins for the paper's datasets:

  * ``sharegpt_like``  — chat traffic: lognormal prompt/output lengths,
    Poisson arrivals, light prefix sharing (conversation turns).
  * ``birdsql_like``   — the Table-1 workload: text-to-SQL over a set of
    database schemas.  Prompts are dominated by a large schema prefix
    shared across all questions on the same database; outputs are short
    SQL.  Token ratio tuned to the paper's Table 1 (~1.08M prompt vs
    ~12.7k decode tokens ⇒ ~85:1).
  * ``multiturn_chat`` — growing shared-prefix conversations (the
    KV-reuse-friendly case motivating the distributed pool).
  * ``burst``          — step/burst arrival pattern for autoscaler tests.
  * ``slo_mixed``      — interleaved interactive (short, latency-bound)
    and batch (long, throughput-bound) arrivals with priority classes
    set — the SLO-aware-scheduling testbed (bench_slo).
  * ``phase_shift``    — prefill-heavy half then decode-heavy half: the
    role-pool rebalancing testbed (bench_pd_pools) — any static P:D
    split is mis-sized for one of the two phases.
  * ``lora_zipf``      — high-density multi-LoRA traffic: every request
    tags one of N adapters with zipf-distributed popularity (a few hot
    adapters, a long cold tail) — the adapter-tiering + LoRA-aware
    routing testbed (bench_lora).
  * ``multi_round_qa`` — million-session multi-turn traffic: a LAZY
    generator (the other workloads materialize lists — at 1M sessions
    that alone would dominate memory) of zipf-deep sessions whose turns
    are separated by lognormal think-times.  Every request carries its
    ``session_id`` so the gateway's sticky session policy can pin the
    conversation to the engine holding its KV prefix.

``summarize`` reduces a finished-request list to the benchmark
headline dict; :class:`StreamingSummary` is its streaming twin for
runs too large to hold every Request — ``observe()`` each finish and
drop the object, exact percentiles below a size threshold and
log-histogram approximations (tolerance-pinned) above it.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.engine.request import Request, SamplingParams

VOCAB = 32_000


@dataclass
class TimedRequest:
    arrival: float
    request: Request


def _toks(rng: np.random.Generator, n: int) -> List[int]:
    return rng.integers(0, VOCAB, size=max(n, 1)).tolist()


def _lognormal_len(rng, mean: float, sigma: float, lo: int, hi: int) -> int:
    mu = math.log(mean) - sigma ** 2 / 2
    return int(np.clip(rng.lognormal(mu, sigma), lo, hi))


def sharegpt_like(rate_rps: float, duration_s: float, seed: int = 0,
                  mean_prompt: float = 220.0, mean_output: float = 180.0
                  ) -> List[TimedRequest]:
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while t < duration_s:
        t += rng.exponential(1.0 / rate_rps)
        plen = _lognormal_len(rng, mean_prompt, 0.9, 8, 4096)
        olen = _lognormal_len(rng, mean_output, 0.8, 4, 1024)
        req = Request(prompt_tokens=_toks(rng, plen),
                      sampling=SamplingParams(max_new_tokens=olen),
                      arrival_time=t)
        out.append(TimedRequest(t, req))
    return out


def birdsql_like(n_requests: int, rate_rps: float, seed: int = 0,
                 n_databases: int = 12, schema_tokens: int = 1600,
                 question_tokens: int = 120, output_tokens: int = 20
                 ) -> List[TimedRequest]:
    """Shared-schema-prefix Text2SQL traffic (Table 1 workload)."""
    rng = np.random.default_rng(seed)
    schemas = [_toks(rng, schema_tokens) for _ in range(n_databases)]
    # zipf-ish database popularity (some DBs are hot)
    popularity = 1.0 / (np.arange(n_databases) + 1.0)
    popularity /= popularity.sum()
    out, t = [], 0.0
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate_rps)
        db = rng.choice(n_databases, p=popularity)
        q = _lognormal_len(rng, question_tokens, 0.6, 16, 512)
        o = _lognormal_len(rng, output_tokens, 0.5, 4, 96)
        prompt = schemas[db] + _toks(rng, q)
        req = Request(prompt_tokens=prompt,
                      sampling=SamplingParams(max_new_tokens=o),
                      arrival_time=t, user=f"db-{db}")
        out.append(TimedRequest(t, req))
    return out


def multiturn_chat(n_conversations: int, turns: int, rate_rps: float,
                   seed: int = 0, sys_prompt: int = 400,
                   turn_tokens: int = 80, output_tokens: int = 120
                   ) -> List[TimedRequest]:
    rng = np.random.default_rng(seed)
    sys_tok = _toks(rng, sys_prompt)
    out, t = [], 0.0
    convs = [list(sys_tok) for _ in range(n_conversations)]
    order = []
    for turn in range(turns):
        for c in range(n_conversations):
            order.append(c)
    for c in order:
        t += rng.exponential(1.0 / rate_rps)
        convs[c] = convs[c] + _toks(rng, turn_tokens)
        o = _lognormal_len(rng, output_tokens, 0.6, 8, 512)
        req = Request(prompt_tokens=list(convs[c]),
                      sampling=SamplingParams(max_new_tokens=o),
                      arrival_time=t, user=f"conv-{c}")
        convs[c] = convs[c] + _toks(rng, o)   # model reply joins context
        out.append(TimedRequest(t, req))
    return out


def burst(base_rps: float, burst_rps: float, duration_s: float,
          burst_at: float, burst_len: float, seed: int = 0,
          mean_prompt: float = 220.0, mean_output: float = 120.0
          ) -> List[TimedRequest]:
    """Step-burst arrivals: autoscaler reaction testbed."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while t < duration_s:
        rate = burst_rps if burst_at <= t < burst_at + burst_len \
            else base_rps
        t += rng.exponential(1.0 / rate)
        plen = _lognormal_len(rng, mean_prompt, 0.8, 8, 2048)
        olen = _lognormal_len(rng, mean_output, 0.7, 4, 512)
        req = Request(prompt_tokens=_toks(rng, plen),
                      sampling=SamplingParams(max_new_tokens=olen),
                      arrival_time=t)
        out.append(TimedRequest(t, req))
    return out


def slo_mixed(rate_rps: float, duration_s: float, seed: int = 0,
              interactive_frac: float = 0.5,
              interactive_prompt: float = 128.0,
              interactive_output: float = 48.0,
              batch_prompt: float = 1800.0,
              batch_output: float = 200.0) -> List[TimedRequest]:
    """Mixed-class arrivals for SLO-aware scheduling benchmarks:
    interactive chat turns (short prompt/output, tight TTFT target)
    Poisson-interleaved with batch jobs (long prompts, long outputs,
    loose TTFT).  Each request carries its ``priority_class`` so the
    scheduler/gateway/autoscaler SLO path sees real class labels."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while t < duration_s:
        t += rng.exponential(1.0 / rate_rps)
        if rng.random() < interactive_frac:
            cls, mp, mo = "interactive", interactive_prompt, \
                interactive_output
        else:
            cls, mp, mo = "batch", batch_prompt, batch_output
        plen = _lognormal_len(rng, mp, 0.5, 8, 4096)
        olen = _lognormal_len(rng, mo, 0.5, 4, 1024)
        req = Request(prompt_tokens=_toks(rng, plen),
                      sampling=SamplingParams(max_new_tokens=olen),
                      arrival_time=t, priority_class=cls)
        out.append(TimedRequest(t, req))
    return out


def phase_shift(duration_s: float, seed: int = 0,
                interactive_frac: float = 1.0,
                prefill_rate_rps: float = 15.0,
                prefill_prompt: float = 512.0,
                prefill_output: float = 16.0,
                decode_rate_rps: float = 2.5,
                decode_prompt: float = 256.0,
                decode_output: float = 400.0) -> List[TimedRequest]:
    """Phase-shifting P/D load: the first half is prefill-heavy (high
    arrival rate of long prompts with short outputs — the TTFT-bound
    phase), the second half decode-heavy (fewer, short prompts with
    long outputs — decode residency and ITL bound).  A static
    prefill:decode split tuned for either phase starves in the other;
    the attainment-driven RolePoolManager rebalance migrates members
    between pools when the phase flips
    (``benchmarks/bench_pd_pools.py``).  Requests default to the
    'interactive' priority class so per-class attainment is the metric
    under test."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while t < duration_s:
        if t < duration_s / 2:
            rate, mp, mo = prefill_rate_rps, prefill_prompt, \
                prefill_output
        else:
            rate, mp, mo = decode_rate_rps, decode_prompt, decode_output
        t += rng.exponential(1.0 / rate)
        cls = ("interactive" if rng.random() < interactive_frac
               else "standard")
        plen = _lognormal_len(rng, mp, 0.35, 8, 4096)
        olen = _lognormal_len(rng, mo, 0.35, 4, 1024)
        req = Request(prompt_tokens=_toks(rng, plen),
                      sampling=SamplingParams(max_new_tokens=olen),
                      arrival_time=t, priority_class=cls)
        out.append(TimedRequest(t, req))
    return out


def lora_zipf(n_adapters: int, rate_rps: float, duration_s: float,
              seed: int = 0, zipf_s: float = 1.1,
              mean_prompt: float = 160.0, mean_output: float = 48.0,
              prefix: str = "lora-") -> List[TimedRequest]:
    """Thousand-adapter zipf trace: Poisson arrivals where each request
    targets adapter ``{prefix}{i}`` drawn from a zipf(s) popularity
    curve — a handful of hot adapters take most traffic while the long
    tail stays cold, so adapter placement/tiering and affinity routing
    (not raw capacity) decide hit rates and cold-load stalls."""
    rng = np.random.default_rng(seed)
    heat = 1.0 / (np.arange(1, n_adapters + 1) ** zipf_s)
    heat /= heat.sum()
    out, t = [], 0.0
    while t < duration_s:
        t += rng.exponential(1.0 / rate_rps)
        a = int(rng.choice(n_adapters, p=heat))
        plen = _lognormal_len(rng, mean_prompt, 0.6, 8, 1024)
        olen = _lognormal_len(rng, mean_output, 0.5, 4, 256)
        req = Request(prompt_tokens=_toks(rng, plen),
                      sampling=SamplingParams(max_new_tokens=olen),
                      arrival_time=t, user=f"u-{a}",
                      lora_adapter=f"{prefix}{a}")
        out.append(TimedRequest(t, req))
    return out


def multi_round_qa(n_sessions: int, session_rate_rps: float,
                   seed: int = 0, rounds_max: int = 8,
                   zipf_s: float = 1.3, think_time_s: float = 20.0,
                   sys_prompt: int = 64, turn_tokens: int = 48,
                   output_tokens: int = 32,
                   shared_sys: bool = False,
                   think_sigma: float = 0.8,
                   stats: Optional[dict] = None
                   ) -> Iterator[TimedRequest]:
    """Million-session multi-round QA: a lazy, time-ordered generator.

    New sessions open as a Poisson stream at ``session_rate_rps``; each
    runs ``1 + min(zipf(zipf_s), rounds_max - 1)`` rounds (a few deep
    power-user conversations, a long tail of one-shots) separated by
    lognormal think-times around ``think_time_s``.  Turn *r*'s prompt
    is the whole conversation so far — system prompt, every earlier
    turn and reply, plus the new turn — so consecutive rounds share a
    growing prefix and routing the session back to the same engine
    converts that prefix into cache hits.

    Memory discipline (this trace runs at ~1M sessions): per-session
    token history is NOT stored.  A session's token stream is
    regenerated deterministically from ``(seed, session index)`` at
    every emission — a counter-mix over the token index, NOT a
    Generator construction per emit, which would dominate the whole
    simulator's per-request cost — so the generator's live state is
    one heap entry per session with a pending round: O(concurrent
    sessions), not O(total tokens).  Every request carries
    ``session_id``/``user``.

    ``shared_sys=True`` makes the first ``sys_prompt`` tokens identical
    across ALL sessions (the common deployment shape: one system prompt,
    many users) while every later token keeps the per-session salt —
    with it, sessions landing on different engines write the SAME
    system-prompt pages, which is what the host-shared SSD pool
    deduplicates and serves as cross-engine hits.

    ``stats`` (optional dict) is updated in place with
    ``open_sessions`` (sessions currently between rounds — the live
    heap size) and ``peak_open_sessions``, so million-session benches
    can report concurrency without a second pass over the trace.
    """
    rng = np.random.default_rng(seed)
    # lognormal mean fix: E[lognormal(mu, s)] = e^(mu + s^2/2), so the
    # observed mean think-time stays ``think_time_s`` for any
    # ``think_sigma`` (0.8 = human chat; ~0.2-0.3 = the regular cadence
    # of agent/tool loops that predictive promotion targets)
    mu = math.log(max(think_time_s, 1e-3)) - think_sigma ** 2 / 2
    per_round = turn_tokens + output_tokens

    def _emit(sid: int, rnd: int, t: float) -> TimedRequest:
        n = sys_prompt + rnd * per_round + turn_tokens
        # deterministic per-(seed, session, index) token stream: the
        # tokens only need to be stable and session-unique (they are
        # cache keys, not text), so a 64-bit mix beats an rng here
        idx = np.arange(n, dtype=np.uint64)
        salt = ((seed * 0x5851F42D + sid) * 0x9E3779B97F4A7C15) \
            & (2**64 - 1)
        x = idx + np.uint64(salt)
        if shared_sys and sys_prompt > 0:
            # session-independent salt for the system-prompt span so
            # its pages content-address identically fleet-wide
            sys_salt = (seed * 0x9E3779B97F4A7C15) & (2**64 - 1)
            x[:sys_prompt] = idx[:sys_prompt] + np.uint64(sys_salt)
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        prompt = (x % np.uint64(VOCAB)).astype(np.int64).tolist()
        req = Request(prompt_tokens=prompt,
                      sampling=SamplingParams(
                          max_new_tokens=output_tokens),
                      arrival_time=t, session_id=f"s{sid}",
                      user=f"s{sid}")
        return TimedRequest(t, req)

    heap: list = []         # (next_arrival, sid, round, total_rounds)
    started = 0
    next_start = rng.exponential(1.0 / session_rate_rps)
    while started < n_sessions or heap:
        if started < n_sessions and (not heap
                                     or next_start <= heap[0][0]):
            sid, t, rnd = started, next_start, 0
            nrounds = 1 + min(int(rng.zipf(zipf_s)), rounds_max - 1) \
                if rounds_max > 1 else 1
            started += 1
            next_start += rng.exponential(1.0 / session_rate_rps)
        else:
            t, sid, rnd, nrounds = heapq.heappop(heap)
        yield _emit(sid, rnd, t)
        if rnd + 1 < nrounds:
            heapq.heappush(
                heap, (t + rng.lognormal(mu, think_sigma), sid, rnd + 1,
                       nrounds))
        if stats is not None:
            stats["open_sessions"] = len(heap)
            stats["peak_open_sessions"] = max(
                stats.get("peak_open_sessions", 0), len(heap))


# ------------------------------------------------------------------ summary
def percentile(vals: List[float], p: float) -> float:
    if not vals:
        return 0.0
    return float(np.percentile(np.asarray(vals), p))


def summarize(requests: List[Request], span_s: Optional[float] = None
              ) -> dict:
    done = [r for r in requests if r.finish_time > 0]
    if not done:
        return {"finished": 0}
    t0 = min(r.arrival_time for r in done)
    t1 = max(r.finish_time for r in done)
    span = span_s or max(t1 - t0, 1e-9)
    prompt_toks = sum(r.prompt_len for r in done)
    out_toks = sum(len(r.output_tokens) for r in done)
    ttfts = [r.ttft * 1000 for r in done]
    itls = [x * 1000 for r in done for x in r.itl]
    return {
        "finished": len(done),
        "prompt_tokens": prompt_toks,
        "decode_tokens": out_toks,
        "total_tput_tok_s": (prompt_toks + out_toks) / span,
        "decode_tput_tok_s": out_toks / span,
        "ttft_avg_ms": float(np.mean(ttfts)),
        "ttft_p99_ms": percentile(ttfts, 99),
        "itl_avg_ms": float(np.mean(itls)) if itls else 0.0,
        "itl_p99_ms": percentile(itls, 99),
        "latency_avg_s": float(np.mean([r.total_latency for r in done])),
        "latency_p99_s": percentile([r.total_latency for r in done], 99),
        "completion_time_s": span,
    }


class StreamingDist:
    """Bounded streaming distribution: exact values (np.percentile
    parity) up to ``exact_max`` samples, then a one-time conversion to
    a fixed log-spaced histogram over [lo, hi].  Histogram percentiles
    carry a relative error bounded by one bin's width —
    ``(hi/lo)**(1/bins) - 1`` (~1.3% at the defaults), pinned by
    tests/test_sessions.py — while memory stays O(bins) no matter how
    many samples stream in."""

    def __init__(self, exact_max: int = 100_000, bins: int = 2048,
                 lo: float = 1e-6, hi: float = 1e5):
        self.exact_max = exact_max
        self.bins = bins
        self._log_lo = math.log(lo)
        self._scale = bins / (math.log(hi) - self._log_lo)
        self._lo, self._hi = lo, hi
        self._vals: Optional[List[float]] = []
        self._hist: Optional[np.ndarray] = None
        self.count = 0
        self.total = 0.0

    @property
    def rel_tolerance(self) -> float:
        """Worst-case relative percentile error once histogrammed."""
        return (self._hi / self._lo) ** (1.0 / self.bins) - 1.0

    def _bin(self, v: float) -> int:
        v = min(max(v, self._lo), self._hi)
        return min(int((math.log(v) - self._log_lo) * self._scale),
                   self.bins - 1)

    def _to_hist(self) -> None:
        self._hist = np.zeros(self.bins, dtype=np.int64)
        for v in self._vals:
            self._hist[self._bin(v)] += 1
        self._vals = None

    def add(self, v: float) -> None:
        self.count += 1
        self.total += v
        if self._hist is None:
            self._vals.append(v)
            if len(self._vals) > self.exact_max:
                self._to_hist()
        else:
            self._hist[self._bin(v)] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        if not self.count:
            return 0.0
        if self._hist is None:
            return percentile(self._vals, p)
        target = (p / 100.0) * (self.count - 1)
        cum = np.cumsum(self._hist)
        i = int(np.searchsorted(cum, target + 1))
        i = min(i, self.bins - 1)
        # geometric bin midpoint (log-spaced edges)
        lo_e = math.exp(self._log_lo + i / self._scale)
        hi_e = math.exp(self._log_lo + (i + 1) / self._scale)
        return math.sqrt(lo_e * hi_e)


class StreamingSummary:
    """Streaming twin of :func:`summarize`: ``observe(req)`` extracts
    each finished request's metrics and lets the object go, so a 1M-
    request run keeps O(exact_max + bins) state instead of every
    Request.  Wire it as ``SchedulerCore.finish_sink`` (what
    ``ClusterConfig.retain_requests=False`` does) and read
    ``summary()`` — same keys as ``summarize`` plus attainment rows
    when ``ttft_slo_s`` targets are given."""

    def __init__(self, exact_max: int = 100_000,
                 ttft_slo_s: Optional[Dict[str, float]] = None):
        self.ttft_ms = StreamingDist(exact_max)
        self.itl_ms = StreamingDist(exact_max)
        self.latency_s = StreamingDist(exact_max)
        self.finished = 0
        self.prompt_tokens = 0
        self.decode_tokens = 0
        self.t0 = float("inf")
        self.t1 = 0.0
        self.ttft_slo_s = ttft_slo_s or {}
        self.slo_seen = 0
        self.slo_ok = 0

    def observe(self, req: Request) -> None:
        if req.finish_time <= 0:
            return
        self.finished += 1
        self.prompt_tokens += req.prompt_len
        self.decode_tokens += len(req.output_tokens)
        self.t0 = min(self.t0, req.arrival_time)
        self.t1 = max(self.t1, req.finish_time)
        ttft = req.ttft
        self.ttft_ms.add(ttft * 1000)
        for gap in req.itl:
            self.itl_ms.add(gap * 1000)
        self.latency_s.add(req.total_latency)
        target = self.ttft_slo_s.get(req.priority_class)
        if target is not None:
            self.slo_seen += 1
            self.slo_ok += int(ttft <= target)

    @property
    def ttft_attainment(self) -> float:
        return self.slo_ok / self.slo_seen if self.slo_seen else 1.0

    def summary(self, span_s: Optional[float] = None) -> dict:
        if not self.finished:
            return {"finished": 0}
        span = span_s or max(self.t1 - self.t0, 1e-9)
        out = {
            "finished": self.finished,
            "prompt_tokens": self.prompt_tokens,
            "decode_tokens": self.decode_tokens,
            "total_tput_tok_s": (self.prompt_tokens
                                 + self.decode_tokens) / span,
            "decode_tput_tok_s": self.decode_tokens / span,
            "ttft_avg_ms": self.ttft_ms.mean,
            "ttft_p99_ms": self.ttft_ms.percentile(99),
            "itl_avg_ms": self.itl_ms.mean,
            "itl_p99_ms": self.itl_ms.percentile(99),
            "latency_avg_s": self.latency_s.mean,
            "latency_p99_s": self.latency_s.percentile(99),
            "completion_time_s": span,
        }
        if self.slo_seen:
            out["ttft_attainment"] = self.ttft_attainment
        return out
