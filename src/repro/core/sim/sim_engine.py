"""Analytic engine backend for cluster-scale simulation.

``SimEngine`` implements the same handle contract as the real JAX
``InferenceEngine`` (submit / step-driven progress / metrics /
match_prefix_len / adapter hooks) but advances on the discrete-event
loop with a roofline cost model (repro.core.optimizer.profiles) instead
of executing matmuls.  Crucially it reuses the *real* page allocator and
content-hash prefix cache, and speaks to the *real* distributed KV pool
— so cache hit/miss/eviction behaviour in benchmarks is produced by the
actual pool code, only the FLOPs are analytic.

Iteration model (vLLM-style continuous batching):
  * each engine iteration is either a prefill chunk (compute-bound) or
    one decode step for the running batch (bandwidth-bound)
  * prefix-cache hits (local or distributed-pool) skip prefill compute
    for the covered tokens; pool fetches pay a transfer-time cost
  * faults (repro.core.diagnostics) scale iteration time via
    ``slowdown`` — a dead device stops making progress.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.kvcache.pool import DistributedKVPool
from repro.core.optimizer.profiles import DEVICES, PerfModel
from repro.core.sim.events import EventLoop
from repro.engine.engine import EngineMetrics, window_throughput
from repro.engine.page_table import PageAllocator, chunk_hashes
from repro.engine.request import Request, RequestState
from repro.models.config import ModelConfig


@dataclass
class SimEngineConfig:
    device_type: str = "a10"
    num_devices: int = 1             # TP degree (perf scales, memory adds)
    page_size: int = 64              # tokens per logical KV block
    max_batch: int = 32
    chunk_size: int = 512
    prefix_caching: bool = True
    chunked_prefill: bool = True
    scheduler_overhead_s: float = 0.002
    # P/D disaggregation (paper §3.2.5: the pool enables a DistServe-
    # style "prefill/decode disaggregation remote pool"):
    #   mixed   — normal colocated engine
    #   prefill — prefills, publishes KV to the pool, hands the request
    #             off (never decodes)
    #   decode  — pulls prefilled KV from the pool, decodes only
    role: str = "mixed"


class SimEngine:
    def __init__(self, cfg: ModelConfig, loop: EventLoop,
                 sim_cfg: SimEngineConfig = None,
                 kv_pool: Optional[DistributedKVPool] = None,
                 engine_id: str = "sim-0", node: str = "node-0"):
        self.cfg = cfg
        self.loop = loop
        self.sc = sim_cfg or SimEngineConfig()
        self.kv_pool = kv_pool
        self.engine_id = engine_id
        self.node = node
        if kv_pool is not None:
            kv_pool.attach_engine(engine_id, node)
        dev = DEVICES[self.sc.device_type]
        self.perf = PerfModel(cfg, dev)
        # TP over num_devices: memory adds, compute/bw scale (0.9 eff.)
        nd = self.sc.num_devices
        self._speed = nd * (0.9 if nd > 1 else 1.0)
        kv_budget = max(dev.hbm_bytes * 0.9 * nd
                        - self.perf.param_bytes, dev.hbm_bytes * 0.05)
        num_pages = int(kv_budget
                        / (self.perf.kv_bytes_per_token * self.sc.page_size))
        self.alloc = PageAllocator(max(num_pages, 16), self.sc.page_size)
        self.waiting: List[Request] = []
        self.prefilling: Optional[Request] = None
        self.running: List[Request] = []
        self.finished: List[Request] = []
        self.slowdown_fn: Callable[[], float] = lambda: 1.0
        self.handoff: Optional[Callable[[Request], None]] = None
        self._pending_handoff = 0
        self._busy = False
        self._adapters: set = set()
        self._m = dict(admitted=0, done=0, preempt=0, prefix_hit=0,
                       remote_hit=0)
        self._tok_events: List[tuple] = []
        self._lat_ewma = 0.0
        self._q_ewma = 0.0
        self.alive = True

    # ---------------------------------------------------------- contract
    def submit(self, req: Request) -> None:
        if req.arrival_time == 0.0:
            req.arrival_time = self.loop.clock.now
        self.waiting.append(req)
        self._kick()

    def register_adapter(self, name: str, weights=None) -> None:
        self._adapters.add(name)

    def unregister_adapter(self, name: str) -> None:
        self._adapters.discard(name)

    def match_prefix_len(self, tokens) -> int:
        return self.alloc.match_len(tokens)

    def healthy(self) -> bool:
        return self.alive and self.slowdown_fn() > 0.0

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.prefilling
                    or self._pending_handoff)

    # ---------------------------------------------------------- scheduling
    def _kick(self) -> None:
        if not self._busy and self.has_work:
            self._busy = True
            self.loop.after(0.0, self._iterate)

    def _pages_for(self, n: int) -> int:
        return -(-n // self.sc.page_size)

    def _try_admit(self) -> Optional[Request]:
        if not self.waiting or len(self.running) >= self.sc.max_batch:
            return None
        req = self.waiting[0]
        now = self.loop.clock.now
        total = req.prompt_len + req.sampling.max_new_tokens
        matched_pages, matched = [], 0
        if self.sc.prefix_caching:
            matched_pages, matched = self.alloc.match_prefix(
                req.prompt_tokens, now)
        remote_pages = 0
        # the distributed pool works even when engine-local prefix
        # caching is off (the paper's "KV cache + Default" rows):
        # cross-engine reuse is the pool's, not the engine's, feature
        if self.kv_pool is not None:
            hashes = chunk_hashes(req.prompt_tokens, self.sc.page_size)
            i = matched // self.sc.page_size
            while i < len(hashes) and \
                    (i + 1) * self.sc.page_size < req.prompt_len:
                if self.kv_pool.fetch(hashes[i], self.engine_id, now) is None:
                    break
                pids = self.alloc.allocate(1, now)
                if not pids:
                    break
                self.alloc.register_hash(pids[0], hashes[i])
                matched_pages += pids
                matched += self.sc.page_size
                remote_pages += 1
                i += 1
        need = self._pages_for(total) - len(matched_pages)
        fresh = self.alloc.allocate(need, now)
        if fresh is None:
            self.alloc.release(matched_pages, now)
            return None
        self.waiting.pop(0)
        req.page_ids = matched_pages + fresh
        req.cached_prefix_tokens = matched
        req.prefill_done_tokens = matched
        req.state = RequestState.PREFILLING
        req.schedule_time = now
        # remote fetch cost is paid once at admit (pipelined transfers)
        req._remote_fetch_s = remote_pages * (  # type: ignore[attr-defined]
            self.perf.kv_bytes_per_token * self.sc.page_size
            / self.kv_pool.network_bw) if remote_pages else 0.0
        self._m["admitted"] += 1
        self._m["prefix_hit"] += matched - remote_pages * self.sc.page_size
        self._m["remote_hit"] += remote_pages * self.sc.page_size
        self._q_ewma = 0.9 * self._q_ewma + 0.1 * req.queue_time
        return req

    def _iterate(self) -> None:
        now = self.loop.clock.now
        slow = self.slowdown_fn()
        if not self.alive or slow <= 0.0:
            self._busy = False        # dead engine: progress stops
            return
        if self.prefilling is None:
            self.prefilling = self._try_admit()
        dt = self.sc.scheduler_overhead_s
        if self.prefilling is not None:
            req = self.prefilling
            remaining = req.prompt_len - req.prefill_done_tokens
            chunk = min(self.sc.chunk_size if self.sc.chunked_prefill
                        else remaining, remaining)
            dt += self.perf.prefill_time(chunk) / (self._speed * slow)
            dt += getattr(req, "_remote_fetch_s", 0.0)
            req._remote_fetch_s = 0.0       # type: ignore[attr-defined]
            req.prefill_done_tokens += chunk
            if req.prefill_done_tokens >= req.prompt_len:
                self._finish_prefill(req, now + dt)
        elif self.running:
            batch = self.running[:self.sc.max_batch]
            ctx = sum(r.total_tokens for r in batch) / len(batch)
            dt += self.perf.decode_step_time(len(batch), ctx) \
                / (self._speed * slow)
            t_done = now + dt
            for r in list(batch):
                r.output_tokens.append(0)
                r.token_times.append(t_done)
                nxt = r.total_tokens
                if self._pages_for(nxt + 1) > len(r.page_ids):
                    pid = self.alloc.allocate(1, t_done)
                    if pid is None:
                        self._preempt(r)
                        continue
                    r.page_ids += pid
                self._maybe_finish(r, t_done)
            self._note_tokens(t_done, len(batch))
        else:
            self._busy = False
            return
        self.loop.after(dt, self._iterate)

    def _finish_prefill(self, req: Request, t: float) -> None:
        # register prompt pages for local reuse + publish to the pool
        if self.sc.prefix_caching or self.kv_pool is not None:
            hashes = chunk_hashes(req.prompt_tokens, self.sc.page_size)
            for i, h in enumerate(hashes):
                pid = req.page_ids[i]
                if self.alloc.pages[pid].block_hash is None:
                    if self.sc.prefix_caching:
                        self.alloc.register_hash(pid, h)
                    if self.kv_pool is not None:
                        size = (self.perf.kv_bytes_per_token
                                * self.sc.page_size)
                        self.kv_pool.publish(h, True, self.engine_id, t,
                                             size_bytes=size)
        if self.sc.role == "prefill" and self.handoff is not None:
            # disaggregated: KV is in the pool; hand the request to a
            # decode engine and free this engine for the next prefill
            self.alloc.release(req.page_ids, t)
            req.page_ids = []
            req.state = RequestState.QUEUED
            req.prefill_done_tokens = 0
            self.prefilling = None
            self._note_tokens(t, req.prompt_len // self.sc.chunk_size + 1)
            # hand off after the pool's metadata lag so the decode side
            # sees the published blocks; track the in-flight request so
            # drain predicates don't observe a momentarily idle pair
            self._pending_handoff += 1
            lag = self.kv_pool.metadata_lag if self.kv_pool else 0.0

            def deliver(req=req):
                self._pending_handoff -= 1
                self.handoff(req)

            # schedule from the (forward-dated) prefill completion time
            self.loop.schedule(t + lag * 1.01, deliver)
            return
        req.output_tokens.append(0)
        if req.first_token_time:
            req.token_times.append(t)        # migrated-in continuation
        else:
            req.first_token_time = t
        req.state = RequestState.RUNNING
        self.prefilling = None
        self.running.append(req)
        self._note_tokens(t, 1)
        self._maybe_finish(req, t)

    def _maybe_finish(self, req: Request, t: float) -> None:
        if len(req.output_tokens) < req.sampling.max_new_tokens:
            return
        req.finish_time = t
        req.state = RequestState.FINISHED
        if req in self.running:
            self.running.remove(req)
        self.alloc.release(req.page_ids, t)
        req.page_ids = []
        self.finished.append(req)
        self._m["done"] += 1
        self._lat_ewma = (0.9 * self._lat_ewma + 0.1 * req.total_latency
                          if self._lat_ewma else req.total_latency)

    def _preempt(self, req: Request) -> None:
        if req in self.running:
            self.running.remove(req)
        self.alloc.release(req.page_ids, self.loop.clock.now)
        req.page_ids = []
        req.output_tokens = []
        req.prefill_done_tokens = 0
        req.state = RequestState.QUEUED
        self.waiting.insert(0, req)
        self._m["preempt"] += 1

    # ------------------------------------------------------- migration
    def migrate_out(self, req: Request, target: "SimEngine") -> bool:
        """Live-migrate a RUNNING request to ``target`` via the pool
        (paper §3.1: the distributed KV cache runtime supports "request
        migration").  All of the sequence's KV blocks — prompt AND
        generated — are published; the target re-admits the request and
        pulls them by hash, so only the block tail is recomputed."""
        if req not in self.running or self.kv_pool is None:
            return False
        now = self.loop.clock.now
        # publish every full block of (prompt + generated) tokens
        seq = list(req.prompt_tokens) + [0] * len(req.output_tokens)
        hashes = chunk_hashes(seq, self.sc.page_size)
        size = self.perf.kv_bytes_per_token * self.sc.page_size
        for h in hashes:
            self.kv_pool.publish(h, True, self.engine_id, now,
                                 size_bytes=size)
        self.running.remove(req)
        self.alloc.release(req.page_ids, now)
        req.page_ids = []
        # target treats the full sequence-so-far as its "prompt": the
        # generated tokens keep their identity via req.output_tokens
        req._migrated_prompt = seq            # type: ignore[attr-defined]
        req.prompt_tokens = seq
        req.prefill_done_tokens = 0
        req.state = RequestState.QUEUED
        self._m["migrations"] = self._m.get("migrations", 0) + 1
        # deliver after metadata visibility so the KV actually transfers
        self.loop.schedule(now + self.kv_pool.metadata_lag * 1.01,
                           lambda: target.submit(req))
        return True

    # ---------------------------------------------------------- metrics
    def _note_tokens(self, t: float, n: int) -> None:
        self._tok_events.append((t, n))
        cutoff = t - 10.0
        while self._tok_events and self._tok_events[0][0] < cutoff:
            self._tok_events.pop(0)

    def metrics(self) -> EngineMetrics:
        tput = window_throughput(self._tok_events, self.loop.clock.now)
        return EngineMetrics(
            num_running=len(self.running) + (1 if self.prefilling else 0),
            num_waiting=len(self.waiting),
            kv_utilization=self.alloc.utilization,
            tokens_per_sec=tput,
            avg_latency=self._lat_ewma,
            avg_queue_time=self._q_ewma,
            admitted_requests=self._m["admitted"],
            finished_requests=self._m["done"],
            preemptions=self._m["preempt"],
            prefix_hit_tokens=self._m["prefix_hit"],
            remote_hit_tokens=self._m["remote_hit"],
            loaded_adapters=tuple(sorted(self._adapters)))
